//! Minimal, dependency-free shim of the `anyhow` API surface used by the
//! `lgc` crate: [`Error`], [`Result`], the [`Context`] extension trait, and
//! the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Vendored so the workspace builds with no network access. Semantics match
//! upstream for the subset implemented:
//!
//! - `Error` is a cheap boxed-free chain of messages: the root cause plus
//!   every `.context(...)` layer added on the way up.
//! - `{}` displays the outermost message; `{:#}` displays the whole chain
//!   joined by `": "` (same as upstream's alternate formatting).
//! - Any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   capturing its `source()` chain.

use std::fmt;

/// Error: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Push a new outermost context layer.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root (innermost) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors upstream's debug rendering closely enough for test output:
        // the outermost message, then the remaining chain as "Caused by".
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)` to results
/// and options.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chains_and_alt_display() {
        let r: Result<()> = Err(io_err().into());
        let e = r.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.root_cause(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("no value").unwrap_err();
        assert_eq!(format!("{e}"), "no value");
    }

    #[test]
    fn bare_ensure() {
        fn f() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(format!("{}", f().unwrap_err()).contains("1 + 1 == 3"));
    }
}
