//! Figure 6 reproduction: char-GRU on Shakespeare — same four panels as
//! Fig. 3, PJRT path (requires `make artifacts`).
//!
//! `cargo bench --bench bench_fig6_rnn_shakespeare` (LGC_ROUNDS=n to resize).

use std::path::Path;

use lgc::bench::{figures, JsonSink};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, PjrtTrainer};
use lgc::metrics::RunLog;
use lgc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let mut json = JsonSink::from_args("fig6_rnn_shakespeare");
    if !Path::new("artifacts/manifest.toml").exists() {
        println!("Figure 6 needs the RNN artifacts — run `make artifacts` first. Skipping.");
        // Still write the (empty) record file so the CI diff step's file
        // list never 404s on an artifact-less runner.
        json.finish();
        return Ok(());
    }
    let rounds = std::env::var("LGC_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    println!("== Figure 6: char-GRU on Shakespeare (PJRT, {rounds} rounds, M=3, N=3) ==");

    let mut logs: Vec<RunLog> = Vec::new();
    for mech in [Mechanism::FedAvg, Mechanism::LgcStatic, Mechanism::LgcDrl] {
        let cfg = ExperimentConfig {
            mechanism: mech,
            workload: Workload::RnnShakespeare,
            rounds,
            devices: 3,
            eval_samples: 256,
            eval_every: 5,
            lr: 0.5,
            h_fixed: 2,
            h_max: 4,
            ..ExperimentConfig::default()
        };
        let rt = Runtime::new(Path::new(&cfg.artifacts_dir))?;
        let mut trainer = PjrtTrainer::new(&rt, &cfg)?;
        let mut exp = Experiment::new(cfg, &trainer);
        let log = exp.run(&mut trainer)?;
        log.write_csv(Path::new(&format!("results/fig6_{}.csv", mech.name())))?;
        println!("  {} done: final next-char acc {:.4}", mech.name(), log.final_acc());
        let m = mech.name();
        json.push(&format!("{m}/final_acc"), log.final_acc(), "sim");
        if let Some(last) = log.last() {
            json.push(&format!("{m}/total_time"), last.total_time_s, "sim_s");
        }
        let bytes: u64 = log.records.iter().map(|r| r.bytes_up).sum();
        json.push(&format!("{m}/bytes_up"), bytes as f64, "bytes");
        logs.push(log);
    }
    json.finish();

    figures::print_convergence(&logs);
    figures::print_budget_panel(&logs, 0, &figures::budget_grid(&logs, 0, 8), "J");
    figures::print_budget_panel(&logs, 1, &figures::budget_grid(&logs, 1, 8), "$");
    figures::print_cost_to_target(&logs, 0.20);
    println!("\nCSV series in results/fig6_*.csv");
    Ok(())
}
