//! Figure 4 reproduction: CNN (~207k params) on MNIST-class data — same
//! four panels as Fig. 3, PJRT path (requires `make artifacts`).
//!
//! `cargo bench --bench bench_fig4_cnn_mnist` (LGC_ROUNDS=n to resize).

use std::path::Path;

use lgc::bench::{figures, JsonSink};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, PjrtTrainer};
use lgc::metrics::RunLog;
use lgc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let mut json = JsonSink::from_args("fig4_cnn_mnist");
    if !Path::new("artifacts/manifest.toml").exists() {
        println!("Figure 4 needs the CNN artifacts — run `make artifacts` first. Skipping.");
        // Still write the (empty) record file so the CI diff step's file
        // list never 404s on an artifact-less runner.
        json.finish();
        return Ok(());
    }
    let rounds = std::env::var("LGC_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);
    println!("== Figure 4: CNN on MNIST-class data (PJRT, {rounds} rounds, M=3, N=3) ==");

    let mut logs: Vec<RunLog> = Vec::new();
    for mech in [Mechanism::FedAvg, Mechanism::LgcStatic, Mechanism::LgcDrl] {
        let cfg = ExperimentConfig {
            mechanism: mech,
            workload: Workload::CnnMnist,
            rounds,
            devices: 3,
            samples_per_device: 1024,
            eval_samples: 256,
            eval_every: 5,
            lr: 0.05,
            h_fixed: 3,
            h_max: 6,
            ..ExperimentConfig::default()
        };
        let rt = Runtime::new(Path::new(&cfg.artifacts_dir))?;
        let mut trainer = PjrtTrainer::new(&rt, &cfg)?;
        let mut exp = Experiment::new(cfg, &trainer);
        let log = exp.run(&mut trainer)?;
        log.write_csv(Path::new(&format!("results/fig4_{}.csv", mech.name())))?;
        println!("  {} done: final acc {:.4}", mech.name(), log.final_acc());
        let m = mech.name();
        json.push(&format!("{m}/final_acc"), log.final_acc(), "sim");
        if let Some(last) = log.last() {
            json.push(&format!("{m}/total_time"), last.total_time_s, "sim_s");
        }
        let bytes: u64 = log.records.iter().map(|r| r.bytes_up).sum();
        json.push(&format!("{m}/bytes_up"), bytes as f64, "bytes");
        logs.push(log);
    }
    json.finish();

    figures::print_convergence(&logs);
    figures::print_budget_panel(&logs, 0, &figures::budget_grid(&logs, 0, 8), "J");
    figures::print_budget_panel(&logs, 1, &figures::budget_grid(&logs, 1, 8), "$");
    figures::print_cost_to_target(&logs, 0.60);
    println!("\nCSV series in results/fig4_*.csv");
    Ok(())
}
