//! Figure 3 reproduction: LR on MNIST-class data — four panels (eval loss
//! vs round, accuracy vs round, accuracy under energy budgets, accuracy
//! under money budgets) for FedAvg vs LGC-without-DRL vs LGC(+DDPG).
//!
//! Expected shape (paper Fig. 3): all three track similar accuracy per
//! round; under energy/money budgets both LGC variants dominate FedAvg, and
//! LGC+DRL dominates LGC-static.
//!
//! `cargo bench --bench bench_fig3_lr_mnist` — uses the PJRT artifacts when
//! present, otherwise the native LR path (set LGC_FAST=1 to force native).

use std::path::Path;

use lgc::bench::{figures, JsonSink};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, LocalTrainer, NativeLrTrainer, PjrtTrainer};
use lgc::metrics::RunLog;
use lgc::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts/manifest.toml").exists()
        && std::env::var("LGC_FAST").is_err();
    let rounds = std::env::var("LGC_ROUNDS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    println!(
        "== Figure 3: LR on MNIST-class data ({} path, {rounds} rounds, M=3, N=3) ==",
        if artifacts { "PJRT" } else { "native" }
    );

    let mut json = JsonSink::from_args("fig3_lr_mnist");
    let mut logs: Vec<RunLog> = Vec::new();
    for mech in [Mechanism::FedAvg, Mechanism::LgcStatic, Mechanism::LgcDrl] {
        let cfg = ExperimentConfig {
            mechanism: mech,
            workload: Workload::LrMnist,
            rounds,
            devices: 3,
            samples_per_device: 1024,
            eval_samples: 512,
            eval_every: 5,
            lr: 0.05,
            h_fixed: 3,
            h_max: 6,
            use_runtime: artifacts,
            ..ExperimentConfig::default()
        };
        let mut trainer: Box<dyn LocalTrainer> = if artifacts {
            let rt = Runtime::new(Path::new(&cfg.artifacts_dir))?;
            Box::new(PjrtTrainer::new(&rt, &cfg)?)
        } else {
            Box::new(NativeLrTrainer::new(&cfg))
        };
        let mut exp = Experiment::new(cfg, trainer.as_ref());
        let log = exp.run(trainer.as_mut())?;
        log.write_csv(Path::new(&format!("results/fig3_{}.csv", mech.name())))?;
        // All sim-deterministic quantities: the trajectory diff treats
        // `sim`/`sim_s`/`bytes` as (near-)exact, pinning the fig curves
        // the same way the golden traces pin step_round. PJRT and native
        // paths differ numerically, so only emit on the CI (native) path.
        if !artifacts {
            let m = mech.name();
            json.push(&format!("{m}/final_acc"), log.final_acc(), "sim");
            json.push(&format!("{m}/best_acc"), log.best_acc(), "sim");
            if let Some(last) = log.last() {
                json.push(&format!("{m}/total_time"), last.total_time_s, "sim_s");
                json.push(&format!("{m}/energy_j"), last.energy_j, "sim");
            }
            let bytes: u64 = log.records.iter().map(|r| r.bytes_up).sum();
            json.push(&format!("{m}/bytes_up"), bytes as f64, "bytes");
        }
        logs.push(log);
    }
    json.finish();

    figures::print_convergence(&logs);
    figures::print_budget_panel(&logs, 0, &figures::budget_grid(&logs, 0, 8), "J");
    figures::print_budget_panel(&logs, 1, &figures::budget_grid(&logs, 1, 8), "$");
    figures::print_cost_to_target(&logs, 0.60);
    println!("\nCSV series in results/fig3_*.csv");
    Ok(())
}
