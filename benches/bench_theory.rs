//! A3: Theorem-1 bound vs measured optimality gap on a strongly-convex
//! federated quadratic, sweeping H and gamma — checks the bound's shape
//! (monotone in H, anti-monotone in gamma, decaying in T) and that it
//! dominates the measurement.

use lgc::bench::{JsonSink, Table};
use lgc::compression::{lgc_compress, CompressScratch, ErrorFeedback};
use lgc::theory::BoundParams;
use lgc::util::Rng;

fn run_quadratic(dim: usize, m: usize, h: usize, k: usize, t_rounds: usize) -> (f64, f64) {
    let mut rng = Rng::new(5);
    let centers: Vec<Vec<f32>> = (0..m)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();
    let wstar: Vec<f32> = (0..dim)
        .map(|i| centers.iter().map(|c| c[i]).sum::<f32>() / m as f32)
        .collect();
    let f = |w: &[f32]| -> f64 {
        centers
            .iter()
            .map(|c| {
                0.5 * w
                    .iter()
                    .zip(c)
                    .map(|(a, b)| ((a - b) as f64).powi(2))
                    .sum::<f64>()
            })
            .sum::<f64>()
            / m as f64
    };
    let fstar = f(&wstar);
    let gamma = k as f64 / dim as f64;
    let a = 1.01 * (4.0 * h as f64 / gamma).max(32.0).max(h as f64);
    let mut global = vec![0f32; dim];
    let mut efs: Vec<ErrorFeedback> = (0..m).map(|_| ErrorFeedback::new(dim)).collect();
    let mut scratch = CompressScratch::default();
    for t in 0..t_rounds {
        let eta = (8.0 / (a + t as f64)) as f32;
        let mut agg = vec![0f32; dim];
        for dev in 0..m {
            let mut w = global.clone();
            for _ in 0..h {
                for i in 0..dim {
                    w[i] -= eta * (w[i] - centers[dev][i]);
                }
            }
            let progress: Vec<f32> = global.iter().zip(&w).map(|(&a, &b)| a - b).collect();
            let mut u = Vec::new();
            efs[dev].compensate(&progress, &mut u);
            let upd = lgc_compress(&u, &[k], &mut scratch);
            efs[dev].absorb(&u, &upd);
            upd.add_into(&mut agg, 1.0 / m as f32);
        }
        for i in 0..dim {
            global[i] -= agg[i];
        }
    }
    let gap = f(&global) - fstar;
    let params = BoundParams {
        l_smooth: 1.0,
        mu: 1.0,
        g: centers
            .iter()
            .map(|c| c.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt())
            .fold(0.0, f64::max)
            + 1.0,
        sigmas: vec![0.0; m],
        batch: 1,
        gammas: vec![gamma; m],
        h_gap: h,
        r0_sq: wstar.iter().map(|&x| (x as f64).powi(2)).sum(),
    };
    (gap, params.bound(t_rounds))
}

fn main() {
    println!("== A3: Theorem-1 bound vs measured gap (federated quadratic, M=3, D=64) ==\n");
    // `--json` pins the sweep: gap and bound are seeded, pure-arithmetic
    // outputs, so they diff under the exact `sim_s` policy.
    let mut json = JsonSink::from_args("theory");
    let mut table = Table::new(&["H", "gamma", "T", "measured gap", "Eq.6 bound", "bound/gap"]);
    for &(h, k) in &[(1usize, 16usize), (1, 32), (2, 8), (2, 32), (4, 16), (4, 32)] {
        for &t in &[500usize, 2000] {
            let (gap, bound) = run_quadratic(64, 3, h, k, t);
            json.push(&format!("h{h}/k{k}/t{t}/gap"), gap, "sim_s");
            json.push(&format!("h{h}/k{k}/t{t}/bound"), bound, "sim_s");
            table.row(&[
                h.to_string(),
                format!("{:.3}", k as f64 / 64.0),
                t.to_string(),
                format!("{gap:.3e}"),
                format!("{bound:.3e}"),
                format!("{:.1e}", bound / gap.max(1e-300)),
            ]);
            assert!(gap <= bound, "bound violated at H={h} k={k} T={t}");
        }
    }
    table.print();
    json.finish();
    println!("\nbound dominates every measurement; gap decays in T, grows in H,");
    println!("shrinks as gamma -> 1 (lighter compression) — the Corollary-1 shape.");
}
