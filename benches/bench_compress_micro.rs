//! Compression microbenches (ablation A2 + perf targets):
//! - Rust-native `lgc_compress` throughput across D (the L3 hot path);
//! - sort-based selection baseline (what `select_nth_unstable` replaces);
//! - wire encode/decode;
//! - the AOT `lgc_compress` PJRT artifact vs the native path (A2).

use std::path::Path;

use lgc::bench::{bench_auto, JsonSink, Table};
use lgc::compression::{
    lgc_compress, lgc_compress_radix, wire, CompressScratch, Compressor, LayerBudget, LgcTopAB,
};
use lgc::runtime::Runtime;
use lgc::util::Rng;

fn sort_based_topk(u: &[f32], k: usize) -> Vec<(u32, f32)> {
    // The naive O(D log D) baseline.
    let mut idx: Vec<u32> = (0..u.len() as u32).collect();
    idx.sort_by(|&a, &b| u[b as usize].abs().total_cmp(&u[a as usize].abs()));
    idx[..k].iter().map(|&i| (i, u[i as usize])).collect()
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let mut json = JsonSink::from_args("compress_micro");
    println!("== compression hot path: native lgc_compress (ks = 1/4/15% of D) ==\n");
    let mut table = Table::new(&[
        "D",
        "hot-path us",
        "GB/s",
        "radix-variant us",
        "sort-baseline us",
        "speedup",
    ]);
    for &d in &[16_384usize, 65_536, 262_144, 1_048_576] {
        let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let ks = [d / 100, d * 4 / 100, d * 15 / 100];
        let mut scratch = CompressScratch::default();
        let r = bench_auto(&format!("lgc_compress D={d}"), 120.0, || {
            std::hint::black_box(lgc_compress(&u, &ks, &mut scratch));
        });
        let rp = bench_auto(&format!("radix D={d}"), 120.0, || {
            std::hint::black_box(lgc_compress_radix(&u, &ks, &mut scratch));
        });
        let k_total = ks.iter().sum::<usize>();
        let rs = bench_auto(&format!("sort-topk D={d}"), 120.0, || {
            std::hint::black_box(sort_based_topk(&u, k_total));
        });
        json.push(&format!("topk/{d}/gib_per_s"), r.gib_per_s(4 * d), "gib/s");
        json.push(&format!("topk/{d}/radix_gib_per_s"), rp.gib_per_s(4 * d), "gib/s");
        table.row(&[
            d.to_string(),
            format!("{:.1}", r.mean_us()),
            format!("{:.2}", r.gib_per_s(4 * d)),
            format!("{:.1}", rp.mean_us()),
            format!("{:.1}", rs.mean_us()),
            format!("{:.2}x vs radix, {:.2}x vs sort", rp.mean_ns / r.mean_ns, rs.mean_ns / r.mean_ns),
        ]);
    }
    table.print();

    // Dyn-dispatch overhead of the Compressor seam: the round loop now calls
    // `Box<dyn Compressor>` instead of `lgc_compress` directly; one virtual
    // call per compress amortized over an O(D) pass must stay in the noise
    // (budget: <= 2%, recorded in EXPERIMENTS.md §Perf).
    println!("\n== dyn-dispatch: Box<dyn Compressor> vs direct call (1M-param CNN shape) ==");
    {
        let d = 1_048_576usize;
        let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let ks = [d / 100, d * 4 / 100, d * 15 / 100];
        let mut scratch = CompressScratch::default();
        let rd = bench_auto("direct lgc_compress D=1M", 300.0, || {
            std::hint::black_box(lgc_compress(&u, &ks, &mut scratch));
        });
        rd.report("");
        let budget = LayerBudget::new(ks.to_vec());
        let mut boxed: Box<dyn Compressor> = Box::new(LgcTopAB);
        let rb = bench_auto("Box<dyn Compressor> D=1M", 300.0, || {
            std::hint::black_box(boxed.compress(&u, &budget, &mut scratch));
        });
        let overhead = (rb.mean_ns / rd.mean_ns - 1.0) * 100.0;
        rb.report(&format!("dyn-dispatch overhead {overhead:+.2}% (budget <= 2%)"));
        json.push("dyn_dispatch/gib_per_s", rb.gib_per_s(4 * d), "gib/s");
    }

    println!("\n== wire encode/decode ==");
    let d = 262_144;
    let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
    let upd = lgc_compress(&u, &[d / 20], &mut CompressScratch::default());
    let r = bench_auto("wire encode (13k entries)", 80.0, || {
        std::hint::black_box(wire::encode(d, &upd.layers[0]));
    });
    r.report(&format!("{:.2} GB/s", r.gib_per_s(upd.layers[0].wire_bytes() as usize)));
    json.push("wire/encode_gib_per_s", r.gib_per_s(upd.layers[0].wire_bytes() as usize), "gib/s");
    let chunk = wire::encode(d, &upd.layers[0]);
    let r = bench_auto("wire decode (13k entries)", 80.0, || {
        std::hint::black_box(wire::decode(&chunk).unwrap());
    });
    r.report(&format!("{:.2} GB/s", r.gib_per_s(chunk.bytes.len())));
    json.push("wire/decode_gib_per_s", r.gib_per_s(chunk.bytes.len()), "gib/s");
    json.finish();

    // A2: artifact path vs native path at the artifact's D.
    if Path::new("artifacts/manifest.toml").exists() {
        println!("\n== A2 ablation: AOT lgc_compress artifact vs rust-native ==");
        let rt = Runtime::new(Path::new("artifacts"))?;
        let exe = rt.load_compress()?;
        let d = exe.d;
        let ks = rt.manifest.compress_ks.clone();
        let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let ra = bench_auto(&format!("PJRT artifact D={d}"), 300.0, || {
            std::hint::black_box(exe.compress(&u).unwrap());
        });
        ra.report("");
        let mut scratch = CompressScratch::default();
        let rn = bench_auto(&format!("rust native D={d}"), 300.0, || {
            std::hint::black_box(lgc_compress(&u, &ks, &mut scratch));
        });
        rn.report(&format!("native is {:.1}x faster", ra.mean_ns / rn.mean_ns));
        println!(
            "\n(the round loop uses the native path; the artifact proves the\n\
             L1 Pallas kernel semantics match bit-for-bit — see runtime_pjrt tests)"
        );
    } else {
        println!("\n(artifacts not built; skipping the A2 PJRT comparison)");
    }
    Ok(())
}
