//! Table 1 + channel-simulator microbench: prints the energy table, verifies
//! sampled means, and times the hot channel-simulation operations.
//! `--json` emits `BENCH_channels.json`: the sampled Table-1 means as
//! deterministic `sim_s` rows (seeded Rng → exact across hosts) and the
//! micro-bench timings as banded throughput rows.

use lgc::bench::{bench_auto, JsonSink, Table};
use lgc::channels::{ChannelType, DeviceChannels, Link};
use lgc::metrics::columns;
use lgc::util::Rng;

fn main() {
    let mut json = JsonSink::from_args("channels");
    println!("== Table 1: energy consumption per communication channel ==\n");
    let mut table = Table::new(&[
        "Channel Type",
        "Mean (J/MB)",
        "Std Dev",
        "sampled mean (J/MB, n=20k)",
        "$/MB",
        "MB/s (good)",
    ]);
    for ty in [ChannelType::G3, ChannelType::G4, ChannelType::G5] {
        let rng = Rng::new(42);
        let mut link = Link::new(ty, &rng, ty as u64);
        let n = 20_000;
        let mb = 1024 * 1024;
        let mean = (0..n).map(|_| link.transfer(mb).energy_j).sum::<f64>() / n as f64;
        json.push(&format!("table1/{}/sampled_j_per_mb", ty.name()), mean, "sim_s");
        table.row(&[
            ty.name().to_string(),
            format!("{:.1}", ty.energy_mean_j_per_mb()),
            format!("{}", lgc::channels::ENERGY_SIGMA),
            format!("{mean:.2}"),
            format!("{:.3}", ty.money_per_mb()),
            format!("{:.2}", ty.bandwidth_mb_s()),
        ]);
    }
    table.print();

    println!("\n== channel simulator microbenches ==");
    let rng = Rng::new(1);
    let mut ch = DeviceChannels::new(
        &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
        &rng,
        0,
    );
    let r = bench_auto("parallel_upload (3 channels, 1MB each)", 50.0, || {
        std::hint::black_box(ch.parallel_upload(&[1 << 20, 1 << 20, 1 << 20]));
    });
    r.report("");
    // iters/s (not us): the drops-only diff band then fails on slowdowns.
    json.push("micro/parallel_upload_iters_per_s", 1e9 / r.mean_ns.max(1.0), "iters/s");
    let mut ch2 = ch.clone();
    let r = bench_auto("fading step_round (3 links)", 50.0, || {
        ch2.step_round();
    });
    r.report("");
    json.push("micro/step_round_iters_per_s", 1e9 / r.mean_ns.max(1.0), "iters/s");
    let link = ch.links[0].clone();
    let r = bench_auto("expected_cost", 50.0, || {
        std::hint::black_box(link.expected_cost(1 << 20));
    });
    r.report("");
    json.push("micro/expected_cost_iters_per_s", 1e9 / r.mean_ns.max(1.0), "iters/s");

    // The canonical per-round CSV schema, from the single source of truth
    // (`metrics::columns`) the writer and tests share — printed here so a
    // bench consumer never hand-rolls (and drifts from) the column names.
    println!("\n== round CSV schema ==\n{}", columns::header());
    assert!(
        columns::ROUND.contains(&"finish_p50_s") && columns::ROUND.contains(&"down_bytes"),
        "columns list lost a known field"
    );
    json.finish();
}
