//! Population-scale bench: rounds/s and peak resident memory vs population
//! size {1k, 10k, 100k} at a fixed cohort of 64, barrier vs semi-async.
//!
//! ```bash
//! cargo bench --bench bench_population_scale
//! ```
//!
//! The claim under test: resident state is O(model + cohort), not
//! O(population × model) — only `DeviceSpec` records (plus compact
//! error-feedback residuals of previously sampled clients) scale with the
//! population, so "peak RSS" should grow far slower than 2 dense model
//! replicas per client would (7850-param LR: ~63 KB/client materialized vs
//! a few hundred bytes as a spec). Cases run smallest population first, so
//! the VmHWM column (a process-lifetime high-water mark) is attributable to
//! the first case that pushes it up.

use std::time::Instant;

use lgc::bench::{JsonSink, Table};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
use lgc::population::SamplerKind;
use lgc::sim::SyncMode;

/// Process peak resident set (VmHWM) in MB, Linux only.
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

fn cfg(population: usize, mode: SyncMode) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds: 3,
        devices: 8,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 1_000_000, // evals would dominate; round 0 + final only
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        population: Some(population),
        cohort: Some(64.min(population)),
        sampler: Some(SamplerKind::UniformK),
        sync_mode: Some(mode),
        streaming: true,
        ..ExperimentConfig::default()
    }
}

struct Case {
    wall_s: f64,
    records: usize,
    peak_materialized: usize,
    residual_kb: f64,
}

fn run_case(population: usize, mode: SyncMode) -> Case {
    let c = cfg(population, mode);
    let mut trainer = NativeLrTrainer::new(&c);
    let mut exp = ExperimentBuilder::new(c)
        .trainer(&trainer)
        .build()
        .expect("build");
    let t0 = Instant::now();
    let log = exp.run(&mut trainer).expect("run");
    let pop = exp.population.as_ref().expect("population mode");
    Case {
        wall_s: t0.elapsed().as_secs_f64(),
        records: log.records.len(),
        peak_materialized: pop.peak_materialized(),
        residual_kb: pop.residual_bytes() as f64 / 1024.0,
    }
}

fn main() {
    let mut json = JsonSink::from_args("population_scale");
    println!("== population scale (LgcStatic / LR, cohort 64, 3 rounds) ==\n");
    let mut table = Table::new(&[
        "mode",
        "population",
        "wall ms",
        "rounds/s",
        "peak materialized",
        "residuals KB",
        "peak RSS MB",
    ]);
    for &population in &[1_000usize, 10_000, 100_000] {
        for (name, mode) in [
            ("barrier", SyncMode::Barrier),
            ("semi-async k=16", SyncMode::SemiAsync { buffer_k: 16 }),
        ] {
            let r = run_case(population, mode);
            assert_eq!(r.records, 3);
            let slug = if matches!(mode, SyncMode::Barrier) { "barrier" } else { "semi-async" };
            json.push(&format!("pop/{population}/{slug}/rounds_per_s"),
                r.records as f64 / r.wall_s.max(1e-9), "rounds/s");
            json.push(&format!("pop/{population}/{slug}/peak_materialized"),
                r.peak_materialized as f64, "count");
            table.row(&[
                name.to_string(),
                population.to_string(),
                format!("{:.1}", r.wall_s * 1e3),
                format!("{:.2}", r.records as f64 / r.wall_s.max(1e-9)),
                r.peak_materialized.to_string(),
                format!("{:.1}", r.residual_kb),
                peak_rss_mb().map_or("n/a".to_string(), |m| format!("{m:.0}")),
            ]);
        }
    }
    table.print();
    json.finish();
    println!(
        "\npeak materialized stays at the cohort size regardless of population; the\n\
         population cost is the spec store (+ residuals of sampled clients), visible\n\
         as the slow RSS growth from 1k to 100k clients."
    );
}
