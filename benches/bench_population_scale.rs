//! Population-scale bench: rounds/s, events/s and peak resident memory vs
//! population size {1k, 10k, 100k, 1M} at a fixed cohort of 64, barrier vs
//! semi-async.
//!
//! ```bash
//! cargo bench --bench bench_population_scale
//! ```
//!
//! The claim under test: resident state is O(model + cohort), not
//! O(population × model) — only the struct-of-arrays population columns
//! (plus compact error-feedback residuals of previously sampled clients)
//! scale with the population, so "peak RSS" should grow far slower than 2
//! dense model replicas per client would (7850-param LR: ~63 KB/client
//! materialized vs ~600 B as SoA columns + channel state). Cases run
//! smallest population first, so the VmHWM column (a process-lifetime
//! high-water mark) is attributable to the first case that pushes it up.

use std::time::Instant;

use lgc::bench::{JsonSink, Table};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
use lgc::population::SamplerKind;
use lgc::sim::SyncMode;

/// Process peak resident set (VmHWM) in MB, Linux only.
fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: f64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb / 1024.0)
}

fn cfg(population: usize, mode: SyncMode) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds: 3,
        devices: 8,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 1_000_000, // evals would dominate; round 0 + final only
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        population: Some(population),
        cohort: Some(64.min(population)),
        sampler: Some(SamplerKind::UniformK),
        sync_mode: Some(mode),
        streaming: true,
        ..ExperimentConfig::default()
    }
}

struct Case {
    wall_s: f64,
    records: usize,
    events: u64,
    peak_materialized: usize,
    residual_kb: f64,
}

fn run_case(population: usize, mode: SyncMode) -> Case {
    let c = cfg(population, mode);
    let mut trainer = NativeLrTrainer::new(&c);
    let mut exp = ExperimentBuilder::new(c)
        .trainer(&trainer)
        .build()
        .expect("build");
    let t0 = Instant::now();
    let log = exp.run(&mut trainer).expect("run");
    let pop = exp.population.as_ref().expect("population mode");
    Case {
        wall_s: t0.elapsed().as_secs_f64(),
        records: log.records.len(),
        events: exp.sim_stats.events,
        peak_materialized: pop.peak_materialized(),
        residual_kb: pop.residual_bytes() as f64 / 1024.0,
    }
}

fn main() {
    // `--quick` (CI smoke) stops at 100k; the full sweep ends on the
    // million-client stadium-scale case.
    let quick = std::env::args().any(|a| a == "--quick");
    let populations: &[usize] = if quick {
        &[1_000, 10_000, 100_000]
    } else {
        &[1_000, 10_000, 100_000, 1_000_000]
    };
    let mut json = JsonSink::from_args("population_scale");
    println!("== population scale (LgcStatic / LR, cohort 64, 3 rounds) ==\n");
    let mut table = Table::new(&[
        "mode",
        "population",
        "wall ms",
        "rounds/s",
        "events/s",
        "peak materialized",
        "residuals KB",
        "peak RSS MB",
    ]);
    for &population in populations {
        for (name, mode) in [
            ("barrier", SyncMode::Barrier),
            ("semi-async k=16", SyncMode::SemiAsync { buffer_k: 16 }),
        ] {
            let r = run_case(population, mode);
            assert_eq!(r.records, 3);
            let slug = if matches!(mode, SyncMode::Barrier) { "barrier" } else { "semi-async" };
            let rounds_per_s = r.records as f64 / r.wall_s.max(1e-9);
            let events_per_s = r.events as f64 / r.wall_s.max(1e-9);
            json.push(&format!("pop/{population}/{slug}/rounds_per_s"), rounds_per_s, "rounds/s");
            json.push(&format!("pop/{population}/{slug}/events_per_s"), events_per_s, "events/s");
            json.push(&format!("pop/{population}/{slug}/peak_materialized"),
                r.peak_materialized as f64, "count");
            if let Some(mb) = peak_rss_mb() {
                json.push(&format!("pop/{population}/{slug}/peak_rss_mb"), mb, "mb");
            }
            table.row(&[
                name.to_string(),
                population.to_string(),
                format!("{:.1}", r.wall_s * 1e3),
                format!("{rounds_per_s:.2}"),
                format!("{events_per_s:.0}"),
                r.peak_materialized.to_string(),
                format!("{:.1}", r.residual_kb),
                peak_rss_mb().map_or("n/a".to_string(), |m| format!("{m:.0}")),
            ]);
        }
    }
    table.print();
    json.finish();
    println!(
        "\npeak materialized stays at the cohort size regardless of population; the\n\
         population cost is the SoA column store (+ residuals of sampled clients),\n\
         visible as the slow RSS growth from 1k clients up to the 1M case."
    );
}
