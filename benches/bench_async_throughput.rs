//! Event-engine throughput micro-bench: events/sec and rounds/sec of the
//! simulation core, barrier vs. semi-async vs. fully-async, and the
//! `std::thread::scope` parallel device-compute path (1 vs. N workers).
//!
//! ```bash
//! cargo bench --bench bench_async_throughput
//! ```
//!
//! Notes: the async modes pace devices by arrival, so their compute runs
//! inline with event handling (threads column shows 1); the parallel path
//! applies to barrier rounds, where all active devices train concurrently.

use std::time::Instant;

use lgc::bench::{JsonSink, Table};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
use lgc::sim::SyncMode;

fn cfg(threads: usize, devices: usize, rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds,
        devices,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 1_000_000, // evals would dominate; round 0 + final only
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        compute_threads: threads,
        ..ExperimentConfig::default()
    }
}

struct RunStats {
    wall_s: f64,
    events: u64,
    records: usize,
    sim_s: f64,
    acc: f64,
}

fn run_one(mode: SyncMode, threads: usize, devices: usize, rounds: usize) -> RunStats {
    let c = cfg(threads, devices, rounds);
    let mut trainer = NativeLrTrainer::new(&c);
    let mut exp = ExperimentBuilder::new(c)
        .trainer(&trainer)
        .sync_mode(mode)
        .build()
        .expect("build");
    let t0 = Instant::now();
    let log = exp.run(&mut trainer).expect("run");
    RunStats {
        wall_s: t0.elapsed().as_secs_f64(),
        events: exp.sim_stats.events,
        records: log.records.len(),
        sim_s: log.last().map_or(0.0, |r| r.total_time_s),
        acc: log.final_acc(),
    }
}

fn main() {
    let devices = 8;
    let rounds = 60;
    let auto = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "== event-engine throughput (LgcStatic / LR, {devices} devices, {rounds} records) ==\n"
    );
    let mut table = Table::new(&[
        "mode",
        "threads",
        "wall ms",
        "events",
        "events/s",
        "rounds/s",
        "sim s",
        "final acc",
    ]);
    let mut json = JsonSink::from_args("async_throughput");
    // Slugs keep the auto case machine-independent ("autothreads", not the
    // resolved core count) so baselines diff across hosts.
    let cases: Vec<(&str, &str, SyncMode, usize)> = vec![
        ("barrier", "barrier/t1", SyncMode::Barrier, 1),
        ("barrier", "barrier/autothreads", SyncMode::Barrier, auto),
        ("semi-async k=4", "semi-async-k4", SyncMode::SemiAsync { buffer_k: 4 }, 1),
        (
            "fully-async d=.7",
            "fully-async-d07",
            SyncMode::FullyAsync { staleness_decay: 0.7 },
            1,
        ),
    ];
    for (name, slug, mode, threads) in cases {
        let r = run_one(mode, threads, devices, rounds);
        json.push(&format!("{slug}/events"), r.events as f64, "count");
        json.push(&format!("{slug}/sim_s"), r.sim_s, "sim_s");
        json.push(&format!("{slug}/events_per_s"), r.events as f64 / r.wall_s.max(1e-9), "events/s");
        json.push(&format!("{slug}/rounds_per_s"), r.records as f64 / r.wall_s.max(1e-9), "rounds/s");
        table.row(&[
            name.to_string(),
            threads.to_string(),
            format!("{:.1}", r.wall_s * 1e3),
            r.events.to_string(),
            format!("{:.0}", r.events as f64 / r.wall_s.max(1e-9)),
            format!("{:.1}", r.records as f64 / r.wall_s.max(1e-9)),
            format!("{:.2}", r.sim_s),
            format!("{:.3}", r.acc),
        ]);
    }
    // Recorder overhead: the same semi-async case with the JSONL trace
    // buffering in memory. The trace-off rows above are the band guard
    // (trace defaults off); this row quantifies what turning it on costs,
    // and the record count is a deterministic counter pinned exactly.
    {
        let c = cfg(1, devices, rounds);
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = ExperimentBuilder::new(c)
            .trainer(&trainer)
            .sync_mode(SyncMode::SemiAsync { buffer_k: 4 })
            .build()
            .expect("build");
        exp.recorder = lgc::obs::Recorder::to_buffer();
        let t0 = Instant::now();
        let log = exp.run(&mut trainer).expect("run");
        let wall_s = t0.elapsed().as_secs_f64();
        let slug = "semi-async-k4-traced";
        json.push(&format!("{slug}/trace_records"), exp.recorder.events() as f64, "count");
        json.push(
            &format!("{slug}/events_per_s"),
            exp.sim_stats.events as f64 / wall_s.max(1e-9),
            "events/s",
        );
        table.row(&[
            "semi-async k=4 +trace".to_string(),
            "1".to_string(),
            format!("{:.1}", wall_s * 1e3),
            exp.sim_stats.events.to_string(),
            format!("{:.0}", exp.sim_stats.events as f64 / wall_s.max(1e-9)),
            format!("{:.1}", log.records.len() as f64 / wall_s.max(1e-9)),
            format!("{:.2}", log.last().map_or(0.0, |r| r.total_time_s)),
            format!("{:.3}", log.final_acc()),
        ]);
    }
    table.print();
    json.finish();
    println!(
        "\nbarrier x{auto} threads parallelizes device local compute (bit-identical \
         results); async modes trade per-event work for straggler immunity — compare \
         the `sim s` column for simulated wall-clock."
    );
}
