//! Ablation A1: layered multi-channel LGC vs single-channel Top-k at equal
//! coordinate budget, sweeping the budget — the design choice at the heart
//! of the paper (one layer per channel, Eq. 2).

use lgc::bench::{JsonSink, Table};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, NativeLrTrainer};

fn run(mech: Mechanism, fracs: Vec<f64>) -> anyhow::Result<(f64, f64, f64, f64)> {
    let cfg = ExperimentConfig {
        mechanism: mech,
        workload: Workload::LrMnist,
        rounds: 30,
        devices: 3,
        samples_per_device: 1024,
        eval_samples: 256,
        eval_every: 5,
        lr: 0.05,
        h_fixed: 3,
        h_max: 6,
        layer_fracs: fracs,
        use_runtime: false,
        ..ExperimentConfig::default()
    };
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);
    let log = exp.run(&mut trainer)?;
    let last = log.last().unwrap();
    Ok((log.final_acc(), last.energy_j, last.money, last.total_time_s))
}

fn main() -> anyhow::Result<()> {
    println!("== A1: layered (3-channel) vs single-channel top-k, equal budget ==\n");
    // `--json` pins the whole ablation grid: every cell is a seeded
    // simulation output, so the rows diff under the exact `sim_s` policy.
    let mut json = JsonSink::from_args("ablation_layers");
    let mut table = Table::new(&[
        "total budget",
        "variant",
        "final acc",
        "energy (J)",
        "money",
        "sim time (s)",
    ]);
    for &budget in &[0.02f64, 0.05, 0.10, 0.20, 0.40] {
        let pct = (budget * 100.0).round() as u32;
        let layered = vec![budget * 0.05, budget * 0.20, budget * 0.75];
        let (acc, e, m, t) = run(Mechanism::LgcStatic, layered)?;
        for (metric, v) in [("acc", acc), ("energy_j", e), ("money", m), ("sim_time_s", t)] {
            json.push(&format!("b{pct}pct/lgc_layered/{metric}"), v, "sim_s");
        }
        table.row(&[
            format!("{:.0}%", budget * 100.0),
            "LGC layered".into(),
            format!("{acc:.4}"),
            format!("{e:.1}"),
            format!("{m:.4}"),
            format!("{t:.1}"),
        ]);
        let (acc, e, m, t) = run(Mechanism::TopK, vec![budget])?;
        for (metric, v) in [("acc", acc), ("energy_j", e), ("money", m), ("sim_time_s", t)] {
            json.push(&format!("b{pct}pct/topk/{metric}"), v, "sim_s");
        }
        table.row(&[
            format!("{:.0}%", budget * 100.0),
            "single-ch topk".into(),
            format!("{acc:.4}"),
            format!("{e:.1}"),
            format!("{m:.4}"),
            format!("{t:.1}"),
        ]);
    }
    table.print();
    json.finish();
    println!(
        "\nexpected shape: equal accuracy at equal budget; layered LGC pays\n\
         less energy/money (bulk rides the cheap channel), single-channel\n\
         top-k pays 5G prices for every byte."
    );
    Ok(())
}
