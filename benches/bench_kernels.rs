//! Kernel micro-bench: the seed's scalar loops vs. the blocked kernels on
//! the train / compress / aggregate hot path.
//!
//! ```bash
//! cargo bench --bench bench_kernels -- --json [--quick]
//! ```
//!
//! Every case times the scalar reference (`kernels::reference`, the exact
//! loops the kernels replaced) and the blocked kernel on identical inputs,
//! then reports the speedup. `--quick` shrinks the timing targets for CI.
//! JSON rows land in `BENCH_kernels.json` and diff against
//! `BENCH_BASELINE.json`: timing and ratio rows get the drops-only band,
//! so a kernel performance regression fails the gate while host jitter
//! does not. The scatter and streaming-accumulate rows are parity checks —
//! those kernels centralize the loop for determinism, not speed — while
//! the fused LR forward/backward row is the headline (target: ≥2× over
//! the seed's skip-branch loop on ~50%-dense generator images).

use std::hint::black_box;

use lgc::bench::{bench_auto, BenchResult, JsonSink, Table};
use lgc::compression::{Layer, LgcUpdate};
use lgc::coordinator::{Aggregator, MeanAggregator};
use lgc::data::MnistGen;
use lgc::kernels;
use lgc::models::{NativeLr, LR_PARAMS};
use lgc::util::Rng;

/// Aggregator / population scale: ~1M coordinates.
const BIG: usize = 1 << 20;

fn duel(
    json: &mut JsonSink,
    table: &mut Table,
    slug: &str,
    scalar: &BenchResult,
    kernel: &BenchResult,
) {
    let speedup = scalar.mean_ns / kernel.mean_ns.max(1.0);
    // Throughput-style rows (iterations/s) so the drops-only diff band
    // points the right way: getting slower fails, getting faster blesses.
    json.push(&format!("{slug}/scalar_iters_per_s"), 1e9 / scalar.mean_ns.max(1.0), "iters/s");
    json.push(&format!("{slug}/kernel_iters_per_s"), 1e9 / kernel.mean_ns.max(1.0), "iters/s");
    json.push(&format!("{slug}/speedup"), speedup, "ratio");
    table.row(&[
        slug.to_string(),
        format!("{:.2}", scalar.mean_us()),
        format!("{:.2}", kernel.mean_us()),
        format!("{speedup:.2}x"),
    ]);
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let target_ms = if quick { 6.0 } else { 40.0 };
    let mut json = JsonSink::from_args("kernels");
    let mut table = Table::new(&["case", "scalar us", "kernel us", "speedup"]);
    let mut rng = Rng::new(23);

    // Fused LR forward/backward: the training hot loop, real generator
    // images (~50% zero pixels — the regime where the seed's skip branch
    // looked attractive and the branch-free 4-bank GEMV must still win).
    let data = MnistGen::new(11).dataset(0, 32);
    let params: Vec<f32> = (0..LR_PARAMS).map(|_| rng.normal() as f32 * 0.05).collect();
    let model = NativeLr::new();
    let mut grad = vec![0f32; LR_PARAMS];
    let scalar = bench_auto("lr fwd/bwd scalar (skip-branch)", target_ms, || {
        black_box(model.loss_grad_reference(&params, &data.x, &data.y, &mut grad));
    });
    let kernel = bench_auto("lr fwd/bwd blocked (4-bank gemv)", target_ms, || {
        black_box(model.loss_grad(&params, &data.x, &data.y, &mut grad));
    });
    duel(&mut json, &mut table, "lr_fwd_bwd/b32", &scalar, &kernel);

    // Dot product at model dim and aggregator dim.
    for (slug, n) in [("dot/7850", LR_PARAMS), ("dot/1m", BIG)] {
        let a: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let scalar = bench_auto(&format!("dot scalar n={n}"), target_ms, || {
            black_box(kernels::reference::dot(&a, &b));
        });
        let kernel = bench_auto(&format!("dot 8-lane n={n}"), target_ms, || {
            black_box(kernels::dot(&a, &b));
        });
        duel(&mut json, &mut table, slug, &scalar, &kernel);
    }

    // Sparse scatter-add: residual-arena / EF / delta-apply shape (1M
    // dense target, ~105k nonzeros). Parity check, not a speedup claim.
    let indices: Vec<u32> = (0..BIG as u32).step_by(10).collect();
    let values: Vec<f32> = indices.iter().map(|_| rng.normal() as f32).collect();
    let mut out = vec![0f32; BIG];
    let scalar = bench_auto("scatter-add inline", target_ms, || {
        for (&i, &v) in indices.iter().zip(&values) {
            out[i as usize] += 0.25 * v;
        }
        black_box(out[0]);
    });
    let kernel = bench_auto("scatter-add kernel", target_ms, || {
        kernels::scatter_add(&mut out, &indices, &values, 0.25);
        black_box(out[0]);
    });
    duel(&mut json, &mut table, "scatter_add/1m_nnz105k", &scalar, &kernel);

    // Streaming aggregation: one layered upload folded into a 1M-dim
    // accumulator through MeanAggregator (the server's streaming path).
    let third = indices.len().div_ceil(3);
    let layers: Vec<Layer> = indices
        .chunks(third)
        .zip(values.chunks(third))
        .map(|(i, v)| Layer { indices: i.to_vec(), values: v.to_vec() })
        .collect();
    let upd = LgcUpdate { dim: BIG, layers };
    let mut acc = vec![0f32; BIG];
    let mut agg = MeanAggregator;
    let scalar = bench_auto("stream-accumulate inline", target_ms, || {
        for layer in &upd.layers {
            for (&i, &v) in layer.indices.iter().zip(&layer.values) {
                acc[i as usize] += v;
            }
        }
        black_box(acc[0]);
    });
    let kernel = bench_auto("stream-accumulate kernel", target_ms, || {
        agg.stream_accumulate(&upd, 1.0, &mut acc);
        black_box(acc[0]);
    });
    duel(&mut json, &mut table, "stream_accumulate/1m", &scalar, &kernel);

    // Chunked parallel norm: sequential baseline vs. auto thread count
    // (bit-identical results; the win is wall-clock only).
    let v: Vec<f32> = (0..BIG).map(|_| rng.normal() as f32 * 0.01).collect();
    let scalar = bench_auto("par_norm2 t=1", target_ms, || {
        black_box(kernels::reduce::par_norm2(&v, 1));
    });
    let kernel = bench_auto("par_norm2 t=auto", target_ms, || {
        black_box(kernels::reduce::par_norm2(&v, 0));
    });
    duel(&mut json, &mut table, "par_norm2/1m_t1_vs_auto", &scalar, &kernel);

    let tag = if quick { " (quick)" } else { "" };
    println!("== blocked kernels vs scalar reference{tag} ==\n");
    table.print();
    json.finish();
}
