//! Edge-tier bench: the cost and effect of hierarchical per-zone
//! aggregation on the stadium-flash-crowd world.
//!
//! ```bash
//! cargo bench --bench bench_edge [-- --json]
//! ```
//!
//! Two panels:
//! 1. **flat vs edge** — the legacy semi-async engine on
//!    `stadium-flash-crowd` without and with the edge tier (5G backhaul):
//!    events/s overhead of holding/flushing/migrating, plus the
//!    deterministic backhaul + migration telemetry;
//! 2. **backhaul throttle sweep** — bw_scale ∈ {1.0, 0.2, 0.05} on a 3G
//!    backhaul: how the simulated finish time and the count of
//!    backhaul-bound rounds grow as the cloud leg starves.
//!
//! With `--json` the deterministic counters land in `BENCH_edge.json` for
//! the CI baseline diff (python/bench_diff.py).

use std::time::Instant;

use lgc::bench::{JsonSink, Table};
use lgc::channels::ChannelType;
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
use lgc::edge::EdgeSettings;
use lgc::scenario::ScenarioRegistry;
use lgc::sim::SyncMode;

fn base_cfg(rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds,
        devices: 6,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 1_000_000, // keep eval out of the timings
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        sync_mode: Some(SyncMode::SemiAsync { buffer_k: 2 }),
        ..ExperimentConfig::default()
    }
}

struct RunStats {
    wall_s: f64,
    sim_s: f64,
    events: u64,
    records: usize,
    backhaul_bytes: u64,
    migrated: u64,
    bound_rounds: u64,
}

fn run(cfg: ExperimentConfig) -> RunStats {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = ExperimentBuilder::new(cfg)
        .trainer(&trainer)
        .build()
        .expect("build");
    let t0 = Instant::now();
    let log = exp.run(&mut trainer).expect("run");
    RunStats {
        wall_s: t0.elapsed().as_secs_f64(),
        sim_s: log.records.last().map_or(0.0, |r| r.total_time_s),
        events: exp.sim_stats.events,
        records: log.records.len(),
        backhaul_bytes: log.records.iter().map(|r| r.backhaul_bytes).sum(),
        migrated: log.records.iter().map(|r| r.migrated_handoff).sum(),
        bound_rounds: log.records.iter().map(|r| r.edge_rounds_bound).sum(),
    }
}

fn main() {
    let mut json = JsonSink::from_args("edge");

    println!("== flat vs edge (stadium-flash-crowd, semi-async, 40 records) ==\n");
    let mut table = Table::new(&[
        "topology",
        "records",
        "events/s",
        "backhaul MB",
        "migrated",
        "bound rounds",
        "wall (s)",
    ]);
    for (label, edge) in [
        ("flat", None),
        (
            "edge (5G backhaul)",
            Some(EdgeSettings { flush_k: 2, ..EdgeSettings::default() }),
        ),
    ] {
        let mut cfg = base_cfg(40);
        cfg.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").expect("preset"));
        cfg.edge_settings = edge;
        let s = run(cfg);
        let slug = if label == "flat" { "flat" } else { "edge" };
        json.push(&format!("topology/{slug}/events_per_s"),
            s.events as f64 / s.wall_s.max(1e-9), "events/s");
        json.push(&format!("topology/{slug}/backhaul_bytes"), s.backhaul_bytes as f64, "bytes");
        json.push(&format!("topology/{slug}/migrated"), s.migrated as f64, "count");
        table.row(&[
            label.to_string(),
            s.records.to_string(),
            format!("{:.0}", s.events as f64 / s.wall_s.max(1e-9)),
            format!("{:.2}", s.backhaul_bytes as f64 / (1024.0 * 1024.0)),
            s.migrated.to_string(),
            s.bound_rounds.to_string(),
            format!("{:.3}", s.wall_s),
        ]);
    }
    table.print();

    println!("\n== backhaul throttle sweep (3G backhaul, 30 records) ==\n");
    let mut table = Table::new(&[
        "bw_scale",
        "sim time (s)",
        "bound rounds",
        "backhaul MB",
        "wall (s)",
    ]);
    for bw_scale in [1.0, 0.2, 0.05] {
        let mut cfg = base_cfg(30);
        cfg.scenario = Some(ScenarioRegistry::resolve("stadium-flash-crowd").expect("preset"));
        cfg.edge_settings = Some(EdgeSettings {
            backhaul: ChannelType::G3,
            bw_scale,
            flush_k: 2,
            ..EdgeSettings::default()
        });
        let s = run(cfg);
        json.push(&format!("throttle/{bw_scale}/bound_rounds"), s.bound_rounds as f64, "count");
        json.push(&format!("throttle/{bw_scale}/sim_s"), s.sim_s, "sim_s");
        table.row(&[
            format!("{bw_scale}"),
            format!("{:.1}", s.sim_s),
            s.bound_rounds.to_string(),
            format!("{:.2}", s.backhaul_bytes as f64 / (1024.0 * 1024.0)),
            format!("{:.3}", s.wall_s),
        ]);
    }
    table.print();
    json.finish();
}
