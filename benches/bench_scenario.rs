//! Scenario-subsystem bench: event throughput under scenario worlds,
//! trace-replay vs Markov dynamics at a 10k-client population, and a
//! handoff-churn sweep.
//!
//! ```bash
//! cargo bench --bench bench_scenario
//! ```
//!
//! Three panels:
//! 1. **events/s** — the legacy semi-async engine with no scenario vs the
//!    `stadium-flash-crowd` world (mobility + phase + handoff-drop work on
//!    top of every tick);
//! 2. **trace-replay vs Markov at 10k population** — cohort rounds/s with
//!    the default Markov chain vs the `diurnal` trace world (replay is a
//!    cursor walk instead of a `choice_weighted` draw per link);
//! 3. **handoff churn sweep** — move_prob ∈ {0, 0.05, 0.2, 0.5} on a
//!    two-zone world: handoffs, in-flight drops, and the throughput cost
//!    of reconfiguration.

use std::time::Instant;

use lgc::bench::{JsonSink, Table};
use lgc::channels::{ChannelType, FadingParams};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
use lgc::scenario::{DynamicsKind, ScenarioRegistry, ScenarioSpec, ZoneSpec};
use lgc::sim::SyncMode;

fn base_cfg(rounds: usize) -> ExperimentConfig {
    ExperimentConfig {
        mechanism: Mechanism::LgcStatic,
        workload: Workload::LrMnist,
        rounds,
        devices: 3,
        samples_per_device: 256,
        eval_samples: 256,
        eval_every: 1_000_000, // keep eval out of the timings
        lr: 0.05,
        h_fixed: 2,
        h_max: 4,
        use_runtime: false,
        ..ExperimentConfig::default()
    }
}

struct RunStats {
    wall_s: f64,
    events: u64,
    records: usize,
    handoffs: u64,
    dropped: u64,
}

fn run(cfg: ExperimentConfig) -> RunStats {
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = ExperimentBuilder::new(cfg)
        .trainer(&trainer)
        .build()
        .expect("build");
    let t0 = Instant::now();
    let log = exp.run(&mut trainer).expect("run");
    RunStats {
        wall_s: t0.elapsed().as_secs_f64(),
        events: exp.sim_stats.events,
        records: log.records.len(),
        handoffs: exp.sim_stats.handoffs,
        dropped: exp.sim_stats.dropped_handoff,
    }
}

fn two_zone_world(move_prob: f64) -> ScenarioSpec {
    use ChannelType::{G3, G4, G5};
    ScenarioSpec {
        name: format!("churn-{move_prob}"),
        move_prob,
        start_spread: true,
        trace_len: 1024,
        zones: vec![
            ZoneSpec {
                name: "wide".into(),
                channels: vec![G5, G4, G3],
                bw_scale: 1.0,
                fading: FadingParams::default(),
                dynamics: DynamicsKind::Markov,
            },
            ZoneSpec {
                name: "smallcell".into(),
                channels: vec![G5, G4],
                bw_scale: 0.9,
                fading: FadingParams::default(),
                dynamics: DynamicsKind::Markov,
            },
        ],
        phases: Vec::new(),
        noma: false,
    }
}

fn main() {
    let mut json = JsonSink::from_args("scenario");
    println!("== scenario engine overhead (legacy semi-async, 40 records) ==\n");
    let mut table = Table::new(&[
        "world",
        "records",
        "events",
        "events/s",
        "handoffs",
        "dropped",
        "wall (s)",
    ]);
    for (label, scenario) in [
        ("none (oracle world)", None),
        (
            "stadium-flash-crowd",
            Some(ScenarioRegistry::resolve("stadium-flash-crowd").expect("preset")),
        ),
    ] {
        let mut cfg = base_cfg(40);
        cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
        cfg.scenario = scenario;
        let s = run(cfg);
        let slug = if label.starts_with("none") { "none" } else { label };
        json.push(&format!("overhead/{slug}/events_per_s"),
            s.events as f64 / s.wall_s.max(1e-9), "events/s");
        json.push(&format!("overhead/{slug}/events"), s.events as f64, "count");
        json.push(&format!("overhead/{slug}/handoffs"), s.handoffs as f64, "count");
        json.push(&format!("overhead/{slug}/dropped"), s.dropped as f64, "count");
        table.row(&[
            label.to_string(),
            s.records.to_string(),
            s.events.to_string(),
            format!("{:.0}", s.events as f64 / s.wall_s.max(1e-9)),
            s.handoffs.to_string(),
            s.dropped.to_string(),
            format!("{:.3}", s.wall_s),
        ]);
    }
    table.print();

    println!("\n== trace replay vs Markov, population 10k / cohort 64 (3 rounds) ==\n");
    let mut table = Table::new(&["dynamics", "rounds/s", "handoffs", "wall (s)"]);
    for (label, scenario) in [
        ("markov (no scenario)", None),
        (
            "diurnal trace replay",
            Some(ScenarioRegistry::resolve("diurnal").expect("preset")),
        ),
    ] {
        let mut cfg = base_cfg(3);
        cfg.devices = 8;
        cfg.population = Some(10_000);
        cfg.cohort = Some(64);
        cfg.scenario = scenario;
        let s = run(cfg);
        let slug = if label.starts_with("markov") { "markov" } else { "diurnal" };
        json.push(&format!("dynamics/{slug}/rounds_per_s"),
            s.records as f64 / s.wall_s.max(1e-9), "rounds/s");
        json.push(&format!("dynamics/{slug}/handoffs"), s.handoffs as f64, "count");
        table.row(&[
            label.to_string(),
            format!("{:.2}", s.records as f64 / s.wall_s.max(1e-9)),
            s.handoffs.to_string(),
            format!("{:.3}", s.wall_s),
        ]);
    }
    table.print();

    println!("\n== handoff churn sweep (two zones, semi-async, 30 records) ==\n");
    let mut table = Table::new(&[
        "move_prob",
        "handoffs",
        "dropped layers",
        "events/s",
        "wall (s)",
    ]);
    for move_prob in [0.0, 0.05, 0.2, 0.5] {
        let mut cfg = base_cfg(30);
        cfg.sync_mode = Some(SyncMode::SemiAsync { buffer_k: 2 });
        cfg.scenario = Some(two_zone_world(move_prob));
        let s = run(cfg);
        json.push(&format!("churn/{move_prob}/handoffs"), s.handoffs as f64, "count");
        json.push(&format!("churn/{move_prob}/dropped"), s.dropped as f64, "count");
        json.push(&format!("churn/{move_prob}/events_per_s"),
            s.events as f64 / s.wall_s.max(1e-9), "events/s");
        table.row(&[
            format!("{move_prob}"),
            s.handoffs.to_string(),
            s.dropped.to_string(),
            format!("{:.0}", s.events as f64 / s.wall_s.max(1e-9)),
            format!("{:.3}", s.wall_s),
        ]);
    }
    table.print();
    json.finish();
}
