//! Figure 5 reproduction: DRL training curves — (a) critic loss vs episode,
//! (b) reward vs episode — while the DDPG agents control LGC on the LR
//! workload (native path, no artifacts needed).
//!
//! Expected shape (paper Fig. 5): loss falls quickly in early episodes;
//! reward trends upward as the policy improves.

use std::path::Path;

use lgc::bench::{JsonSink, Table};
use lgc::config::{ExperimentConfig, Mechanism, Workload};
use lgc::coordinator::{Experiment, NativeLrTrainer};
use lgc::drl::Transition;

fn main() -> anyhow::Result<()> {
    let episodes: usize = std::env::var("LGC_EPISODES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(12);
    let rounds_per_episode = 20;
    println!("== Figure 5: DDPG training ({episodes} episodes x {rounds_per_episode} rounds) ==");

    let cfg = ExperimentConfig {
        mechanism: Mechanism::LgcDrl,
        workload: Workload::LrMnist,
        rounds: episodes * rounds_per_episode,
        devices: 3,
        samples_per_device: 1024,
        eval_samples: 256,
        eval_every: 10,
        lr: 0.05,
        h_fixed: 3,
        h_max: 8,
        use_runtime: false,
        ..ExperimentConfig::default()
    };
    let mut trainer = NativeLrTrainer::new(&cfg);
    let mut exp = Experiment::new(cfg, &trainer);

    let mut json = JsonSink::from_args("fig5_drl");
    let mut table = Table::new(&["episode", "mean reward", "critic loss", "actor Q", "episode energy (J)"]);
    let mut csv = String::from("episode,mean_reward,critic_loss,actor_q,episode_energy_j\n");
    let mut final_ep = (f64::NAN, f64::NAN);
    for ep in 0..episodes {
        // Fresh FL problem each episode; the DDPG agents persist (Fig. 5).
        exp.reset_episode(&trainer);
        let mut reward = 0.0;
        let mut nr = 0usize;
        let mut energy = 0.0;
        for round in 0..rounds_per_episode {
            if let Some(rec) = exp.step_round(round, &mut trainer)? {
                if rec.drl_reward.is_finite() {
                    reward += rec.drl_reward;
                    nr += 1;
                }
                energy = rec.energy_j;
            }
        }
        // Read out the critic by one offline learn step per agent.
        let mut closs = 0.0;
        let mut aq = 0.0;
        let mut na = 0usize;
        for agent in exp.agents.iter_mut().flatten() {
            if agent.ddpg.replay.len() >= 64 {
                let stats = agent.ddpg.learn();
                closs += stats.critic_loss;
                aq += stats.actor_q;
                na += 1;
            }
        }
        let (closs, aq) = if na > 0 {
            (closs / na as f64, aq / na as f64)
        } else {
            (f64::NAN, f64::NAN)
        };
        let mr = reward / nr.max(1) as f64;
        table.row(&[
            ep.to_string(),
            format!("{mr:.4}"),
            format!("{closs:.5}"),
            format!("{aq:.4}"),
            format!("{energy:.1}"),
        ]);
        csv.push_str(&format!("{ep},{mr:.6},{closs:.6},{aq:.6},{energy:.1}\n"));
        final_ep = (mr, energy);
    }
    table.print();
    // Sim-deterministic trajectory rows (the DDPG path is fully seeded);
    // the raw learn-step timing stays out — wall time isn't comparable
    // across runners.
    json.push("ddpg/final_mean_reward", final_ep.0, "sim");
    json.push("ddpg/final_episode_energy", final_ep.1, "sim");
    std::fs::create_dir_all("results")?;
    std::fs::write(Path::new("results/fig5_drl.csv"), csv)?;
    println!("\nCSV series in results/fig5_drl.csv");

    // Also exercise the raw DDPG learning curve on a stationary toy problem
    // (pure Fig. 5(a) shape, decoupled from FL noise).
    println!("\n-- critic loss on stationary toy control (sanity curve) --");
    let mut agent = lgc::drl::Ddpg::new(
        1,
        1,
        lgc::config::DrlConfig { warmup: 32, batch: 32, hidden: 32, gamma: 0.0, ..Default::default() },
        lgc::util::Rng::new(1),
    );
    let mut env = lgc::util::Rng::new(2);
    let mut first = f64::NAN;
    let mut last = f64::NAN;
    for step in 0..2000 {
        let s = vec![env.range(-1.0, 1.0) as f32];
        let a = agent.act_explore(&s);
        let r = -((a[0] - s[0]) * (a[0] - s[0]));
        if let Some(stats) = agent.observe(Transition {
            state: s.clone(),
            action: a,
            reward: r,
            next_state: s,
            done: true,
        }) {
            if first.is_nan() {
                first = stats.critic_loss;
            }
            last = stats.critic_loss;
            if step % 400 == 0 {
                println!("step {step:>5}: critic loss {:.5}", stats.critic_loss);
            }
        }
    }
    println!("critic loss {first:.5} -> {last:.5} (should fall)");
    json.push("ddpg/toy_critic_loss_last", last, "sim");
    json.finish();

    // §Perf: one DDPG learn step (batch 32, hidden 32) — target < 200 us.
    let r = lgc::bench::bench_auto("ddpg learn step", 100.0, || {
        std::hint::black_box(agent.learn());
    });
    r.report("(target < 200 us)");
    Ok(())
}
