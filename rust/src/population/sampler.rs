//! The [`ClientSampler`] seam — which clients of a [`Population`]
//! participate in each round.
//!
//! Partial participation is itself a communication/computation trade-off
//! knob (Fast Federated Learning by Balancing Communication Trade-Offs,
//! IEEE TCOM 2021): the server only pays for the sampled cohort, and
//! convergence degrades gracefully with the sampling fraction. Samplers are
//! deterministic given their construction RNG — the simulator's
//! reproducibility contract (`tests/population.rs` proves same-seed runs
//! replay bit for bit).
//!
//! | sampler | rule | notes |
//! |---------|------|-------|
//! | [`FullParticipation`] | every client, every round | bit-for-bit equal to the fully-materialized reference loop |
//! | [`UniformK`] | k distinct clients uniformly among eligible | the classic FedAvg `C`-fraction |
//! | [`WeightedBySamples`] | k distinct, P ∝ local sample count | Efraimidis–Spirakis A-Res weighted reservoir |
//! | [`AvailabilityMarkov`] | k uniformly among *online* clients | the on/off churn chain lives in [`Population`] |

use super::Population;
use crate::util::Rng;

/// Built-in sampler kinds, as named by the `sampler` config key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SamplerKind {
    Full,
    UniformK,
    WeightedBySamples,
    AvailabilityMarkov,
}

impl SamplerKind {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "full" | "full-participation" | "all" => Ok(SamplerKind::Full),
            "uniform" | "uniform-k" | "uniform_k" => Ok(SamplerKind::UniformK),
            "weighted" | "weighted-by-samples" | "weighted_by_samples" => {
                Ok(SamplerKind::WeightedBySamples)
            }
            "availability" | "availability-markov" | "availability_markov" | "markov" => {
                Ok(SamplerKind::AvailabilityMarkov)
            }
            other => Err(format!(
                "unknown sampler `{other}` (full|uniform-k|weighted-by-samples|availability-markov)"
            )),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SamplerKind::Full => "full",
            SamplerKind::UniformK => "uniform-k",
            SamplerKind::WeightedBySamples => "weighted-by-samples",
            SamplerKind::AvailabilityMarkov => "availability-markov",
        }
    }
}

/// Build the built-in sampler for `kind` with cohort size `k` and its own
/// forked RNG stream.
pub fn build_sampler(kind: SamplerKind, k: usize, rng: Rng) -> Box<dyn ClientSampler> {
    match kind {
        SamplerKind::Full => Box::new(FullParticipation::new()),
        SamplerKind::UniformK => Box::new(UniformK::new(k, rng)),
        SamplerKind::WeightedBySamples => Box::new(WeightedBySamples::new(k, rng)),
        SamplerKind::AvailabilityMarkov => Box::new(AvailabilityMarkov::new(k, rng)),
    }
}

/// Cohort selection for one round, plus slot replacement for the async
/// engines.
///
/// Contract:
/// - `sample` returns **ascending** client ids (aggregation order — and for
///   `FullParticipation`, the exact device order of the reference loop);
/// - except for `FullParticipation` (which hands back every id and lets the
///   driver skip out-of-budget clients exactly like the reference loop),
///   returned clients must be [`Population::eligible`];
/// - two instances built from the same RNG produce the same sequence.
pub trait ClientSampler: Send {
    /// Short human-readable name for logs.
    fn name(&self) -> String;

    /// Select the round's cohort into `out` (cleared first) — the
    /// allocation-free form the engines drive with a hoisted buffer, so
    /// steady-state rounds reuse one cohort allocation. Implementations
    /// keep any eligible-id scan in internal scratch for the same reason.
    fn sample_into(&mut self, round: usize, pop: &Population, out: &mut Vec<usize>);

    /// Select the round's cohort (convenience wrapper over
    /// [`ClientSampler::sample_into`]).
    fn sample(&mut self, round: usize, pop: &Population) -> Vec<usize> {
        let mut out = Vec::new();
        self.sample_into(round, pop, &mut out);
        out
    }

    /// Pick one replacement client for a freed async slot. `busy[id]` marks
    /// clients currently in flight (also excluded by eligibility — the
    /// slice makes the intent explicit and guards future samplers).
    fn sample_replacement(&mut self, pop: &Population, busy: &[bool]) -> Option<usize>;
}

/// Every client, every round — today's behavior, reproduced bit for bit
/// over a materialized population (the driver applies the same per-client
/// budget skip as the reference loop).
#[derive(Clone, Debug, Default)]
pub struct FullParticipation {
    /// Round-robin cursor for async slot replacement.
    cursor: usize,
}

impl FullParticipation {
    pub fn new() -> Self {
        Self::default()
    }
}

impl ClientSampler for FullParticipation {
    fn name(&self) -> String {
        "full".to_string()
    }

    fn sample_into(&mut self, _round: usize, pop: &Population, out: &mut Vec<usize>) {
        out.clear();
        out.extend(0..pop.len());
    }

    fn sample_replacement(&mut self, pop: &Population, busy: &[bool]) -> Option<usize> {
        let n = pop.len();
        for step in 0..n {
            let id = (self.cursor + step) % n;
            if !busy[id] && pop.eligible(id) {
                self.cursor = (id + 1) % n;
                return Some(id);
            }
        }
        None
    }
}

/// Uniform-without-replacement over the eligible clients: partial Fisher–
/// Yates over the eligible id list, then sorted ascending.
#[derive(Clone, Debug)]
pub struct UniformK {
    pub k: usize,
    rng: Rng,
    /// Eligible-id scratch, reused across rounds (no steady-state alloc).
    elig: Vec<usize>,
}

impl UniformK {
    pub fn new(k: usize, rng: Rng) -> Self {
        assert!(k >= 1, "cohort must be >= 1");
        UniformK { k, rng, elig: Vec::new() }
    }
}

/// Uniform single draw among eligible, non-busy clients: rejection sampling
/// first (O(1) in the common cohort ≪ population regime, where nearly every
/// client is an eligible candidate), exact O(population) scan as the
/// sparse-eligibility fallback — so an async Broadcast that rotates the
/// whole pool never costs O(cohort × population) on a healthy population.
fn uniform_replacement(pop: &Population, busy: &[bool], rng: &mut Rng) -> Option<usize> {
    for _ in 0..32 {
        let id = rng.index(pop.len());
        if !busy[id] && pop.eligible(id) {
            return Some(id);
        }
    }
    let elig: Vec<usize> = pop
        .eligible_ids()
        .into_iter()
        .filter(|&i| !busy[i])
        .collect();
    if elig.is_empty() {
        None
    } else {
        Some(elig[rng.index(elig.len())])
    }
}

/// In-place partial Fisher–Yates: keep `k` uniform-without-replacement
/// entries of `elig` (all of them if `k >= len`), sorted ascending. Draw
/// order is the classic `rng.index(n - i)` per kept slot.
fn uniform_among(elig: &mut Vec<usize>, k: usize, rng: &mut Rng) {
    let n = elig.len();
    if n <= k {
        return; // already ascending
    }
    for i in 0..k {
        let j = i + rng.index(n - i);
        elig.swap(i, j);
    }
    elig.truncate(k);
    elig.sort_unstable();
}

impl ClientSampler for UniformK {
    fn name(&self) -> String {
        format!("uniform-k({})", self.k)
    }

    fn sample_into(&mut self, _round: usize, pop: &Population, out: &mut Vec<usize>) {
        pop.eligible_into(&mut self.elig);
        uniform_among(&mut self.elig, self.k, &mut self.rng);
        out.clear();
        out.extend_from_slice(&self.elig);
    }

    fn sample_replacement(&mut self, pop: &Population, busy: &[bool]) -> Option<usize> {
        uniform_replacement(pop, busy, &mut self.rng)
    }
}

/// Weighted-without-replacement, P(client) ∝ its local sample count
/// (McMahan-style importance): A-Res weighted reservoir — key
/// `u^(1/w)`, keep the k largest keys.
#[derive(Clone, Debug)]
pub struct WeightedBySamples {
    pub k: usize,
    rng: Rng,
    /// Eligible-id scratch, reused across rounds (no steady-state alloc).
    elig: Vec<usize>,
    /// A-Res key scratch, reused the same way.
    keyed: Vec<(f64, usize)>,
}

impl WeightedBySamples {
    pub fn new(k: usize, rng: Rng) -> Self {
        assert!(k >= 1, "cohort must be >= 1");
        WeightedBySamples { k, rng, elig: Vec::new(), keyed: Vec::new() }
    }
}

impl ClientSampler for WeightedBySamples {
    fn name(&self) -> String {
        format!("weighted-by-samples({})", self.k)
    }

    fn sample_into(&mut self, _round: usize, pop: &Population, out: &mut Vec<usize>) {
        pop.eligible_into(&mut self.elig);
        out.clear();
        if self.elig.len() <= self.k {
            out.extend_from_slice(&self.elig);
            return;
        }
        self.keyed.clear();
        for &i in &self.elig {
            let w = pop.samples(i).max(1) as f64;
            let u = self.rng.uniform().max(1e-300);
            self.keyed.push((u.powf(1.0 / w), i));
        }
        self.keyed.sort_by(|a, b| b.0.total_cmp(&a.0));
        out.extend(self.keyed[..self.k].iter().map(|&(_, i)| i));
        out.sort_unstable();
    }

    fn sample_replacement(&mut self, pop: &Population, busy: &[bool]) -> Option<usize> {
        let elig: Vec<usize> = pop
            .eligible_ids()
            .into_iter()
            .filter(|&i| !busy[i])
            .collect();
        if elig.is_empty() {
            return None;
        }
        let total: f64 = elig.iter().map(|&i| pop.samples(i).max(1) as f64).sum();
        let mut t = self.rng.uniform() * total;
        for &i in &elig {
            t -= pop.samples(i).max(1) as f64;
            if t <= 0.0 {
                return Some(i);
            }
        }
        Some(*elig.last().unwrap())
    }
}

/// Uniform over the clients whose availability chain says they are
/// **online** right now. The per-client on/off Markov chain itself is
/// stepped by [`Population::step_round`] (and mid-upload dropouts by
/// [`Population::midround_offline`]) — this sampler is the selection rule
/// that respects it. With churn disabled it degenerates to [`UniformK`].
#[derive(Clone, Debug)]
pub struct AvailabilityMarkov {
    pub k: usize,
    rng: Rng,
    /// Eligible-id scratch, reused across rounds (no steady-state alloc).
    elig: Vec<usize>,
}

impl AvailabilityMarkov {
    pub fn new(k: usize, rng: Rng) -> Self {
        assert!(k >= 1, "cohort must be >= 1");
        AvailabilityMarkov { k, rng, elig: Vec::new() }
    }
}

impl ClientSampler for AvailabilityMarkov {
    fn name(&self) -> String {
        format!("availability-markov({})", self.k)
    }

    fn sample_into(&mut self, _round: usize, pop: &Population, out: &mut Vec<usize>) {
        // Eligibility already excludes offline clients.
        pop.eligible_into(&mut self.elig);
        uniform_among(&mut self.elig, self.k, &mut self.rng);
        out.clear();
        out.extend_from_slice(&self.elig);
    }

    fn sample_replacement(&mut self, pop: &Population, busy: &[bool]) -> Option<usize> {
        uniform_replacement(pop, busy, &mut self.rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{ChannelType, DeviceChannels};
    use crate::compression::DenseNoop;
    use crate::population::SpecSeed;
    use crate::resources::{ComputeCostModel, ResourceMeter};

    fn synthetic_pop(samples: &[usize]) -> Population {
        let rng = Rng::new(3);
        Population::new(
            samples.iter().enumerate().map(|(id, &n)| {
                SpecSeed::new(
                    id,
                    DeviceChannels::new(&[ChannelType::G5], &rng, id),
                    Box::new(DenseNoop),
                    rng.fork(id as u64),
                )
                .samples(n)
                .meter(ResourceMeter::new(f64::INFINITY, f64::INFINITY))
                .compute(ComputeCostModel::for_params(100))
            }),
            samples.len().min(4),
            0.0,
            0.0,
        )
    }

    #[test]
    fn full_participation_returns_everyone_ascending() {
        let pop = synthetic_pop(&[10; 7]);
        let mut s = FullParticipation::new();
        assert_eq!(s.sample(0, &pop), (0..7).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_k_is_k_distinct_ascending_and_seeded() {
        let pop = synthetic_pop(&[10; 30]);
        let mut a = UniformK::new(5, Rng::new(9));
        let mut b = UniformK::new(5, Rng::new(9));
        let mut c = UniformK::new(5, Rng::new(10));
        let (sa, sb, sc) = (a.sample(0, &pop), b.sample(0, &pop), c.sample(0, &pop));
        assert_eq!(sa.len(), 5);
        assert!(sa.windows(2).all(|w| w[0] < w[1]), "{sa:?}");
        assert_eq!(sa, sb, "same seed, same cohort");
        assert_ne!(sa, sc, "different seed should differ (w.h.p.)");
        // Consecutive rounds rotate the cohort.
        assert_ne!(a.sample(1, &pop), sb);
    }

    #[test]
    fn weighted_prefers_heavy_shards() {
        // 5 heavy clients (1000 samples) vs 5 light (10): over 200 draws of
        // k=2 the heavies must dominate overwhelmingly.
        let samples: Vec<usize> = (0..10).map(|i| if i < 5 { 1000 } else { 10 }).collect();
        let pop = synthetic_pop(&samples);
        let mut s = WeightedBySamples::new(2, Rng::new(21));
        let mut heavy = 0usize;
        let mut light = 0usize;
        for round in 0..200 {
            for id in s.sample(round, &pop) {
                if id < 5 {
                    heavy += 1;
                } else {
                    light += 1;
                }
            }
        }
        assert!(heavy > 4 * light, "heavy {heavy} vs light {light}");
    }

    #[test]
    fn samplers_skip_ineligible_clients() {
        let mut pop = synthetic_pop(&[10; 8]);
        // Exhaust client 2's budget: no sampler may pick it again.
        {
            let g = vec![0f32; 4];
            let mut d = pop.materialize(2, &g);
            d.meter = ResourceMeter::new(0.0, 0.0);
            d.meter.record_round(1.0, 0.0, 0.0, 0.0);
            pop.demobilize(d.into_parts(), true);
        }
        let mut s = UniformK::new(8, Rng::new(4));
        let cohort = s.sample(0, &pop);
        assert!(!cohort.contains(&2), "{cohort:?}");
        assert_eq!(cohort.len(), 7);
        let mut f = FullParticipation::new();
        let busy = vec![false; 8];
        for _ in 0..14 {
            let id = f.sample_replacement(&pop, &busy).unwrap();
            assert_ne!(id, 2);
        }
    }

    #[test]
    fn sample_into_matches_sample_for_every_builtin() {
        // The in-place form the engines drive must make the exact same RNG
        // draws as the allocating wrapper.
        let pop = synthetic_pop(&[10, 1000, 10, 500, 10, 10, 250, 10, 10, 10]);
        for kind in [
            SamplerKind::Full,
            SamplerKind::UniformK,
            SamplerKind::WeightedBySamples,
            SamplerKind::AvailabilityMarkov,
        ] {
            let mut a = build_sampler(kind, 3, Rng::new(77));
            let mut b = build_sampler(kind, 3, Rng::new(77));
            let mut buf = Vec::new();
            for round in 0..5 {
                a.sample_into(round, &pop, &mut buf);
                assert_eq!(buf, b.sample(round, &pop), "{}", kind.name());
            }
        }
    }
}
