//! Population-scale client virtualization: lazy device cohorts over a
//! struct-of-arrays client store.
//!
//! The paper evaluates LGC on a handful of always-on edge devices, where
//! every [`Device`](crate::coordinator::Device) permanently owns two dense
//! model replicas plus compressor error-feedback memory — O(population ×
//! model_dim) resident state. Real cross-device FL runs a small sampled
//! cohort per round over a vast, churning population (cf. "To Talk or to
//! Work", arXiv:2012.11804). This module makes population size a free
//! parameter, and keeps the per-tick population sweeps cache-linear at
//! millions of clients:
//!
//! - [`SpecSeed`] is the builder for one client's demobilized state: seeded
//!   channel bundle, compute profile, resource meter, data-shard id, the
//!   freshly-constructed compressor and the private availability-churn RNG.
//! - [`Population`] stores clients as **parallel arrays** (struct of
//!   arrays): one column each for shard / samples / online / prev-loss /
//!   sync state / meters / compute profiles / channel bundles / churn RNGs.
//!   The per-tick fading and churn sweeps walk these columns linearly (and
//!   in parallel across [`Population::set_sweep_threads`] workers when the
//!   population is large — bit-identical for any worker count, because
//!   every client's RNG streams are private).
//! - Persisted error-feedback residuals live in a shared **arena** (one
//!   sparse `(index, value)` pool plus one dense `f32` pool) with a
//!   three-word `{kind, offset, len}` reference per client — no per-client
//!   `Vec` allocations, and the arena compacts itself once dead spans
//!   outweigh live ones. The standalone [`Residual`] enum remains the
//!   documented compact encoding (and the unit-tested drain/restore
//!   contract); the store is its arena-backed bulk form.
//! - Compressor state is **rehydrated from a compact
//!   [`CompressorSeed`]** instead of keeping a resident
//!   `Box<dyn Compressor>` per client: demobilization exports the seed and
//!   parks the box in a small per-`name()` pool (at most `cohort` boxes per
//!   distinct compressor name), and materialization pops a pooled box and
//!   restores the client's seed into it. A compressor whose output depends
//!   on draw *history* (RandK's reused permutation) opts out via
//!   `export_seed() == None` and stays resident per client — bit-for-bit
//!   legacy behavior.
//! - Materialization and demobilization recycle every O(model) buffer
//!   through internal free lists (dense replicas, error-memory vectors,
//!   compression scratch), so a steady-state cohort round performs no
//!   population- or model-sized heap allocation (`tests/alloc_steady.rs`
//!   asserts this with a counting allocator).
//! - [`ClientSampler`] ([`sampler`]) is the pluggable cohort-selection seam:
//!   [`FullParticipation`] reproduces the fully-materialized reference loop
//!   bit for bit (proven against the frozen `Experiment::step_round` oracle
//!   in `tests/population.rs`), [`UniformK`] / [`WeightedBySamples`] are the
//!   classic partial-participation rules, and [`AvailabilityMarkov`] samples
//!   only clients whose per-client on/off churn chain (stepped here, in the
//!   population) says they are online. A client that churns offline
//!   mid-upload feeds the existing lost-layer restitution path — its shipped
//!   coordinates return to the error memory, so gradient mass is delayed,
//!   never destroyed.
//!
//! Demobilization contract: when a client leaves the cohort, its error
//! memory is drained into the arena-backed residual and its O(model)
//! working buffers are recycled. If the round ended *without* the
//! compressor running (an all-silent plan), the pending local progress
//! `w_sync − ŵ` is folded into the error memory first so nothing is lost;
//! if the compressor *did* run, the progress already lives in `delivered
//! layers + error memory` and folding would double-count — the
//! `compressed_since_sync` flag keeps the two cases straight. See
//! DESIGN.md §"Sharded event engine & SoA population".

pub mod sampler;

pub use sampler::{
    build_sampler, AvailabilityMarkov, ClientSampler, FullParticipation, SamplerKind, UniformK,
    WeightedBySamples,
};

use crate::channels::DeviceChannels;
use crate::compression::{Compressor, CompressorSeed, ErrorFeedback};
use crate::coordinator::device::{Device, DeviceParts};
use crate::downlink::SyncState;
use crate::resources::{ComputeCostModel, ResourceMeter};
use crate::util::Rng;

/// Below this population size the fading/churn sweeps stay sequential —
/// thread-spawn overhead would dominate, and the parallel path is only a
/// wall-clock optimization (per-client RNG streams make it bit-identical).
const PAR_SWEEP_MIN: usize = 4096;

/// Compact persisted error-feedback residual of a demobilized client.
///
/// Encoding picks the smaller of two forms at export time: sparse
/// `(index, value)` pairs (8 B/nonzero) while at most half the coordinates
/// are nonzero, plain dense `f32` (4 B/coordinate) beyond that — so the
/// persisted state never exceeds one dense model and is empty for clients
/// that have not participated yet. Export/restore is bitwise lossless
/// (signed zeros included).
///
/// [`Population`] stores residuals in a shared arena with the same
/// encoding rule; this standalone enum is the single-client form (and the
/// unit-tested reference for the drain/restore contract).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Residual {
    /// No dropped mass carried (client never compressed, or compressed
    /// losslessly).
    #[default]
    Empty,
    /// `(coordinate, value)` pairs, ascending, values all nonzero bits.
    Sparse(Vec<(u32, f32)>),
    /// Dense residual (cheaper than pairs once more than half the
    /// coordinates are nonzero — the common case for top-K error feedback).
    Dense(Vec<f32>),
}

impl Residual {
    /// Drain `ef` into its compact form, releasing the dense memory.
    pub fn drain_from(ef: &mut ErrorFeedback) -> Residual {
        let e = ef.take_memory();
        let nnz = e.iter().filter(|v| v.to_bits() != 0).count();
        if nnz == 0 {
            return Residual::Empty;
        }
        if nnz * 2 > e.len() {
            return Residual::Dense(e);
        }
        Residual::Sparse(
            e.iter()
                .enumerate()
                .filter(|(_, v)| v.to_bits() != 0)
                .map(|(i, &v)| (i as u32, v))
                .collect(),
        )
    }

    /// Rebuild the dense memory inside `ef` (consumes the residual).
    pub fn restore_into(self, ef: &mut ErrorFeedback, dim: usize) {
        match self {
            Residual::Empty => {}
            Residual::Sparse(pairs) => {
                let mut e = vec![0.0f32; dim];
                crate::kernels::scatter_set_pairs(&mut e, &pairs);
                ef.set_memory(e);
            }
            Residual::Dense(e) => {
                assert_eq!(e.len(), dim, "dense residual dim mismatch");
                ef.set_memory(e);
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, Residual::Empty)
    }

    /// Nonzero coordinates carried.
    pub fn nnz(&self) -> usize {
        match self {
            Residual::Empty => 0,
            Residual::Sparse(v) => v.len(),
            Residual::Dense(v) => v.iter().filter(|x| x.to_bits() != 0).count(),
        }
    }

    /// Approximate heap bytes of the persisted form.
    pub fn bytes(&self) -> usize {
        match self {
            Residual::Empty => 0,
            Residual::Sparse(v) => v.len() * 8,
            Residual::Dense(v) => v.len() * 4,
        }
    }
}

/// Builder for one client's demobilized record — the construction-time form
/// [`Population::new`] consumes (both population init and the internal
/// demobilization path funnel through the same column writes, replacing the
/// old eight-argument `DeviceSpec::new`).
///
/// Required state goes through [`SpecSeed::new`]; everything else defaults
/// (legacy identity shard mapping, one sample, unbounded meter) and chains:
///
/// ```ignore
/// SpecSeed::new(id, channels, compressor, churn_rng)
///     .shard(id % devices)
///     .samples(n_m)
///     .meter(ResourceMeter::new(e, m))
///     .compute(profile)
/// ```
pub struct SpecSeed {
    id: usize,
    shard: usize,
    samples: usize,
    channels: DeviceChannels,
    meter: ResourceMeter,
    compute: ComputeCostModel,
    compressor: Box<dyn Compressor>,
    churn_rng: Rng,
}

impl SpecSeed {
    pub fn new(
        id: usize,
        channels: DeviceChannels,
        compressor: Box<dyn Compressor>,
        churn_rng: Rng,
    ) -> Self {
        SpecSeed {
            id,
            shard: id,
            samples: 1,
            channels,
            meter: ResourceMeter::new(f64::INFINITY, f64::INFINITY),
            compute: ComputeCostModel::for_params(1),
            compressor,
            churn_rng,
        }
    }

    /// Trainer data shard this client draws batches from (population mode
    /// maps many clients onto `cfg.devices` shards, `id % cfg.devices`;
    /// default: the legacy identity mapping).
    pub fn shard(mut self, shard: usize) -> Self {
        self.shard = shard;
        self
    }

    /// Local sample count n_m of the shard (weighted sampling/aggregation).
    pub fn samples(mut self, samples: usize) -> Self {
        self.samples = samples;
        self
    }

    pub fn meter(mut self, meter: ResourceMeter) -> Self {
        self.meter = meter;
        self
    }

    pub fn compute(mut self, compute: ComputeCostModel) -> Self {
        self.compute = compute;
        self
    }
}

/// Residual encoding of one client's arena span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ResKind {
    Empty,
    Sparse,
    Dense,
}

/// Per-client reference into the shared residual arena: `len` entries of
/// the `kind` pool starting at `off`. Three words instead of a `Vec` per
/// client.
#[derive(Clone, Copy, Debug)]
struct ResRef {
    kind: ResKind,
    off: usize,
    len: usize,
}

impl ResRef {
    const EMPTY: ResRef = ResRef { kind: ResKind::Empty, off: 0, len: 0 };
}

/// Where a demobilized client's compressor state lives.
enum CompressorSlot {
    /// Rehydratable: the compact seed, plus the index of the per-name box
    /// pool a pooled instance is popped from at materialization (assigned
    /// once at admission; `restore_seed` makes any same-name box this
    /// client's, bit for bit).
    Seeded { pool: u16, seed: CompressorSeed },
    /// Resident: this compressor's output depends on draw history beyond
    /// any seed (`export_seed() == None`, e.g. RandK's reused permutation),
    /// so the client keeps its own box. `None` while materialized.
    Resident(Option<Box<dyn Compressor>>),
}

/// The client store: struct-of-arrays columns, one entry per client, with
/// materialization bookkeeping, arena-backed residuals, pooled compressor
/// boxes, recycled O(model) buffers, and the population-wide dynamics
/// (channel fading for every client, availability churn).
pub struct Population {
    cohort: usize,
    /// Per-tick probability that an online client drops offline (0 = no
    /// churn; also gates the mid-upload dropout draw).
    churn_down: f64,
    /// Per-tick probability that an offline client comes back.
    churn_up: f64,
    materialized: usize,
    peak_materialized: usize,
    /// Worker threads for the O(population) sweeps (1 = sequential; the
    /// engine wires the resolved `shards` config here).
    sweep_threads: usize,

    // --- per-client columns (all `len()` long) ---
    shard: Vec<u32>,
    samples: Vec<u32>,
    online: Vec<bool>,
    prev_loss: Vec<f64>,
    last_delta: Vec<f64>,
    sync_states: Vec<SyncState>,
    meters: Vec<ResourceMeter>,
    computes: Vec<ComputeCostModel>,
    /// Multi-channel uplink state — `None` while the client is materialized
    /// (the channels move into the live `Device` and back).
    channels: Vec<Option<DeviceChannels>>,
    /// Private RNG stream of each client's churn chain.
    churn_rng: Vec<Rng>,
    res: Vec<ResRef>,
    comp: Vec<CompressorSlot>,

    // --- shared residual arena ---
    sparse: Vec<(u32, f32)>,
    dense: Vec<f32>,
    dead_sparse: usize,
    dead_dense: usize,
    /// Ping-pong buffers for arena compaction (retained capacity, so the
    /// amortized compaction allocates nothing once warmed up).
    sparse_spare: Vec<(u32, f32)>,
    dense_spare: Vec<f32>,

    // --- recycled O(model) buffers and pooled compressor boxes ---
    /// Per-`name()` pools of interchangeable seeded compressor boxes, at
    /// most `cohort` each.
    pools: Vec<(String, Vec<Box<dyn Compressor>>)>,
    /// Recycled dense f32 buffers (model replicas, error-memory vectors).
    f32_pool: Vec<Vec<f32>>,
    /// Recycled per-device compression workspaces.
    scratch_pool: Vec<(crate::compression::CompressScratch, Vec<f32>)>,
}

impl Population {
    /// Build the store from per-client seeds (ids must be dense and
    /// ascending from 0). Seeds are consumed one at a time, so a lazy
    /// iterator keeps peak build memory at one compressor box per pool
    /// slot rather than one per client.
    pub fn new(
        seeds: impl IntoIterator<Item = SpecSeed>,
        cohort: usize,
        churn_down: f64,
        churn_up: f64,
    ) -> Self {
        assert!((0.0..=1.0).contains(&churn_down) && (0.0..=1.0).contains(&churn_up));
        let mut p = Population {
            cohort,
            churn_down,
            churn_up,
            materialized: 0,
            peak_materialized: 0,
            sweep_threads: 1,
            shard: Vec::new(),
            samples: Vec::new(),
            online: Vec::new(),
            prev_loss: Vec::new(),
            last_delta: Vec::new(),
            sync_states: Vec::new(),
            meters: Vec::new(),
            computes: Vec::new(),
            channels: Vec::new(),
            churn_rng: Vec::new(),
            res: Vec::new(),
            comp: Vec::new(),
            sparse: Vec::new(),
            dense: Vec::new(),
            dead_sparse: 0,
            dead_dense: 0,
            sparse_spare: Vec::new(),
            dense_spare: Vec::new(),
            pools: Vec::new(),
            f32_pool: Vec::new(),
            scratch_pool: Vec::new(),
        };
        for seed in seeds {
            p.admit(seed);
        }
        assert!(!p.channels.is_empty(), "population needs at least one client");
        assert!(
            cohort >= 1 && cohort <= p.channels.len(),
            "cohort {cohort} out of range for population {}",
            p.channels.len()
        );
        p
    }

    /// Append one client's columns. The compressor is seeded into a
    /// per-name pool when it supports rehydration, else kept resident.
    fn admit(&mut self, seed: SpecSeed) {
        let SpecSeed { id, shard, samples, channels, meter, compute, compressor, churn_rng } =
            seed;
        assert_eq!(
            id,
            self.channels.len(),
            "SpecSeed ids must be dense and ascending (got {id})"
        );
        let slot = match compressor.export_seed() {
            Some(s) => {
                let name = compressor.name();
                let pool = self.pool_index(&name);
                let boxes = &mut self.pools[pool as usize].1;
                if boxes.len() < self.cohort {
                    boxes.push(compressor);
                }
                // else: drop the box — `restore_seed` rebuilds this
                // client's state inside any pooled same-name instance.
                CompressorSlot::Seeded { pool, seed: s }
            }
            None => CompressorSlot::Resident(Some(compressor)),
        };
        self.comp.push(slot);
        self.shard.push(u32::try_from(shard).expect("shard exceeds u32"));
        self.samples.push(u32::try_from(samples).expect("samples exceed u32"));
        self.online.push(true);
        self.prev_loss.push(f64::NAN);
        self.last_delta.push(0.0);
        self.sync_states.push(SyncState::default());
        self.meters.push(meter);
        self.computes.push(compute);
        self.channels.push(Some(channels));
        self.churn_rng.push(churn_rng);
        self.res.push(ResRef::EMPTY);
    }

    fn pool_index(&mut self, name: &str) -> u16 {
        if let Some(i) = self.pools.iter().position(|(n, _)| n == name) {
            return i as u16;
        }
        self.pools.push((name.to_string(), Vec::new()));
        u16::try_from(self.pools.len() - 1).expect("more than 65k distinct compressor names")
    }

    pub fn len(&self) -> usize {
        self.channels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.channels.is_empty()
    }

    /// Target cohort size per round.
    pub fn cohort(&self) -> usize {
        self.cohort
    }

    /// Worker threads for the O(population) fading/churn sweeps. Purely a
    /// wall-clock knob: every client's RNG streams are private, so the
    /// result is bit-identical for any count (and small populations stay
    /// sequential regardless).
    pub fn set_sweep_threads(&mut self, threads: usize) {
        self.sweep_threads = threads.max(1);
    }

    pub fn shard(&self, id: usize) -> usize {
        self.shard[id] as usize
    }

    pub fn samples(&self, id: usize) -> usize {
        self.samples[id] as usize
    }

    pub fn online(&self, id: usize) -> bool {
        self.online[id]
    }

    pub fn within_budget(&self, id: usize) -> bool {
        self.meters[id].within_budget()
    }

    pub fn is_materialized(&self, id: usize) -> bool {
        self.channels[id].is_none()
    }

    /// The client's persisted resource meter (a stale copy while the client
    /// is materialized — the live meter travels with its `Device`).
    pub fn meter(&self, id: usize) -> &ResourceMeter {
        &self.meters[id]
    }

    /// The client's persisted downlink synchronization state.
    pub fn sync_state(&self, id: usize) -> SyncState {
        self.sync_states[id]
    }

    pub fn residual_is_empty(&self, id: usize) -> bool {
        self.res[id].kind == ResKind::Empty
    }

    /// Nonzero coordinates of the client's persisted residual.
    pub fn residual_nnz(&self, id: usize) -> usize {
        let r = self.res[id];
        match r.kind {
            ResKind::Empty => 0,
            ResKind::Sparse => r.len,
            ResKind::Dense => self.dense[r.off..r.off + r.len]
                .iter()
                .filter(|x| x.to_bits() != 0)
                .count(),
        }
    }

    /// Arena bytes of the client's persisted residual (same accounting as
    /// [`Residual::bytes`]).
    pub fn residual_bytes_of(&self, id: usize) -> usize {
        let r = self.res[id];
        match r.kind {
            ResKind::Empty => 0,
            ResKind::Sparse => r.len * 8,
            ResKind::Dense => r.len * 4,
        }
    }

    /// Can this client be sampled right now? Demobilized, within budget,
    /// and online.
    pub fn eligible(&self, id: usize) -> bool {
        self.channels[id].is_some() && self.online[id] && self.meters[id].within_budget()
    }

    /// Fill `out` with the ascending ids of all currently eligible clients
    /// — the allocation-free form samplers use every round (O(population)
    /// scan over the store's columns).
    pub fn eligible_into(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend((0..self.len()).filter(|&i| self.eligible(i)));
    }

    /// Ascending ids of all currently eligible clients.
    pub fn eligible_ids(&self) -> Vec<usize> {
        let mut out = Vec::new();
        self.eligible_into(&mut out);
        out
    }

    pub fn any_within_budget(&self) -> bool {
        self.meters.iter().any(|m| m.within_budget())
    }

    /// Could an ineligible population become eligible again without engine
    /// action? True while some in-budget client is online, or offline but
    /// able to churn back (`churn_up > 0`). The async cohort engine keeps
    /// its clock alive on this, so a transient everybody-offline moment
    /// pauses the pool instead of ending the run.
    pub fn may_become_eligible(&self) -> bool {
        self.meters
            .iter()
            .zip(&self.online)
            .any(|(m, &on)| m.within_budget() && (on || self.churn_up > 0.0))
    }

    /// Currently materialized client count.
    pub fn materialized(&self) -> usize {
        self.materialized
    }

    /// High-water mark of simultaneously materialized clients — the memory
    /// bound the cohort engines are proven against (≤ cohort at all times).
    pub fn peak_materialized(&self) -> usize {
        self.peak_materialized
    }

    /// Total live arena bytes of all persisted residuals (dead spans
    /// awaiting compaction excluded).
    pub fn residual_bytes(&self) -> usize {
        (0..self.len()).map(|i| self.residual_bytes_of(i)).sum()
    }

    /// Total boxed compressors resident in the store (per-name pools plus
    /// the resident lane) — the bound the seed-rehydration design is
    /// proven against: O(cohort × distinct names + opt-out clients), not
    /// O(population).
    pub fn pooled_boxes(&self) -> usize {
        let pooled: usize = self.pools.iter().map(|(_, b)| b.len()).sum();
        let resident = self
            .comp
            .iter()
            .filter(|c| matches!(c, CompressorSlot::Resident(Some(_))))
            .count();
        pooled + resident
    }

    /// Cumulative (energy, money) across every client's meter. Exact once
    /// all clients are demobilized (a materialized client's meter column is
    /// a stale copy — the live meter travels with its `Device`).
    pub fn meter_totals(&self) -> (f64, f64) {
        self.meters
            .iter()
            .fold((0.0, 0.0), |acc, m| (acc.0 + m.energy_used, acc.1 + m.money_used))
    }

    /// [`Population::meter_totals`] restricted to demobilized clients —
    /// async drivers add the live devices' meters on top.
    pub fn demobilized_meter_totals(&self) -> (f64, f64) {
        self.meters
            .iter()
            .zip(&self.channels)
            .filter(|(_, ch)| ch.is_some())
            .fold((0.0, 0.0), |acc, (m, _)| {
                (acc.0 + m.energy_used, acc.1 + m.money_used)
            })
    }

    /// Advance the population-wide dynamics by one round/tick: every
    /// demobilized client's fading chains (materialized clients' channels
    /// advance inside their live `Device`, exactly like the reference
    /// loop) and, when churn is enabled, every demobilized client's
    /// availability chain. With churn disabled the second sweep is skipped
    /// outright, and the fading sweep makes the exact same RNG draws as
    /// the fully-materialized loop's `channels.step_round()` sweep.
    ///
    /// Both sweeps are linear scans over the store's columns and run
    /// chunked across [`Population::set_sweep_threads`] workers for large
    /// populations. Splitting fading from churn (the legacy store
    /// interleaved them per client) and parallelizing are both invisible
    /// bitwise: every client's link RNGs and churn RNG are private
    /// streams, so per-client draw order is unchanged and no draw crosses
    /// clients.
    pub fn step_round(&mut self) {
        self.step_fading();
        if self.churn_down > 0.0 || self.churn_up > 0.0 {
            self.step_churn();
        }
    }

    fn step_fading(&mut self) {
        let n = self.channels.len();
        let threads = self.sweep_threads;
        if threads > 1 && n >= PAR_SWEEP_MIN {
            let chunk = n.div_ceil(threads);
            std::thread::scope(|s| {
                for part in self.channels.chunks_mut(chunk) {
                    s.spawn(move || {
                        for ch in part.iter_mut().flatten() {
                            ch.step_round();
                        }
                    });
                }
            });
        } else {
            for ch in self.channels.iter_mut().flatten() {
                ch.step_round();
            }
        }
    }

    fn step_churn(&mut self) {
        let n = self.online.len();
        let (down, up) = (self.churn_down, self.churn_up);
        let threads = self.sweep_threads;
        let run = |online: &mut [bool], rngs: &mut [Rng], chs: &[Option<DeviceChannels>]| {
            for i in 0..online.len() {
                if chs[i].is_none() {
                    continue; // materialized: the live Device owns the draw
                }
                if online[i] {
                    if rngs[i].uniform() < down {
                        online[i] = false;
                    }
                } else if rngs[i].uniform() < up {
                    online[i] = true;
                }
            }
        };
        if threads > 1 && n >= PAR_SWEEP_MIN {
            let chunk = n.div_ceil(threads);
            std::thread::scope(|s| {
                for ((online, rngs), chs) in self
                    .online
                    .chunks_mut(chunk)
                    .zip(self.churn_rng.chunks_mut(chunk))
                    .zip(self.channels.chunks(chunk))
                {
                    s.spawn(move || run(online, rngs, chs));
                }
            });
        } else {
            run(&mut self.online, &mut self.churn_rng, &self.channels);
        }
    }

    /// One Bernoulli draw from the client's churn stream: does the client
    /// drop offline while its upload is in flight? No draw (and `false`)
    /// when churn is disabled, so churn-free runs stay bit-identical to the
    /// reference loop.
    pub fn midround_offline(&mut self, id: usize) -> bool {
        if self.churn_down <= 0.0 {
            return false;
        }
        if self.churn_rng[id].uniform() < self.churn_down {
            self.online[id] = false;
            true
        } else {
            false
        }
    }

    /// Pop a recycled dense buffer (empty, capacity retained) or start a
    /// fresh one.
    fn take_buf(&mut self) -> Vec<f32> {
        self.f32_pool.pop().unwrap_or_default()
    }

    fn recycle_buf(&mut self, mut v: Vec<f32>) {
        if v.capacity() > 0 {
            v.clear();
            self.f32_pool.push(v);
        }
    }

    /// Wake a client up into a full [`Device`], synchronized to `global`:
    /// dense replicas filled from the recycled buffer pool, channel state
    /// moved in, a pooled compressor rehydrated from the client's seed (or
    /// its resident box moved in), the arena residual scattered into the
    /// error memory.
    pub fn materialize(&mut self, id: usize, global: &[f32]) -> Device {
        let dim = global.len();
        let channels = self.channels[id]
            .take()
            .unwrap_or_else(|| panic!("client {id} is already materialized"));
        let mut compressor = match &mut self.comp[id] {
            CompressorSlot::Seeded { pool, seed } => {
                let mut b = self.pools[*pool as usize].1.pop().unwrap_or_else(|| {
                    panic!(
                        "compressor pool underflow for client {id}: more than `cohort` \
                         clients materialized at once"
                    )
                });
                b.restore_seed(seed);
                b
            }
            CompressorSlot::Resident(slot) => slot
                .take()
                .unwrap_or_else(|| panic!("client {id} is already materialized")),
        };
        // Rehydrate the persisted residual from the arena; the client's
        // span dies here (it is re-encoded at demobilization).
        let r = std::mem::replace(&mut self.res[id], ResRef::EMPTY);
        match r.kind {
            ResKind::Empty => {
                // Pre-fill the error memory from the buffer pool (bitwise
                // equal to the lazy `ensure_dim` zeros, but recycled):
                // demobilization drained the box's memory vector, so
                // without this every Empty-residual materialization would
                // re-allocate a dense model inside the first compress.
                if let Some(ef) = compressor.error_memory_mut() {
                    let mut e = self.take_buf();
                    e.resize(dim, 0.0);
                    ef.set_memory(e);
                }
            }
            ResKind::Sparse => {
                let mut e = self.take_buf();
                e.resize(dim, 0.0);
                crate::kernels::scatter_set_pairs(&mut e, &self.sparse[r.off..r.off + r.len]);
                self.dead_sparse += r.len;
                let ef = compressor
                    .error_memory_mut()
                    .expect("residual persisted for a compressor without error memory");
                ef.set_memory(e);
            }
            ResKind::Dense => {
                assert_eq!(r.len, dim, "dense residual dim mismatch");
                let mut e = self.take_buf();
                e.extend_from_slice(&self.dense[r.off..r.off + r.len]);
                self.dead_dense += r.len;
                let ef = compressor
                    .error_memory_mut()
                    .expect("residual persisted for a compressor without error memory");
                ef.set_memory(e);
            }
        }
        let mut hat = self.take_buf();
        hat.extend_from_slice(global);
        let mut sync = self.take_buf();
        sync.extend_from_slice(global);
        let mut dev = Device::from_replicas(
            id,
            hat,
            sync,
            compressor,
            channels,
            self.meters[id].clone(),
            self.computes[id],
        );
        if let Some((scratch, progress)) = self.scratch_pool.pop() {
            dev.install_scratch(scratch, progress);
        }
        dev.prev_loss = self.prev_loss[id];
        dev.last_delta = self.last_delta[id];
        dev.sync_state = self.sync_states[id];
        self.materialized += 1;
        self.peak_materialized = self.peak_materialized.max(self.materialized);
        dev
    }

    /// Put a client back to rest: persist meter/loss state to the columns,
    /// drain the error memory into the residual arena, export the
    /// compressor's seed back to its pool (or park the resident box), and
    /// recycle every O(model) buffer.
    ///
    /// `compressed_since_sync`: whether the compressor ran after the
    /// device's last `sync`. If it did, the round's net progress already
    /// lives in `delivered layers + error memory` and must NOT be folded
    /// again; if it did not (all-silent plan), the pending progress
    /// `w_sync − ŵ` is folded into the error memory so it survives
    /// demobilization. (A compressor without error memory genuinely drops
    /// pending progress — the dense baselines' documented behavior, same as
    /// their lossy-upload path.)
    ///
    /// Note the fold is mass-preserving but not bit-identical to the
    /// fully-materialized loop for silent rounds: a permanent device keeps
    /// training from its drifted `ŵ`, while a demobilized client
    /// rematerializes at the current global with the delta parked here —
    /// the one documented divergence of the cohort engines (built-in
    /// policies never emit silent plans, so the `FullParticipation` oracle
    /// is unaffected).
    pub fn demobilize(&mut self, parts: DeviceParts, compressed_since_sync: bool) {
        let DeviceParts {
            id,
            params_hat,
            params_sync,
            mut compressor,
            channels,
            meter,
            prev_loss,
            last_delta,
            sync_state,
            scratch,
            progress_buf,
        } = parts;
        if !compressed_since_sync {
            let pending = params_sync
                .iter()
                .zip(&params_hat)
                .any(|(a, b)| a.to_bits() != b.to_bits());
            if pending {
                if let Some(ef) = compressor.error_memory_mut() {
                    ef.ensure_dim(params_hat.len());
                    // Deliberately NOT the dense kernel: the `d != 0.0`
                    // skip keeps an existing −0.0 in the error memory from
                    // being flushed to +0.0 by `e += +0.0` — the Residual
                    // nnz/bytes accounting and the bitwise demobilize
                    // round-trip test depend on the sign bit surviving.
                    for (i, (&w, &wh)) in params_sync.iter().zip(&params_hat).enumerate() {
                        let d = w - wh;
                        if d != 0.0 {
                            ef.restitute(i, d);
                        }
                    }
                }
            }
        }
        // Drain the error memory into the arena (the [`Residual`] encoding
        // rule, without a per-client Vec) and recycle its dense vector.
        debug_assert!(matches!(self.res[id].kind, ResKind::Empty), "span leaked");
        if let Some(ef) = compressor.error_memory_mut() {
            let e = ef.take_memory();
            let nnz = e.iter().filter(|v| v.to_bits() != 0).count();
            self.res[id] = if nnz == 0 {
                ResRef::EMPTY
            } else if nnz * 2 > e.len() {
                let off = self.dense.len();
                self.dense.extend_from_slice(&e);
                ResRef { kind: ResKind::Dense, off, len: e.len() }
            } else {
                let off = self.sparse.len();
                self.sparse.extend(
                    e.iter()
                        .enumerate()
                        .filter(|(_, v)| v.to_bits() != 0)
                        .map(|(i, &v)| (i as u32, v)),
                );
                ResRef { kind: ResKind::Sparse, off, len: nnz }
            };
            self.recycle_buf(e);
        } else {
            self.res[id] = ResRef::EMPTY;
        }
        // Route the compressor home. Pooled boxes keep their working
        // memory (the pool holds at most `cohort` boxes per name, so the
        // retained capacity is O(cohort × model), the same order as the
        // live cohort); resident boxes are per-client — O(population) —
        // and must trim to O(1).
        match &mut self.comp[id] {
            CompressorSlot::Seeded { pool, seed } => {
                *seed = compressor
                    .export_seed()
                    .expect("seeded compressor stopped exporting a seed");
                self.pools[*pool as usize].1.push(compressor);
            }
            CompressorSlot::Resident(slot) => {
                compressor.trim_working_memory();
                debug_assert!(slot.is_none(), "demobilizing a client twice");
                *slot = Some(compressor);
            }
        }
        debug_assert!(self.channels[id].is_none(), "demobilizing a client twice");
        self.channels[id] = Some(channels);
        self.meters[id] = meter;
        self.prev_loss[id] = prev_loss;
        self.last_delta[id] = last_delta;
        self.sync_states[id] = sync_state;
        self.recycle_buf(params_hat);
        self.recycle_buf(params_sync);
        if self.scratch_pool.len() < self.cohort {
            self.scratch_pool.push((scratch, progress_buf));
        }
        self.materialized -= 1;
        // Amortized arena compaction: once dead spans outweigh live ones,
        // ping-pong the pool into its spare buffer (retained capacity —
        // no steady-state allocation) and rewrite the live offsets.
        if self.dead_sparse * 2 > self.sparse.len() && self.dead_sparse > 0 {
            self.compact_sparse();
        }
        if self.dead_dense * 2 > self.dense.len() && self.dead_dense > 0 {
            self.compact_dense();
        }
    }

    fn compact_sparse(&mut self) {
        let mut out = std::mem::take(&mut self.sparse_spare);
        out.clear();
        for r in self.res.iter_mut() {
            if r.kind == ResKind::Sparse {
                let new_off = out.len();
                out.extend_from_slice(&self.sparse[r.off..r.off + r.len]);
                r.off = new_off;
            }
        }
        self.sparse_spare = std::mem::replace(&mut self.sparse, out);
        self.dead_sparse = 0;
    }

    fn compact_dense(&mut self) {
        let mut out = std::mem::take(&mut self.dense_spare);
        out.clear();
        for r in self.res.iter_mut() {
            if r.kind == ResKind::Dense {
                let new_off = out.len();
                out.extend_from_slice(&self.dense[r.off..r.off + r.len]);
                r.off = new_off;
            }
        }
        self.dense_spare = std::mem::replace(&mut self.dense, out);
        self.dead_dense = 0;
    }

    /// Fresh FL episode: meters, residuals, compressor episode state and
    /// availability restart; channel fading chains keep their streams (like
    /// the fully-materialized `reset_episode`). Seeds rewind via
    /// [`CompressorSeed::reset`]; pooled boxes need no touch-up — the next
    /// materialization's `restore_seed` overwrites any stream state, and
    /// their error memories were drained at demobilization.
    pub fn reset_episode(&mut self, energy_budget: f64, money_budget: f64) {
        assert_eq!(self.materialized, 0, "reset_episode with clients in flight");
        self.sparse.clear();
        self.dense.clear();
        self.dead_sparse = 0;
        self.dead_dense = 0;
        for r in &mut self.res {
            *r = ResRef::EMPTY;
        }
        for slot in &mut self.comp {
            match slot {
                CompressorSlot::Seeded { seed, .. } => seed.reset(),
                CompressorSlot::Resident(Some(c)) => c.reset(),
                CompressorSlot::Resident(None) => unreachable!("materialized == 0"),
            }
        }
        for m in &mut self.meters {
            *m = ResourceMeter::new(energy_budget, money_budget);
        }
        for x in &mut self.prev_loss {
            *x = f64::NAN;
        }
        for x in &mut self.last_delta {
            *x = 0.0;
        }
        for s in &mut self.sync_states {
            *s = SyncState::default();
        }
        for o in &mut self.online {
            *o = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ChannelType;
    use crate::compression::{ErrorCompensated, LgcTopAB, Qsgd};

    fn seed(id: usize, seed: u64) -> SpecSeed {
        let rng = Rng::new(seed);
        SpecSeed::new(
            id,
            DeviceChannels::new(&[ChannelType::G5, ChannelType::G3], &rng, id),
            Box::new(ErrorCompensated::new(LgcTopAB)),
            rng.fork(0xC0FFEE ^ id as u64),
        )
        .shard(id % 2)
        .samples(100 + id)
        .meter(ResourceMeter::new(f64::INFINITY, f64::INFINITY))
        .compute(ComputeCostModel::for_params(1000))
    }

    fn pop(n: usize, cohort: usize) -> Population {
        Population::new((0..n).map(|i| seed(i, 7)), cohort, 0.0, 0.0)
    }

    #[test]
    fn materialize_demobilize_roundtrip_preserves_residual_bitwise() {
        let mut p = pop(4, 2);
        let global = vec![0.25f32; 64];
        let mut dev = p.materialize(1, &global);
        assert_eq!(p.materialized(), 1);
        // Make some local progress, then compress so the error memory fills.
        for (i, x) in dev.params_hat.iter_mut().enumerate() {
            *x += (i as f32 + 1.0) * 1e-3;
        }
        let plan = crate::channels::AllocationPlan { counts: vec![4, 4] };
        let (_, _, _) = dev.compress_and_upload(&plan);
        dev.sync(&global);
        let mem_before = dev.error_memory().unwrap().memory().to_vec();
        assert!(mem_before.iter().any(|&x| x != 0.0));
        p.demobilize(dev.into_parts(), true);
        assert_eq!(p.materialized(), 0);
        assert!(!p.residual_is_empty(1));
        // Rematerialize: the error memory must come back bit-for-bit.
        let dev2 = p.materialize(1, &global);
        let mem_after = dev2.error_memory().unwrap().memory().to_vec();
        assert_eq!(mem_before.len(), mem_after.len());
        for (a, b) in mem_before.iter().zip(&mem_after) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        p.demobilize(dev2.into_parts(), true);
    }

    #[test]
    fn pending_progress_folds_into_residual_when_not_compressed() {
        let mut p = pop(3, 1);
        let global = vec![1.0f32; 32];
        let mut dev = p.materialize(0, &global);
        // Local progress without any compress call (silent round).
        for x in dev.params_hat.iter_mut() {
            *x -= 0.125;
        }
        p.demobilize(dev.into_parts(), false);
        assert_eq!(p.residual_nnz(0), 32, "all 32 coordinates moved");
        // u = w_sync − ŵ = +0.125 per coordinate.
        let dev2 = p.materialize(0, &global);
        let mem = dev2.error_memory().unwrap().memory().to_vec();
        assert!(mem.iter().all(|&x| (x - 0.125).abs() < 1e-7));
        p.demobilize(dev2.into_parts(), true);
    }

    #[test]
    fn peak_materialized_tracks_high_water() {
        let mut p = pop(5, 3);
        let g = vec![0f32; 8];
        let a = p.materialize(0, &g);
        let b = p.materialize(3, &g);
        assert_eq!(p.peak_materialized(), 2);
        p.demobilize(a.into_parts(), true);
        let c = p.materialize(4, &g);
        assert_eq!(p.materialized(), 2);
        assert_eq!(p.peak_materialized(), 2);
        p.demobilize(b.into_parts(), true);
        p.demobilize(c.into_parts(), true);
        assert_eq!(p.materialized(), 0);
    }

    #[test]
    fn sync_state_persists_through_demobilize() {
        let mut p = pop(3, 1);
        let global = vec![0f32; 16];
        let mut dev = p.materialize(2, &global);
        assert_eq!(dev.sync_state, SyncState::default());
        dev.sync_state = SyncState {
            synced_version: 9,
            synced_round: 4,
            pending_layers: 1,
            staleness: 3,
        };
        p.demobilize(dev.into_parts(), true);
        assert_eq!(p.sync_state(2).synced_version, 9);
        let dev2 = p.materialize(2, &global);
        assert_eq!(
            dev2.sync_state,
            SyncState { synced_version: 9, synced_round: 4, pending_layers: 1, staleness: 3 }
        );
        p.demobilize(dev2.into_parts(), true);
        // reset_episode clears it.
        p.reset_episode(f64::INFINITY, f64::INFINITY);
        assert_eq!(p.sync_state(2), SyncState::default());
    }

    #[test]
    fn churn_chain_moves_clients_on_and_off() {
        let seeds = (0..50).map(|i| seed(i, 11));
        let mut p = Population::new(seeds, 10, 0.4, 0.5);
        let mut saw_offline = false;
        let mut saw_back_online = false;
        let mut was_offline = vec![false; 50];
        for _ in 0..40 {
            p.step_round();
            for i in 0..50 {
                if !p.online(i) {
                    saw_offline = true;
                    was_offline[i] = true;
                } else if was_offline[i] {
                    saw_back_online = true;
                }
            }
        }
        assert!(saw_offline && saw_back_online);
    }

    #[test]
    fn residual_compact_forms_roundtrip() {
        let mut ef = ErrorFeedback::new(10);
        // Mostly-zero memory -> sparse.
        ef.restitute(3, 1.5);
        ef.restitute(7, -2.0);
        let r = Residual::drain_from(&mut ef);
        assert!(matches!(r, Residual::Sparse(_)));
        assert_eq!(r.nnz(), 2);
        assert_eq!(r.bytes(), 16);
        let mut ef2 = ErrorFeedback::new(0);
        r.restore_into(&mut ef2, 10);
        assert_eq!(ef2.memory()[3], 1.5);
        assert_eq!(ef2.memory()[7], -2.0);
        // Mostly-nonzero -> dense.
        let mut ef3 = ErrorFeedback::new(10);
        for i in 0..9 {
            ef3.restitute(i, i as f32 + 1.0);
        }
        let r = Residual::drain_from(&mut ef3);
        assert!(matches!(r, Residual::Dense(_)));
        assert_eq!(r.bytes(), 40);
        // Empty stays empty.
        let mut ef4 = ErrorFeedback::new(10);
        assert!(Residual::drain_from(&mut ef4).is_empty());
    }

    #[test]
    fn compressor_boxes_bounded_by_cohort_not_population() {
        // 100 seeded (ErrorCompensated<LgcTopAB>) clients share one pool of
        // at most `cohort` boxes.
        let p = pop(100, 4);
        assert!(p.pooled_boxes() <= 4, "pooled {}", p.pooled_boxes());
        // RandK opts out of seeding (history-dependent permutation) and
        // stays resident per client.
        let rk = Population::new(
            (0..10).map(|i| {
                let rng = Rng::new(3);
                SpecSeed::new(
                    i,
                    DeviceChannels::new(&[ChannelType::G5], &rng, i),
                    Box::new(crate::compression::RandK::new(rng.fork(i as u64), false)),
                    rng.fork(0xC0FFEE ^ i as u64),
                )
            }),
            2,
            0.0,
            0.0,
        );
        assert_eq!(rk.pooled_boxes(), 10);
    }

    #[test]
    fn qsgd_seed_rehydration_is_bitwise() {
        // Two clients share one pooled Qsgd box (cohort 1); their private
        // quantization streams must interleave exactly as if each kept its
        // own box: advance A, advance B, then A again — A's second draw
        // must continue A's stream, not B's.
        let mk = |n: usize, cohort: usize| {
            Population::new(
                (0..n).map(|i| {
                    let rng = Rng::new(21);
                    SpecSeed::new(
                        i,
                        DeviceChannels::new(&[ChannelType::G5], &rng, i),
                        Box::new(Qsgd::new(crate::compression::quantize::QsgdQuantizer::new(
                            4,
                            rng.fork(0x0561D ^ ((i as u64) << 8)),
                        ))),
                        rng.fork(0xC0FFEE ^ i as u64),
                    )
                }),
                cohort,
                0.0,
                0.0,
            )
        };
        let global = vec![0f32; 64];
        let u: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) * 1e-2).collect();
        let plan = crate::channels::AllocationPlan { counts: vec![64] };
        let round = |p: &mut Population, id: usize| {
            let mut d = p.materialize(id, &global);
            for (x, &v) in d.params_hat.iter_mut().zip(&u) {
                *x -= v;
            }
            let (up, _, _) = d.compress_and_upload(&plan);
            d.sync(&global);
            p.demobilize(d.into_parts(), true);
            up.decode()
        };
        // Pooled (cohort 1 — both clients share a single box) vs. a fresh
        // population where each client got its own box (cohort 2 keeps
        // both boxes pooled, but the first two materializations pop
        // distinct boxes).
        let mut pooled = mk(2, 1);
        let a1 = round(&mut pooled, 0);
        let b1 = round(&mut pooled, 1);
        let a2 = round(&mut pooled, 0);
        let mut fresh = mk(2, 2);
        let fa1 = round(&mut fresh, 0);
        let fb1 = round(&mut fresh, 1);
        let fa2 = round(&mut fresh, 0);
        for (x, y) in [(a1, fa1), (b1, fb1), (a2, fa2)] {
            assert_eq!(x.len(), y.len());
            for (a, b) in x.iter().zip(&y) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn residual_arena_compacts_and_roundtrips_under_churn() {
        let mut p = pop(6, 3);
        let global = vec![0.5f32; 48];
        let plan = crate::channels::AllocationPlan { counts: vec![3, 3] };
        let mut expected: Vec<Option<Vec<f32>>> = vec![None; 6];
        for cycle in 0..8 {
            for id in 0..3 {
                let client = (cycle + id * 2) % 6;
                let mut dev = p.materialize(client, &global);
                if let Some(mem) = &expected[client] {
                    let got = dev.error_memory().unwrap().memory();
                    assert_eq!(got.len(), mem.len(), "client {client} cycle {cycle}");
                    for (a, b) in got.iter().zip(mem) {
                        assert_eq!(a.to_bits(), b.to_bits(), "client {client} cycle {cycle}");
                    }
                }
                for (i, x) in dev.params_hat.iter_mut().enumerate() {
                    *x += ((i + client + cycle) as f32 + 1.0) * 1e-3;
                }
                let _ = dev.compress_and_upload(&plan);
                dev.sync(&global);
                expected[client] = Some(dev.error_memory().unwrap().memory().to_vec());
                p.demobilize(dev.into_parts(), true);
            }
        }
        // Live accounting matches the per-client view after compactions.
        let total: usize = (0..6).map(|i| p.residual_bytes_of(i)).sum();
        assert_eq!(p.residual_bytes(), total);
    }

    #[test]
    fn steady_state_buffers_are_recycled() {
        // After one warmup cycle the store's free lists feed every
        // materialization: replicas, error memory, and scratch all come
        // from the pools, so the pool sizes reach a fixed point.
        let mut p = pop(4, 2);
        let global = vec![0.1f32; 32];
        let plan = crate::channels::AllocationPlan { counts: vec![2, 2] };
        let mut cycle = |p: &mut Population| {
            for id in 0..2 {
                let mut dev = p.materialize(id, &global);
                for x in dev.params_hat.iter_mut() {
                    *x += 1e-3;
                }
                let _ = dev.compress_and_upload(&plan);
                dev.sync(&global);
                p.demobilize(dev.into_parts(), true);
            }
        };
        cycle(&mut p);
        let bufs = p.f32_pool.len();
        let scratch = p.scratch_pool.len();
        for _ in 0..5 {
            cycle(&mut p);
            assert_eq!(p.f32_pool.len(), bufs);
            assert_eq!(p.scratch_pool.len(), scratch);
        }
    }
}
