//! Population-scale client virtualization: lazy device cohorts over a store
//! of cheap per-client records.
//!
//! The paper evaluates LGC on a handful of always-on edge devices, where
//! every [`Device`](crate::coordinator::Device) permanently owns two dense
//! model replicas plus compressor error-feedback memory — O(population ×
//! model_dim) resident state. Real cross-device FL runs a small sampled
//! cohort per round over a vast, churning population (cf. "To Talk or to
//! Work", arXiv:2012.11804). This module makes population size a free
//! parameter:
//!
//! - [`DeviceSpec`] is the *demobilized* form of a client: seeded channel
//!   state (the fading chains keep advancing while unsampled), compute
//!   profile, resource meter, data-shard id, the compressor box (cross-round
//!   RNG streams) and a **compact persisted error-feedback [`Residual`]** —
//!   everything O(1) in the model dimension except the residual, which is
//!   empty until the client first participates and never larger than one
//!   dense model.
//! - [`Population`] holds one spec per client and **materializes** a full
//!   `Device` (dense `params_hat`/`params_sync` replicas, working buffers)
//!   only when that client is sampled into the round's cohort, demobilizing
//!   it back to a spec afterwards. Resident memory is O(model + cohort), not
//!   O(population × model); `peak_materialized` proves the bound.
//! - [`ClientSampler`] ([`sampler`]) is the pluggable cohort-selection seam:
//!   [`FullParticipation`] reproduces the fully-materialized reference loop
//!   bit for bit (proven against the frozen `Experiment::step_round` oracle
//!   in `tests/population.rs`), [`UniformK`] / [`WeightedBySamples`] are the
//!   classic partial-participation rules, and [`AvailabilityMarkov`] samples
//!   only clients whose per-client on/off churn chain (stepped here, in the
//!   population) says they are online. A client that churns offline
//!   mid-upload feeds the existing lost-layer restitution path — its shipped
//!   coordinates return to the error memory, so gradient mass is delayed,
//!   never destroyed.
//!
//! Demobilization contract: when a client leaves the cohort, its error
//! memory is drained into the spec's [`Residual`] and its O(model) working
//! buffers are released ([`crate::compression::Compressor::trim_working_memory`]).
//! If the round ended *without* the compressor running (an all-silent plan),
//! the pending local progress `w_sync − ŵ` is folded into the error memory
//! first so nothing is lost; if the compressor *did* run, the progress
//! already lives in `delivered layers + error memory` and folding would
//! double-count — the `compressed_since_sync` flag keeps the two cases
//! straight. See DESIGN.md §"Population, sampling & streaming aggregation".

pub mod sampler;

pub use sampler::{
    build_sampler, AvailabilityMarkov, ClientSampler, FullParticipation, SamplerKind, UniformK,
    WeightedBySamples,
};

use crate::channels::DeviceChannels;
use crate::compression::{Compressor, ErrorFeedback};
use crate::coordinator::device::{Device, DeviceParts};
use crate::downlink::SyncState;
use crate::resources::{ComputeCostModel, ResourceMeter};
use crate::util::Rng;

/// Compact persisted error-feedback residual of a demobilized client.
///
/// Encoding picks the smaller of two forms at export time: sparse
/// `(index, value)` pairs (8 B/nonzero) while at most half the coordinates
/// are nonzero, plain dense `f32` (4 B/coordinate) beyond that — so the
/// persisted state never exceeds one dense model and is empty for clients
/// that have not participated yet. Export/restore is bitwise lossless
/// (signed zeros included).
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Residual {
    /// No dropped mass carried (client never compressed, or compressed
    /// losslessly).
    #[default]
    Empty,
    /// `(coordinate, value)` pairs, ascending, values all nonzero bits.
    Sparse(Vec<(u32, f32)>),
    /// Dense residual (cheaper than pairs once more than half the
    /// coordinates are nonzero — the common case for top-K error feedback).
    Dense(Vec<f32>),
}

impl Residual {
    /// Drain `ef` into its compact form, releasing the dense memory.
    pub fn drain_from(ef: &mut ErrorFeedback) -> Residual {
        let e = ef.take_memory();
        let nnz = e.iter().filter(|v| v.to_bits() != 0).count();
        if nnz == 0 {
            return Residual::Empty;
        }
        if nnz * 2 > e.len() {
            return Residual::Dense(e);
        }
        Residual::Sparse(
            e.iter()
                .enumerate()
                .filter(|(_, v)| v.to_bits() != 0)
                .map(|(i, &v)| (i as u32, v))
                .collect(),
        )
    }

    /// Rebuild the dense memory inside `ef` (consumes the residual).
    pub fn restore_into(self, ef: &mut ErrorFeedback, dim: usize) {
        match self {
            Residual::Empty => {}
            Residual::Sparse(pairs) => {
                let mut e = vec![0.0f32; dim];
                for (i, v) in pairs {
                    e[i as usize] = v;
                }
                ef.set_memory(e);
            }
            Residual::Dense(e) => {
                assert_eq!(e.len(), dim, "dense residual dim mismatch");
                ef.set_memory(e);
            }
        }
    }

    pub fn is_empty(&self) -> bool {
        matches!(self, Residual::Empty)
    }

    /// Nonzero coordinates carried.
    pub fn nnz(&self) -> usize {
        match self {
            Residual::Empty => 0,
            Residual::Sparse(v) => v.len(),
            Residual::Dense(v) => v.iter().filter(|x| x.to_bits() != 0).count(),
        }
    }

    /// Approximate heap bytes of the persisted form.
    pub fn bytes(&self) -> usize {
        match self {
            Residual::Empty => 0,
            Residual::Sparse(v) => v.len() * 8,
            Residual::Dense(v) => v.len() * 4,
        }
    }
}

/// The demobilized form of one client: everything that must persist across
/// sampling epochs, and nothing that scales with the model dimension except
/// the [`Residual`].
pub struct DeviceSpec {
    pub id: usize,
    /// Trainer data shard this client draws batches from (population mode
    /// maps many clients onto `cfg.devices` shards, `id % cfg.devices`).
    pub shard: usize,
    /// Local sample count n_m of the shard (weighted sampling/aggregation).
    pub samples: usize,
    /// Multi-channel uplink state — `None` while the client is materialized
    /// (the channels move into the live `Device` and back).
    pub channels: Option<DeviceChannels>,
    pub meter: ResourceMeter,
    pub compute: ComputeCostModel,
    /// The compressor box (cross-round RNG streams persist; the error
    /// memory is drained into `residual` while demobilized) — `None` while
    /// materialized.
    pub compressor: Option<Box<dyn Compressor>>,
    /// Compact persisted error-feedback residual.
    pub residual: Residual,
    /// Training-loss of the client's previous round (DRL δ state).
    pub prev_loss: f64,
    pub last_delta: f64,
    /// Downlink synchronization state — persists across demobilization so
    /// a resampled client remembers its last confirmed sync and staleness
    /// gap (inert zeros when the downlink is disabled).
    pub sync_state: SyncState,
    /// Availability churn chain state (AvailabilityMarkov sampling).
    pub online: bool,
    /// Private RNG stream of the churn chain.
    churn_rng: Rng,
}

impl DeviceSpec {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        shard: usize,
        samples: usize,
        channels: DeviceChannels,
        meter: ResourceMeter,
        compute: ComputeCostModel,
        compressor: Box<dyn Compressor>,
        churn_rng: Rng,
    ) -> Self {
        DeviceSpec {
            id,
            shard,
            samples,
            channels: Some(channels),
            meter,
            compute,
            compressor: Some(compressor),
            residual: Residual::Empty,
            prev_loss: f64::NAN,
            last_delta: 0.0,
            sync_state: SyncState::default(),
            online: true,
            churn_rng,
        }
    }
}

/// The client store: one [`DeviceSpec`] per client, with materialization
/// bookkeeping and the population-wide dynamics (channel fading for every
/// client, availability churn).
pub struct Population {
    specs: Vec<DeviceSpec>,
    cohort: usize,
    /// Per-tick probability that an online client drops offline (0 = no
    /// churn; also gates the mid-upload dropout draw).
    churn_down: f64,
    /// Per-tick probability that an offline client comes back.
    churn_up: f64,
    materialized: usize,
    peak_materialized: usize,
}

impl Population {
    pub fn new(specs: Vec<DeviceSpec>, cohort: usize, churn_down: f64, churn_up: f64) -> Self {
        assert!(!specs.is_empty(), "population needs at least one client");
        assert!(
            cohort >= 1 && cohort <= specs.len(),
            "cohort {cohort} out of range for population {}",
            specs.len()
        );
        assert!((0.0..=1.0).contains(&churn_down) && (0.0..=1.0).contains(&churn_up));
        Population {
            specs,
            cohort,
            churn_down,
            churn_up,
            materialized: 0,
            peak_materialized: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    /// Target cohort size per round.
    pub fn cohort(&self) -> usize {
        self.cohort
    }

    pub fn spec(&self, id: usize) -> &DeviceSpec {
        &self.specs[id]
    }

    pub fn shard(&self, id: usize) -> usize {
        self.specs[id].shard
    }

    pub fn samples(&self, id: usize) -> usize {
        self.specs[id].samples
    }

    pub fn online(&self, id: usize) -> bool {
        self.specs[id].online
    }

    pub fn within_budget(&self, id: usize) -> bool {
        self.specs[id].meter.within_budget()
    }

    pub fn is_materialized(&self, id: usize) -> bool {
        self.specs[id].channels.is_none()
    }

    /// Can this client be sampled right now? Demobilized, within budget,
    /// and online.
    pub fn eligible(&self, id: usize) -> bool {
        let s = &self.specs[id];
        s.channels.is_some() && s.online && s.meter.within_budget()
    }

    /// Ascending ids of all currently eligible clients (O(population) scan —
    /// the per-round cost sampling is allowed to pay; specs are cheap).
    pub fn eligible_ids(&self) -> Vec<usize> {
        (0..self.specs.len()).filter(|&i| self.eligible(i)).collect()
    }

    pub fn any_within_budget(&self) -> bool {
        self.specs.iter().any(|s| s.meter.within_budget())
    }

    /// Could an ineligible population become eligible again without engine
    /// action? True while some in-budget client is online, or offline but
    /// able to churn back (`churn_up > 0`). The async cohort engine keeps
    /// its clock alive on this, so a transient everybody-offline moment
    /// pauses the pool instead of ending the run.
    pub fn may_become_eligible(&self) -> bool {
        self.specs
            .iter()
            .any(|s| s.meter.within_budget() && (s.online || self.churn_up > 0.0))
    }

    /// Currently materialized client count.
    pub fn materialized(&self) -> usize {
        self.materialized
    }

    /// High-water mark of simultaneously materialized clients — the memory
    /// bound the cohort engines are proven against (≤ cohort at all times).
    pub fn peak_materialized(&self) -> usize {
        self.peak_materialized
    }

    /// Total heap bytes of all persisted residuals.
    pub fn residual_bytes(&self) -> usize {
        self.specs.iter().map(|s| s.residual.bytes()).sum()
    }

    /// Cumulative (energy, money) across every client's meter. Exact once
    /// all clients are demobilized (a materialized client's spec meter is a
    /// stale copy — the live meter travels with its `Device`).
    pub fn meter_totals(&self) -> (f64, f64) {
        self.specs.iter().fold((0.0, 0.0), |acc, s| {
            (acc.0 + s.meter.energy_used, acc.1 + s.meter.money_used)
        })
    }

    /// [`Population::meter_totals`] restricted to demobilized clients —
    /// async drivers add the live devices' meters on top.
    pub fn demobilized_meter_totals(&self) -> (f64, f64) {
        self.specs
            .iter()
            .filter(|s| s.channels.is_some())
            .fold((0.0, 0.0), |acc, s| {
                (acc.0 + s.meter.energy_used, acc.1 + s.meter.money_used)
            })
    }

    /// Advance the population-wide dynamics by one round/tick: every
    /// demobilized client's fading chains (materialized clients' channels
    /// advance inside their live `Device`, exactly like the reference loop)
    /// and, when churn is enabled, every demobilized client's availability
    /// chain. With churn disabled this makes the exact same RNG draws as
    /// the fully-materialized loop's `channels.step_round()` sweep.
    pub fn step_round(&mut self) {
        let (down, up) = (self.churn_down, self.churn_up);
        let churn = down > 0.0 || up > 0.0;
        for spec in &mut self.specs {
            if let Some(ch) = &mut spec.channels {
                ch.step_round();
            } else {
                continue; // materialized: the live Device owns the dynamics
            }
            if churn {
                if spec.online {
                    if spec.churn_rng.uniform() < down {
                        spec.online = false;
                    }
                } else if spec.churn_rng.uniform() < up {
                    spec.online = true;
                }
            }
        }
    }

    /// One Bernoulli draw from the client's churn stream: does the client
    /// drop offline while its upload is in flight? No draw (and `false`)
    /// when churn is disabled, so churn-free runs stay bit-identical to the
    /// reference loop.
    pub fn midround_offline(&mut self, id: usize) -> bool {
        if self.churn_down <= 0.0 {
            return false;
        }
        let spec = &mut self.specs[id];
        if spec.churn_rng.uniform() < self.churn_down {
            spec.online = false;
            true
        } else {
            false
        }
    }

    /// Wake a client up into a full [`Device`], synchronized to `global`:
    /// dense replicas allocated now, channel/compressor state moved in, the
    /// persisted residual rehydrated into the error memory.
    pub fn materialize(&mut self, id: usize, global: &[f32]) -> Device {
        let spec = &mut self.specs[id];
        let channels = spec
            .channels
            .take()
            .unwrap_or_else(|| panic!("client {id} is already materialized"));
        let mut compressor = spec
            .compressor
            .take()
            .unwrap_or_else(|| panic!("client {id} is already materialized"));
        let residual = std::mem::take(&mut spec.residual);
        if !residual.is_empty() {
            let ef = compressor
                .error_memory_mut()
                .expect("residual persisted for a compressor without error memory");
            residual.restore_into(ef, global.len());
        }
        let mut dev = Device::new(
            id,
            global.to_vec(),
            compressor,
            channels,
            spec.meter.clone(),
            spec.compute,
        );
        dev.prev_loss = spec.prev_loss;
        dev.last_delta = spec.last_delta;
        dev.sync_state = spec.sync_state;
        self.materialized += 1;
        self.peak_materialized = self.peak_materialized.max(self.materialized);
        dev
    }

    /// Put a client back to rest: persist meter/loss state, drain the error
    /// memory into the compact residual, release O(model) buffers, drop the
    /// dense replicas (they go out of scope with `parts`).
    ///
    /// `compressed_since_sync`: whether the compressor ran after the
    /// device's last `sync`. If it did, the round's net progress already
    /// lives in `delivered layers + error memory` and must NOT be folded
    /// again; if it did not (all-silent plan), the pending progress
    /// `w_sync − ŵ` is folded into the error memory so it survives
    /// demobilization. (A compressor without error memory genuinely drops
    /// pending progress — the dense baselines' documented behavior, same as
    /// their lossy-upload path.)
    ///
    /// Note the fold is mass-preserving but not bit-identical to the
    /// fully-materialized loop for silent rounds: a permanent device keeps
    /// training from its drifted `ŵ`, while a demobilized client
    /// rematerializes at the current global with the delta parked here —
    /// the one documented divergence of the cohort engines (built-in
    /// policies never emit silent plans, so the `FullParticipation` oracle
    /// is unaffected).
    pub fn demobilize(&mut self, parts: DeviceParts, compressed_since_sync: bool) {
        let DeviceParts {
            id,
            params_hat,
            params_sync,
            mut compressor,
            channels,
            meter,
            prev_loss,
            last_delta,
            sync_state,
        } = parts;
        if !compressed_since_sync {
            let pending = params_sync
                .iter()
                .zip(&params_hat)
                .any(|(a, b)| a.to_bits() != b.to_bits());
            if pending {
                if let Some(ef) = compressor.error_memory_mut() {
                    ef.ensure_dim(params_hat.len());
                    for (i, (&w, &wh)) in params_sync.iter().zip(&params_hat).enumerate() {
                        let d = w - wh;
                        if d != 0.0 {
                            ef.restitute(i, d);
                        }
                    }
                }
            }
        }
        let residual = compressor
            .error_memory_mut()
            .map(Residual::drain_from)
            .unwrap_or(Residual::Empty);
        compressor.trim_working_memory();
        let spec = &mut self.specs[id];
        debug_assert!(spec.channels.is_none(), "demobilizing a client twice");
        spec.residual = residual;
        spec.compressor = Some(compressor);
        spec.channels = Some(channels);
        spec.meter = meter;
        spec.prev_loss = prev_loss;
        spec.last_delta = last_delta;
        spec.sync_state = sync_state;
        self.materialized -= 1;
    }

    /// Fresh FL episode: meters, residuals, compressor episode state and
    /// availability restart; channel fading chains keep their streams (like
    /// the fully-materialized `reset_episode`).
    pub fn reset_episode(&mut self, energy_budget: f64, money_budget: f64) {
        assert_eq!(self.materialized, 0, "reset_episode with clients in flight");
        for spec in &mut self.specs {
            spec.residual = Residual::Empty;
            if let Some(c) = spec.compressor.as_mut() {
                c.reset();
            }
            spec.meter = ResourceMeter::new(energy_budget, money_budget);
            spec.prev_loss = f64::NAN;
            spec.last_delta = 0.0;
            spec.sync_state = SyncState::default();
            spec.online = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ChannelType;
    use crate::compression::{ErrorCompensated, LgcTopAB};

    fn spec(id: usize, seed: u64) -> DeviceSpec {
        let rng = Rng::new(seed);
        DeviceSpec::new(
            id,
            id % 2,
            100 + id,
            DeviceChannels::new(&[ChannelType::G5, ChannelType::G3], &rng, id),
            ResourceMeter::new(f64::INFINITY, f64::INFINITY),
            ComputeCostModel::for_params(1000),
            Box::new(ErrorCompensated::new(LgcTopAB)),
            rng.fork(0xC0FFEE ^ id as u64),
        )
    }

    fn pop(n: usize, cohort: usize) -> Population {
        Population::new((0..n).map(|i| spec(i, 7)).collect(), cohort, 0.0, 0.0)
    }

    #[test]
    fn materialize_demobilize_roundtrip_preserves_residual_bitwise() {
        let mut p = pop(4, 2);
        let global = vec![0.25f32; 64];
        let mut dev = p.materialize(1, &global);
        assert_eq!(p.materialized(), 1);
        // Make some local progress, then compress so the error memory fills.
        for (i, x) in dev.params_hat.iter_mut().enumerate() {
            *x += (i as f32 + 1.0) * 1e-3;
        }
        let plan = crate::channels::AllocationPlan { counts: vec![4, 4] };
        let (_, _, _) = dev.compress_and_upload(&plan);
        dev.sync(&global);
        let mem_before = dev.error_memory().unwrap().memory().to_vec();
        assert!(mem_before.iter().any(|&x| x != 0.0));
        p.demobilize(dev.into_parts(), true);
        assert_eq!(p.materialized(), 0);
        assert!(!p.spec(1).residual.is_empty());
        // Rematerialize: the error memory must come back bit-for-bit.
        let dev2 = p.materialize(1, &global);
        let mem_after = dev2.error_memory().unwrap().memory().to_vec();
        assert_eq!(mem_before.len(), mem_after.len());
        for (a, b) in mem_before.iter().zip(&mem_after) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        p.demobilize(dev2.into_parts(), true);
    }

    #[test]
    fn pending_progress_folds_into_residual_when_not_compressed() {
        let mut p = pop(3, 1);
        let global = vec![1.0f32; 32];
        let mut dev = p.materialize(0, &global);
        // Local progress without any compress call (silent round).
        for x in dev.params_hat.iter_mut() {
            *x -= 0.125;
        }
        p.demobilize(dev.into_parts(), false);
        let r = &p.spec(0).residual;
        assert_eq!(r.nnz(), 32, "all 32 coordinates moved");
        // u = w_sync − ŵ = +0.125 per coordinate.
        let dev2 = p.materialize(0, &global);
        let mem = dev2.error_memory().unwrap().memory().to_vec();
        assert!(mem.iter().all(|&x| (x - 0.125).abs() < 1e-7));
        p.demobilize(dev2.into_parts(), true);
    }

    #[test]
    fn peak_materialized_tracks_high_water() {
        let mut p = pop(5, 3);
        let g = vec![0f32; 8];
        let a = p.materialize(0, &g);
        let b = p.materialize(3, &g);
        assert_eq!(p.peak_materialized(), 2);
        p.demobilize(a.into_parts(), true);
        let c = p.materialize(4, &g);
        assert_eq!(p.materialized(), 2);
        assert_eq!(p.peak_materialized(), 2);
        p.demobilize(b.into_parts(), true);
        p.demobilize(c.into_parts(), true);
        assert_eq!(p.materialized(), 0);
    }

    #[test]
    fn sync_state_persists_through_demobilize() {
        let mut p = pop(3, 1);
        let global = vec![0f32; 16];
        let mut dev = p.materialize(2, &global);
        assert_eq!(dev.sync_state, SyncState::default());
        dev.sync_state = SyncState {
            synced_version: 9,
            synced_round: 4,
            pending_layers: 1,
            staleness: 3,
        };
        p.demobilize(dev.into_parts(), true);
        assert_eq!(p.spec(2).sync_state.synced_version, 9);
        let dev2 = p.materialize(2, &global);
        assert_eq!(
            dev2.sync_state,
            SyncState { synced_version: 9, synced_round: 4, pending_layers: 1, staleness: 3 }
        );
        p.demobilize(dev2.into_parts(), true);
        // reset_episode clears it.
        p.reset_episode(f64::INFINITY, f64::INFINITY);
        assert_eq!(p.spec(2).sync_state, SyncState::default());
    }

    #[test]
    fn churn_chain_moves_clients_on_and_off() {
        let specs = (0..50).map(|i| spec(i, 11)).collect();
        let mut p = Population::new(specs, 10, 0.4, 0.5);
        let mut saw_offline = false;
        let mut saw_back_online = false;
        let mut was_offline = vec![false; 50];
        for _ in 0..40 {
            p.step_round();
            for i in 0..50 {
                if !p.online(i) {
                    saw_offline = true;
                    was_offline[i] = true;
                } else if was_offline[i] {
                    saw_back_online = true;
                }
            }
        }
        assert!(saw_offline && saw_back_online);
    }

    #[test]
    fn residual_compact_forms_roundtrip() {
        let mut ef = ErrorFeedback::new(10);
        // Mostly-zero memory -> sparse.
        ef.restitute(3, 1.5);
        ef.restitute(7, -2.0);
        let r = Residual::drain_from(&mut ef);
        assert!(matches!(r, Residual::Sparse(_)));
        assert_eq!(r.nnz(), 2);
        assert_eq!(r.bytes(), 16);
        let mut ef2 = ErrorFeedback::new(0);
        r.restore_into(&mut ef2, 10);
        assert_eq!(ef2.memory()[3], 1.5);
        assert_eq!(ef2.memory()[7], -2.0);
        // Mostly-nonzero -> dense.
        let mut ef3 = ErrorFeedback::new(10);
        for i in 0..9 {
            ef3.restitute(i, i as f32 + 1.0);
        }
        let r = Residual::drain_from(&mut ef3);
        assert!(matches!(r, Residual::Dense(_)));
        assert_eq!(r.bytes(), 40);
        // Empty stays empty.
        let mut ef4 = ErrorFeedback::new(10);
        assert!(Residual::drain_from(&mut ef4).is_empty());
    }
}
