//! Discrete-event simulation core: virtual clock, per-layer in-flight
//! transfers, and the [`SyncMode`] seam (barrier / semi-async / fully-async
//! servers).
//!
//! The engine replaces the round-synchronous for-loop as the execution
//! substrate of [`Experiment::run`](crate::coordinator::Experiment::run):
//!
//! - [`event`]: the [`Event`] taxonomy (`FadingTick`, `ComputeDone`,
//!   `LayerArrived`, `Broadcast`) and the deterministic binary-heap
//!   [`EventQueue`] ordered by `(virtual time, scheduling sequence)`;
//! - [`mode`]: the [`SyncMode`] seam — `Barrier` reproduces the pre-engine
//!   synchronous loop bit-for-bit, `SemiAsync` buffers `buffer_k` uploads
//!   FedBuff-style, `FullyAsync` applies each upload on arrival with
//!   FedAsync staleness weighting;
//! - [`engine`]: the driver, including the `std::thread::scope` parallel
//!   device-compute path over split
//!   [`DeviceTrainer`](crate::coordinator::DeviceTrainer) handles.
//!
//! See DESIGN.md §"Event engine & sync modes" for the taxonomy, the
//! equivalence argument, and how to add a new mode.

pub mod engine;
pub mod event;
pub mod mode;

pub use event::{Event, EventQueue};
pub use mode::SyncMode;

/// Engine counters exposed after a run via `Experiment::sim_stats`
/// (events/sec throughput for benches, plus async-mode telemetry).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    /// Events popped from the queue over the run.
    pub events: u64,
    /// Round records emitted (server aggregations in async modes).
    pub records: u64,
    /// Updates applied with staleness > 0 (async modes; 0 under barrier).
    pub stale_updates: u64,
    /// Layers erased in transit (async modes ride the lossy channel path).
    pub lost_layers: u64,
    /// Uploads lost to mid-upload availability churn (population mode).
    pub dropped_offline: u64,
    /// Zone changes over the run (scenario mobility + forced phases).
    pub handoffs: u64,
    /// In-flight uplink layers dropped because a handoff removed their
    /// channel (scenario mode; restituted into error-feedback memory).
    pub dropped_handoff: u64,
    /// Held edge contributions migrated edge-to-edge on handoff instead of
    /// being dropped (edge tier; 0 when the edge is disabled).
    pub migrated_handoff: u64,
}
