//! Discrete events and the virtual-time event queue.
//!
//! The simulation core is a binary min-heap of [`Scheduled`] entries ordered
//! by `(time, seq)`: virtual seconds first, insertion sequence second. The
//! `seq` tie-break makes event ordering *total* and deterministic — two
//! events at the same instant pop in the order they were scheduled, so a
//! seeded run replays identically regardless of heap internals.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One simulation event. Everything the engine reacts to is one of these
/// kinds (see DESIGN.md §"Event engine & sync modes").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// Advance every link's Markov fading chain. Barrier mode fires one tick
    /// at the start of each round (the pre-engine semantics); async modes
    /// fire it on a fixed virtual period (`cfg.fading_tick_s`), decoupling
    /// channel dynamics from round boundaries.
    FadingTick,
    /// `device` finished its local SGD steps and starts uploading.
    ComputeDone { device: usize },
    /// One compressed layer of `device`'s upload landed at the server after
    /// crossing `channel`. `layer` indexes the emitted layers of the upload
    /// (0 = base layer).
    LayerArrived { device: usize, channel: usize, layer: usize },
    /// The whole upload transmission of cohort slot `device` finished —
    /// the population cohort engines drive server action per completed
    /// upload (the slot's radio went quiet: delivered layers are in, churn
    /// losses are known). Never scheduled by the legacy per-layer paths.
    UploadDone { device: usize },
    /// The server finished an aggregation and pushes the fresh global model
    /// to the devices that are waiting for it.
    Broadcast,
    /// One compressed layer of the server's *downlink* broadcast landed at
    /// `device` after crossing its downlink `channel`. `layer` indexes the
    /// broadcast's layers (0 = base layer). Only scheduled when the
    /// downlink is enabled (`cfg.downlink`).
    DownlinkLayerArrived { device: usize, channel: usize, layer: usize },
    /// One partial-aggregate frame from zone `zone`'s edge node crossed
    /// its backhaul link and landed at the cloud (`flush` identifies the
    /// flush so reordered arrivals pick up the right payload). Only
    /// scheduled by the legacy engines when the edge tier is enabled
    /// (`cfg.edge`); the population cohort engines run the backhaul in
    /// accounting-only fidelity and never schedule it.
    BackhaulArrived { zone: usize, flush: u64 },
    /// `device` confirmed its downlink synchronization: the base layer
    /// arrived (legacy engines — enhancement layers may still trail,
    /// tracked in the device's `SyncState`), or the whole accounting-only
    /// broadcast completed (population cohort engines, where `device` is
    /// the cohort slot index). Only scheduled when the downlink is enabled.
    SyncConfirmed { device: usize },
}

/// A heap entry: an [`Event`] at a virtual time, with an insertion sequence
/// number for deterministic tie-breaking.
#[derive(Clone, Debug)]
struct Scheduled {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed on purpose: BinaryHeap is a max-heap, we want the
        // earliest (time, seq) on top.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue over virtual time.
#[derive(Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    next_seq: u64,
    popped: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Schedule `event` at virtual time `time` (seconds). Events at equal
    /// times pop in scheduling order.
    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "event scheduled at non-finite time");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    /// Pop the earliest event, if any.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let s = self.heap.pop()?;
        self.popped += 1;
        Some((s.time, s.event))
    }

    /// Total events popped over the queue's lifetime — the engine reports
    /// this as `SimStats::events` (single source of truth for throughput).
    pub fn popped(&self) -> u64 {
        self.popped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{ChannelType, DeviceChannels};
    use crate::util::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, Event::Broadcast);
        q.push(0.5, Event::ComputeDone { device: 1 });
        q.push(1.0, Event::FadingTick);
        assert_eq!(q.pop().unwrap().1, Event::ComputeDone { device: 1 });
        assert_eq!(q.pop().unwrap().1, Event::FadingTick);
        assert_eq!(q.pop().unwrap().1, Event::Broadcast);
        assert!(q.pop().is_none());
        assert_eq!(q.popped(), 3);
    }

    #[test]
    fn equal_times_pop_in_scheduling_order() {
        let mut q = EventQueue::new();
        for device in 0..8 {
            q.push(1.25, Event::ComputeDone { device });
        }
        for device in 0..8 {
            assert_eq!(q.pop().unwrap().1, Event::ComputeDone { device });
        }
    }

    /// The layered-coding premise made concrete: with the base layer mapped
    /// to the faster channel (and no bigger than the enhancement layer), its
    /// arrival event always precedes the enhancement layer's arrival.
    #[test]
    fn base_layer_arrival_precedes_enhancement_on_faster_channel() {
        let rng = Rng::new(7);
        let ch = DeviceChannels::new(&[ChannelType::G5, ChannelType::G3], &rng, 0);
        for (base_bytes, enh_bytes) in
            [(1_000u64, 1_000u64), (500, 4_000), (10_000, 10_000), (64, 1 << 20)]
        {
            assert!(base_bytes <= enh_bytes);
            let mut q = EventQueue::new();
            let t_base = ch.links[0].expected_cost(base_bytes).time_s;
            let t_enh = ch.links[1].expected_cost(enh_bytes).time_s;
            // Base layer is scheduled first, as the engine emits layers in
            // layer order — the seq tie-break covers the equal-time case.
            q.push(t_base, Event::LayerArrived { device: 0, channel: 0, layer: 0 });
            q.push(t_enh, Event::LayerArrived { device: 0, channel: 1, layer: 1 });
            let first = q.pop().unwrap().1;
            assert_eq!(
                first,
                Event::LayerArrived { device: 0, channel: 0, layer: 0 },
                "base layer must land first ({base_bytes}B on 5G vs {enh_bytes}B on 3G)"
            );
        }
    }

    #[test]
    fn seq_numbers_make_ordering_stable_across_interleaved_pushes() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::Broadcast);
        q.push(3.0, Event::FadingTick);
        q.pop(); // Broadcast
        q.push(3.0, Event::ComputeDone { device: 0 });
        assert_eq!(q.pop().unwrap().1, Event::FadingTick);
        assert_eq!(q.pop().unwrap().1, Event::ComputeDone { device: 0 });
    }
}
