//! Discrete events and the sharded virtual-time event queue.
//!
//! The simulation core is a set of per-shard binary min-heaps of
//! [`Scheduled`] entries merged by `(time, shard, seq)`: virtual seconds
//! first, insertion sequence second (the shard component is vacuous — see
//! below). Device-owned events hash to a shard by client id; control-plane
//! events (fading ticks, broadcasts, backhaul arrivals) ride a dedicated
//! shard 0, so the per-tick population-wide work they trigger can fan out
//! in parallel while per-device causality stays within one shard.
//!
//! **Determinism argument.** `seq` is a single global counter assigned at
//! push time, so every scheduled entry carries a globally unique `(time,
//! seq)` key and the cross-shard merge (pop the minimum key among the shard
//! heads) reproduces the total order of a single heap *exactly*, for any
//! shard count. The `shard` component of the merge key never breaks a tie
//! because no two entries share `(time, seq)` — sharding is a layout
//! choice, not a semantic one, which is what keeps all four engines
//! bitwise-identical for `shards ∈ {1, 2, …, auto}`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One simulation event. Everything the engine reacts to is one of these
/// kinds (see DESIGN.md §"Event engine & sync modes").
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// Advance every link's Markov fading chain. Barrier mode fires one tick
    /// at the start of each round (the pre-engine semantics); async modes
    /// fire it on a fixed virtual period (`cfg.fading_tick_s`), decoupling
    /// channel dynamics from round boundaries.
    FadingTick,
    /// `device` finished its local SGD steps and starts uploading.
    ComputeDone { device: usize },
    /// One compressed layer of `device`'s upload landed at the server after
    /// crossing `channel`. `layer` indexes the emitted layers of the upload
    /// (0 = base layer).
    LayerArrived { device: usize, channel: usize, layer: usize },
    /// The whole upload transmission of cohort slot `device` finished —
    /// the population cohort engines drive server action per completed
    /// upload (the slot's radio went quiet: delivered layers are in, churn
    /// losses are known). Never scheduled by the legacy per-layer paths.
    UploadDone { device: usize },
    /// The server finished an aggregation and pushes the fresh global model
    /// to the devices that are waiting for it.
    Broadcast,
    /// One compressed layer of the server's *downlink* broadcast landed at
    /// `device` after crossing its downlink `channel`. `layer` indexes the
    /// broadcast's layers (0 = base layer). Only scheduled when the
    /// downlink is enabled (`cfg.downlink`).
    DownlinkLayerArrived { device: usize, channel: usize, layer: usize },
    /// One partial-aggregate frame from zone `zone`'s edge node crossed
    /// its backhaul link and landed at the cloud (`flush` identifies the
    /// flush so reordered arrivals pick up the right payload). Only
    /// scheduled by the legacy engines when the edge tier is enabled
    /// (`cfg.edge`); the population cohort engines run the backhaul in
    /// accounting-only fidelity and never schedule it.
    BackhaulArrived { zone: usize, flush: u64 },
    /// `device` confirmed its downlink synchronization: the base layer
    /// arrived (legacy engines — enhancement layers may still trail,
    /// tracked in the device's `SyncState`), or the whole accounting-only
    /// broadcast completed (population cohort engines, where `device` is
    /// the cohort slot index). Only scheduled when the downlink is enabled.
    SyncConfirmed { device: usize },
}

impl Event {
    /// The client id that owns this event, or `None` for control-plane
    /// events (fading ticks, server broadcasts, edge backhaul frames) that
    /// live on the dedicated shard 0.
    fn device(&self) -> Option<usize> {
        match *self {
            Event::ComputeDone { device }
            | Event::LayerArrived { device, .. }
            | Event::UploadDone { device }
            | Event::DownlinkLayerArrived { device, .. }
            | Event::SyncConfirmed { device } => Some(device),
            Event::FadingTick | Event::Broadcast | Event::BackhaulArrived { .. } => None,
        }
    }

    /// Stable snake_case kind label, matching the trace-schema vocabulary
    /// — shared by the recorder, debug logging, and engine diagnostics.
    pub fn label(&self) -> &'static str {
        match self {
            Event::FadingTick => "fading_tick",
            Event::ComputeDone { .. } => "compute_done",
            Event::LayerArrived { .. } => "layer_arrived",
            Event::UploadDone { .. } => "upload_done",
            Event::Broadcast => "broadcast",
            Event::DownlinkLayerArrived { .. } => "downlink_layer_arrived",
            Event::BackhaulArrived { .. } => "backhaul_arrived",
            Event::SyncConfirmed { .. } => "sync_confirmed",
        }
    }
}

impl std::fmt::Display for Event {
    /// Compact one-token form: the kind label plus the identifying keys
    /// (`compute_done[dev=3]`, `backhaul_arrived[zone=1,flush=7]`).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Event::FadingTick | Event::Broadcast => write!(f, "{}", self.label()),
            Event::ComputeDone { device }
            | Event::UploadDone { device }
            | Event::SyncConfirmed { device } => write!(f, "{}[dev={device}]", self.label()),
            Event::LayerArrived { device, channel, layer }
            | Event::DownlinkLayerArrived { device, channel, layer } => {
                write!(f, "{}[dev={device},ch={channel},layer={layer}]", self.label())
            }
            Event::BackhaulArrived { zone, flush } => {
                write!(f, "{}[zone={zone},flush={flush}]", self.label())
            }
        }
    }
}

/// A heap entry: an [`Event`] at a virtual time, with an insertion sequence
/// number for deterministic tie-breaking.
#[derive(Clone, Debug)]
struct Scheduled {
    time: f64,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time.to_bits() == other.time.to_bits() && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed on purpose: BinaryHeap is a max-heap, we want the
        // earliest (time, seq) on top.
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Sharded min-heap event queue over virtual time.
///
/// [`EventQueue::new`] keeps the classic single-heap layout; the engines
/// construct [`EventQueue::with_shards`] from the `shards` config key.
/// Either way the pop order is the total `(time, seq)` order (see the
/// module docs for why the merge is exact).
pub struct EventQueue {
    shards: Vec<BinaryHeap<Scheduled>>,
    next_seq: u64,
    popped: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::with_shards(1)
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// A queue of `shards` per-shard heaps (clamped to at least 1). Shard 0
    /// is the control-plane shard; device events hash over the rest (or
    /// share shard 0 when `shards == 1`).
    pub fn with_shards(shards: usize) -> Self {
        let shards = shards.max(1);
        EventQueue {
            shards: (0..shards).map(|_| BinaryHeap::new()).collect(),
            next_seq: 0,
            popped: 0,
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The shard an event lands on: control-plane events on shard 0,
    /// device events on `1 + device % (shards − 1)`.
    fn shard_of(&self, event: &Event) -> usize {
        let n = self.shards.len();
        match event.device() {
            Some(device) if n > 1 => 1 + device % (n - 1),
            _ => 0,
        }
    }

    /// Schedule `event` at virtual time `time` (seconds). Events at equal
    /// times pop in scheduling order, regardless of the shard they hash to.
    pub fn push(&mut self, time: f64, event: Event) {
        debug_assert!(time.is_finite(), "event scheduled at non-finite time");
        let seq = self.next_seq;
        self.next_seq += 1;
        let shard = self.shard_of(&event);
        self.shards[shard].push(Scheduled { time, seq, event });
    }

    /// Pop the earliest event across all shards, if any: an O(shards) scan
    /// of the shard heads for the minimum `(time, seq)` key.
    pub fn pop(&mut self) -> Option<(f64, Event)> {
        let mut best: Option<(f64, u64, usize)> = None;
        for (shard, heap) in self.shards.iter().enumerate() {
            if let Some(head) = heap.peek() {
                let better = match best {
                    None => true,
                    Some((t, seq, _)) => match head.time.total_cmp(&t) {
                        Ordering::Less => true,
                        Ordering::Equal => head.seq < seq,
                        Ordering::Greater => false,
                    },
                };
                if better {
                    best = Some((head.time, head.seq, shard));
                }
            }
        }
        let (_, _, shard) = best?;
        let s = self.shards[shard].pop().expect("peeked head vanished");
        self.popped += 1;
        Some((s.time, s.event))
    }

    /// Total events popped over the queue's lifetime — the engine reports
    /// this as `SimStats::events` (single source of truth for throughput).
    pub fn popped(&self) -> u64 {
        self.popped
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{ChannelType, DeviceChannels};
    use crate::util::Rng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(2.0, Event::Broadcast);
        q.push(0.5, Event::ComputeDone { device: 1 });
        q.push(1.0, Event::FadingTick);
        assert_eq!(q.pop().unwrap().1, Event::ComputeDone { device: 1 });
        assert_eq!(q.pop().unwrap().1, Event::FadingTick);
        assert_eq!(q.pop().unwrap().1, Event::Broadcast);
        assert!(q.pop().is_none());
        assert_eq!(q.popped(), 3);
    }

    #[test]
    fn equal_times_pop_in_scheduling_order() {
        let mut q = EventQueue::new();
        for device in 0..8 {
            q.push(1.25, Event::ComputeDone { device });
        }
        for device in 0..8 {
            assert_eq!(q.pop().unwrap().1, Event::ComputeDone { device });
        }
    }

    /// The layered-coding premise made concrete: with the base layer mapped
    /// to the faster channel (and no bigger than the enhancement layer), its
    /// arrival event always precedes the enhancement layer's arrival.
    #[test]
    fn base_layer_arrival_precedes_enhancement_on_faster_channel() {
        let rng = Rng::new(7);
        let ch = DeviceChannels::new(&[ChannelType::G5, ChannelType::G3], &rng, 0);
        for (base_bytes, enh_bytes) in
            [(1_000u64, 1_000u64), (500, 4_000), (10_000, 10_000), (64, 1 << 20)]
        {
            assert!(base_bytes <= enh_bytes);
            let mut q = EventQueue::new();
            let t_base = ch.links[0].expected_cost(base_bytes).time_s;
            let t_enh = ch.links[1].expected_cost(enh_bytes).time_s;
            // Base layer is scheduled first, as the engine emits layers in
            // layer order — the seq tie-break covers the equal-time case.
            q.push(t_base, Event::LayerArrived { device: 0, channel: 0, layer: 0 });
            q.push(t_enh, Event::LayerArrived { device: 0, channel: 1, layer: 1 });
            let first = q.pop().unwrap().1;
            assert_eq!(
                first,
                Event::LayerArrived { device: 0, channel: 0, layer: 0 },
                "base layer must land first ({base_bytes}B on 5G vs {enh_bytes}B on 3G)"
            );
        }
    }

    #[test]
    fn seq_numbers_make_ordering_stable_across_interleaved_pushes() {
        let mut q = EventQueue::new();
        q.push(3.0, Event::Broadcast);
        q.push(3.0, Event::FadingTick);
        q.pop(); // Broadcast
        q.push(3.0, Event::ComputeDone { device: 0 });
        assert_eq!(q.pop().unwrap().1, Event::FadingTick);
        assert_eq!(q.pop().unwrap().1, Event::ComputeDone { device: 0 });
    }

    /// The tentpole contract: any shard count replays the single-heap total
    /// order exactly, on an adversarial interleaving of pushes and pops with
    /// heavy time collisions across many devices.
    #[test]
    fn any_shard_count_matches_single_heap_order() {
        let trace = |shards: usize| {
            let mut q = EventQueue::with_shards(shards);
            let mut rng = Rng::new(99);
            let mut out = Vec::new();
            for step in 0..500 {
                // Coarse times force cross-device and cross-kind ties.
                let t = (rng.index(16) as f64) * 0.25;
                let dev = rng.index(37);
                let ev = match step % 7 {
                    0 => Event::FadingTick,
                    1 => Event::Broadcast,
                    2 => Event::BackhaulArrived { zone: dev % 3, flush: step as u64 },
                    3 => Event::ComputeDone { device: dev },
                    4 => Event::LayerArrived { device: dev, channel: dev % 2, layer: 0 },
                    5 => Event::UploadDone { device: dev },
                    _ => Event::SyncConfirmed { device: dev },
                };
                q.push(t, ev);
                if step % 3 == 0 {
                    out.push(q.pop().unwrap());
                }
            }
            while let Some(e) = q.pop() {
                out.push(e);
            }
            out
        };
        let reference = trace(1);
        for shards in [2, 3, 8, 64] {
            let got = trace(shards);
            assert_eq!(got.len(), reference.len());
            for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
                assert_eq!(a.0.to_bits(), b.0.to_bits(), "time at pop {i}, {shards} shards");
                assert_eq!(a.1, b.1, "event at pop {i}, {shards} shards");
            }
        }
    }

    #[test]
    fn display_labels_are_compact_and_stable() {
        assert_eq!(Event::FadingTick.to_string(), "fading_tick");
        assert_eq!(Event::ComputeDone { device: 3 }.to_string(), "compute_done[dev=3]");
        assert_eq!(
            Event::LayerArrived { device: 2, channel: 1, layer: 0 }.to_string(),
            "layer_arrived[dev=2,ch=1,layer=0]"
        );
        assert_eq!(
            Event::BackhaulArrived { zone: 1, flush: 7 }.to_string(),
            "backhaul_arrived[zone=1,flush=7]"
        );
        assert_eq!(Event::Broadcast.label(), "broadcast");
    }

    #[test]
    fn control_events_ride_shard_zero_and_device_events_hash() {
        let q = EventQueue::with_shards(4);
        assert_eq!(q.shard_count(), 4);
        assert_eq!(q.shard_of(&Event::FadingTick), 0);
        assert_eq!(q.shard_of(&Event::Broadcast), 0);
        assert_eq!(q.shard_of(&Event::BackhaulArrived { zone: 2, flush: 1 }), 0);
        // Device events spread over shards 1..=3, stable per client.
        assert_eq!(q.shard_of(&Event::ComputeDone { device: 0 }), 1);
        assert_eq!(q.shard_of(&Event::ComputeDone { device: 1 }), 2);
        assert_eq!(q.shard_of(&Event::ComputeDone { device: 3 }), 1);
        assert_eq!(
            q.shard_of(&Event::UploadDone { device: 5 }),
            q.shard_of(&Event::SyncConfirmed { device: 5 }),
        );
        // Single-shard queue folds everything onto shard 0.
        let q1 = EventQueue::new();
        assert_eq!(q1.shard_of(&Event::ComputeDone { device: 9 }), 0);
    }
}
