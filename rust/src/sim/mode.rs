//! The [`SyncMode`] seam: how the server turns device uploads into global
//! model updates over virtual time.
//!
//! | mode | server behavior | literature |
//! |------|-----------------|------------|
//! | [`SyncMode::Barrier`] | wait for *every* active device each round (the pre-engine loop, reproduced bit-for-bit) | FedAvg, McMahan et al. 2017 |
//! | [`SyncMode::SemiAsync`] | buffer completed uploads; aggregate every `buffer_k` of them | FedBuff-style buffered aggregation (cf. arXiv:2012.11804, arXiv:2105.11028) |
//! | [`SyncMode::FullyAsync`] | apply each upload on arrival, scaled by `staleness_decay^staleness` | FedAsync-style staleness weighting |

/// Server synchronization discipline for one experiment. Orthogonal to the
/// mechanism preset (compressor x aggregator x policy): any mechanism can
/// run under any mode. Resolved by the builder as
/// `cfg.sync_mode` > preset default > `Barrier`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub enum SyncMode {
    /// Round-synchronous: the round ends when the slowest active device's
    /// last layer lands. Numerically identical to the pre-engine loop
    /// (`Experiment::step_round`), proven by `tests/sim_engine.rs`.
    #[default]
    Barrier,
    /// Buffered semi-asynchronous aggregation: devices run at their own
    /// pace; the server aggregates as soon as `buffer_k` complete uploads
    /// are buffered, then broadcasts to the devices that contributed (and
    /// any others waiting). Stragglers no longer stall the fleet.
    SemiAsync {
        /// Uploads per aggregation (>= 1). Values above the device count
        /// still work — the engine flushes a partial buffer when every
        /// device is waiting on it.
        buffer_k: usize,
    },
    /// Fully asynchronous: every completed upload is applied immediately,
    /// weighted by `staleness_decay^s` where `s` is the number of server
    /// model versions that elapsed since the device last synchronized.
    FullyAsync {
        /// Per-version staleness discount in (0, 1]. 1.0 = no discount.
        staleness_decay: f64,
    },
}

impl SyncMode {
    /// Display / config name of the mode kind.
    pub fn name(&self) -> &'static str {
        match self {
            SyncMode::Barrier => "barrier",
            SyncMode::SemiAsync { .. } => "semi-async",
            SyncMode::FullyAsync { .. } => "fully-async",
        }
    }

    /// Build from a config-file kind string plus the parameter keys
    /// (`buffer_k`, `staleness_decay`); parameters irrelevant to the kind
    /// are ignored.
    pub fn parse(kind: &str, buffer_k: usize, staleness_decay: f64) -> Result<Self, String> {
        let mode = match kind.to_ascii_lowercase().as_str() {
            "barrier" | "sync" => SyncMode::Barrier,
            "semi-async" | "semi_async" | "semiasync" | "fedbuff" => {
                SyncMode::SemiAsync { buffer_k }
            }
            "fully-async" | "fully_async" | "async" | "fedasync" => {
                SyncMode::FullyAsync { staleness_decay }
            }
            other => return Err(format!("unknown sync_mode `{other}`")),
        };
        mode.validate()?;
        Ok(mode)
    }

    /// Parameter sanity (also run by `ExperimentConfig::validate`).
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            SyncMode::Barrier => Ok(()),
            SyncMode::SemiAsync { buffer_k } => {
                if buffer_k == 0 {
                    Err("semi-async buffer_k must be >= 1".into())
                } else {
                    Ok(())
                }
            }
            SyncMode::FullyAsync { staleness_decay } => {
                if staleness_decay > 0.0 && staleness_decay <= 1.0 {
                    Ok(())
                } else {
                    Err(format!(
                        "fully-async staleness_decay must lie in (0, 1], got {staleness_decay}"
                    ))
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds_and_aliases() {
        assert_eq!(SyncMode::parse("barrier", 2, 0.5).unwrap(), SyncMode::Barrier);
        assert_eq!(
            SyncMode::parse("semi-async", 3, 0.5).unwrap(),
            SyncMode::SemiAsync { buffer_k: 3 }
        );
        assert_eq!(
            SyncMode::parse("FedAsync", 2, 0.7).unwrap(),
            SyncMode::FullyAsync { staleness_decay: 0.7 }
        );
        assert!(SyncMode::parse("nope", 2, 0.5).is_err());
    }

    #[test]
    fn invalid_params_rejected() {
        assert!(SyncMode::parse("semi-async", 0, 0.5).is_err());
        assert!(SyncMode::parse("fully-async", 2, 0.0).is_err());
        assert!(SyncMode::parse("fully-async", 2, 1.5).is_err());
        assert!(SyncMode::FullyAsync { staleness_decay: 1.0 }.validate().is_ok());
    }
}
