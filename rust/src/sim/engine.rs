//! The discrete-event simulation engine driving an
//! [`Experiment`](crate::coordinator::Experiment) under a
//! [`SyncMode`](super::SyncMode).
//!
//! Every compressed layer is its own in-flight transfer: the engine turns an
//! upload into one [`Event::LayerArrived`] per emitted layer, with the
//! arrival time derived from the layer's channel cost sample — so the server
//! observes a base layer on 5G long before an enhancement layer crawling
//! over 3G, and the async modes act on completed uploads without waiting for
//! the fleet.
//!
//! **Barrier mode is the pre-engine synchronous loop, reproduced
//! bit-for-bit** (see `Experiment::step_round`, kept as the reference
//! implementation, and the equivalence test in `tests/sim_engine.rs`):
//! same per-component RNG streams, same f64 accumulation order for the
//! per-round reductions, same per-device call sequences. The one deliberate
//! relaxation: `RoundPolicy::decide` runs for all devices at round start and
//! `RoundPolicy::observe` for all devices at broadcast (the synchronous loop
//! interleaved them per device). Per-policy and per-agent call order is
//! unchanged, so every built-in policy is unaffected.
//!
//! Async modes additionally route uploads through the **lossy** channel path
//! ([`Device::upload_lossy`]): fading-dependent layer erasure actually
//! happens, and lost layers are restituted into the device's error-feedback
//! memory rather than silently discarded.

use anyhow::Result;

use super::event::{Event, EventQueue};
use super::{SimStats, SyncMode};
use crate::channels::{AllocationPlan, TransferCost};
use crate::compression::{Layer, LgcUpdate};
use crate::coordinator::device::{Device, LayerTransfer};
use crate::coordinator::experiment::Experiment;
use crate::coordinator::trainer::{DeviceTrainer, LocalTrainer};
use crate::drl::DeviceAgent;
use crate::edge::HeldContribution;
use crate::metrics::{percentile, RoundRecord, RunLog};
use crate::obs::{Attribution, Ev, Phase, Recorder};
use crate::population::{ClientSampler, Population};
use crate::scenario::Scenario;

/// Drive `exp` to completion under its resolved sync mode, appending one
/// [`RoundRecord`] per round (barrier) or per server aggregation (async).
/// Population-mode experiments (a [`Population`] present) run the cohort
/// engines instead: clients are materialized only while sampled, so
/// resident memory stays O(model + cohort).
pub fn run(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
) -> Result<()> {
    // Scenario totals are scenario-lifetime counters; snapshot them so
    // `sim_stats` reports *this run's* share even across repeated `run`
    // calls on one experiment (multi-episode DRL).
    let scenario0 = exp
        .scenario
        .as_ref()
        .map(|s| (s.handoffs_total(), s.dropped_total()))
        .unwrap_or((0, 0));
    let edge0 = exp.edge.as_ref().map(|e| e.migrated_total()).unwrap_or(0);
    // Take the recorder out for the run (the engines borrow `exp`'s fields
    // piecemeal, and the recorder must stay writable throughout); flushed
    // and handed back below, even on an engine error.
    let mut rec = std::mem::take(&mut exp.recorder);
    let loop_t0 = rec.phase_start();
    let result = if exp.population.is_some() {
        run_cohort(exp, trainer, log, &mut rec)
    } else {
        match exp.sync_mode {
            SyncMode::Barrier => run_barrier(exp, trainer, log, &mut rec),
            SyncMode::SemiAsync { buffer_k } => {
                run_async(exp, trainer, log, AsyncKind::Semi { buffer_k }, &mut rec)
            }
            SyncMode::FullyAsync { staleness_decay } => {
                run_async(exp, trainer, log, AsyncKind::Fully { staleness_decay }, &mut rec)
            }
        }
    };
    rec.phase_end(Phase::EventLoop, loop_t0);
    let flush_err = rec.flush().map(|_| ()).err();
    exp.recorder = rec;
    if let Some(sc) = exp.scenario.as_ref() {
        exp.sim_stats.handoffs = sc.handoffs_total() - scenario0.0;
        exp.sim_stats.dropped_handoff = sc.dropped_total() - scenario0.1;
    }
    if let Some(edge) = exp.edge.as_ref() {
        exp.sim_stats.migrated_handoff = edge.migrated_total() - edge0;
    }
    if let Some(e) = flush_err {
        return result.and(Err(anyhow::anyhow!("failed to write trace file: {e}")));
    }
    result
}

/// Drain the edge tier's record-window counters into the four edge record
/// fields `(backhaul_bytes, backhaul_p95_s, migrated_handoff,
/// edge_rounds_bound)` — all zero when the tier is disabled. A window is
/// *backhaul-bound* when its backhaul p95 exceeds the access-side finish
/// p95 the caller computed for the same window.
fn drain_edge_window(exp: &mut Experiment, finish_p95_s: f64) -> (u64, f64, u64, u64) {
    let Some(edge) = exp.edge.as_mut() else {
        return (0, 0.0, 0, 0);
    };
    let mut w = edge.window.take();
    let p95 = if w.backhaul_walls.is_empty() {
        0.0
    } else {
        percentile(&mut w.backhaul_walls, 95.0)
    };
    let bound = (finish_p95_s.is_finite() && p95 > finish_p95_s) as u64;
    (w.backhaul_bytes, p95, w.migrated, bound)
}

/// Advance the scenario world by one tick at virtual time `t` and re-apply
/// zone configuration to every affected **legacy** (pre-materialized)
/// device's uplink bundle, plus its downlink bundle when the downlink is
/// simulated. The cohort engines reconfigure their live slots themselves —
/// demobilized clients pick the current world up at materialization.
fn scenario_tick_legacy(exp: &mut Experiment, t: f64, rec: &mut Recorder) {
    let Some(sc) = exp.scenario.as_mut() else { return };
    let fx = sc.tick(t);
    for &id in &fx.reconfigure {
        sc.configure(id, &mut exp.devices[id].channels);
        if let Some(dl) = exp.downlink.as_mut() {
            sc.configure(id, dl.links_mut(id));
        }
        if rec.on() {
            rec.push(Ev::new("handoff", t).client(id).zone(sc.zone_of(id)));
        }
        // Edge tier: the device's contributions still held at its old
        // zone's node follow it to the new zone (migration, not the
        // restitution fallback — frames already on the backhaul wire stay
        // put, and in-flight *access* layers still restitute).
        if let Some(edge) = exp.edge.as_mut() {
            let zone = sc.zone_of(id);
            if edge.zone_of(id) != zone {
                edge.migrate(id, zone);
                if rec.on() {
                    rec.push(Ev::new("migrate", t).client(id).zone(zone));
                }
            }
        }
    }
    if let Some(edge) = exp.edge.as_mut() {
        // Phase-scripted backhaul throttle (`backhaul_scale` in the
        // scenario DSL) lands on every zone's backhaul link.
        edge.set_phase_scale(sc.backhaul_scale());
    }
}

/// Tear down one delivered uplink layer caught on a channel a handoff
/// removed: restitute its mass into the device's error-feedback memory,
/// empty it **in place** (callers rely on position stability against their
/// layer→channel maps and purge the empties before the server sees the
/// payload), and count the drop. The single tear-down sequence shared by
/// the legacy async engine (lazily, at the layer's `LayerArrived`) and the
/// cohort engine (batched, at the slot's `UploadDone`) — so the two paths
/// cannot drift apart.
fn drop_handoff_layer(dev: &mut Device, scenario: &mut Option<Scenario>, layer: &mut Layer) {
    let torn = std::mem::replace(layer, Layer { indices: Vec::new(), values: Vec::new() });
    dev.restitute_layer(&torn);
    if let Some(sc) = scenario.as_mut() {
        sc.note_dropped(1);
    }
}

/// `compute_threads` semantics: 0 = one worker per available core, n = n.
fn resolve_threads(cfg_threads: usize) -> usize {
    match cfg_threads {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// `shards` semantics: 0 = auto (one event-queue shard per available
/// core), n = n. Purely a throughput knob — the sharded queue's
/// `(time, shard, seq)` merge reproduces the single-heap order exactly,
/// so any value is bit-identical (tests/scale_engine.rs). The same count
/// drives the population store's parallel fading/churn sweeps.
fn resolve_shards(cfg_shards: usize) -> usize {
    match cfg_shards {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

// ---------------------------------------------------------------------------
// Barrier mode
// ---------------------------------------------------------------------------

fn run_barrier(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
    rec: &mut Recorder,
) -> Result<()> {
    let m = exp.devices.len();
    let samples: Vec<usize> = (0..m).map(|i| trainer.device_samples(i)).collect();
    let threads = resolve_threads(exp.cfg.compute_threads);
    // Parallel compute needs independently-owned per-device trainers; fall
    // back to the sequential path when the backend cannot split. Whatever
    // happens, hand the handles back afterwards so the trainer stays usable
    // for further runs (with the advanced sampler state).
    let mut handles = if threads > 1 { trainer.split_device_trainers() } else { None };
    let result = barrier_rounds(exp, trainer, log, &mut handles, threads, &samples, rec);
    if let Some(h) = handles.take() {
        trainer.restore_device_trainers(h);
    }
    result
}

fn barrier_rounds(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
    handles: &mut Option<Vec<Box<dyn DeviceTrainer>>>,
    threads: usize,
    samples: &[usize],
    rec: &mut Recorder,
) -> Result<()> {
    let m = exp.devices.len();
    if let Some(h) = handles.as_ref() {
        anyhow::ensure!(
            h.len() == m,
            "split_device_trainers returned {} handles for {m} devices",
            h.len()
        );
    }
    let mut queue = EventQueue::with_shards(resolve_shards(exp.cfg.shards));
    let mut stats = SimStats::default();

    // Emit one barrier-round record — factored out so the downlink path
    // (which finalizes a round at the last `SyncConfirmed` instead of at
    // `Broadcast`) runs the exact same f64 reductions in the exact same
    // order as the legacy inline code.
    #[allow(clippy::too_many_arguments)]
    fn emit_barrier_record(
        exp: &mut Experiment,
        trainer: &mut dyn LocalTrainer,
        log: &mut RunLog,
        stats: &mut SimStats,
        round: usize,
        round_wall: f64,
        loss_sum: f64,
        loss_n: usize,
        reward_acc: f64,
        reward_n: usize,
        bytes_up: u64,
        active: &[bool],
        walls: &[f64],
        completed: u64,
        comp_s: &[f64],
        slow_chs: &[i64],
        bh_wall: f64,
        rec: &mut Recorder,
    ) -> Result<()> {
        let m = active.len();
        let done = round + 1 == exp.cfg.rounds;
        // Round-time attribution: the critical device is the slowest
        // upload (compute + access transfer); the backhaul segment is
        // whatever the slowest zone frame added past the access side, the
        // downlink segment whatever the slowest broadcast added past both.
        // The four named segments tile `round_wall` exactly (wait = 0 in
        // barrier mode — nothing idles inside a barrier round).
        let mut attr = Attribution::none();
        let mut crit = usize::MAX;
        for i in 0..m {
            if active[i] && (crit == usize::MAX || walls[i] > walls[crit]) {
                crit = i;
            }
        }
        if crit != usize::MAX {
            let access = walls[crit];
            attr.compute = comp_s[crit];
            attr.uplink = (access - comp_s[crit]).max(0.0);
            attr.backhaul = (bh_wall - access).max(0.0);
            attr.downlink = (round_wall - access.max(bh_wall)).max(0.0);
            attr.crit_client = crit as i64;
            attr.crit_channel = slow_chs[crit];
        }
        attr.finalize(round_wall);
        // Drain the downlink's per-window totals (zero when disabled).
        let down = exp
            .downlink
            .as_mut()
            .map(|d| d.window.take())
            .unwrap_or_default();
        // And the scenario's (zero when no scenario is configured).
        let sw = exp
            .scenario
            .as_mut()
            .map(|s| s.window.take())
            .unwrap_or_default();
        let zone_p50 = exp.scenario.as_ref().map(|s| s.zone_p50()).unwrap_or(0.0);
        exp.total_time_s += round_wall;
        let (eval_loss, eval_acc) = if round % exp.cfg.eval_every == 0 || done {
            trainer.eval(&exp.server.params)?
        } else {
            (f64::NAN, f64::NAN)
        };
        let (tot_energy, tot_money) = exp.devices.iter().fold((0.0, 0.0), |acc, d| {
            (acc.0 + d.meter.energy_used, acc.1 + d.meter.money_used)
        });
        let mut finishes: Vec<f64> =
            (0..m).filter(|&i| active[i]).map(|i| walls[i]).collect();
        let finish_p50_s = percentile(&mut finishes, 50.0);
        let finish_p95_s = percentile(&mut finishes, 95.0);
        let (backhaul_bytes, backhaul_p95_s, migrated_handoff, edge_rounds_bound) =
            drain_edge_window(exp, finish_p95_s);
        log.push(RoundRecord {
            round,
            train_loss: loss_sum / loss_n.max(1) as f64,
            eval_loss,
            eval_acc,
            energy_j: tot_energy,
            money: tot_money,
            round_time_s: round_wall,
            total_time_s: exp.total_time_s,
            bytes_up,
            drl_reward: if reward_n > 0 {
                reward_acc / reward_n as f64
            } else {
                f64::NAN
            },
            finish_p50_s,
            finish_p95_s,
            stale_updates: 0,
            sampled: active.iter().filter(|&&a| a).count() as u64,
            completed,
            dropped_offline: 0,
            // Barrier sync never applies a stale update.
            staleness_p50: 0.0,
            staleness_p95: 0.0,
            down_bytes: down.bytes,
            down_energy_j: down.energy_j,
            down_money: down.money,
            handoffs: sw.handoffs,
            dropped_handoff: sw.dropped_handoff,
            zone_p50,
            backhaul_bytes,
            backhaul_p95_s,
            migrated_handoff,
            edge_rounds_bound,
            bound_by: attr.bound_by(),
            crit_client: attr.crit_client,
            crit_channel: attr.crit_channel,
        });
        rec.push_round(exp.total_time_s, round, round_wall, &attr);
        stats.records += 1;
        Ok(())
    }

    // The single barrier-round broadcast trigger: once nothing is pending
    // on the access side, either schedule the Broadcast at the round's wall
    // time (flat topology — exactly once), or, with the edge tier, hold
    // every received upload at its zone's node and put the per-zone
    // partial-aggregate frames on the backhaul — the Broadcast then fires
    // at the last `BackhaulArrived` instead, so the round can be
    // backhaul-bound. Payloads stay in `recv_bufs` (the barrier aggregates
    // all-at-once anyway); the held entries mark which zones owe a frame.
    #[allow(clippy::too_many_arguments)]
    fn maybe_broadcast(
        exp: &mut Experiment,
        queue: &mut EventQueue,
        pending_compute: usize,
        pending_layers: usize,
        scheduled: &mut bool,
        round_wall: f64,
        pending_backhaul: &mut usize,
        rec: &mut Recorder,
    ) {
        if pending_compute != 0 || pending_layers != 0 || *scheduled {
            return;
        }
        *scheduled = true;
        let base = exp.total_time_s;
        let Some(edge) = exp.edge.as_mut() else {
            queue.push(round_wall, Event::Broadcast);
            return;
        };
        for i in 0..exp.received.len() {
            if !exp.received[i] {
                continue;
            }
            let zone = exp.scenario.as_ref().map_or(0, |sc| sc.zone_of(i));
            edge.hold(
                zone,
                HeldContribution {
                    device: i,
                    update: LgcUpdate { dim: 0, layers: Vec::new() },
                    weight: 0.0,
                    version: 0,
                    loss: 0.0,
                    reward: f64::NAN,
                    finish_s: 0.0,
                },
            );
        }
        let flushes = edge.flush_all(round_wall);
        if flushes.is_empty() {
            queue.push(round_wall, Event::Broadcast);
            return;
        }
        for (zone, flush, arrive, bytes) in flushes {
            if rec.on() {
                // Transfer spans are emitted at scheduling time: the
                // enqueue carries the frame's bytes, the (future-dated)
                // arrival its backhaul crossing as `dur`.
                rec.push(Ev::new("backhaul_enqueue", base + round_wall).zone(zone).bytes(bytes));
                rec.push(
                    Ev::new("backhaul_arrive", base + arrive).zone(zone).dur(arrive - round_wall),
                );
            }
            queue.push(arrive, Event::BackhaulArrived { zone, flush });
            *pending_backhaul += 1;
        }
    }

    // Per-round state, indexed by device — hoisted out of the round loop
    // and reset-filled each round, so steady-state rounds reuse the same
    // nine allocations instead of remaking them. Event times within a
    // round are offsets from the round start, so the f64 arithmetic
    // matches the synchronous loop exactly; the virtual clock is
    // `exp.total_time_s`.
    let mut active = vec![false; m];
    let mut syncs = vec![false; m];
    let mut hs = vec![0usize; m];
    let mut plans: Vec<Option<AllocationPlan>> = (0..m).map(|_| None).collect();
    let mut losses = vec![0.0f64; m];
    let mut comp_s = vec![0.0f64; m];
    let mut comp_j = vec![0.0f64; m];
    let mut walls = vec![0.0f64; m];
    // Slowest active channel of each device's upload this round (-1 when it
    // did not sync) — the `crit_channel` attribution column.
    let mut slow_chs = vec![-1i64; m];
    // Downlink round state (inert when the downlink is disabled).
    let mut down_updates: Vec<Option<LgcUpdate>> = (0..m).map(|_| None).collect();
    'rounds: for round in 0..exp.cfg.rounds {
        active.iter_mut().for_each(|x| *x = false);
        syncs.iter_mut().for_each(|x| *x = false);
        hs.iter_mut().for_each(|x| *x = 0);
        plans.iter_mut().for_each(|x| *x = None);
        losses.iter_mut().for_each(|x| *x = 0.0);
        comp_s.iter_mut().for_each(|x| *x = 0.0);
        comp_j.iter_mut().for_each(|x| *x = 0.0);
        walls.iter_mut().for_each(|x| *x = 0.0);
        slow_chs.iter_mut().for_each(|x| *x = -1);
        down_updates.iter_mut().for_each(|x| *x = None);
        let mut round_wall = 0.0f64;
        let mut bh_wall = 0.0f64;
        let mut bytes_up = 0u64;
        let mut pending_compute = 0usize;
        let mut pending_layers = 0usize;
        let mut pending_backhaul = 0usize;
        let mut broadcast_scheduled = false;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut reward_acc = 0.0f64;
        let mut reward_n = 0usize;
        let mut pending_down = 0usize;
        let mut completed_uploads = 0u64;

        queue.push(0.0, Event::FadingTick);
        while let Some((t, ev)) = queue.pop() {
            match ev {
                Event::FadingTick => {
                    // Network dynamics advance for every device (in-budget
                    // or not), exactly like the synchronous loop.
                    for dev in &mut exp.devices {
                        dev.channels.step_round();
                    }
                    if let Some(dl) = exp.downlink.as_mut() {
                        dl.step_round();
                    }
                    if let Some(edge) = exp.edge.as_mut() {
                        edge.step_round();
                    }
                    // Scenario world: mobility & phases at round start.
                    // Barrier rounds never carry in-flight layers across a
                    // tick, so a barrier handoff can never drop one (the
                    // documented barrier/async divergence) — and held edge
                    // contributions never straddle a tick either, so
                    // barrier migration is structurally zero.
                    let clock = exp.total_time_s;
                    scenario_tick_legacy(exp, clock, rec);
                    for i in 0..m {
                        active[i] = exp.devices[i].meter.within_budget();
                    }
                    if active.iter().all(|&a| !a) {
                        break 'rounds; // every device out of budget
                    }
                    for i in 0..m {
                        syncs[i] = active[i] && (round + 1) % exp.sync_gap[i] == 0;
                    }
                    exp.received.iter_mut().for_each(|r| *r = false);
                    // The policy seam, in device order.
                    for i in 0..m {
                        if !active[i] {
                            continue;
                        }
                        let (h, plan) =
                            exp.policy
                                .decide(round, &exp.devices[i], exp.agents[i].as_mut());
                        hs[i] = h;
                        plans[i] = Some(plan);
                    }
                    if rec.on() {
                        for i in 0..m {
                            if active[i] {
                                rec.push(Ev::new("compute_start", clock).round(round).client(i));
                            }
                        }
                    }
                    // Local compute (Alg. 1 lines 5-7): parallel when the
                    // trainer split off per-device handles, else sequential.
                    // Both paths are bit-identical (per-device RNG streams).
                    let train_t0 = rec.phase_start();
                    if let Some(hnds) = handles.as_mut() {
                        parallel_local_steps(
                            &mut exp.devices,
                            hnds,
                            &hs,
                            &active,
                            exp.cfg.lr,
                            threads,
                            &mut losses,
                        )?;
                    } else {
                        for i in 0..m {
                            if active[i] {
                                losses[i] =
                                    exp.devices[i].local_steps(trainer, hs[i], exp.cfg.lr)?;
                            }
                        }
                    }
                    rec.phase_end(Phase::Train, train_t0);
                    for i in 0..m {
                        if !active[i] {
                            continue;
                        }
                        let (j, s) = exp.devices[i].compute_cost(hs[i]);
                        comp_j[i] = j;
                        comp_s[i] = s;
                        queue.push(s, Event::ComputeDone { device: i });
                        pending_compute += 1;
                    }
                }
                Event::ComputeDone { device: i } => {
                    pending_compute -= 1;
                    let base = exp.total_time_s;
                    if rec.on() {
                        rec.push(
                            Ev::new("compute_done", base + comp_s[i])
                                .round(round)
                                .client(i)
                                .dur(comp_s[i]),
                        );
                    }
                    let plan = plans[i].take().expect("plan decided at round start");
                    // Communication (lines 8-11): the compressor seam.
                    let (mut wall, comm_j, comm_money, bytes) = if syncs[i] {
                        let cp_t0 = rec.phase_start();
                        let (update, wall, costs) = exp.devices[i].compress_and_upload(&plan);
                        rec.phase_end(Phase::Compress, cp_t0);
                        for (ch, c) in costs.iter().enumerate() {
                            if c.time_s > 0.0
                                && (slow_chs[i] < 0
                                    || c.time_s > costs[slow_chs[i] as usize].time_s)
                            {
                                slow_chs[i] = ch as i64;
                            }
                        }
                        if !update.layers.is_empty() {
                            // One in-flight transfer per emitted layer:
                            // layer c rides the plan's c-th active channel
                            // (after zone projection — the mapping the
                            // device actually uploaded on) and lands after
                            // that channel's sampled transfer time.
                            let channels = exp.devices[i].effective_layer_channels(&plan);
                            for (layer_idx, &ch) in
                                channels.iter().take(update.layers.len()).enumerate()
                            {
                                if rec.on() {
                                    let arrive = base + comp_s[i] + costs[ch].time_s;
                                    rec.push(
                                        Ev::new("uplink_arrive", arrive)
                                            .round(round)
                                            .client(i)
                                            .layer(layer_idx)
                                            .channel(ch)
                                            .dur(costs[ch].time_s),
                                    );
                                }
                                queue.push(
                                    comp_s[i] + costs[ch].time_s,
                                    Event::LayerArrived { device: i, channel: ch, layer: layer_idx },
                                );
                                pending_layers += 1;
                            }
                            if exp.devices[i].sparse_wire() {
                                exp.server
                                    .decode_from_wire_into(&update, &mut exp.recv_bufs[i])?;
                            } else {
                                exp.recv_bufs[i] = update;
                            }
                            exp.received[i] = true;
                        }
                        let (j, mo, by) = TransferCost::fold_totals(&costs);
                        (wall, j, mo, by)
                    } else {
                        (0.0, 0.0, 0.0, 0) // no sync this round (lines 14-17)
                    };
                    wall += comp_s[i];
                    walls[i] = wall;
                    round_wall = round_wall.max(wall);
                    let dev = &mut exp.devices[i];
                    dev.meter.record_round(comp_j[i], comm_j, comm_money, wall);
                    if dev.prev_loss.is_nan() {
                        dev.prev_loss = losses[i];
                    }
                    let delta = dev.prev_loss - losses[i];
                    dev.prev_loss = losses[i];
                    dev.last_delta = delta;
                    bytes_up += bytes;
                    maybe_broadcast(
                        exp,
                        &mut queue,
                        pending_compute,
                        pending_layers,
                        &mut broadcast_scheduled,
                        round_wall,
                        &mut pending_backhaul,
                        rec,
                    );
                }
                Event::LayerArrived { .. } => {
                    pending_layers -= 1;
                    maybe_broadcast(
                        exp,
                        &mut queue,
                        pending_compute,
                        pending_layers,
                        &mut broadcast_scheduled,
                        round_wall,
                        &mut pending_backhaul,
                        rec,
                    );
                }
                Event::BackhaulArrived { flush, .. } => {
                    // A zone's partial-aggregate frame landed at the cloud.
                    // Barrier payloads ride `recv_bufs`; the held entries
                    // are markers, so just retire the flush. The round's
                    // wall now extends to the slowest backhaul, and the
                    // Broadcast fires when the last frame is in.
                    let edge = exp.edge.as_mut().expect("edge enabled");
                    drop(edge.take_arrived(flush));
                    pending_backhaul -= 1;
                    bh_wall = bh_wall.max(t);
                    round_wall = round_wall.max(t);
                    if pending_backhaul == 0 {
                        queue.push(round_wall, Event::Broadcast);
                    }
                }
                ev @ Event::UploadDone { .. } => {
                    unreachable!("{ev} is only scheduled by the cohort engines")
                }
                Event::Broadcast => {
                    // Reductions in device order: the f64 accumulation order
                    // of the synchronous loop, preserved.
                    let done = round + 1 == exp.cfg.rounds;
                    for i in 0..m {
                        if !active[i] {
                            continue;
                        }
                        loss_sum += losses[i];
                        loss_n += 1;
                        let delta = exp.devices[i].last_delta;
                        if let Some(r) = exp.policy.observe(
                            &exp.devices[i],
                            exp.agents[i].as_mut(),
                            delta,
                            done,
                        ) {
                            reward_acc += r;
                            reward_n += 1;
                        }
                    }
                    // Aggregation + broadcast (lines 18-22): the aggregator
                    // seam.
                    let received_idx: Vec<usize> =
                        (0..m).filter(|&i| exp.received[i]).collect();
                    completed_uploads = received_idx.len() as u64;
                    let base = exp.total_time_s;
                    if !received_idx.is_empty() {
                        let weights: Vec<f64> =
                            received_idx.iter().map(|&i| samples[i] as f64).collect();
                        let uploads: Vec<&LgcUpdate> =
                            received_idx.iter().map(|&i| &exp.recv_bufs[i]).collect();
                        let ag_t0 = rec.phase_start();
                        exp.server.set_round_weights(&weights);
                        exp.server.aggregate_and_apply(&uploads);
                        rec.phase_end(Phase::Aggregate, ag_t0);
                        if rec.on() {
                            let ev = Ev::new("aggregate", base + round_wall);
                            rec.push(ev.round(round).bytes(bytes_up));
                        }
                        if exp.downlink.is_none() {
                            // Legacy free-instant broadcast: the frozen
                            // `step_round` semantics, bit for bit.
                            for &i in &received_idx {
                                exp.devices[i].sync(&exp.server.params);
                            }
                        } else {
                            // Simulated downlink: each device's delta rides
                            // its downlink links as per-layer in-flight
                            // transfers; the round finalizes at the last
                            // `SyncConfirmed`.
                            for &i in &received_idx {
                                let dl = exp.downlink.as_mut().expect("downlink enabled");
                                let tr = dl.encode_for(
                                    i,
                                    &exp.server.params,
                                    round as u64 + 1,
                                    round,
                                );
                                // Edge-cached broadcast: the first fetch per
                                // (zone, version) pulls the model over the
                                // backhaul once; every other device in the
                                // zone streams from the edge cache and only
                                // pays its access-side cost.
                                let start = match exp.edge.as_mut() {
                                    Some(edge) if edge.cache_downlink() => {
                                        let zone = exp
                                            .scenario
                                            .as_ref()
                                            .map_or(0, |sc| sc.zone_of(i));
                                        edge.down_fetch(zone, round as u64 + 1, round_wall)
                                    }
                                    _ => round_wall,
                                };
                                let dev = &mut exp.devices[i];
                                // The upload was aggregated above: wipe the
                                // shipped progress (what `sync` did on the
                                // free path) before the delta streams in.
                                dev.begin_downlink_sync();
                                dev.meter.record_downlink(tr.energy_j, tr.money);
                                if tr.update.layers.is_empty() {
                                    dev.sync_state.synced_version = round as u64 + 1;
                                    dev.sync_state.synced_round = round;
                                    continue;
                                }
                                dev.sync_state.pending_layers = tr.update.layers.len();
                                for (c, &ch) in tr.channels.iter().enumerate() {
                                    if rec.on() {
                                        rec.push(
                                            Ev::new(
                                                "downlink_arrive",
                                                base + start + tr.costs[ch].time_s,
                                            )
                                            .round(round)
                                            .client(i)
                                            .layer(c)
                                            .channel(ch)
                                            .dur(tr.costs[ch].time_s),
                                        );
                                    }
                                    queue.push(
                                        start + tr.costs[ch].time_s,
                                        Event::DownlinkLayerArrived {
                                            device: i,
                                            channel: ch,
                                            layer: c,
                                        },
                                    );
                                }
                                down_updates[i] = Some(tr.update);
                                pending_down += 1;
                            }
                        }
                    }
                    if pending_down == 0 {
                        emit_barrier_record(
                            exp, trainer, log, &mut stats, round, round_wall, loss_sum,
                            loss_n, reward_acc, reward_n, bytes_up, &active, &walls,
                            completed_uploads, &comp_s, &slow_chs, bh_wall, rec,
                        )?;
                    }
                }
                Event::DownlinkLayerArrived { device: i, layer, .. } => {
                    let update = down_updates[i].as_ref().expect("downlink in flight");
                    exp.devices[i].apply_downlink_layer(&update.layers[layer]);
                    if exp.devices[i].sync_state.pending_layers == 0 {
                        // Hand the consumed payload back for buffer reuse.
                        if let (Some(u), Some(dl)) =
                            (down_updates[i].take(), exp.downlink.as_mut())
                        {
                            dl.recycle(u);
                        }
                        // Barrier semantics: confirmation means *every*
                        // layer landed (the async engines confirm at the
                        // base layer instead).
                        queue.push(t, Event::SyncConfirmed { device: i });
                    }
                }
                Event::SyncConfirmed { device: i } => {
                    if rec.on() {
                        rec.push(
                            Ev::new("sync_confirm", exp.total_time_s + t).round(round).client(i),
                        );
                    }
                    let dev = &mut exp.devices[i];
                    dev.sync_state.synced_version = round as u64 + 1;
                    dev.sync_state.synced_round = round;
                    pending_down -= 1;
                    // The barrier round now ends when the slowest downlink
                    // confirms, not when the slowest upload lands.
                    round_wall = round_wall.max(t);
                    if pending_down == 0 {
                        emit_barrier_record(
                            exp, trainer, log, &mut stats, round, round_wall, loss_sum,
                            loss_n, reward_acc, reward_n, bytes_up, &active, &walls,
                            completed_uploads, &comp_s, &slow_chs, bh_wall, rec,
                        )?;
                    }
                }
            }
        }
    }
    stats.events = queue.popped();
    exp.sim_stats = stats;
    Ok(())
}

/// Run every active device's local steps, striped over at most `threads`
/// scoped worker threads. Each job owns a disjoint `&mut Device` plus its
/// own [`DeviceTrainer`] handle, so the results are bit-identical to the
/// sequential path regardless of thread count or scheduling.
fn parallel_local_steps(
    devices: &mut [Device],
    handles: &mut [Box<dyn DeviceTrainer>],
    hs: &[usize],
    active: &[bool],
    lr: f32,
    threads: usize,
    losses: &mut [f64],
) -> Result<()> {
    struct Job<'a> {
        dev: &'a mut Device,
        tr: &'a mut dyn DeviceTrainer,
        h: usize,
        out: &'a mut f64,
        err: Option<anyhow::Error>,
    }
    let mut jobs: Vec<Job> = Vec::new();
    for (((dev, tr), (&h, &is_active)), out) in devices
        .iter_mut()
        .zip(handles.iter_mut())
        .zip(hs.iter().zip(active.iter()))
        .zip(losses.iter_mut())
    {
        if !is_active {
            continue;
        }
        jobs.push(Job { dev, tr: &mut **tr, h, out, err: None });
    }
    if jobs.is_empty() {
        return Ok(());
    }
    let chunk = jobs.len().div_ceil(threads.max(1));
    std::thread::scope(|s| {
        for batch in jobs.chunks_mut(chunk) {
            s.spawn(move || {
                for job in batch.iter_mut() {
                    match job.dev.local_steps_split(job.tr, job.h, lr) {
                        Ok(loss) => *job.out = loss,
                        Err(e) => job.err = Some(e),
                    }
                }
            });
        }
    });
    for job in jobs {
        if let Some(e) = job.err {
            return Err(e);
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Async modes (semi-async buffered / fully-async staleness-weighted)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy)]
enum AsyncKind {
    Semi { buffer_k: usize },
    Fully { staleness_decay: f64 },
}

/// Per-device lifecycle state for the async engine.
#[derive(Default)]
struct DevState {
    /// False once the device ran out of budget (it never restarts).
    alive: bool,
    /// Upload finished; waiting for the next broadcast to resync + restart.
    waiting: bool,
    started_at: f64,
    /// When this device's own transmission finishes (compute end + max
    /// channel transfer time, lost layers included — the radio is occupied
    /// either way, and loss is only detectable after TX ends).
    tx_end: f64,
    /// Server version the device last synchronized to.
    model_version: u64,
    /// Whether the last upload actually invoked the compressor (false for an
    /// all-silent plan): only then does the round's progress live in
    /// `delivered layers + error memory`, requiring a resync. A device that
    /// never compressed keeps accumulating locally, like barrier non-sync
    /// rounds.
    compressed: bool,
    loss: f64,
    comp_s: f64,
    comp_j: f64,
    plan: Option<AllocationPlan>,
    /// Delivered layers still in flight (scheduled arrivals outstanding).
    expected: usize,
    arrived: usize,
    /// Per-emitted-layer fates of the in-flight upload (scenario mode uses
    /// the channel mapping to resolve handoff drops; empty otherwise).
    transfers: Vec<LayerTransfer>,
    update: Option<LgcUpdate>,
    /// In-flight downlink broadcast payload (downlink enabled only).
    down_update: Option<LgcUpdate>,
    /// Server version the in-flight (or last confirmed) downlink brings
    /// the device to.
    down_version: u64,
    /// A fresh broadcast fired while the previous downlink's enhancement
    /// layers were still in flight: re-encode against the then-current
    /// global the moment the downlink radio frees up.
    wants_resync: bool,
    /// Slowest delivered channel of the in-flight upload (-1 when nothing
    /// was delivered) — the `crit_channel` attribution column.
    slow_ch: i64,
}

/// One completed upload parked in the semi-async server buffer.
struct Buffered {
    /// Owner device — the decoded update's buffer returns to
    /// `recv_bufs[device]` after aggregation (steady-state reuse).
    device: usize,
    update: LgcUpdate,
    weight: f64,
    loss: f64,
    staleness: u64,
    duration: f64,
}

/// Shared mutable context of the async run (everything that is not the
/// experiment, the queue, or per-device state).
struct AsyncCtx {
    kind: AsyncKind,
    samples: Vec<usize>,
    buffer: Vec<Buffered>,
    /// Devices with compute or layers still in flight.
    busy: usize,
    /// Devices with a downlink broadcast in flight toward them — they are
    /// neither busy nor waiting, but are *guaranteed future producers*
    /// (they restart at their base-layer `SyncConfirmed`), so the
    /// "fleet parked" flush heuristics must not fire while any remain or
    /// semi-async would degrade toward `buffer_k = 1` under slow
    /// downlinks.
    downlinking: usize,
    server_version: u64,
    last_record_t: f64,
    window_bytes: u64,
    window_rewards: f64,
    window_reward_n: usize,
    stats: SimStats,
    /// Critical contribution of the current record window (the longest
    /// completed upload): its duration, compute share, client and slowest
    /// channel. Reset at every record; -1 sentinels mean "none yet".
    win_crit_dur: f64,
    win_crit_comp: f64,
    win_crit_client: i64,
    win_crit_channel: i64,
}

fn run_async(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
    kind: AsyncKind,
    rec: &mut Recorder,
) -> Result<()> {
    let m = exp.devices.len();
    let mut queue = EventQueue::with_shards(resolve_shards(exp.cfg.shards));
    let mut st: Vec<DevState> = (0..m).map(|_| DevState::default()).collect();
    let mut ctx = AsyncCtx {
        kind,
        samples: (0..m).map(|i| trainer.device_samples(i)).collect(),
        buffer: Vec::new(),
        busy: 0,
        downlinking: 0,
        server_version: 0,
        last_record_t: exp.total_time_s,
        window_bytes: 0,
        window_rewards: 0.0,
        window_reward_n: 0,
        stats: SimStats::default(),
        win_crit_dur: -1.0,
        win_crit_comp: 0.0,
        win_crit_client: -1,
        win_crit_channel: -1,
    };
    let clock0 = exp.total_time_s;

    for i in 0..m {
        begin_device_round(exp, trainer, &mut st, &mut queue, &mut ctx, i, clock0, 0, rec)?;
    }
    if ctx.busy == 0 {
        exp.sim_stats = ctx.stats;
        return Ok(()); // nobody within budget
    }
    queue.push(clock0 + exp.cfg.fading_tick_s, Event::FadingTick);

    // Defensive bound: an async run always advances virtual time (compute
    // takes > 0 s), but a pathological setup where no record is ever emitted
    // (e.g. every upload erased forever) should fail loudly, not spin.
    const ASYNC_EVENT_CAP: u64 = 50_000_000;

    while log.records.len() < exp.cfg.rounds {
        let Some((t, ev)) = queue.pop() else { break };
        anyhow::ensure!(
            queue.popped() <= ASYNC_EVENT_CAP,
            "async engine exceeded {ASYNC_EVENT_CAP} events with only {} of {} records — \
             livelocked scenario?",
            log.records.len(),
            exp.cfg.rounds
        );
        match ev {
            Event::FadingTick => {
                // Channel dynamics on a fixed virtual period, decoupled from
                // device round boundaries.
                for dev in &mut exp.devices {
                    dev.channels.step_round();
                }
                if let Some(dl) = exp.downlink.as_mut() {
                    dl.step_round();
                }
                if let Some(edge) = exp.edge.as_mut() {
                    edge.step_round();
                }
                // Scenario mobility & phases run on the same virtual
                // period; a handoff here may strand in-flight layers on a
                // vanished channel — they resolve (restitute + drop) at
                // their scheduled arrival. A handoff also migrates the
                // device's contributions held at its old zone's edge node
                // (see `scenario_tick_legacy`).
                scenario_tick_legacy(exp, t, rec);
                if st.iter().any(|d| d.alive) {
                    queue.push(t + exp.cfg.fading_tick_s, Event::FadingTick);
                }
            }
            Event::ComputeDone { device: i } => {
                let plan = st[i].plan.take().expect("plan set at round start");
                // An all-silent plan never invokes the compressor — the
                // device must then skip the resync or its accumulated local
                // progress would be discarded (mirrors the barrier loop's
                // `received` guard).
                st[i].compressed = !plan.is_silent();
                // The lossy per-layer path: fading erasures happen, and lost
                // layers were restituted into the error memory by the
                // device (never silently discarded).
                let cp_t0 = rec.phase_start();
                let outcome = exp.devices[i].upload_lossy(&plan);
                rec.phase_end(Phase::Compress, cp_t0);
                let (comm_j, comm_money, bytes) = TransferCost::fold_totals(&outcome.costs);
                exp.devices[i].meter.record_round(
                    st[i].comp_j,
                    comm_j,
                    comm_money,
                    st[i].comp_s + outcome.wall_time_s,
                );
                ctx.window_bytes += bytes;
                ctx.stats.lost_layers += outcome.lost_layers as u64;
                // Policy learning signal, now that the meter is fresh.
                let loss = st[i].loss;
                let dev = &mut exp.devices[i];
                if dev.prev_loss.is_nan() {
                    dev.prev_loss = loss;
                }
                let delta = dev.prev_loss - loss;
                dev.prev_loss = loss;
                dev.last_delta = delta;
                let done = log.records.len() + 1 >= exp.cfg.rounds;
                if let Some(r) =
                    exp.policy
                        .observe(&exp.devices[i], exp.agents[i].as_mut(), delta, done)
                {
                    ctx.window_rewards += r;
                    ctx.window_reward_n += 1;
                }
                // One in-flight transfer per *delivered* layer.
                st[i].slow_ch = -1;
                for tr in &outcome.transfers {
                    if tr.delivered
                        && (st[i].slow_ch < 0
                            || outcome.costs[tr.channel].time_s
                                > outcome.costs[st[i].slow_ch as usize].time_s)
                    {
                        st[i].slow_ch = tr.channel as i64;
                    }
                }
                if rec.on() {
                    rec.push(Ev::new("compute_done", t).client(i).dur(st[i].comp_s));
                    for (layer_idx, tr) in outcome.transfers.iter().enumerate() {
                        if tr.delivered {
                            rec.push(
                                Ev::new("uplink_arrive", t + outcome.costs[tr.channel].time_s)
                                    .client(i)
                                    .layer(layer_idx)
                                    .channel(tr.channel)
                                    .dur(outcome.costs[tr.channel].time_s),
                            );
                        } else {
                            // Fading erasure: the layer's airtime was spent
                            // but it never arrives.
                            rec.push(
                                Ev::new("uplink_drop", t)
                                    .client(i)
                                    .layer(layer_idx)
                                    .channel(tr.channel),
                            );
                        }
                    }
                }
                let mut expected = 0usize;
                for (layer_idx, tr) in outcome.transfers.iter().enumerate() {
                    if tr.delivered {
                        queue.push(
                            t + outcome.costs[tr.channel].time_s,
                            Event::LayerArrived {
                                device: i,
                                channel: tr.channel,
                                layer: layer_idx,
                            },
                        );
                        expected += 1;
                    }
                }
                st[i].update = Some(outcome.update);
                st[i].transfers = outcome.transfers;
                st[i].expected = expected;
                st[i].arrived = 0;
                st[i].tx_end = t + outcome.wall_time_s;
                if expected == 0 {
                    // Nothing survived (or an all-silent plan): the upload
                    // completes once the device's own transmission ends (it
                    // cannot detect a loss earlier). If the compressor ran,
                    // the device still resyncs at the next broadcast — its
                    // progress was absorbed into delivered layers + error
                    // memory.
                    let tx_end = st[i].tx_end;
                    complete_upload(
                        exp, trainer, &mut st, &mut queue, &mut ctx, log, i, tx_end, rec,
                    )?;
                }
            }
            Event::LayerArrived { device: i, channel: ch, layer } => {
                // Scenario handoff drop: if a zone change tore down the
                // channel this layer was riding while it was in flight, the
                // layer never completes — its mass is restituted into the
                // device's error memory (the lost-layer path) and it leaves
                // the pending payload. Resolved lazily at the scheduled
                // arrival time, so no queue surgery is needed.
                if exp.scenario.is_some() && !exp.devices[i].channels.links[ch].is_up() {
                    // Emitted-layer index -> delivered-layer position:
                    // `update.layers` holds delivered layers in emitted
                    // order, so the position is the delivered-prefix count.
                    let pos = st[i].transfers[..layer]
                        .iter()
                        .filter(|tr| tr.delivered)
                        .count();
                    if let Some(update) = st[i].update.as_mut() {
                        if let Some(l) = update.layers.get_mut(pos) {
                            if !l.values.is_empty() {
                                drop_handoff_layer(&mut exp.devices[i], &mut exp.scenario, l);
                                if rec.on() {
                                    rec.push(
                                        Ev::new("uplink_drop", t)
                                            .client(i)
                                            .layer(layer)
                                            .channel(ch),
                                    );
                                }
                            }
                        }
                    }
                }
                st[i].arrived += 1;
                if st[i].arrived == st[i].expected {
                    complete_upload(exp, trainer, &mut st, &mut queue, &mut ctx, log, i, t, rec)?;
                }
            }
            Event::BackhaulArrived { flush, .. } => {
                // A zone's partial-aggregate frame landed at the cloud: the
                // folded contributions now flow through the sync-mode server
                // logic, with staleness measured here (the server may have
                // advanced while the frame crossed the backhaul).
                let edge = exp.edge.as_mut().expect("edge enabled");
                let arrived = edge.take_arrived(flush);
                match ctx.kind {
                    AsyncKind::Semi { buffer_k } => {
                        for c in arrived {
                            let staleness = ctx.server_version - c.version;
                            if exp.cfg.streaming {
                                if ctx.buffer.is_empty() {
                                    exp.server.stream_begin();
                                }
                                exp.server.stream_accumulate(&c.update, c.weight);
                                exp.recv_bufs[c.device] = c.update;
                                ctx.buffer.push(Buffered {
                                    device: c.device,
                                    update: LgcUpdate { dim: 0, layers: Vec::new() },
                                    weight: c.weight,
                                    loss: c.loss,
                                    staleness,
                                    duration: c.finish_s,
                                });
                            } else {
                                ctx.buffer.push(Buffered {
                                    device: c.device,
                                    update: c.update,
                                    weight: c.weight,
                                    loss: c.loss,
                                    staleness,
                                    duration: c.finish_s,
                                });
                            }
                        }
                        // Same FedBuff trigger as the flat path; "parked"
                        // additionally requires an idle edge (a pending
                        // frame is a guaranteed future producer).
                        let fleet_parked = ctx.busy == 0
                            && ctx.downlinking == 0
                            && !edge_kick_idle(exp, &mut queue, t, rec);
                        if ctx.buffer.len() >= buffer_k
                            || (fleet_parked && !ctx.buffer.is_empty())
                        {
                            aggregate_semi_buffer(exp, trainer, &mut ctx, log, t, buffer_k, rec)?;
                            queue.push(t, Event::Broadcast);
                        } else if fleet_parked && ctx.buffer.is_empty() {
                            queue.push(t, Event::Broadcast);
                        }
                    }
                    AsyncKind::Fully { staleness_decay } => {
                        // FedAsync applies each folded contribution as its
                        // own single-upload batch, in held (arrival) order.
                        for mut c in arrived {
                            let staleness = ctx.server_version - c.version;
                            let w = staleness_decay.powf(staleness as f64) as f32;
                            for layer in &mut c.update.layers {
                                for v in &mut layer.values {
                                    *v *= w;
                                }
                            }
                            if exp.cfg.streaming {
                                exp.server.stream_begin();
                                exp.server.stream_accumulate(&c.update, c.weight);
                                exp.server.stream_apply();
                            } else {
                                exp.server.set_round_weights(&[c.weight]);
                                exp.server.aggregate_and_apply(&[&c.update]);
                            }
                            exp.recv_bufs[c.device] = c.update;
                            ctx.server_version += 1;
                            push_async_record(
                                exp,
                                trainer,
                                &mut ctx,
                                log,
                                t,
                                &[(c.loss, c.finish_s, staleness)],
                                rec,
                            )?;
                        }
                        queue.push(t, Event::Broadcast);
                    }
                }
            }
            ev @ Event::UploadDone { .. } => {
                unreachable!("{ev} is only scheduled by the cohort engines")
            }
            Event::Broadcast => {
                // Resync + restart every device waiting on a fresh model —
                // but never before the device's own radio went quiet (a
                // lost layer's airtime was still spent).
                let era = log.records.len();
                for i in 0..m {
                    if !st[i].waiting {
                        continue;
                    }
                    if st[i].compressed && exp.downlink.is_some() {
                        // The fresh model travels over the simulated
                        // downlink; the device restarts at its base-layer
                        // `SyncConfirmed`, not here.
                        if exp.devices[i].sync_state.pending_layers > 0 {
                            // Previous broadcast's enhancement layers still
                            // occupy the downlink radio: re-encode once it
                            // frees (against the then-current global).
                            st[i].wants_resync = true;
                            continue;
                        }
                        st[i].waiting = false;
                        let restart_at = t.max(st[i].tx_end);
                        start_async_downlink(
                            exp, trainer, &mut st, &mut queue, &mut ctx, i, restart_at, era, rec,
                        )?;
                    } else {
                        st[i].waiting = false;
                        if st[i].compressed {
                            exp.devices[i].sync(&exp.server.params);
                            st[i].model_version = ctx.server_version;
                        }
                        let restart_at = t.max(st[i].tx_end);
                        begin_device_round(
                            exp, trainer, &mut st, &mut queue, &mut ctx, i, restart_at, era, rec,
                        )?;
                    }
                }
            }
            Event::DownlinkLayerArrived { device: i, layer, .. } => {
                {
                    let update = st[i].down_update.as_ref().expect("downlink in flight");
                    exp.devices[i].apply_downlink_layer(&update.layers[layer]);
                }
                if layer == 0 {
                    // Base layer landed: the device may proceed on a
                    // partial (base-only) model while enhancement layers
                    // trail — `SyncState::pending_layers` tracks them.
                    queue.push(t, Event::SyncConfirmed { device: i });
                }
                if exp.devices[i].sync_state.pending_layers == 0 {
                    // Whole broadcast landed: full confirmation (payload
                    // goes back to the downlink's buffer pool). Only now
                    // does the device stop counting as a pending producer —
                    // a base-restarted device with trailing layers may be
                    // waiting + wants_resync, which guarantees another
                    // downlink (and upload) the moment the radio frees.
                    ctx.downlinking -= 1;
                    if let (Some(u), Some(dl)) =
                        (st[i].down_update.take(), exp.downlink.as_mut())
                    {
                        dl.recycle(u);
                    }
                    let v = st[i].down_version;
                    let dev = &mut exp.devices[i];
                    dev.sync_state.synced_version = v;
                    dev.sync_state.synced_round = log.records.len();
                    if st[i].wants_resync {
                        // A newer global is owed: start its downlink now
                        // that the radio is free.
                        st[i].wants_resync = false;
                        st[i].waiting = false;
                        let era = log.records.len();
                        start_async_downlink(
                            exp, trainer, &mut st, &mut queue, &mut ctx, i, t, era, rec,
                        )?;
                    } else if let AsyncKind::Semi { buffer_k } = ctx.kind {
                        // If the device died on its download charges and it
                        // was the last pending producer, a partial buffer
                        // would strand forever — flush it now. (A pending
                        // edge frame still counts as a producer; the kick
                        // puts any sub-threshold partials on the backhaul.)
                        if ctx.busy == 0
                            && ctx.downlinking == 0
                            && !edge_kick_idle(exp, &mut queue, t, rec)
                            && !ctx.buffer.is_empty()
                        {
                            aggregate_semi_buffer(exp, trainer, &mut ctx, log, t, buffer_k, rec)?;
                            queue.push(t, Event::Broadcast);
                        }
                    }
                }
            }
            Event::SyncConfirmed { device: i } => {
                // The base model is in: restart the device on it, recording
                // the staleness gap it starts from (the server may have
                // aggregated further while the downlink was in flight).
                // `ctx.downlinking` stays up until the *full* broadcast
                // lands — the trailing layers keep the device a pending
                // producer for the flush heuristics.
                if rec.on() {
                    rec.push(Ev::new("sync_confirm", t).client(i));
                }
                st[i].model_version = st[i].down_version;
                exp.devices[i].sync_state.staleness =
                    ctx.server_version - st[i].down_version;
                let era = log.records.len();
                begin_device_round(exp, trainer, &mut st, &mut queue, &mut ctx, i, t, era, rec)?;
            }
        }
    }
    ctx.stats.events = queue.popped();
    exp.sim_stats = ctx.stats;
    Ok(())
}

/// Encode device `i`'s downlink broadcast (delta vs the server's mirror)
/// and schedule one [`Event::DownlinkLayerArrived`] per layer starting at
/// `now`. The device's downlink radio must be free (no pending layers).
/// An empty delta confirms instantly: the device restarts without waiting.
#[allow(clippy::too_many_arguments)]
fn start_async_downlink(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    st: &mut [DevState],
    queue: &mut EventQueue,
    ctx: &mut AsyncCtx,
    i: usize,
    now: f64,
    era: usize,
    rec: &mut Recorder,
) -> Result<()> {
    debug_assert_eq!(exp.devices[i].sync_state.pending_layers, 0);
    let dl = exp.downlink.as_mut().expect("downlink enabled");
    let tr = dl.encode_for(i, &exp.server.params, ctx.server_version, era);
    let dev = &mut exp.devices[i];
    // Only compressed (upload-complete) devices reach here: their round's
    // progress lives in `delivered layers + error memory`, so wipe it from
    // the replicas — exactly what `Device::sync` did on the free-broadcast
    // path — before the delta layers stream in.
    dev.begin_downlink_sync();
    dev.meter.record_downlink(tr.energy_j, tr.money);
    st[i].down_version = ctx.server_version;
    if tr.update.layers.is_empty() {
        dev.sync_state.synced_version = ctx.server_version;
        dev.sync_state.synced_round = era;
        dev.sync_state.staleness = 0;
        st[i].model_version = ctx.server_version;
        return begin_device_round(exp, trainer, st, queue, ctx, i, now, era, rec);
    }
    dev.sync_state.pending_layers = tr.update.layers.len();
    // Edge-cached broadcast: the first fetch per (zone, version) pulls the
    // model over the backhaul once; later devices in the zone stream from
    // the edge cache and only pay the access-side cost.
    let start = match exp.edge.as_mut() {
        Some(edge) if edge.cache_downlink() => {
            let zone = exp.scenario.as_ref().map_or(0, |sc| sc.zone_of(i));
            edge.down_fetch(zone, ctx.server_version, now)
        }
        _ => now,
    };
    for (c, &ch) in tr.channels.iter().enumerate() {
        if rec.on() {
            rec.push(
                Ev::new("downlink_arrive", start + tr.costs[ch].time_s)
                    .client(i)
                    .layer(c)
                    .channel(ch)
                    .dur(tr.costs[ch].time_s),
            );
        }
        queue.push(
            start + tr.costs[ch].time_s,
            Event::DownlinkLayerArrived { device: i, channel: ch, layer: c },
        );
    }
    st[i].down_update = Some(tr.update);
    ctx.downlinking += 1;
    Ok(())
}

/// Start one device round at virtual time `now`: policy decision, local
/// steps, and a `ComputeDone` scheduled after the compute time.
#[allow(clippy::too_many_arguments)]
fn begin_device_round(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    st: &mut [DevState],
    queue: &mut EventQueue,
    ctx: &mut AsyncCtx,
    i: usize,
    now: f64,
    era: usize,
    rec: &mut Recorder,
) -> Result<()> {
    if !exp.devices[i].meter.within_budget() {
        if st[i].alive && rec.on() {
            rec.push(Ev::new("client_offline", now).client(i));
        }
        st[i].alive = false;
        return Ok(());
    }
    if rec.on() {
        rec.push(Ev::new("compute_start", now).round(era).client(i));
    }
    let (h, plan) = exp.policy.decide(era, &exp.devices[i], exp.agents[i].as_mut());
    let train_t0 = rec.phase_start();
    let loss = exp.devices[i].local_steps(trainer, h, exp.cfg.lr)?;
    rec.phase_end(Phase::Train, train_t0);
    let (comp_j, comp_s) = exp.devices[i].compute_cost(h);
    let s = &mut st[i];
    s.alive = true;
    s.waiting = false;
    s.started_at = now;
    s.loss = loss;
    s.comp_s = comp_s;
    s.comp_j = comp_j;
    s.plan = Some(plan);
    s.expected = 0;
    s.arrived = 0;
    s.update = None;
    queue.push(now + comp_s, Event::ComputeDone { device: i });
    ctx.busy += 1;
    Ok(())
}

/// All of device `i`'s delivered layers have landed: hand the update to the
/// sync-mode server logic and park the device until the next broadcast.
#[allow(clippy::too_many_arguments)]
fn complete_upload(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    st: &mut [DevState],
    queue: &mut EventQueue,
    ctx: &mut AsyncCtx,
    log: &mut RunLog,
    i: usize,
    t: f64,
    rec: &mut Recorder,
) -> Result<()> {
    st[i].waiting = true;
    ctx.busy -= 1;
    let duration = t - st[i].started_at;
    let staleness = ctx.server_version - st[i].model_version;
    // Window attribution: remember the longest completed upload — it is
    // the record window's critical path (compute + uplink; the rest of the
    // window is `wait`).
    if duration > ctx.win_crit_dur {
        ctx.win_crit_dur = duration;
        ctx.win_crit_comp = st[i].comp_s;
        ctx.win_crit_client = i as i64;
        ctx.win_crit_channel = st[i].slow_ch;
    }
    let mut update = st[i].update.take().expect("upload in flight");
    // Layers emptied by a handoff drop are already restituted — purge them
    // so the server never sees (or decodes) a torn-down layer.
    update.layers.retain(|l| !l.values.is_empty());
    // Round-trip through the wire format, as the server sees it (reusing the
    // per-device decode buffer).
    if !update.layers.is_empty() && exp.devices[i].sparse_wire() {
        let mut buf = std::mem::replace(
            &mut exp.recv_bufs[i],
            LgcUpdate { dim: 0, layers: Vec::new() },
        );
        exp.server.decode_from_wire_into(&update, &mut buf)?;
        update = buf;
    }
    if !update.layers.is_empty() && exp.edge.is_some() {
        // Edge tier: the upload terminates at the device's zone node, not
        // at the cloud. The contribution is held (with the metadata the
        // server will need at application time) until the zone's partial
        // aggregate crosses the backhaul — the sync-mode server logic then
        // runs at `BackhaulArrived`, with staleness measured there.
        let zone = exp.scenario.as_ref().map_or(0, |sc| sc.zone_of(i));
        if rec.on() {
            rec.push(Ev::new("edge_fold", t).client(i).zone(zone));
        }
        let edge = exp.edge.as_mut().expect("edge enabled");
        edge.hold(
            zone,
            HeldContribution {
                device: i,
                update,
                weight: ctx.samples[i] as f64,
                version: st[i].model_version,
                loss: st[i].loss,
                reward: f64::NAN,
                finish_s: duration,
            },
        );
        if edge.ready_to_flush(zone) {
            if let Some((flush, arrive, bytes)) = edge.begin_flush(zone, t) {
                if rec.on() {
                    rec.push(Ev::new("backhaul_enqueue", t).zone(zone).bytes(bytes));
                    rec.push(Ev::new("backhaul_arrive", arrive).zone(zone).dur(arrive - t));
                }
                queue.push(arrive, Event::BackhaulArrived { zone, flush });
            }
        }
    } else if !update.layers.is_empty() {
        match ctx.kind {
            AsyncKind::Semi { buffer_k: _ } => {
                if exp.cfg.streaming {
                    // Fold into the server's running aggregate on arrival;
                    // only record metadata is parked, and the decode buffer
                    // returns to its owner immediately — the server never
                    // holds O(buffer_k) decoded updates.
                    if ctx.buffer.is_empty() {
                        exp.server.stream_begin();
                    }
                    exp.server.stream_accumulate(&update, ctx.samples[i] as f64);
                    exp.recv_bufs[i] = update;
                    ctx.buffer.push(Buffered {
                        device: i,
                        update: LgcUpdate { dim: 0, layers: Vec::new() },
                        weight: ctx.samples[i] as f64,
                        loss: st[i].loss,
                        staleness,
                        duration,
                    });
                } else {
                    ctx.buffer.push(Buffered {
                        device: i,
                        update,
                        weight: ctx.samples[i] as f64,
                        loss: st[i].loss,
                        staleness,
                        duration,
                    });
                }
            }
            AsyncKind::Fully { staleness_decay } => {
                // FedAsync-style application: scale by decay^staleness, then
                // flow through the aggregator seam as a single-upload batch.
                // (powf, not powi: staleness is unbounded, and decay in
                // (0, 1] underflows to 0 for ultra-stale updates — exactly
                // the documented suppression.)
                let w = staleness_decay.powf(staleness as f64) as f32;
                for layer in &mut update.layers {
                    for v in &mut layer.values {
                        *v *= w;
                    }
                }
                if exp.cfg.streaming {
                    exp.server.stream_begin();
                    exp.server.stream_accumulate(&update, ctx.samples[i] as f64);
                    exp.server.stream_apply();
                } else {
                    exp.server.set_round_weights(&[ctx.samples[i] as f64]);
                    exp.server.aggregate_and_apply(&[&update]);
                }
                // Hand the decode buffer back for reuse by the next upload.
                exp.recv_bufs[i] = update;
                ctx.server_version += 1;
                push_async_record(
                    exp,
                    trainer,
                    ctx,
                    log,
                    t,
                    &[(st[i].loss, duration, staleness)],
                    rec,
                )?;
                queue.push(t, Event::Broadcast);
            }
        }
    } else if matches!(ctx.kind, AsyncKind::Fully { .. }) {
        // Entirely lost: nothing to apply, but resync the device (its
        // progress sits in the error memory now).
        queue.push(t, Event::Broadcast);
    }
    if exp.edge.is_some() {
        // With the edge tier, the buffer only fills at `BackhaulArrived`;
        // here the sole risk is a parked fleet with partials stranded below
        // their zones' flush thresholds. Kick them onto the backhaul — if
        // nothing was pending at all, fall through to the flat parked-fleet
        // handling so the run still makes progress.
        if ctx.busy == 0 && ctx.downlinking == 0 && !edge_kick_idle(exp, queue, t, rec) {
            if let AsyncKind::Semi { buffer_k } = ctx.kind {
                if !ctx.buffer.is_empty() {
                    aggregate_semi_buffer(exp, trainer, ctx, log, t, buffer_k, rec)?;
                }
            }
            queue.push(t, Event::Broadcast);
        }
    } else if let AsyncKind::Semi { buffer_k } = ctx.kind {
        let fleet_parked = ctx.busy == 0 && ctx.downlinking == 0;
        if ctx.buffer.len() >= buffer_k || (fleet_parked && !ctx.buffer.is_empty()) {
            // FedBuff trigger — or a flush when the whole fleet is parked on
            // a buffer that can no longer fill (devices mid-download still
            // count as producers: their uploads are coming).
            aggregate_semi_buffer(exp, trainer, ctx, log, t, buffer_k, rec)?;
            queue.push(t, Event::Broadcast);
        } else if fleet_parked && ctx.buffer.is_empty() {
            // Everyone waiting, nothing aggregable (all uploads erased):
            // broadcast anyway so the fleet resyncs and retries.
            queue.push(t, Event::Broadcast);
        }
    }
    Ok(())
}

/// With the whole fleet parked, no future upload can push a zone past its
/// flush threshold — put every held partial on the backhaul now. Returns
/// true while any edge work is still pending (frames just flushed, or
/// already in flight): a `BackhaulArrived` is then guaranteed to drive the
/// run forward, so the caller must not force a flush/broadcast. Always
/// false when the edge tier is disabled.
fn edge_kick_idle(
    exp: &mut Experiment,
    queue: &mut EventQueue,
    now: f64,
    rec: &mut Recorder,
) -> bool {
    let Some(edge) = exp.edge.as_mut() else { return false };
    for (zone, flush, arrive, bytes) in edge.flush_all(now) {
        if rec.on() {
            rec.push(Ev::new("backhaul_enqueue", now).zone(zone).bytes(bytes));
            rec.push(Ev::new("backhaul_arrive", arrive).zone(zone).dur(arrive - now));
        }
        queue.push(arrive, Event::BackhaulArrived { zone, flush });
    }
    edge.pending_total() > 0
}

/// Aggregate the first `min(len, buffer_k)` buffered uploads through the
/// aggregator seam and emit one round record.
fn aggregate_semi_buffer(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    ctx: &mut AsyncCtx,
    log: &mut RunLog,
    t: f64,
    buffer_k: usize,
    rec: &mut Recorder,
) -> Result<()> {
    // Streaming folds every buffered upload on arrival, so a flush always
    // drains the whole buffer; the batch path takes at most `buffer_k`.
    let take = if exp.cfg.streaming {
        ctx.buffer.len()
    } else {
        ctx.buffer.len().min(buffer_k.max(1))
    };
    let batch: Vec<Buffered> = ctx.buffer.drain(..take).collect();
    let contributions: Vec<(f64, f64, u64)> =
        batch.iter().map(|b| (b.loss, b.duration, b.staleness)).collect();
    let ag_t0 = rec.phase_start();
    if exp.cfg.streaming {
        exp.server.stream_apply();
        // Decode buffers were already handed back on arrival; the parked
        // entries carry empty placeholders.
    } else {
        let weights: Vec<f64> = batch.iter().map(|b| b.weight).collect();
        let uploads: Vec<&LgcUpdate> = batch.iter().map(|b| &b.update).collect();
        exp.server.set_round_weights(&weights);
        exp.server.aggregate_and_apply(&uploads);
        // Return the decode buffers to their owner devices for steady-state
        // reuse (each next upload decodes into them again).
        for b in batch {
            exp.recv_bufs[b.device] = b.update;
        }
    }
    rec.phase_end(Phase::Aggregate, ag_t0);
    ctx.server_version += 1;
    push_async_record(exp, trainer, ctx, log, t, &contributions, rec)
}

/// Emit one async-mode [`RoundRecord`]: one per server aggregation, with the
/// window since the previous record as its time span.
fn push_async_record(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    ctx: &mut AsyncCtx,
    log: &mut RunLog,
    now: f64,
    contributions: &[(f64, f64, u64)],
    rec: &mut Recorder,
) -> Result<()> {
    let round = log.records.len();
    let done = round + 1 >= exp.cfg.rounds;
    let train_loss = if contributions.is_empty() {
        f64::NAN
    } else {
        contributions.iter().map(|c| c.0).sum::<f64>() / contributions.len() as f64
    };
    let mut finishes: Vec<f64> = contributions.iter().map(|c| c.1).collect();
    let stale_updates = contributions.iter().filter(|c| c.2 > 0).count() as u64;
    ctx.stats.stale_updates += stale_updates;
    // Staleness distribution of the window's applied updates, and the
    // window's downlink totals (zero when the downlink is disabled).
    let mut stale_vals: Vec<f64> = contributions.iter().map(|c| c.2 as f64).collect();
    let staleness_p50 = percentile(&mut stale_vals, 50.0);
    let staleness_p95 = percentile(&mut stale_vals, 95.0);
    let down = exp
        .downlink
        .as_mut()
        .map(|d| d.window.take())
        .unwrap_or_default();
    let sw = exp
        .scenario
        .as_mut()
        .map(|s| s.window.take())
        .unwrap_or_default();
    let zone_p50 = exp.scenario.as_ref().map(|s| s.zone_p50()).unwrap_or(0.0);
    let (eval_loss, eval_acc) = if round % exp.cfg.eval_every == 0 || done {
        trainer.eval(&exp.server.params)?
    } else {
        (f64::NAN, f64::NAN)
    };
    let (tot_energy, tot_money) = exp.devices.iter().fold((0.0, 0.0), |acc, d| {
        (acc.0 + d.meter.energy_used, acc.1 + d.meter.money_used)
    });
    let finish_p50_s = percentile(&mut finishes, 50.0);
    let finish_p95_s = percentile(&mut finishes, 95.0);
    let (backhaul_bytes, backhaul_p95_s, migrated_handoff, edge_rounds_bound) =
        drain_edge_window(exp, finish_p95_s);
    // Window attribution: the longest completed upload is the critical
    // path; everything past it is `wait` (server idle / buffer residency).
    let round_time = now - ctx.last_record_t;
    let mut attr = Attribution::none();
    if ctx.win_crit_client >= 0 {
        attr.compute = ctx.win_crit_comp;
        attr.uplink = (ctx.win_crit_dur - ctx.win_crit_comp).max(0.0);
        attr.crit_client = ctx.win_crit_client;
        attr.crit_channel = ctx.win_crit_channel;
    }
    attr.finalize(round_time);
    let record = RoundRecord {
        round,
        train_loss,
        eval_loss,
        eval_acc,
        energy_j: tot_energy,
        money: tot_money,
        round_time_s: round_time,
        total_time_s: now,
        bytes_up: ctx.window_bytes,
        drl_reward: if ctx.window_reward_n > 0 {
            ctx.window_rewards / ctx.window_reward_n as f64
        } else {
            f64::NAN
        },
        finish_p50_s,
        finish_p95_s,
        stale_updates,
        sampled: contributions.len() as u64,
        completed: contributions.len() as u64,
        dropped_offline: 0,
        staleness_p50,
        staleness_p95,
        down_bytes: down.bytes,
        down_energy_j: down.energy_j,
        down_money: down.money,
        handoffs: sw.handoffs,
        dropped_handoff: sw.dropped_handoff,
        zone_p50,
        backhaul_bytes,
        backhaul_p95_s,
        migrated_handoff,
        edge_rounds_bound,
        bound_by: attr.bound_by(),
        crit_client: attr.crit_client,
        crit_channel: attr.crit_channel,
    };
    if rec.on() {
        rec.push(Ev::new("aggregate", now).round(round).bytes(ctx.window_bytes));
        rec.push_round(now, round, round_time, &attr);
    }
    exp.total_time_s = now;
    ctx.last_record_t = now;
    ctx.window_bytes = 0;
    ctx.window_rewards = 0.0;
    ctx.window_reward_n = 0;
    ctx.win_crit_dur = -1.0;
    ctx.win_crit_comp = 0.0;
    ctx.win_crit_client = -1;
    ctx.win_crit_channel = -1;
    log.push(record);
    ctx.stats.records += 1;
    Ok(())
}

// ---------------------------------------------------------------------------
// Population cohort engines
// ---------------------------------------------------------------------------
//
// Population mode replaces the permanently-materialized fleet with a
// `Population` of cheap per-client specs: each round (barrier) or slot
// (async) materializes a full `Device` only for the sampled clients and
// demobilizes them afterwards, so resident memory is O(model + cohort)
// regardless of population size (`Population::peak_materialized` proves
// the bound in tests/population.rs).

/// Dispatch the population cohort engine for the experiment's sync mode.
/// The population and sampler are taken out for the duration of the run
/// (same pattern as the split trainer handles) and always handed back.
fn run_cohort(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
    rec: &mut Recorder,
) -> Result<()> {
    let mut pop = exp.population.take().expect("population mode");
    let mut sampler = exp
        .sampler
        .take()
        .expect("population mode always carries a sampler");
    let result = match exp.sync_mode {
        SyncMode::Barrier => {
            cohort_barrier_rounds(exp, trainer, log, &mut pop, sampler.as_mut(), rec)
        }
        SyncMode::SemiAsync { buffer_k } => cohort_async_rounds(
            exp,
            trainer,
            log,
            &mut pop,
            sampler.as_mut(),
            AsyncKind::Semi { buffer_k },
            rec,
        ),
        SyncMode::FullyAsync { staleness_decay } => cohort_async_rounds(
            exp,
            trainer,
            log,
            &mut pop,
            sampler.as_mut(),
            AsyncKind::Fully { staleness_decay },
            rec,
        ),
    };
    exp.population = Some(pop);
    exp.sampler = Some(sampler);
    result
}

/// Lazily materialize client `id`'s DRL agent (population mode). Agents
/// are per-client *learning* state — they persist for the rest of the run
/// once created, but creation is deferred to first participation so
/// build-time memory stays O(population × spec) rather than O(population ×
/// agent). The fork tag matches the legacy builder's exactly (and
/// `Experiment::rng` is never consumed during runs), so full participation
/// stays bit-for-bit.
fn ensure_agent(exp: &mut Experiment, id: usize) {
    if exp.policy.needs_agents() && exp.agents[id].is_none() {
        let (d_min, d_total) = exp.d_bounds();
        let staleness_aware = exp.downlink.is_some();
        let rng = exp.rng().fork(0xD_00 + id as u64);
        exp.agents[id] = Some(DeviceAgent::new_with(
            exp.cfg.channel_types.len(),
            exp.cfg.h_max,
            d_total,
            d_min,
            exp.cfg.drl.clone(),
            rng,
            staleness_aware,
        ));
    }
}

/// Barrier-synchronous cohort rounds. With `FullParticipation`, a
/// population the size of the device fleet, no churn and batch aggregation
/// this replays `Experiment::step_round` **bit for bit** for every policy
/// that uploads each round (all the built-ins) — the materialize → decide
/// → train → upload → observe per-client sequence, the f64 reduction
/// order, and every RNG stream are identical (the equivalence oracle in
/// tests/population.rs). One documented divergence: a policy emitting an
/// all-silent plan keeps the drifted local model across rounds in the
/// legacy loop, whereas demobilization parks the pending progress in the
/// error memory and rematerializes at the current global. Streaming
/// aggregation folds each upload on arrival instead of batching
/// (documented float tolerance vs batch).
fn cohort_barrier_rounds(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
    pop: &mut Population,
    sampler: &mut dyn ClientSampler,
    rec: &mut Recorder,
) -> Result<()> {
    let mut stats = SimStats::default();
    let streaming = exp.cfg.streaming;
    // The O(population) sweeps in step_round() run chunked across the
    // resolved shard count (bit-identical for any value — private
    // per-client RNG streams).
    pop.set_sweep_threads(resolve_shards(exp.cfg.shards));
    // Reusable decode buffers: one per received upload (batch) or a single
    // shared one (streaming — the upload is folded the moment it decodes).
    let mut decoded: Vec<LgcUpdate> = Vec::new();
    // Per-round cohort state, hoisted and cleared each round so a
    // steady-state round reuses the same six allocations.
    let mut cohort: Vec<usize> = Vec::new();
    let mut live: Vec<(Device, bool, bool)> = Vec::new();
    let mut received_live: Vec<usize> = Vec::new();
    let mut weights: Vec<f64> = Vec::new();
    let mut finishes: Vec<f64> = Vec::new();
    let mut zones_uploaded: Vec<usize> = Vec::new();
    'rounds: for round in 0..exp.cfg.rounds {
        // 1. Population-wide dynamics: every demobilized client's fading
        // chains (nobody is materialized between rounds) + availability,
        // plus every client's downlink fading chain when enabled.
        pop.step_round();
        if let Some(dl) = exp.downlink.as_mut() {
            dl.step_round();
        }
        if let Some(edge) = exp.edge.as_mut() {
            edge.step_round();
        }
        // Scenario mobility & phases advance once per round. Nobody is
        // materialized between rounds, so no live bundle needs immediate
        // reconfiguration — each sampled client's channels are configured
        // to its current zone at materialization below.
        if let Some(sc) = exp.scenario.as_mut() {
            let _ = sc.tick(exp.total_time_s);
            if let Some(edge) = exp.edge.as_mut() {
                edge.set_phase_scale(sc.backhaul_scale());
            }
        }
        if !pop.any_within_budget() {
            break 'rounds;
        }
        // 2. Cohort selection: the sampler seam (in-place, reusing the
        // hoisted buffer).
        sampler.sample_into(round, pop, &mut cohort);
        live.clear();
        received_live.clear();
        weights.clear();
        finishes.clear();
        // Zones with at least one received upload this round: each owes one
        // partial-aggregate frame on its backhaul (accounting-only, like
        // the cohort downlink — see the edge module docs).
        zones_uploaded.clear();
        let base = exp.total_time_s;
        let mut round_wall = 0.0f64;
        // Critical-path tracking for the attribution columns.
        let mut crit_wall = -1.0f64;
        let mut crit_comp = 0.0f64;
        let mut crit_client = -1i64;
        let mut crit_ch = -1i64;
        let mut attr_backhaul = 0.0f64;
        let mut attr_downlink = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut bytes_up = 0u64;
        let mut reward_acc = 0.0f64;
        let mut reward_n = 0usize;
        let mut dropped_offline = 0u64;
        let mut nrecv = 0usize;
        if streaming {
            exp.server.stream_begin();
        }
        // 3. Per-client round, in ascending id order (the reference loop's
        // device order): materialize, decide, train, upload, account.
        for &id in &cohort {
            if pop.is_materialized(id) || !pop.within_budget(id) || !pop.online(id) {
                continue; // the reference loop's per-device budget skip
            }
            ensure_agent(exp, id);
            if rec.on() {
                rec.push(Ev::new("compute_start", base).round(round).client(id));
            }
            let mut dev = pop.materialize(id, &exp.server.params);
            // The client wakes up in its *current* zone: availability mask,
            // fading params, dynamics and scales applied to the uplink and
            // (accounting-only) downlink bundles.
            if let Some(sc) = exp.scenario.as_ref() {
                sc.configure(id, &mut dev.channels);
                if let Some(dl) = exp.downlink.as_mut() {
                    sc.configure(id, dl.links_mut(id));
                }
            }
            let (h, plan) = exp.policy.decide(round, &dev, exp.agents[id].as_mut());
            let train_t0 = rec.phase_start();
            let loss = dev.local_steps_sharded(trainer, pop.shard(id), h, exp.cfg.lr)?;
            rec.phase_end(Phase::Train, train_t0);
            loss_sum += loss;
            loss_n += 1;
            let (comp_j, comp_s) = dev.compute_cost(h);
            let compressed = !plan.is_silent();
            let cp_t0 = rec.phase_start();
            let (update, mut wall, costs) = dev.compress_and_upload(&plan);
            rec.phase_end(Phase::Compress, cp_t0);
            let mut received = false;
            if !update.layers.is_empty() {
                if pop.midround_offline(id) {
                    // The radio went dark before the server ACK: the whole
                    // upload feeds the lost-layer restitution path (mass
                    // delayed into the error memory, never destroyed).
                    dev.restitute_update(&update);
                    dropped_offline += 1;
                    if rec.on() {
                        rec.push(Ev::new("churn_drop", base).round(round).client(id));
                    }
                } else {
                    let slot = if streaming { 0 } else { nrecv };
                    if decoded.len() <= slot {
                        decoded.push(LgcUpdate { dim: 0, layers: Vec::new() });
                    }
                    if dev.sparse_wire() {
                        exp.server.decode_from_wire_into(&update, &mut decoded[slot])?;
                    } else {
                        decoded[slot] = update;
                    }
                    // `SpecSeed::samples` caches `device_samples(shard)`
                    // at build time (shard sizes are static), so this is
                    // the reference loop's exact weight without re-querying
                    // the trainer — the one weight convention of every
                    // cohort path.
                    let w = pop.samples(id) as f64;
                    if streaming {
                        exp.server.stream_accumulate(&decoded[slot], w);
                    } else {
                        weights.push(w);
                    }
                    nrecv += 1;
                    received = true;
                    if exp.edge.is_some() {
                        let z = exp.scenario.as_ref().map_or(0, |sc| sc.zone_of(id));
                        if !zones_uploaded.contains(&z) {
                            zones_uploaded.push(z);
                        }
                    }
                }
            }
            let (comm_j, comm_money, bytes) = TransferCost::fold_totals(&costs);
            wall += comp_s;
            round_wall = round_wall.max(wall);
            if rec.on() {
                let done_ev = Ev::new("compute_done", base + comp_s).round(round).client(id);
                rec.push(done_ev.dur(comp_s));
                for (ch, c) in costs.iter().enumerate() {
                    if c.time_s > 0.0 {
                        rec.push(
                            Ev::new("uplink_arrive", base + comp_s + c.time_s)
                                .round(round)
                                .client(id)
                                .channel(ch)
                                .dur(c.time_s),
                        );
                    }
                }
            }
            if wall > crit_wall {
                crit_wall = wall;
                crit_comp = comp_s;
                crit_client = id as i64;
                crit_ch = -1;
                for (ch, c) in costs.iter().enumerate() {
                    if c.time_s > 0.0
                        && (crit_ch < 0 || c.time_s > costs[crit_ch as usize].time_s)
                    {
                        crit_ch = ch as i64;
                    }
                }
            }
            finishes.push(wall);
            dev.meter.record_round(comp_j, comm_j, comm_money, wall);
            if dev.prev_loss.is_nan() {
                dev.prev_loss = loss;
            }
            let delta = dev.prev_loss - loss;
            dev.prev_loss = loss;
            dev.last_delta = delta;
            bytes_up += bytes;
            let done = round + 1 == exp.cfg.rounds;
            if let Some(r) = exp.policy.observe(&dev, exp.agents[id].as_mut(), delta, done) {
                reward_acc += r;
                reward_n += 1;
            }
            if received {
                received_live.push(live.len());
            }
            live.push((dev, compressed, received));
        }
        stats.dropped_offline += dropped_offline;
        // 4. Aggregation + broadcast: the aggregator seam (batch order ==
        // ascending client id, exactly the reference loop).
        let ag_t0 = rec.phase_start();
        let applied = if streaming {
            exp.server.stream_apply()
        } else if nrecv > 0 {
            let uploads: Vec<&LgcUpdate> = decoded[..nrecv].iter().collect();
            exp.server.set_round_weights(&weights);
            exp.server.aggregate_and_apply(&uploads);
            true
        } else {
            false
        };
        rec.phase_end(Phase::Aggregate, ag_t0);
        if applied {
            // Each contributing zone's partial crossed the backhaul before
            // the cloud could aggregate: the round extends by the slowest
            // frame (the per-zone flushes run in parallel).
            if let Some(edge) = exp.edge.as_mut() {
                let mut bh_wall = 0.0f64;
                for &z in &zones_uploaded {
                    bh_wall = bh_wall.max(edge.charge_flush(z));
                }
                round_wall += bh_wall;
                attr_backhaul = bh_wall;
            }
            let mut down_wall = 0.0f64;
            for &k in &received_live {
                let dev = &mut live[k].0;
                dev.sync(&exp.server.params);
                if let Some(dl) = exp.downlink.as_mut() {
                    // Accounting-only fidelity (see downlink module docs):
                    // the client got the exact global above; the
                    // broadcast's bytes/energy/money/time are charged from
                    // the budget-determined layer sizes.
                    let (mut wall, e, mo, _by) =
                        dl.charge_broadcast(dev.id, exp.server.params.len());
                    // Edge-cached broadcast: the zone's first fetch of this
                    // version pulls the model over the backhaul once; the
                    // zone's other clients stream from the cache.
                    if let Some(edge) = exp.edge.as_mut() {
                        if edge.cache_downlink() {
                            let z = exp
                                .scenario
                                .as_ref()
                                .map_or(0, |sc| sc.zone_of(dev.id));
                            wall += edge.down_fetch(z, round as u64 + 1, 0.0);
                        }
                    }
                    dev.meter.record_downlink(e, mo);
                    dev.sync_state.synced_version = round as u64 + 1;
                    dev.sync_state.synced_round = round;
                    down_wall = down_wall.max(wall);
                }
            }
            // The round now ends when the slowest broadcast completes
            // (the broadcasts start after aggregation, in parallel).
            round_wall += down_wall;
            attr_downlink = down_wall;
        }
        // 5. Demobilize the cohort: meters/losses persist to the store's
        // columns, the error memory drains into the residual arena, the
        // dense replicas and scratch recycle into the store's pools.
        for (dev, compressed, _) in live.drain(..) {
            pop.demobilize(dev.into_parts(), compressed);
        }
        // 6. Evaluate + record — the reference loop's exact bookkeeping.
        exp.total_time_s += round_wall;
        let done_round = round + 1 == exp.cfg.rounds;
        let (eval_loss, eval_acc) = if round % exp.cfg.eval_every == 0 || done_round {
            trainer.eval(&exp.server.params)?
        } else {
            (f64::NAN, f64::NAN)
        };
        let (tot_energy, tot_money) = pop.meter_totals();
        let down = exp
            .downlink
            .as_mut()
            .map(|d| d.window.take())
            .unwrap_or_default();
        let sw = exp
            .scenario
            .as_mut()
            .map(|s| s.window.take())
            .unwrap_or_default();
        let zone_p50 = exp.scenario.as_ref().map(|s| s.zone_p50()).unwrap_or(0.0);
        let finish_p50_s = percentile(&mut finishes, 50.0);
        let finish_p95_s = percentile(&mut finishes, 95.0);
        let (backhaul_bytes, backhaul_p95_s, migrated_handoff, edge_rounds_bound) =
            drain_edge_window(exp, finish_p95_s);
        // Attribution mirrors the barrier engine: slowest upload = critical
        // path, then the backhaul/downlink extensions added above.
        let mut attr = Attribution::none();
        if crit_client >= 0 {
            attr.compute = crit_comp;
            attr.uplink = (crit_wall - crit_comp).max(0.0);
            attr.backhaul = attr_backhaul;
            attr.downlink = attr_downlink;
            attr.crit_client = crit_client;
            attr.crit_channel = crit_ch;
        }
        attr.finalize(round_wall);
        if rec.on() {
            if applied {
                rec.push(Ev::new("aggregate", exp.total_time_s).round(round).bytes(bytes_up));
            }
            rec.push_round(exp.total_time_s, round, round_wall, &attr);
        }
        log.push(RoundRecord {
            round,
            train_loss: if loss_n == 0 { f64::NAN } else { loss_sum / loss_n as f64 },
            eval_loss,
            eval_acc,
            energy_j: tot_energy,
            money: tot_money,
            round_time_s: round_wall,
            total_time_s: exp.total_time_s,
            bytes_up,
            drl_reward: if reward_n > 0 {
                reward_acc / reward_n as f64
            } else {
                f64::NAN
            },
            finish_p50_s,
            finish_p95_s,
            stale_updates: 0,
            sampled: loss_n as u64,
            completed: nrecv as u64,
            dropped_offline,
            staleness_p50: 0.0,
            staleness_p95: 0.0,
            down_bytes: down.bytes,
            down_energy_j: down.energy_j,
            down_money: down.money,
            handoffs: sw.handoffs,
            dropped_handoff: sw.dropped_handoff,
            zone_p50,
            backhaul_bytes,
            backhaul_p95_s,
            migrated_handoff,
            edge_rounds_bound,
            bound_by: attr.bound_by(),
            crit_client: attr.crit_client,
            crit_channel: attr.crit_channel,
        });
        stats.records += 1;
    }
    exp.sim_stats = stats;
    Ok(())
}

/// One async cohort slot: the in-flight state of whichever client currently
/// occupies it. On broadcast the client demobilizes and the sampler picks a
/// replacement, so at most `Population::cohort()` clients are ever
/// materialized.
struct CohortSlot {
    client: usize,
    dev: Option<Device>,
    started_at: f64,
    comp_s: f64,
    comp_j: f64,
    loss: f64,
    plan: Option<AllocationPlan>,
    compressed: bool,
    model_version: u64,
    update: Option<LgcUpdate>,
    /// Channel each *delivered* layer of the in-flight upload rode
    /// (aligned with `update.layers`; scenario handoff-drop bookkeeping).
    layer_channels: Vec<usize>,
    waiting: bool,
    /// The slot's broadcast download is in flight (downlink enabled): the
    /// client demobilizes at its `SyncConfirmed`, not at `Broadcast`.
    syncing: bool,
    retired: bool,
    /// Slowest delivered channel of the in-flight upload (-1 when nothing
    /// was delivered) — the `crit_channel` attribution column.
    slow_ch: i64,
}

impl CohortSlot {
    fn idle() -> Self {
        CohortSlot {
            client: 0,
            dev: None,
            started_at: 0.0,
            comp_s: 0.0,
            comp_j: 0.0,
            loss: 0.0,
            plan: None,
            compressed: false,
            model_version: 0,
            update: None,
            layer_channels: Vec::new(),
            waiting: false,
            syncing: false,
            retired: true,
            slow_ch: -1,
        }
    }
}

/// Per-aggregation-window counters of the cohort async engine, plus the
/// window's critical-path (longest completed upload) attribution state.
struct CohortWindow {
    bytes: u64,
    rewards: f64,
    reward_n: usize,
    dropped: u64,
    crit_dur: f64,
    crit_comp: f64,
    crit_client: i64,
    crit_channel: i64,
}

impl Default for CohortWindow {
    fn default() -> Self {
        CohortWindow {
            bytes: 0,
            rewards: 0.0,
            reward_n: 0,
            dropped: 0,
            crit_dur: -1.0,
            crit_comp: 0.0,
            crit_client: -1,
            crit_channel: -1,
        }
    }
}

/// Materialize `client` into `slots[slot_idx]` and start its round: policy
/// decision, local steps, and a `ComputeDone` after the compute time.
#[allow(clippy::too_many_arguments)]
fn begin_cohort_slot(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    pop: &mut Population,
    slots: &mut [CohortSlot],
    queue: &mut EventQueue,
    slot_idx: usize,
    client: usize,
    now: f64,
    era: usize,
    server_version: u64,
    rec: &mut Recorder,
) -> Result<()> {
    ensure_agent(exp, client);
    if rec.on() {
        rec.push(Ev::new("compute_start", now).round(era).client(client));
    }
    let mut dev = pop.materialize(client, &exp.server.params);
    // Wake the client up in its current scenario zone (uplink and
    // accounting-only downlink bundles).
    if let Some(sc) = exp.scenario.as_ref() {
        sc.configure(client, &mut dev.channels);
        if let Some(dl) = exp.downlink.as_mut() {
            sc.configure(client, dl.links_mut(client));
        }
    }
    let (h, plan) = exp.policy.decide(era, &dev, exp.agents[client].as_mut());
    let train_t0 = rec.phase_start();
    let loss = dev.local_steps_sharded(trainer, pop.shard(client), h, exp.cfg.lr)?;
    rec.phase_end(Phase::Train, train_t0);
    let (comp_j, comp_s) = dev.compute_cost(h);
    let s = &mut slots[slot_idx];
    s.client = client;
    s.dev = Some(dev);
    s.started_at = now;
    s.comp_s = comp_s;
    s.comp_j = comp_j;
    s.loss = loss;
    s.plan = Some(plan);
    s.compressed = false;
    s.model_version = server_version;
    s.update = None;
    s.layer_channels.clear();
    s.waiting = false;
    s.syncing = false;
    s.retired = false;
    s.slow_ch = -1;
    queue.push(now + comp_s, Event::ComputeDone { device: slot_idx });
    Ok(())
}

/// Apply the buffered semi-async window (streaming: finalize the running
/// aggregate; batch: drive the aggregator over the parked payloads) and
/// emit its record.
#[allow(clippy::too_many_arguments)]
fn flush_semi_cohort(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    pop: &Population,
    slots: &[CohortSlot],
    log: &mut RunLog,
    stats: &mut SimStats,
    window: &mut CohortWindow,
    last_record_t: &mut f64,
    streaming: bool,
    pending: &mut Vec<(f64, f64, u64)>,
    pending_updates: &mut Vec<LgcUpdate>,
    pending_weights: &mut Vec<f64>,
    window_zones: &mut Vec<usize>,
    free_bufs: &mut Vec<LgcUpdate>,
    server_version: &mut u64,
    t: f64,
    rec: &mut Recorder,
) -> Result<()> {
    let ag_t0 = rec.phase_start();
    if streaming {
        exp.server.stream_apply();
    } else {
        let uploads: Vec<&LgcUpdate> = pending_updates.iter().collect();
        exp.server.set_round_weights(&pending_weights[..]);
        exp.server.aggregate_and_apply(&uploads);
    }
    rec.phase_end(Phase::Aggregate, ag_t0);
    // Every zone that buffered a contribution this window shipped one
    // partial-aggregate frame over its backhaul (accounting-only).
    if let Some(edge) = exp.edge.as_mut() {
        for &z in window_zones.iter() {
            let _ = edge.charge_flush(z);
        }
    }
    window_zones.clear();
    *server_version += 1;
    let contributions = std::mem::take(pending);
    // Drained window buffers go back to the free list for reuse.
    free_bufs.append(pending_updates);
    pending_weights.clear();
    push_cohort_record(
        exp, trainer, pop, slots, log, stats, window, last_record_t, t, &contributions, rec,
    )
}

/// Emit one cohort-async [`RoundRecord`] (one per server aggregation), with
/// the window since the previous record as its time span. Energy/money
/// totals sum every demobilized spec's meter plus the live slots' meters.
#[allow(clippy::too_many_arguments)]
fn push_cohort_record(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    pop: &Population,
    slots: &[CohortSlot],
    log: &mut RunLog,
    stats: &mut SimStats,
    window: &mut CohortWindow,
    last_record_t: &mut f64,
    now: f64,
    contributions: &[(f64, f64, u64)],
    rec: &mut Recorder,
) -> Result<()> {
    let round = log.records.len();
    let done = round + 1 >= exp.cfg.rounds;
    let train_loss = if contributions.is_empty() {
        f64::NAN
    } else {
        contributions.iter().map(|c| c.0).sum::<f64>() / contributions.len() as f64
    };
    let mut finishes: Vec<f64> = contributions.iter().map(|c| c.1).collect();
    let stale_updates = contributions.iter().filter(|c| c.2 > 0).count() as u64;
    stats.stale_updates += stale_updates;
    let mut stale_vals: Vec<f64> = contributions.iter().map(|c| c.2 as f64).collect();
    let staleness_p50 = percentile(&mut stale_vals, 50.0);
    let staleness_p95 = percentile(&mut stale_vals, 95.0);
    let down = exp
        .downlink
        .as_mut()
        .map(|d| d.window.take())
        .unwrap_or_default();
    let sw = exp
        .scenario
        .as_mut()
        .map(|s| s.window.take())
        .unwrap_or_default();
    let zone_p50 = exp.scenario.as_ref().map(|s| s.zone_p50()).unwrap_or(0.0);
    let (eval_loss, eval_acc) = if round % exp.cfg.eval_every == 0 || done {
        trainer.eval(&exp.server.params)?
    } else {
        (f64::NAN, f64::NAN)
    };
    let (mut tot_energy, mut tot_money) = pop.demobilized_meter_totals();
    for s in slots {
        if let Some(d) = &s.dev {
            tot_energy += d.meter.energy_used;
            tot_money += d.meter.money_used;
        }
    }
    let finish_p50_s = percentile(&mut finishes, 50.0);
    let finish_p95_s = percentile(&mut finishes, 95.0);
    let (backhaul_bytes, backhaul_p95_s, migrated_handoff, edge_rounds_bound) =
        drain_edge_window(exp, finish_p95_s);
    // Window attribution, mirroring the legacy async engine.
    let round_time = now - *last_record_t;
    let mut attr = Attribution::none();
    if window.crit_client >= 0 {
        attr.compute = window.crit_comp;
        attr.uplink = (window.crit_dur - window.crit_comp).max(0.0);
        attr.crit_client = window.crit_client;
        attr.crit_channel = window.crit_channel;
    }
    attr.finalize(round_time);
    let record = RoundRecord {
        round,
        train_loss,
        eval_loss,
        eval_acc,
        energy_j: tot_energy,
        money: tot_money,
        round_time_s: round_time,
        total_time_s: now,
        bytes_up: window.bytes,
        drl_reward: if window.reward_n > 0 {
            window.rewards / window.reward_n as f64
        } else {
            f64::NAN
        },
        finish_p50_s,
        finish_p95_s,
        stale_updates,
        // Invariant shared with the barrier engine: every sampled upload
        // either completed or dropped offline (completed + dropped_offline
        // == sampled; fading-erased uploads are tracked as lost layers).
        sampled: contributions.len() as u64 + window.dropped,
        completed: contributions.len() as u64,
        dropped_offline: window.dropped,
        staleness_p50,
        staleness_p95,
        down_bytes: down.bytes,
        down_energy_j: down.energy_j,
        down_money: down.money,
        handoffs: sw.handoffs,
        dropped_handoff: sw.dropped_handoff,
        zone_p50,
        backhaul_bytes,
        backhaul_p95_s,
        migrated_handoff,
        edge_rounds_bound,
        bound_by: attr.bound_by(),
        crit_client: attr.crit_client,
        crit_channel: attr.crit_channel,
    };
    if rec.on() {
        rec.push(Ev::new("aggregate", now).round(round).bytes(window.bytes));
        rec.push_round(now, round, round_time, &attr);
    }
    exp.total_time_s = now;
    *last_record_t = now;
    *window = CohortWindow::default();
    log.push(record);
    stats.records += 1;
    Ok(())
}

/// Event-driven cohort engine for the async sync modes: `cohort` slots run
/// concurrently; each completed upload folds into the server (buffered
/// FedBuff-style under `Semi`, applied immediately with staleness decay
/// under `Fully`), and every broadcast demobilizes the finished client and
/// samples a replacement — a steady-state pool over the whole population.
/// Uploads ride the lossy channel path, complete when the slot's radio goes
/// quiet (compute end + slowest layer), and may be lost wholesale to
/// mid-upload availability churn (restituted into error memory, counted as
/// `dropped_offline`).
fn cohort_async_rounds(
    exp: &mut Experiment,
    trainer: &mut dyn LocalTrainer,
    log: &mut RunLog,
    pop: &mut Population,
    sampler: &mut dyn ClientSampler,
    kind: AsyncKind,
    rec: &mut Recorder,
) -> Result<()> {
    let n_slots = pop.cohort();
    let streaming = exp.cfg.streaming;
    let mut queue = EventQueue::with_shards(resolve_shards(exp.cfg.shards));
    // The O(population) sweeps at each FadingTick run chunked across the
    // same shard count (bit-identical for any value).
    pop.set_sweep_threads(resolve_shards(exp.cfg.shards));
    let mut stats = SimStats::default();
    let mut slots: Vec<CohortSlot> = (0..n_slots).map(|_| CohortSlot::idle()).collect();
    let mut busy = vec![false; pop.len()];
    let mut in_flight = 0usize;
    // Slots whose broadcast download is in flight: not in_flight, but
    // guaranteed to hand their slot to a fresh producer at SyncConfirmed —
    // the parked-pool flush must wait for them (see the legacy engine's
    // `downlinking` counter).
    let mut syncing_count = 0usize;
    let mut server_version = 0u64;
    // Buffered-window state (Semi): record metadata always; payloads and
    // weights only on the batch (non-streaming) path.
    let mut pending: Vec<(f64, f64, u64)> = Vec::new();
    let mut pending_updates: Vec<LgcUpdate> = Vec::new();
    let mut pending_weights: Vec<f64> = Vec::new();
    let mut window = CohortWindow::default();
    // Zones with a buffered (Semi) contribution this window — each owes one
    // partial-aggregate backhaul frame, charged at the flush.
    let mut window_zones: Vec<usize> = Vec::new();
    let mut last_record_t = exp.total_time_s;
    let mut decode_buf = LgcUpdate { dim: 0, layers: Vec::new() };
    // Recycled update buffers for the batch window (see the Semi arm).
    let mut free_bufs: Vec<LgcUpdate> = Vec::new();
    let clock0 = exp.total_time_s;

    let mut initial: Vec<usize> = sampler
        .sample(0, pop)
        .into_iter()
        .filter(|&id| pop.eligible(id))
        .collect();
    initial.truncate(n_slots);
    for (slot_idx, client) in initial.into_iter().enumerate() {
        begin_cohort_slot(
            exp, trainer, pop, &mut slots, &mut queue, slot_idx, client, clock0, 0,
            server_version, rec,
        )?;
        busy[client] = true;
        in_flight += 1;
    }
    if in_flight == 0 {
        exp.sim_stats = stats;
        return Ok(()); // nobody eligible
    }
    queue.push(clock0 + exp.cfg.fading_tick_s, Event::FadingTick);

    // Same defensive bound as the legacy async engine.
    const COHORT_EVENT_CAP: u64 = 50_000_000;

    while log.records.len() < exp.cfg.rounds {
        let Some((t, ev)) = queue.pop() else { break };
        anyhow::ensure!(
            queue.popped() <= COHORT_EVENT_CAP,
            "cohort engine exceeded {COHORT_EVENT_CAP} events with only {} of {} records",
            log.records.len(),
            exp.cfg.rounds
        );
        match ev {
            Event::FadingTick => {
                // Whole-population dynamics: demobilized specs advance in
                // the store, live slot devices in place.
                pop.step_round();
                if let Some(dl) = exp.downlink.as_mut() {
                    dl.step_round();
                }
                if let Some(edge) = exp.edge.as_mut() {
                    edge.step_round();
                }
                for s in slots.iter_mut() {
                    if let Some(dev) = s.dev.as_mut() {
                        dev.channels.step_round();
                    }
                }
                // Scenario mobility & phases: only *live* slot devices
                // need immediate reconfiguration (their in-flight layers
                // resolve at `UploadDone`); demobilized clients — the vast
                // majority of a large population — pick their new zone up
                // when next materialized (`begin_cohort_slot` configures
                // both the uplink and downlink bundles). `reconfigure` is
                // ascending, so one scan over the O(cohort) slots suffices.
                if let Some(sc) = exp.scenario.as_mut() {
                    let fx = sc.tick(t);
                    if !fx.reconfigure.is_empty() {
                        for s in slots.iter_mut() {
                            if s.retired || fx.reconfigure.binary_search(&s.client).is_err() {
                                continue;
                            }
                            if let Some(dev) = s.dev.as_mut() {
                                sc.configure(s.client, &mut dev.channels);
                            }
                            if let Some(dl) = exp.downlink.as_mut() {
                                sc.configure(s.client, dl.links_mut(s.client));
                            }
                            if rec.on() {
                                let zone = sc.zone_of(s.client);
                                rec.push(Ev::new("handoff", t).client(s.client).zone(zone));
                            }
                            // Accounting-only migration (nothing is ever
                            // physically held in the cohort engines): a
                            // waiting slot's completed upload logically sat
                            // at its old zone's edge awaiting the next
                            // flush — count its move.
                            if let Some(edge) = exp.edge.as_mut() {
                                let z = sc.zone_of(s.client);
                                if edge.zone_of(s.client) != z {
                                    edge.migrate(s.client, z);
                                    rec.push(Ev::new("migrate", t).client(s.client).zone(z));
                                    if s.waiting {
                                        edge.note_migrated(1);
                                    }
                                }
                            }
                        }
                    }
                    if let Some(edge) = exp.edge.as_mut() {
                        edge.set_phase_scale(sc.backhaul_scale());
                    }
                }
                // Revive retired slots: a slot retires when the sampler
                // finds nobody eligible at broadcast time, but churn (or a
                // budget refill in future samplers) can bring clients back
                // — re-probe so a transient everybody-offline moment only
                // pauses the pool.
                for i in 0..slots.len() {
                    if !slots[i].retired {
                        continue;
                    }
                    match sampler.sample_replacement(pop, &busy) {
                        Some(next) => {
                            begin_cohort_slot(
                                exp,
                                trainer,
                                pop,
                                &mut slots,
                                &mut queue,
                                i,
                                next,
                                t,
                                log.records.len(),
                                server_version,
                                rec,
                            )?;
                            busy[next] = true;
                            in_flight += 1;
                        }
                        None => break, // nobody eligible for any slot
                    }
                }
                if slots.iter().any(|s| !s.retired) || pop.may_become_eligible() {
                    queue.push(t + exp.cfg.fading_tick_s, Event::FadingTick);
                }
            }
            Event::ComputeDone { device: i } => {
                let s = &mut slots[i];
                let plan = s.plan.take().expect("plan set at slot start");
                s.compressed = !plan.is_silent();
                let client = s.client;
                let (comp_j, comp_s, loss) = (s.comp_j, s.comp_s, s.loss);
                let dev = s.dev.as_mut().expect("device in flight");
                let outcome = dev.upload_lossy(&plan);
                let (comm_j, comm_money, bytes) = TransferCost::fold_totals(&outcome.costs);
                dev.meter
                    .record_round(comp_j, comm_j, comm_money, comp_s + outcome.wall_time_s);
                window.bytes += bytes;
                stats.lost_layers += outcome.lost_layers as u64;
                if dev.prev_loss.is_nan() {
                    dev.prev_loss = loss;
                }
                let delta = dev.prev_loss - loss;
                dev.prev_loss = loss;
                dev.last_delta = delta;
                let done = log.records.len() + 1 >= exp.cfg.rounds;
                if let Some(r) = exp.policy.observe(dev, exp.agents[client].as_mut(), delta, done)
                {
                    window.rewards += r;
                    window.reward_n += 1;
                }
                // Channel mapping of the delivered layers (aligned with
                // `update.layers`) — the handoff-drop check at `UploadDone`
                // needs it to spot layers whose channel has since vanished.
                let layer_channels: Vec<usize> = outcome
                    .transfers
                    .iter()
                    .filter(|tr| tr.delivered)
                    .map(|tr| tr.channel)
                    .collect();
                // Slowest delivered channel: the slot's critical uplink for
                // window attribution (-1 when nothing got through).
                s.slow_ch = -1;
                for tr in &outcome.transfers {
                    if tr.delivered
                        && (s.slow_ch < 0
                            || outcome.costs[tr.channel].time_s
                                > outcome.costs[s.slow_ch as usize].time_s)
                    {
                        s.slow_ch = tr.channel as i64;
                    }
                }
                if rec.on() {
                    rec.push(Ev::new("compute_done", t).client(client).dur(comp_s));
                    for (layer_idx, tr) in outcome.transfers.iter().enumerate() {
                        if tr.delivered {
                            rec.push(
                                Ev::new("uplink_arrive", t + outcome.costs[tr.channel].time_s)
                                    .client(client)
                                    .layer(layer_idx)
                                    .channel(tr.channel)
                                    .dur(outcome.costs[tr.channel].time_s),
                            );
                        } else {
                            rec.push(
                                Ev::new("uplink_drop", t)
                                    .client(client)
                                    .layer(layer_idx)
                                    .channel(tr.channel),
                            );
                        }
                    }
                }
                let mut update = outcome.update;
                if !update.layers.is_empty() && pop.midround_offline(client) {
                    // Mid-upload churn: the server never ACKs, so every
                    // delivered layer is restituted like a fading erasure.
                    dev.restitute_update(&update);
                    update.layers.clear();
                    stats.dropped_offline += 1;
                    window.dropped += 1;
                    rec.push(Ev::new("churn_drop", t).client(client));
                }
                s.update = Some(update);
                s.layer_channels = layer_channels;
                queue.push(t + outcome.wall_time_s, Event::UploadDone { device: i });
            }
            Event::UploadDone { device: i } => {
                let duration = t - slots[i].started_at;
                let staleness = server_version - slots[i].model_version;
                let client = slots[i].client;
                let loss = slots[i].loss;
                slots[i].waiting = true;
                in_flight -= 1;
                // Track the window's critical (longest) upload for round-time
                // attribution.
                if duration > window.crit_dur {
                    window.crit_dur = duration;
                    window.crit_comp = slots[i].comp_s;
                    window.crit_client = client as i64;
                    window.crit_channel = slots[i].slow_ch;
                }
                let mut update = slots[i].update.take().expect("upload in flight");
                // Scenario handoff drop: the slot's radio just went quiet —
                // any delivered layer whose channel has since vanished from
                // the client's zone never completed its association;
                // restitute it and purge it from the payload.
                if exp.scenario.is_some() && !update.layers.is_empty() {
                    let s = &mut slots[i];
                    if let Some(dev) = s.dev.as_mut() {
                        let mut any_dropped = false;
                        for (pos, &ch) in s.layer_channels.iter().enumerate() {
                            if pos >= update.layers.len() {
                                break;
                            }
                            if !dev.channels.links[ch].is_up()
                                && !update.layers[pos].values.is_empty()
                            {
                                drop_handoff_layer(dev, &mut exp.scenario, &mut update.layers[pos]);
                                any_dropped = true;
                            }
                        }
                        if any_dropped {
                            update.layers.retain(|l| !l.values.is_empty());
                        }
                    }
                }
                let delivered = !update.layers.is_empty();
                if delivered {
                    // Wire round-trip into the shared decode buffer.
                    if slots[i].dev.as_ref().expect("device in flight").sparse_wire() {
                        exp.server.decode_from_wire_into(&update, &mut decode_buf)?;
                    } else {
                        decode_buf = update;
                    }
                    let weight = pop.samples(client) as f64;
                    let zone = exp.scenario.as_ref().map_or(0, |sc| sc.zone_of(client));
                    match kind {
                        AsyncKind::Semi { .. } => {
                            if exp.edge.is_some() && !window_zones.contains(&zone) {
                                window_zones.push(zone);
                            }
                            if streaming {
                                if pending.is_empty() {
                                    exp.server.stream_begin();
                                }
                                exp.server.stream_accumulate(&decode_buf, weight);
                            } else {
                                // Move the decoded update into the window
                                // and recycle a drained buffer — no O(model)
                                // clone per upload, zero steady-state
                                // allocation once the free list warms up.
                                let parked = std::mem::replace(
                                    &mut decode_buf,
                                    free_bufs
                                        .pop()
                                        .unwrap_or(LgcUpdate { dim: 0, layers: Vec::new() }),
                                );
                                pending_updates.push(parked);
                                pending_weights.push(weight);
                            }
                            pending.push((loss, duration, staleness));
                        }
                        AsyncKind::Fully { staleness_decay } => {
                            let w = staleness_decay.powf(staleness as f64) as f32;
                            for layer in &mut decode_buf.layers {
                                for v in &mut layer.values {
                                    *v *= w;
                                }
                            }
                            if streaming {
                                exp.server.stream_begin();
                                exp.server.stream_accumulate(&decode_buf, weight);
                                exp.server.stream_apply();
                            } else {
                                exp.server.set_round_weights(&[weight]);
                                exp.server.aggregate_and_apply(&[&decode_buf]);
                            }
                            server_version += 1;
                            // Fully-async: each applied contribution rode
                            // its zone's backhaul as its own frame
                            // (accounting-only, no event).
                            if let Some(edge) = exp.edge.as_mut() {
                                let _ = edge.charge_flush(zone);
                            }
                            push_cohort_record(
                                exp,
                                trainer,
                                pop,
                                &slots,
                                log,
                                &mut stats,
                                &mut window,
                                &mut last_record_t,
                                t,
                                &[(loss, duration, staleness)],
                                rec,
                            )?;
                            queue.push(t, Event::Broadcast);
                        }
                    }
                } else if matches!(kind, AsyncKind::Fully { .. }) {
                    // Entirely lost: nothing to apply, but resync + replace.
                    queue.push(t, Event::Broadcast);
                }
                if let AsyncKind::Semi { buffer_k } = kind {
                    if pending.len() >= buffer_k.max(1) {
                        flush_semi_cohort(
                            exp,
                            trainer,
                            pop,
                            &slots,
                            log,
                            &mut stats,
                            &mut window,
                            &mut last_record_t,
                            streaming,
                            &mut pending,
                            &mut pending_updates,
                            &mut pending_weights,
                            &mut window_zones,
                            &mut free_bufs,
                            &mut server_version,
                            t,
                            rec,
                        )?;
                        queue.push(t, Event::Broadcast);
                    } else if in_flight == 0 && syncing_count == 0 {
                        // Whole pool parked: flush a partial buffer, or just
                        // broadcast so everyone resyncs and rotates. Slots
                        // mid-download are future producers, so they hold
                        // the flush open.
                        if !pending.is_empty() {
                            flush_semi_cohort(
                                exp,
                                trainer,
                                pop,
                                &slots,
                                log,
                                &mut stats,
                                &mut window,
                                &mut last_record_t,
                                streaming,
                                &mut pending,
                                &mut pending_updates,
                                &mut pending_weights,
                                &mut window_zones,
                                &mut free_bufs,
                                &mut server_version,
                                t,
                                rec,
                            )?;
                        }
                        queue.push(t, Event::Broadcast);
                    }
                }
            }
            Event::Broadcast => {
                // Every waiting slot: resync (if its progress was absorbed
                // by a compress), demobilize, and hand the slot to a
                // sampler-chosen replacement client. With the downlink
                // enabled, a compressed slot's resync rides its downlink
                // first — demobilization moves to its `SyncConfirmed`.
                for i in 0..slots.len() {
                    if slots[i].retired || !slots[i].waiting {
                        continue;
                    }
                    slots[i].waiting = false;
                    let compressed = slots[i].compressed;
                    if compressed && exp.downlink.is_some() {
                        let client = slots[i].client;
                        let dev = slots[i].dev.as_mut().expect("waiting slot has a device");
                        dev.sync(&exp.server.params);
                        let dl = exp.downlink.as_mut().expect("downlink enabled");
                        let (wall, e, mo, _by) =
                            dl.charge_broadcast(client, exp.server.params.len());
                        dev.meter.record_downlink(e, mo);
                        dev.sync_state.synced_version = server_version;
                        dev.sync_state.synced_round = log.records.len();
                        slots[i].syncing = true;
                        syncing_count += 1;
                        queue.push(t + wall, Event::SyncConfirmed { device: i });
                        continue;
                    }
                    let client = slots[i].client;
                    let mut dev = slots[i].dev.take().expect("waiting slot has a device");
                    if compressed {
                        dev.sync(&exp.server.params);
                    }
                    pop.demobilize(dev.into_parts(), compressed);
                    busy[client] = false;
                    match sampler.sample_replacement(pop, &busy) {
                        Some(next) => {
                            begin_cohort_slot(
                                exp,
                                trainer,
                                pop,
                                &mut slots,
                                &mut queue,
                                i,
                                next,
                                t,
                                log.records.len(),
                                server_version,
                                rec,
                            )?;
                            busy[next] = true;
                            in_flight += 1;
                        }
                        None => slots[i].retired = true,
                    }
                }
            }
            Event::SyncConfirmed { device: i } => {
                // The slot's broadcast download completed: demobilize the
                // client (its SyncState persists to the spec) and hand the
                // slot to a replacement, exactly like the instant path.
                if !slots[i].syncing {
                    continue; // drained by the run's end
                }
                slots[i].syncing = false;
                syncing_count -= 1;
                let client = slots[i].client;
                if rec.on() {
                    rec.push(Ev::new("sync_confirm", t).client(client));
                }
                let dev = slots[i].dev.take().expect("syncing slot has a device");
                pop.demobilize(dev.into_parts(), true);
                busy[client] = false;
                match sampler.sample_replacement(pop, &busy) {
                    Some(next) => {
                        begin_cohort_slot(
                            exp,
                            trainer,
                            pop,
                            &mut slots,
                            &mut queue,
                            i,
                            next,
                            t,
                            log.records.len(),
                            server_version,
                            rec,
                        )?;
                        busy[next] = true;
                        in_flight += 1;
                    }
                    None => slots[i].retired = true,
                }
                // If no replacement was eligible and this was the last
                // pending producer, a partial window would strand — flush.
                if matches!(kind, AsyncKind::Semi { .. })
                    && in_flight == 0
                    && syncing_count == 0
                    && !pending.is_empty()
                {
                    flush_semi_cohort(
                        exp,
                        trainer,
                        pop,
                        &slots,
                        log,
                        &mut stats,
                        &mut window,
                        &mut last_record_t,
                        streaming,
                        &mut pending,
                        &mut pending_updates,
                        &mut pending_weights,
                        &mut window_zones,
                        &mut free_bufs,
                        &mut server_version,
                        t,
                        rec,
                    )?;
                    queue.push(t, Event::Broadcast);
                }
            }
            ev @ (Event::LayerArrived { .. }
            | Event::DownlinkLayerArrived { .. }
            | Event::BackhaulArrived { .. }) => {
                unreachable!(
                    "cohort engine got {ev} at t={t}: transfers complete via \
                     UploadDone/SyncConfirmed (edge backhaul is accounting-only here)"
                )
            }
        }
    }
    // Drain: demobilize whatever is still materialized so the population
    // accounts for every client when the caller inspects it. A slot whose
    // compressor ran resyncs first (its progress lives in delivered layers
    // + error memory — end-of-run in-flight layers are truncated, exactly
    // like the legacy async engine's unapplied tail buffer).
    for s in slots.iter_mut() {
        if let Some(mut dev) = s.dev.take() {
            if s.compressed {
                dev.sync(&exp.server.params);
            }
            pop.demobilize(dev.into_parts(), s.compressed);
        }
    }
    stats.events = queue.popped();
    exp.sim_stats = stats;
    Ok(())
}
