//! Per-device FL control agent: maps the paper's state / action / reward
//! (Sec. 3.2, Eq. 11–16) onto the DDPG core.
//!
//! - **State** (Eq. 11–12): per-resource communication and computation
//!   consumption of the last round, remaining budget fractions, current
//!   per-channel effective bandwidth, and the last loss delta.
//! - **Action** (Eq. 13): `(H_m, D_{m,1..N})` — local step count and
//!   per-channel coordinate allocation, decoded from the actor's
//!   `[-1,1]^{1+N}` output.
//! - **Reward** (Eq. 14–16): weighted ratio of consecutive utilities
//!   `U_{m,r} = δ / ε_{m,r}` (loss improvement per unit of resource).

use super::ddpg::{Ddpg, StepStats};
use super::replay::Transition;
use crate::channels::{allocate_budget, AllocationPlan, DeviceChannels};
use crate::config::DrlConfig;
use crate::resources::{ResourceMeter, RESOURCES};
use crate::util::Rng;

/// Decoded action for the round loop.
#[derive(Clone, Debug)]
pub struct ControlDecision {
    /// Local SGD steps H_m^(t) in [1, h_max].
    pub local_steps: usize,
    /// Per-channel coordinate allocation (layer-to-channel mapping).
    pub plan: AllocationPlan,
    /// Raw actor output (stored in the replay transition).
    pub raw: Vec<f32>,
}

/// Normalization constants so state features are O(1).
#[derive(Clone, Copy, Debug)]
pub struct StateScales {
    pub energy: f64,
    pub money: f64,
    pub bandwidth: f64,
    pub loss: f64,
    /// Staleness-gap normalizer (rounds of model age behind the server).
    pub staleness: f64,
}

impl Default for StateScales {
    fn default() -> Self {
        StateScales { energy: 500.0, money: 0.05, bandwidth: 12.0, loss: 2.5, staleness: 8.0 }
    }
}

/// Utility tracker for the Eq. 16 reward.
#[derive(Clone, Debug, Default)]
pub struct RewardTracker {
    prev_utility: Option<Vec<f64>>,
    pub last_reward: f64,
}

impl RewardTracker {
    /// Utilities `U_{m,r} = δ / ε_r` (Eq. 14); `δ = loss_prev − loss_cur`
    /// (positive = improvement), `ε_r` the round's consumption (Eq. 15b).
    fn utilities(delta: f64, eps: &[f64]) -> Vec<f64> {
        eps.iter().map(|&e| delta / e.max(1e-9)).collect()
    }

    /// Eq. 16 with uniform weights α_r = 1/R, ratio-clamped for stability
    /// (consecutive-utility ratios blow up when U^t ≈ 0).
    pub fn reward(&mut self, delta: f64, eps: &[f64]) -> f64 {
        let u = Self::utilities(delta, eps);
        let r = match &self.prev_utility {
            Some(prev) => {
                let mut acc = 0.0;
                for (un, up) in u.iter().zip(prev) {
                    let ratio = if up.abs() > 1e-9 {
                        (un / up).clamp(-5.0, 5.0)
                    } else {
                        un.clamp(-5.0, 5.0)
                    };
                    acc += ratio / u.len() as f64;
                }
                acc
            }
            // First round: reward the raw utility (scaled, clamped).
            None => u.iter().map(|x| x.clamp(-5.0, 5.0)).sum::<f64>() / u.len() as f64,
        };
        self.prev_utility = Some(u);
        self.last_reward = r;
        r
    }
}

/// The per-device controller (one DDPG agent per device, as in the paper).
pub struct DeviceAgent {
    pub ddpg: Ddpg,
    pub scales: StateScales,
    pub h_max: usize,
    /// Total coordinate cap D (Eq. 10b).
    pub d_total: usize,
    /// Floor so the update never degenerates to zero traffic.
    pub d_min: usize,
    pub tracker: RewardTracker,
    last_state: Option<Vec<f32>>,
    last_action: Option<Vec<f32>>,
    pub n_channels: usize,
    /// Whether the state vector carries the downlink staleness gap as an
    /// extra feature. Off by default so pre-downlink configurations keep
    /// the exact network shapes (and RNG draws) of the frozen oracle.
    pub staleness_aware: bool,
}

impl DeviceAgent {
    pub fn new(
        n_channels: usize,
        h_max: usize,
        d_total: usize,
        d_min: usize,
        cfg: DrlConfig,
        rng: Rng,
    ) -> Self {
        Self::new_with(n_channels, h_max, d_total, d_min, cfg, rng, false)
    }

    /// [`DeviceAgent::new`] with an explicit staleness-awareness flag —
    /// the builder passes `true` when the simulated downlink is enabled,
    /// widening the state by one feature (the device's staleness gap).
    pub fn new_with(
        n_channels: usize,
        h_max: usize,
        d_total: usize,
        d_min: usize,
        cfg: DrlConfig,
        rng: Rng,
        staleness_aware: bool,
    ) -> Self {
        let state_dim = Self::state_dim_with(n_channels, staleness_aware);
        let action_dim = 1 + n_channels;
        DeviceAgent {
            ddpg: Ddpg::new(state_dim, action_dim, cfg, rng),
            scales: StateScales::default(),
            h_max,
            d_total,
            d_min,
            tracker: RewardTracker::default(),
            last_state: None,
            last_action: None,
            n_channels,
            staleness_aware,
        }
    }

    /// 2R consumption components + R remaining fracs + N bandwidths + loss δ.
    pub fn state_dim(n_channels: usize) -> usize {
        Self::state_dim_with(n_channels, false)
    }

    /// [`DeviceAgent::state_dim`], plus the staleness feature when aware.
    pub fn state_dim_with(n_channels: usize, staleness_aware: bool) -> usize {
        2 * RESOURCES.len() + RESOURCES.len() + n_channels + 1 + usize::from(staleness_aware)
    }

    /// Build the Eq. 11 state vector from the meters and channel
    /// conditions. `staleness` is the device's downlink staleness gap
    /// (`SyncState::staleness`); it enters the state only for
    /// staleness-aware agents and is ignored otherwise, so pre-downlink
    /// call sites simply pass 0.
    pub fn observe_state(
        &self,
        meter: &ResourceMeter,
        channels: &DeviceChannels,
        last_loss_delta: f64,
        staleness: u64,
    ) -> Vec<f32> {
        let s = &self.scales;
        let mut v =
            Vec::with_capacity(Self::state_dim_with(self.n_channels, self.staleness_aware));
        // E_{m,r,comm}, E_{m,r,comp} per resource (Eq. 12a/12b).
        for (ri, _r) in RESOURCES.iter().enumerate() {
            let rc = &meter.last_round[ri];
            let scale = if ri == 0 { s.energy } else { s.money };
            v.push((rc.comm / scale) as f32);
            v.push((rc.comp / scale) as f32);
        }
        for r in RESOURCES {
            v.push(meter.remaining_frac(r) as f32);
        }
        for link in &channels.links {
            v.push((link.effective_bandwidth() / s.bandwidth) as f32);
        }
        v.push((last_loss_delta / s.loss) as f32);
        if self.staleness_aware {
            v.push((staleness as f64 / s.staleness) as f32);
        }
        v
    }

    /// Choose this round's `(H_m, D_{m,n})` (exploratory during training).
    pub fn decide(&mut self, state: &[f32], explore: bool) -> ControlDecision {
        let raw = if explore {
            self.ddpg.act_explore(state)
        } else {
            self.ddpg.act_greedy(state)
        };
        self.last_state = Some(state.to_vec());
        self.last_action = Some(raw.clone());
        self.decode(&raw)
    }

    /// Decode a raw `[-1,1]^{1+N}` action into a feasible decision
    /// (projection enforces Eq. 10b/10c).
    pub fn decode(&self, raw: &[f32]) -> ControlDecision {
        assert_eq!(raw.len(), 1 + self.n_channels);
        let h01 = ((raw[0] as f64) + 1.0) / 2.0;
        let local_steps = 1 + (h01 * (self.h_max as f64 - 1.0)).round() as usize;
        let fracs: Vec<f64> = raw[1..].iter().map(|&x| x as f64).collect();
        let plan = allocate_budget(&fracs, self.d_total, self.d_min);
        ControlDecision { local_steps: local_steps.min(self.h_max), plan, raw: raw.to_vec() }
    }

    /// Complete the transition after the round executed: compute the Eq. 16
    /// reward, push to replay, and learn. Returns (reward, learn stats).
    pub fn feedback(
        &mut self,
        loss_delta: f64,
        eps: &[f64],
        next_state: Vec<f32>,
        done: bool,
    ) -> (f64, Option<StepStats>) {
        let reward = self.tracker.reward(loss_delta, eps);
        let (state, action) = match (self.last_state.take(), self.last_action.take()) {
            (Some(s), Some(a)) => (s, a),
            _ => return (reward, None),
        };
        let stats = self.ddpg.observe(Transition {
            state,
            action,
            reward: reward as f32,
            next_state,
            done,
        });
        (reward, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::ChannelType;

    fn agent() -> DeviceAgent {
        DeviceAgent::new(3, 8, 1000, 16, DrlConfig::default(), Rng::new(1))
    }

    #[test]
    fn state_vector_dimension() {
        let a = agent();
        let meter = ResourceMeter::new(1000.0, 1.0);
        let ch = DeviceChannels::new(
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &Rng::new(2),
            0,
        );
        let s = a.observe_state(&meter, &ch, 0.1, 0);
        assert_eq!(s.len(), DeviceAgent::state_dim(3));
        assert_eq!(s.len(), a.ddpg.state_dim());
        assert!(s.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn staleness_aware_agent_has_one_extra_feature() {
        let a = DeviceAgent::new_with(3, 8, 1000, 16, DrlConfig::default(), Rng::new(1), true);
        let meter = ResourceMeter::new(1000.0, 1.0);
        let ch = DeviceChannels::new(
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &Rng::new(2),
            0,
        );
        let s = a.observe_state(&meter, &ch, 0.1, 4);
        assert_eq!(s.len(), DeviceAgent::state_dim(3) + 1);
        assert_eq!(s.len(), DeviceAgent::state_dim_with(3, true));
        assert_eq!(s.len(), a.ddpg.state_dim());
        assert_eq!(*s.last().unwrap(), (4.0 / 8.0) as f32);
        // An unaware agent ignores the staleness argument entirely.
        let b = agent();
        let s0 = b.observe_state(&meter, &ch, 0.1, 0);
        let s9 = b.observe_state(&meter, &ch, 0.1, 9);
        assert_eq!(s0, s9);
    }

    #[test]
    fn decode_respects_bounds() {
        let a = agent();
        for raw in [
            vec![-1.0f32, -1.0, -1.0, -1.0],
            vec![1.0, 1.0, 1.0, 1.0],
            vec![0.0, 0.3, -0.7, 0.9],
        ] {
            let d = a.decode(&raw);
            assert!((1..=8).contains(&d.local_steps), "{d:?}");
            assert!(d.plan.total() >= 16 && d.plan.total() <= 1000, "{d:?}");
        }
    }

    #[test]
    fn decode_h_monotone_in_raw() {
        let a = agent();
        let lo = a.decode(&[-1.0, 0.0, 0.0, 0.0]).local_steps;
        let hi = a.decode(&[1.0, 0.0, 0.0, 0.0]).local_steps;
        assert_eq!(lo, 1);
        assert_eq!(hi, 8);
    }

    #[test]
    fn reward_prefers_cheaper_same_improvement() {
        // Same δ at round t+1; lower resource use => higher utility ratio.
        let mut cheap = RewardTracker::default();
        let mut dear = RewardTracker::default();
        // Round 1 identical.
        cheap.reward(0.1, &[10.0, 1.0]);
        dear.reward(0.1, &[10.0, 1.0]);
        // Round 2: same improvement, different cost.
        let r_cheap = cheap.reward(0.1, &[5.0, 0.5]);
        let r_dear = dear.reward(0.1, &[20.0, 2.0]);
        assert!(r_cheap > r_dear, "cheap {r_cheap} <= dear {r_dear}");
    }

    #[test]
    fn reward_negative_when_loss_worsens() {
        let mut t = RewardTracker::default();
        t.reward(0.1, &[1.0, 1.0]);
        let r = t.reward(-0.2, &[1.0, 1.0]);
        assert!(r < 0.0, "worsening loss should be punished, got {r}");
    }

    #[test]
    fn reward_bounded() {
        let mut t = RewardTracker::default();
        t.reward(1e-12, &[1e-9, 1e-9]);
        let r = t.reward(1e9, &[1e-9, 1e-9]);
        assert!(r.abs() <= 5.0, "{r}");
    }

    #[test]
    fn feedback_learns_after_warmup() {
        let mut a = DeviceAgent::new(
            2,
            4,
            100,
            4,
            DrlConfig { warmup: 4, batch: 4, hidden: 16, ..DrlConfig::default() },
            Rng::new(3),
        );
        let mut got_stats = false;
        let state = vec![0.0f32; DeviceAgent::state_dim(2)];
        for i in 0..64 {
            a.decide(&state, true);
            let (_, stats) = a.feedback(0.05, &[1.0, 0.1], state.clone(), i % 8 == 7);
            got_stats |= stats.is_some();
        }
        assert!(got_stats, "agent never learned");
    }
}
