//! Experience replay buffer (Sec. 3.1: the tuple
//! `(s, a, r, s')` store the critic samples from).

use crate::util::Rng;

/// One transition.
#[derive(Clone, Debug, PartialEq)]
pub struct Transition {
    pub state: Vec<f32>,
    pub action: Vec<f32>,
    pub reward: f32,
    pub next_state: Vec<f32>,
    /// Terminal flag (no bootstrap from s').
    pub done: bool,
}

/// Fixed-capacity ring buffer with uniform sampling.
#[derive(Clone, Debug)]
pub struct ReplayBuffer {
    buf: Vec<Transition>,
    capacity: usize,
    head: usize,
}

impl ReplayBuffer {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        ReplayBuffer { buf: Vec::with_capacity(capacity), capacity, head: 0 }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn push(&mut self, t: Transition) {
        if self.buf.len() < self.capacity {
            self.buf.push(t);
        } else {
            self.buf[self.head] = t;
            self.head = (self.head + 1) % self.capacity;
        }
    }

    /// Sample `n` transitions uniformly with replacement.
    pub fn sample<'a>(&'a self, n: usize, rng: &mut Rng, out: &mut Vec<&'a Transition>) {
        out.clear();
        if self.buf.is_empty() {
            return;
        }
        for _ in 0..n {
            out.push(&self.buf[rng.index(self.buf.len())]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tr(r: f32) -> Transition {
        Transition {
            state: vec![r],
            action: vec![0.0],
            reward: r,
            next_state: vec![r + 1.0],
            done: false,
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut rb = ReplayBuffer::new(3);
        for i in 0..5 {
            rb.push(tr(i as f32));
        }
        assert_eq!(rb.len(), 3);
        let rewards: Vec<f32> = rb.buf.iter().map(|t| t.reward).collect();
        // 0 and 1 overwritten by 3 and 4
        assert!(rewards.contains(&2.0) && rewards.contains(&3.0) && rewards.contains(&4.0));
    }

    #[test]
    fn sample_uniform() {
        let mut rb = ReplayBuffer::new(10);
        for i in 0..10 {
            rb.push(tr(i as f32));
        }
        let mut rng = Rng::new(1);
        let mut out = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..50 {
            rb.sample(4, &mut rng, &mut out);
            assert_eq!(out.len(), 4);
            for t in &out {
                seen.insert(t.reward as i64);
            }
        }
        assert!(seen.len() >= 9, "sampling missed most of the buffer: {seen:?}");
    }

    #[test]
    fn sample_empty_is_empty() {
        let rb = ReplayBuffer::new(4);
        let mut rng = Rng::new(2);
        let mut out = Vec::new();
        rb.sample(3, &mut rng, &mut out);
        assert!(out.is_empty());
    }
}
