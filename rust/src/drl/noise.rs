//! Ornstein–Uhlenbeck exploration noise (Lillicrap et al. 2015, Sec. 7).
//!
//! Temporally correlated noise added to the actor's action during training:
//! `dx = θ(μ − x)dt + σ dW`. Correlation helps exploration in control
//! problems where consecutive actions should be coherent.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub struct OuNoise {
    pub theta: f64,
    pub sigma: f64,
    pub mu: f64,
    pub dt: f64,
    state: Vec<f64>,
    rng: Rng,
}

impl OuNoise {
    pub fn new(dim: usize, theta: f64, sigma: f64, rng: Rng) -> Self {
        OuNoise { theta, sigma, mu: 0.0, dt: 1.0, state: vec![0.0; dim], rng }
    }

    pub fn reset(&mut self) {
        self.state.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Next noise vector.
    pub fn sample(&mut self, out: &mut Vec<f32>) {
        out.clear();
        for x in self.state.iter_mut() {
            let dw = self.rng.normal() * self.dt.sqrt();
            *x += self.theta * (self.mu - *x) * self.dt + self.sigma * dw;
            out.push(*x as f32);
        }
    }

    /// Decay sigma (common schedule as training stabilizes).
    pub fn decay_sigma(&mut self, factor: f64, min_sigma: f64) {
        self.sigma = (self.sigma * factor).max(min_sigma);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_reverts_to_mu() {
        let mut n = OuNoise::new(1, 0.15, 0.2, Rng::new(1));
        let mut out = Vec::new();
        let mut acc = 0.0;
        let steps = 20_000;
        for _ in 0..steps {
            n.sample(&mut out);
            acc += out[0] as f64;
        }
        assert!((acc / steps as f64).abs() < 0.12);
    }

    #[test]
    fn temporally_correlated() {
        let mut n = OuNoise::new(1, 0.05, 0.1, Rng::new(2));
        let mut out = Vec::new();
        let xs: Vec<f64> = (0..5000)
            .map(|_| {
                n.sample(&mut out);
                out[0] as f64
            })
            .collect();
        // lag-1 autocorrelation should be clearly positive
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 = xs.iter().map(|x| (x - mean).powi(2)).sum();
        let cov: f64 = xs.windows(2).map(|w| (w[0] - mean) * (w[1] - mean)).sum();
        let rho = cov / var;
        assert!(rho > 0.7, "lag-1 autocorr {rho}");
    }

    #[test]
    fn decay_bounded_below() {
        let mut n = OuNoise::new(2, 0.15, 0.2, Rng::new(3));
        for _ in 0..1000 {
            n.decay_sigma(0.9, 0.02);
        }
        assert!((n.sigma - 0.02).abs() < 1e-12);
    }
}
