//! DDPG (Lillicrap et al. 2015) — the control algorithm of paper Sec. 3.3.
//!
//! Actor `π(s|θ^π)` (tanh head, actions in [-1,1]^A), critic `Q(s,a|θ^Q)`,
//! target copies with soft updates (τ), uniform replay, OU exploration.
//!
//! Critic loss: MSE to `y = r + γ(1−done) Q'(s', π'(s'))` (Eq. 18).
//! Actor update: deterministic policy gradient — ascend `Q(s, π(s))` by
//! chaining `∂Q/∂a` (critic input-gradient) through the actor.

use super::adam::Adam;
use super::mlp::{Act, Cache, Grads, Mlp};
use super::noise::OuNoise;
use super::replay::{ReplayBuffer, Transition};
use crate::config::DrlConfig;
use crate::util::Rng;

/// Diagnostics from one learning step.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    pub critic_loss: f64,
    pub actor_q: f64,
}

pub struct Ddpg {
    pub actor: Mlp,
    pub critic: Mlp,
    pub actor_target: Mlp,
    pub critic_target: Mlp,
    actor_opt: Adam,
    critic_opt: Adam,
    pub replay: ReplayBuffer,
    noise: OuNoise,
    cfg: DrlConfig,
    state_dim: usize,
    action_dim: usize,
    rng: Rng,
    steps: usize,
    // scratch
    sample_buf: Vec<f32>,
    noise_buf: Vec<f32>,
}

impl Ddpg {
    pub fn new(state_dim: usize, action_dim: usize, cfg: DrlConfig, seed_rng: Rng) -> Self {
        let mut rng = seed_rng;
        let h = cfg.hidden;
        let actor = Mlp::new(
            &[state_dim, h, h, action_dim],
            &[Act::Relu, Act::Relu, Act::Tanh],
            &mut rng,
        );
        let critic = Mlp::new(
            &[state_dim + action_dim, h, h, 1],
            &[Act::Relu, Act::Relu, Act::Linear],
            &mut rng,
        );
        let actor_target = actor.clone();
        let critic_target = critic.clone();
        let actor_opt = Adam::new(&actor, cfg.actor_lr as f32);
        let critic_opt = Adam::new(&critic, cfg.critic_lr as f32);
        let replay = ReplayBuffer::new(cfg.replay_capacity);
        let noise = OuNoise::new(action_dim, cfg.noise_theta, cfg.noise_sigma, rng.fork(0xA0));
        Ddpg {
            actor,
            critic,
            actor_target,
            critic_target,
            actor_opt,
            critic_opt,
            replay,
            noise,
            cfg,
            state_dim,
            action_dim,
            rng,
            steps: 0,
            sample_buf: Vec::new(),
            noise_buf: Vec::new(),
        }
    }

    pub fn action_dim(&self) -> usize {
        self.action_dim
    }

    pub fn state_dim(&self) -> usize {
        self.state_dim
    }

    /// Exploratory action: π(s) + OU noise, clamped to [-1, 1]. During the
    /// warmup phase actions are uniform random for coverage.
    pub fn act_explore(&mut self, state: &[f32]) -> Vec<f32> {
        debug_assert_eq!(state.len(), self.state_dim);
        if self.steps < self.cfg.warmup {
            return (0..self.action_dim)
                .map(|_| self.rng.range(-1.0, 1.0) as f32)
                .collect();
        }
        let mut a = self.actor.infer(state);
        self.noise.sample(&mut self.noise_buf);
        for (ai, &n) in a.iter_mut().zip(&self.noise_buf) {
            *ai = (*ai + n).clamp(-1.0, 1.0);
        }
        a
    }

    /// Greedy action (evaluation).
    pub fn act_greedy(&self, state: &[f32]) -> Vec<f32> {
        self.actor.infer(state)
    }

    /// Store a transition and run one learning step if enough data.
    pub fn observe(&mut self, t: Transition) -> Option<StepStats> {
        self.replay.push(t);
        self.steps += 1;
        if self.replay.len() < self.cfg.batch.max(8) || self.steps < self.cfg.warmup {
            return None;
        }
        Some(self.learn())
    }

    /// One DDPG learning step on a replay minibatch.
    pub fn learn(&mut self) -> StepStats {
        let b = self.cfg.batch.min(self.replay.len());
        let mut batch: Vec<&Transition> = Vec::with_capacity(b);
        // Split borrow: sample indices first into owned copies.
        let mut rng = self.rng.fork(self.steps as u64);
        self.replay.sample(b, &mut rng, &mut batch);
        let batch: Vec<Transition> = batch.into_iter().cloned().collect();

        // ---- Critic update ---------------------------------------------
        // Targets y_i from target nets.
        let mut targets = Vec::with_capacity(b);
        for t in &batch {
            let a_next = self.actor_target.infer(&t.next_state);
            self.sample_buf.clear();
            self.sample_buf.extend_from_slice(&t.next_state);
            self.sample_buf.extend_from_slice(&a_next);
            let q_next = self.critic_target.infer(&self.sample_buf)[0];
            let bootstrap = if t.done { 0.0 } else { self.cfg.gamma as f32 * q_next };
            targets.push(t.reward + bootstrap);
        }
        // Batched critic forward/backward.
        let mut sa = Vec::with_capacity(b * (self.state_dim + self.action_dim));
        for t in &batch {
            sa.extend_from_slice(&t.state);
            sa.extend_from_slice(&t.action);
        }
        let mut cache = Cache::default();
        let q = self.critic.forward(&sa, &mut cache);
        let mut dout = Vec::with_capacity(b);
        let mut critic_loss = 0.0f64;
        for i in 0..b {
            let err = q[i] - targets[i];
            critic_loss += (err as f64) * (err as f64);
            dout.push(2.0 * err / b as f32);
        }
        critic_loss /= b as f64;
        let mut cg = Grads::zeros_like(&self.critic);
        self.critic.backward(&cache, &dout, &mut cg);
        self.critic_opt.step(&mut self.critic, &cg);

        // ---- Actor update ----------------------------------------------
        // Maximize Q(s, π(s)): dQ/da via critic input grads, then chain
        // through the actor; ascend => negate gradients.
        let mut s_batch = Vec::with_capacity(b * self.state_dim);
        for t in &batch {
            s_batch.extend_from_slice(&t.state);
        }
        let mut a_cache = Cache::default();
        let actions = self.actor.forward(&s_batch, &mut a_cache);
        let mut sa2 = Vec::with_capacity(b * (self.state_dim + self.action_dim));
        for i in 0..b {
            sa2.extend_from_slice(&batch[i].state);
            sa2.extend_from_slice(&actions[i * self.action_dim..(i + 1) * self.action_dim]);
        }
        let mut q_cache = Cache::default();
        let q2 = self.critic.forward(&sa2, &mut q_cache);
        let actor_q = q2.iter().map(|&x| x as f64).sum::<f64>() / b as f64;
        // dQ/d(input) with dout = 1/b (mean over batch)
        let mut dummy = Grads::zeros_like(&self.critic);
        let dsa = self.critic.backward(&q_cache, &vec![1.0 / b as f32; b], &mut dummy);
        // Extract the action part of the input gradient; negate for ascent.
        let mut da = Vec::with_capacity(b * self.action_dim);
        for i in 0..b {
            let off = i * (self.state_dim + self.action_dim) + self.state_dim;
            for j in 0..self.action_dim {
                da.push(-dsa[off + j]);
            }
        }
        let mut ag = Grads::zeros_like(&self.actor);
        self.actor.backward(&a_cache, &da, &mut ag);
        self.actor_opt.step(&mut self.actor, &ag);

        // ---- Target soft updates ---------------------------------------
        let tau = self.cfg.tau as f32;
        self.actor_target.soft_update_from(&self.actor, tau);
        self.critic_target.soft_update_from(&self.critic, tau);

        StepStats { critic_loss, actor_q }
    }

    /// Reset the exploration process (e.g., per episode).
    pub fn reset_noise(&mut self) {
        self.noise.reset();
    }

    pub fn decay_exploration(&mut self, factor: f64, min_sigma: f64) {
        self.noise.decay_sigma(factor, min_sigma);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy continuous control: state s ~ U(-1,1); reward = -(a - s)^2.
    /// Optimal policy: a = s. DDPG should learn it quickly.
    #[test]
    fn solves_match_the_state_problem() {
        let cfg = DrlConfig {
            actor_lr: 2e-3,
            critic_lr: 1e-2,
            gamma: 0.0, // single-step episodes
            tau: 0.05,
            replay_capacity: 4096,
            batch: 32,
            hidden: 32,
            noise_sigma: 0.3,
            noise_theta: 0.15,
            warmup: 64,
        };
        let mut agent = Ddpg::new(1, 1, cfg, Rng::new(7));
        let mut env_rng = Rng::new(8);
        for _ in 0..1500 {
            let s = vec![env_rng.range(-1.0, 1.0) as f32];
            let a = agent.act_explore(&s);
            let r = -((a[0] - s[0]) * (a[0] - s[0]));
            agent.observe(Transition {
                state: s.clone(),
                action: a,
                reward: r,
                next_state: s,
                done: true,
            });
        }
        // Evaluate greedy policy.
        let mut err = 0.0f64;
        let n = 50;
        for i in 0..n {
            let s = -1.0 + 2.0 * (i as f32) / (n - 1) as f32;
            let a = agent.act_greedy(&[s])[0];
            err += ((a - s) as f64).powi(2);
        }
        let mse = err / n as f64;
        assert!(mse < 0.05, "greedy policy MSE {mse} too high");
    }

    #[test]
    fn critic_loss_decreases_on_stationary_problem() {
        let cfg = DrlConfig {
            warmup: 16,
            batch: 16,
            hidden: 24,
            gamma: 0.0,
            ..DrlConfig::default()
        };
        let mut agent = Ddpg::new(2, 1, cfg, Rng::new(9));
        let mut rng = Rng::new(10);
        let mut first = None;
        let mut last = 0.0;
        for step in 0..800 {
            let s = vec![rng.normal() as f32, rng.normal() as f32];
            let a = agent.act_explore(&s);
            let r = s[0] * a[0]; // simple bilinear reward
            if let Some(stats) = agent.observe(Transition {
                state: s.clone(),
                action: a,
                reward: r,
                next_state: s,
                done: true,
            }) {
                if first.is_none() && step > 50 {
                    first = Some(stats.critic_loss);
                }
                last = stats.critic_loss;
            }
        }
        assert!(last < first.unwrap(), "critic loss should fall: {first:?} -> {last}");
    }

    #[test]
    fn actions_bounded() {
        let mut agent = Ddpg::new(3, 2, DrlConfig::default(), Rng::new(11));
        for i in 0..200 {
            let s = vec![i as f32, -(i as f32), 0.5];
            let a = agent.act_explore(&s);
            assert_eq!(a.len(), 2);
            assert!(a.iter().all(|&x| (-1.0..=1.0).contains(&x)), "{a:?}");
        }
    }
}
