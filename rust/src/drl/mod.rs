//! Learning-based control (paper Sec. 3): DDPG with per-device agents that
//! pick local computation `H_m` and the layer-to-channel allocation
//! `D_{m,n}` every round.

pub mod adam;
pub mod agent;
pub mod ddpg;
pub mod mlp;
pub mod noise;
pub mod replay;

pub use agent::{ControlDecision, DeviceAgent, RewardTracker};
pub use ddpg::{Ddpg, StepStats};
pub use mlp::{Act, Mlp};
pub use replay::{ReplayBuffer, Transition};
