//! Adam optimizer over an [`Mlp`]'s parameters (Kingma & Ba 2015).

use super::mlp::{Grads, Mlp};

/// Adam state: first/second moments per parameter tensor.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    mw: Vec<Vec<f32>>,
    vw: Vec<Vec<f32>>,
    mb: Vec<Vec<f32>>,
    vb: Vec<Vec<f32>>,
}

impl Adam {
    pub fn new(mlp: &Mlp, lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            mw: mlp.layers.iter().map(|l| vec![0.0; l.w.len()]).collect(),
            vw: mlp.layers.iter().map(|l| vec![0.0; l.w.len()]).collect(),
            mb: mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
            vb: mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
        }
    }

    /// Apply one descent step with gradients `g` (descend; negate `g`
    /// beforehand for ascent).
    pub fn step(&mut self, mlp: &mut Mlp, g: &Grads) {
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for (l, layer) in mlp.layers.iter_mut().enumerate() {
            Self::step_tensor(
                &mut layer.w, &g.dw[l], &mut self.mw[l], &mut self.vw[l],
                self.lr, self.beta1, self.beta2, self.eps, b1t, b2t,
            );
            Self::step_tensor(
                &mut layer.b, &g.db[l], &mut self.mb[l], &mut self.vb[l],
                self.lr, self.beta1, self.beta2, self.eps, b1t, b2t,
            );
        }
    }

    /// The per-coordinate update lives in [`crate::kernels::adam_step`]
    /// (same expression, bitwise-identical — hoisted so it vectorizes).
    #[allow(clippy::too_many_arguments)]
    fn step_tensor(
        p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32],
        lr: f32, beta1: f32, beta2: f32, eps: f32, b1t: f32, b2t: f32,
    ) {
        crate::kernels::adam_step(p, g, m, v, lr, beta1, beta2, eps, b1t, b2t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::drl::mlp::{Act, Cache};
    use crate::util::Rng;

    #[test]
    fn adam_fits_linear_regression() {
        let mut rng = Rng::new(1);
        let mut mlp = Mlp::new(&[2, 1], &[Act::Linear], &mut rng);
        let mut adam = Adam::new(&mlp, 0.05);
        // target: y = 3x0 - 2x1 + 0.5
        let mut cache = Cache::default();
        for _ in 0..800 {
            let x: Vec<f32> = (0..2).map(|_| rng.normal() as f32).collect();
            let target = 3.0 * x[0] - 2.0 * x[1] + 0.5;
            let out = mlp.forward(&x, &mut cache);
            let err = out[0] - target;
            let mut g = Grads::zeros_like(&mlp);
            mlp.backward(&cache, &[err], &mut g);
            adam.step(&mut mlp, &g);
        }
        let w = &mlp.layers[0].w;
        let b = mlp.layers[0].b[0];
        assert!((w[0] - 3.0).abs() < 0.1, "w0={}", w[0]);
        assert!((w[1] + 2.0).abs() < 0.1, "w1={}", w[1]);
        assert!((b - 0.5).abs() < 0.1, "b={b}");
    }

    #[test]
    fn step_count_bias_correction() {
        let mut rng = Rng::new(2);
        let mut mlp = Mlp::new(&[1, 1], &[Act::Linear], &mut rng);
        let mut adam = Adam::new(&mlp, 0.1);
        let w0 = mlp.layers[0].w[0];
        let mut g = Grads::zeros_like(&mlp);
        g.dw[0][0] = 1.0;
        adam.step(&mut mlp, &g);
        // First step with bias correction moves by ~lr exactly.
        assert!((w0 - mlp.layers[0].w[0] - 0.1).abs() < 1e-4);
    }
}
