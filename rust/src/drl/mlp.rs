//! Dense MLP with manual backprop — the function approximator for DDPG.
//!
//! No autograd crate exists offline, so forward/backward are hand-written
//! and verified against finite differences in the tests. Shapes are tiny
//! (state/action dims < 16, hidden <= 128); the inner loops run on the
//! blocked kernels from [`crate::kernels`] — the forward `dot` is the
//! 8-lane reduction (reassociated, deterministic), while backward,
//! soft-update and grad scaling are per-coordinate kernels and stay
//! bitwise-identical to the plain loops they replaced.

use crate::kernels;
use crate::util::Rng;

/// Activation for a layer's output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Linear,
    Relu,
    Tanh,
}

impl Act {
    #[inline]
    fn apply(&self, z: f32) -> f32 {
        match self {
            Act::Linear => z,
            Act::Relu => z.max(0.0),
            Act::Tanh => z.tanh(),
        }
    }

    /// Derivative in terms of the *activated* output a = act(z).
    #[inline]
    fn dact(&self, a: f32) -> f32 {
        match self {
            Act::Linear => 1.0,
            Act::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Act::Tanh => 1.0 - a * a,
        }
    }
}

/// One dense layer: `out = act(W x + b)`, `W` row-major `[out_dim, in_dim]`.
#[derive(Clone, Debug)]
pub struct Dense {
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub in_dim: usize,
    pub out_dim: usize,
    pub act: Act,
}

impl Dense {
    pub fn new(in_dim: usize, out_dim: usize, act: Act, rng: &mut Rng) -> Self {
        // He/Xavier-ish: U(-s, s), s = sqrt(6/(in+out)).
        let s = (6.0 / (in_dim + out_dim) as f64).sqrt();
        let w = (0..in_dim * out_dim)
            .map(|_| rng.range(-s, s) as f32)
            .collect();
        Dense { w, b: vec![0.0; out_dim], in_dim, out_dim, act }
    }

    pub fn nparams(&self) -> usize {
        self.w.len() + self.b.len()
    }
}

/// Multi-layer perceptron.
#[derive(Clone, Debug)]
pub struct Mlp {
    pub layers: Vec<Dense>,
}

/// Per-forward activations cache (batched): `acts[0]` is the input batch,
/// `acts[l+1]` the activated output of layer `l`.
#[derive(Clone, Debug, Default)]
pub struct Cache {
    pub acts: Vec<Vec<f32>>,
    pub batch: usize,
}

/// Parameter gradients, same shapes as the MLP.
#[derive(Clone, Debug)]
pub struct Grads {
    pub dw: Vec<Vec<f32>>,
    pub db: Vec<Vec<f32>>,
}

impl Grads {
    pub fn zeros_like(mlp: &Mlp) -> Self {
        Grads {
            dw: mlp.layers.iter().map(|l| vec![0.0; l.w.len()]).collect(),
            db: mlp.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
        }
    }

    pub fn scale(&mut self, a: f32) {
        for g in self.dw.iter_mut().chain(self.db.iter_mut()) {
            kernels::scale(a, g);
        }
    }
}

impl Mlp {
    /// Build from layer sizes, e.g. `[in, h, h, out]` with per-layer acts
    /// (len = sizes.len() - 1).
    pub fn new(sizes: &[usize], acts: &[Act], rng: &mut Rng) -> Self {
        assert_eq!(acts.len(), sizes.len() - 1);
        let layers = sizes
            .windows(2)
            .zip(acts)
            .map(|(w, &a)| Dense::new(w[0], w[1], a, rng))
            .collect();
        Mlp { layers }
    }

    pub fn in_dim(&self) -> usize {
        self.layers.first().map_or(0, |l| l.in_dim)
    }

    pub fn out_dim(&self) -> usize {
        self.layers.last().map_or(0, |l| l.out_dim)
    }

    pub fn nparams(&self) -> usize {
        self.layers.iter().map(Dense::nparams).sum()
    }

    /// Batched forward; `x` is `[batch, in_dim]` row-major. Returns the
    /// output and fills `cache` for backward.
    pub fn forward(&self, x: &[f32], cache: &mut Cache) -> Vec<f32> {
        let batch = x.len() / self.in_dim();
        debug_assert_eq!(batch * self.in_dim(), x.len());
        cache.batch = batch;
        cache.acts.clear();
        cache.acts.push(x.to_vec());
        let mut cur = x.to_vec();
        for layer in &self.layers {
            let mut out = vec![0f32; batch * layer.out_dim];
            for bi in 0..batch {
                let xrow = &cur[bi * layer.in_dim..(bi + 1) * layer.in_dim];
                let orow = &mut out[bi * layer.out_dim..(bi + 1) * layer.out_dim];
                for (o, orow_o) in orow.iter_mut().enumerate() {
                    let wrow = &layer.w[o * layer.in_dim..(o + 1) * layer.in_dim];
                    let z = layer.b[o] + kernels::dot(wrow, xrow);
                    *orow_o = layer.act.apply(z);
                }
            }
            cache.acts.push(out.clone());
            cur = out;
        }
        cur
    }

    /// Inference without caching (single row convenience).
    pub fn infer(&self, x: &[f32]) -> Vec<f32> {
        let mut cache = Cache::default();
        self.forward(x, &mut cache)
    }

    /// Batched backward from `dout` (`[batch, out_dim]`, d loss / d output).
    /// Returns d loss / d input and accumulates parameter grads into `grads`
    /// (caller zeroes them). Gradients are summed over the batch.
    pub fn backward(&self, cache: &Cache, dout: &[f32], grads: &mut Grads) -> Vec<f32> {
        let batch = cache.batch;
        let mut delta = dout.to_vec();
        for (l, layer) in self.layers.iter().enumerate().rev() {
            let a_out = &cache.acts[l + 1];
            let a_in = &cache.acts[l];
            // delta_z = delta * act'(a_out)
            for (d, &a) in delta.iter_mut().zip(a_out.iter()) {
                *d *= layer.act.dact(a);
            }
            let dw = &mut grads.dw[l];
            let db = &mut grads.db[l];
            let mut dx = vec![0f32; batch * layer.in_dim];
            for bi in 0..batch {
                let drow = &delta[bi * layer.out_dim..(bi + 1) * layer.out_dim];
                let xrow = &a_in[bi * layer.in_dim..(bi + 1) * layer.in_dim];
                let dxrow = &mut dx[bi * layer.in_dim..(bi + 1) * layer.in_dim];
                for (o, &dz) in drow.iter().enumerate() {
                    db[o] += dz;
                    let wrow = &layer.w[o * layer.in_dim..(o + 1) * layer.in_dim];
                    let dwrow = &mut dw[o * layer.in_dim..(o + 1) * layer.in_dim];
                    // Two per-coordinate axpys — bitwise-identical to the
                    // old fused loop (each output coordinate sees the same
                    // op sequence).
                    kernels::axpy(dz, xrow, dwrow);
                    kernels::axpy(dz, wrow, dxrow);
                }
            }
            delta = dx;
        }
        delta
    }

    /// Soft update toward `src`: θ ← (1−τ)θ + τ·θ_src (DDPG target nets).
    pub fn soft_update_from(&mut self, src: &Mlp, tau: f32) {
        for (dst, s) in self.layers.iter_mut().zip(&src.layers) {
            kernels::scale_add(1.0 - tau, &mut dst.w, tau, &s.w);
            kernels::scale_add(1.0 - tau, &mut dst.b, tau, &s.b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd_check(mlp: &Mlp, x: &[f32], loss_grad: impl Fn(&[f32]) -> (f32, Vec<f32>)) {
        // Analytic grads
        let mut cache = Cache::default();
        let out = mlp.forward(x, &mut cache);
        let (_, dout) = loss_grad(&out);
        let mut grads = Grads::zeros_like(mlp);
        let dx = mlp.backward(&cache, &dout, &mut grads);

        let eps = 1e-3f32;
        let f = |m: &Mlp, xv: &[f32]| -> f32 {
            let mut c = Cache::default();
            let o = m.forward(xv, &mut c);
            loss_grad(&o).0
        };
        // check a few weight entries per layer
        let mut rng = Rng::new(99);
        for l in 0..mlp.layers.len() {
            for _ in 0..4 {
                let i = rng.index(mlp.layers[l].w.len());
                let mut mp = mlp.clone();
                mp.layers[l].w[i] += eps;
                let mut mm = mlp.clone();
                mm.layers[l].w[i] -= eps;
                let fd = (f(&mp, x) - f(&mm, x)) / (2.0 * eps);
                let an = grads.dw[l][i];
                assert!(
                    (fd - an).abs() < 1e-2 + 0.02 * fd.abs(),
                    "layer {l} w[{i}]: fd={fd} analytic={an}"
                );
            }
            // bias entry
            let i = rng.index(mlp.layers[l].b.len());
            let mut mp = mlp.clone();
            mp.layers[l].b[i] += eps;
            let mut mm = mlp.clone();
            mm.layers[l].b[i] -= eps;
            let fd = (f(&mp, x) - f(&mm, x)) / (2.0 * eps);
            let an = grads.db[l][i];
            assert!((fd - an).abs() < 1e-2 + 0.02 * fd.abs(), "layer {l} b[{i}]: {fd} vs {an}");
        }
        // input grads
        for ii in 0..x.len().min(6) {
            let mut xp = x.to_vec();
            xp[ii] += eps;
            let mut xm = x.to_vec();
            xm[ii] -= eps;
            let fd = (f(mlp, &xp) - f(mlp, &xm)) / (2.0 * eps);
            assert!(
                (fd - dx[ii]).abs() < 1e-2 + 0.02 * fd.abs(),
                "dx[{ii}]: fd={fd} analytic={}",
                dx[ii]
            );
        }
    }

    #[test]
    fn gradients_match_finite_differences_scalar_loss() {
        let mut rng = Rng::new(1);
        let mlp = Mlp::new(&[4, 8, 3], &[Act::Tanh, Act::Linear], &mut rng);
        let x: Vec<f32> = (0..8).map(|_| rng.normal() as f32 * 0.5).collect(); // batch 2
        // loss = 0.5 * sum(out^2)  =>  dout = out
        fd_check(&mlp, &x, |out| {
            (0.5 * out.iter().map(|o| o * o).sum::<f32>(), out.to_vec())
        });
    }

    #[test]
    fn gradients_match_finite_differences_relu() {
        let mut rng = Rng::new(2);
        let mlp = Mlp::new(&[3, 16, 16, 2], &[Act::Relu, Act::Relu, Act::Tanh], &mut rng);
        let x: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
        fd_check(&mlp, &x, |out| (out.iter().sum::<f32>(), vec![1.0; out.len()]));
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Rng::new(3);
        let mlp = Mlp::new(&[5, 7, 2], &[Act::Relu, Act::Linear], &mut rng);
        let x = vec![0.1f32; 5 * 3];
        let out = mlp.infer(&x);
        assert_eq!(out.len(), 2 * 3);
        assert_eq!(mlp.nparams(), 5 * 7 + 7 + 7 * 2 + 2);
    }

    #[test]
    fn tanh_output_bounded() {
        let mut rng = Rng::new(4);
        let mlp = Mlp::new(&[2, 8, 3], &[Act::Relu, Act::Tanh], &mut rng);
        for s in 0..20 {
            let x = vec![s as f32 * 10.0, -(s as f32) * 7.0];
            assert!(mlp.infer(&x).iter().all(|&a| (-1.0..=1.0).contains(&a)));
        }
    }

    #[test]
    fn soft_update_converges_to_source() {
        let mut rng = Rng::new(5);
        let src = Mlp::new(&[2, 4, 1], &[Act::Relu, Act::Linear], &mut rng);
        let mut dst = Mlp::new(&[2, 4, 1], &[Act::Relu, Act::Linear], &mut rng);
        for _ in 0..600 {
            dst.soft_update_from(&src, 0.05);
        }
        for (d, s) in dst.layers.iter().zip(&src.layers) {
            for (a, b) in d.w.iter().zip(&s.w) {
                assert!((a - b).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn batch_grads_are_sum_of_single_grads() {
        let mut rng = Rng::new(6);
        let mlp = Mlp::new(&[3, 5, 2], &[Act::Tanh, Act::Linear], &mut rng);
        let x1: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
        let x2: Vec<f32> = (0..3).map(|_| rng.normal() as f32).collect();
        let mut joint = [x1.clone(), x2.clone()].concat();
        let mut cache = Cache::default();
        mlp.forward(&joint, &mut cache);
        let mut gj = Grads::zeros_like(&mlp);
        mlp.backward(&cache, &vec![1.0; 4], &mut gj);

        let mut gs = Grads::zeros_like(&mlp);
        for x in [&x1, &x2] {
            let mut c = Cache::default();
            mlp.forward(x, &mut c);
            mlp.backward(&c, &vec![1.0; 2], &mut gs);
        }
        for l in 0..mlp.layers.len() {
            for (a, b) in gj.dw[l].iter().zip(&gs.dw[l]) {
                assert!((a - b).abs() < 1e-5);
            }
        }
        joint.clear(); // silence unused-mut lint paranoia
    }
}
