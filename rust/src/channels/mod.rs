//! Multi-channel mobile-edge network simulator (paper Sec. 1, 4.1).
//!
//! Each device owns several uplink channels (3G / 4G / 5G). Per channel we
//! model:
//!
//! - **energy** (J/MB): Gaussian with the Table-1 parameters
//!   (3G mean 1296, 4G 2.2x, 5G 2.5x2.2x; sigma 3.3e-4), following
//!   Wang et al. 2019 as the paper does;
//! - **money** ($/MB): flat per-MB tariff per technology (5G data is the
//!   most expensive, 3G the cheapest — standard mobile pricing shape);
//! - **bandwidth** (MB/s): a 3-state Markov fading chain (Good / Mid / Bad)
//!   so conditions are *dynamic*, which is the premise of the DRL controller;
//! - **latency** (s): per-transfer setup time.
//!
//! [`Link`] samples a concrete `(time, energy, money)` for a transfer of a
//! given byte size; [`DeviceChannels`] is the per-device bundle the
//! coordinator and the DRL agent observe.

pub mod allocator;

pub use allocator::{allocate_budget, AllocationPlan};

use crate::scenario::dynamics::ChannelDynamics;
use crate::util::Rng;

/// Channel technology, with Table-1 energy parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChannelType {
    G3,
    G4,
    G5,
}

/// Base energy cost of 3G in J/MB (paper Table 1).
pub const ENERGY_3G_J_PER_MB: f64 = 1296.0;
/// Table 1: sigma of the Gaussian energy model.
pub const ENERGY_SIGMA: f64 = 0.00033;

impl ChannelType {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "3g" | "g3" => Ok(ChannelType::G3),
            "4g" | "g4" | "lte" => Ok(ChannelType::G4),
            "5g" | "g5" => Ok(ChannelType::G5),
            other => Err(format!("unknown channel type `{other}`")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChannelType::G3 => "3G",
            ChannelType::G4 => "4G",
            ChannelType::G5 => "5G",
        }
    }

    /// Mean energy per MB uploaded (Table 1).
    pub fn energy_mean_j_per_mb(&self) -> f64 {
        match self {
            ChannelType::G3 => ENERGY_3G_J_PER_MB,
            ChannelType::G4 => 2.2 * ENERGY_3G_J_PER_MB,
            ChannelType::G5 => 2.5 * 2.2 * ENERGY_3G_J_PER_MB,
        }
    }

    /// Money tariff per MB (currency units). The paper reports money cost but
    /// not the tariff table; we use a typical monotone-in-speed pricing.
    pub fn money_per_mb(&self) -> f64 {
        match self {
            ChannelType::G3 => 0.01,
            ChannelType::G4 => 0.02,
            ChannelType::G5 => 0.05,
        }
    }

    /// Nominal (good-state) uplink bandwidth in MB/s.
    pub fn bandwidth_mb_s(&self) -> f64 {
        match self {
            ChannelType::G3 => 0.25,  // ~2 Mbps
            ChannelType::G4 => 1.5,   // ~12 Mbps
            ChannelType::G5 => 12.0,  // ~100 Mbps
        }
    }

    /// Per-transfer latency (radio setup + RTT) in seconds.
    pub fn latency_s(&self) -> f64 {
        match self {
            ChannelType::G3 => 0.30,
            ChannelType::G4 => 0.08,
            ChannelType::G5 => 0.02,
        }
    }
}

/// Markov fading state of a link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fading {
    Good,
    Mid,
    Bad,
}

impl Fading {
    /// Array index of the state (Good 0, Mid 1, Bad 2) into
    /// [`FadingParams`] tables. The state itself carries no numbers —
    /// gains and loss probabilities live in the owning link's
    /// [`FadingParams`] (a bare `Fading` has no way to know which zone's
    /// constants apply).
    pub fn index(&self) -> usize {
        match self {
            Fading::Good => 0,
            Fading::Mid => 1,
            Fading::Bad => 2,
        }
    }
}

/// The fading-chain constants, extracted from the formerly hard-coded
/// `Fading` methods so scenario zones and presets can override them — the
/// `Default` is the seed's Table-1 chain, bit for bit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FadingParams {
    /// Bandwidth multiplier per state (Good/Mid/Bad), each in `(0, 1]`.
    pub gain: [f64; 3],
    /// Whole-transfer erasure probability per state, each in `[0, 1)`.
    pub loss: [f64; 3],
    /// Row-stochastic transition matrix (row = current state).
    pub transition: [[f64; 3]; 3],
}

impl Default for FadingParams {
    fn default() -> Self {
        // The seed's constants: sticky chain, dwell ~5 rounds (Good row),
        // Table-1-era gains and loss probabilities.
        FadingParams {
            gain: [1.0, 0.45, 0.12],
            loss: [0.0, 0.03, 0.20],
            transition: [
                [0.80, 0.15, 0.05],
                [0.20, 0.65, 0.15],
                [0.10, 0.30, 0.60],
            ],
        }
    }
}

impl FadingParams {
    pub fn gain_of(&self, f: Fading) -> f64 {
        self.gain[f.index()]
    }

    pub fn loss_of(&self, f: Fading) -> f64 {
        self.loss[f.index()]
    }

    /// One chain step from `f` — with default params, the exact RNG draw
    /// sequence of the frozen oracle (one `choice_weighted` per step).
    pub fn step(&self, f: Fading, rng: &mut Rng) -> Fading {
        let rows = self.transition[f.index()];
        match rng.choice_weighted(&rows) {
            0 => Fading::Good,
            1 => Fading::Mid,
            _ => Fading::Bad,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        for (i, &g) in self.gain.iter().enumerate() {
            if !(g > 0.0 && g <= 1.0) {
                return Err(format!("fading gain[{i}] = {g} not in (0, 1]"));
            }
        }
        for (i, &l) in self.loss.iter().enumerate() {
            if !(0.0..1.0).contains(&l) {
                return Err(format!("fading loss[{i}] = {l} not in [0, 1)"));
            }
        }
        for (i, row) in self.transition.iter().enumerate() {
            let sum: f64 = row.iter().sum();
            if row.iter().any(|&p| p < 0.0) || (sum - 1.0).abs() > 1e-6 {
                return Err(format!("fading transition row {i} {row:?} is not stochastic"));
            }
        }
        Ok(())
    }
}

/// Cost sample for one transfer over one channel.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferCost {
    /// Wall-clock seconds (latency + bytes / effective bandwidth).
    pub time_s: f64,
    /// Joules consumed (Table-1 Gaussian x MB).
    pub energy_j: f64,
    /// Currency units.
    pub money: f64,
    /// Bytes moved.
    pub bytes: u64,
}

impl TransferCost {
    pub fn zero() -> Self {
        Self::default()
    }

    /// Running totals for **one channel across transfers**: all four fields
    /// sum, including `time_s` (cumulative airtime of this channel). This is
    /// *not* wall-clock composition — concurrent transfers overlap, so wall
    /// time comes from the event engine (`crate::sim`), which takes the max
    /// arrival over a device's parallel channels per upload.
    pub fn accumulate(&mut self, other: &TransferCost) {
        self.time_s += other.time_s;
        self.energy_j += other.energy_j;
        self.money += other.money;
        self.bytes += other.bytes;
    }

    /// One upload's `(energy_j, money, bytes)` totals across its per-channel
    /// costs (time excluded — wall time is the max, not the sum). The single
    /// fold shared by the event engine and the synchronous reference loop,
    /// so their accounting cannot drift.
    pub fn fold_totals(costs: &[TransferCost]) -> (f64, f64, u64) {
        costs.iter().fold((0.0, 0.0, 0u64), |acc, c| {
            (acc.0 + c.energy_j, acc.1 + c.money, acc.2 + c.bytes)
        })
    }
}

/// One uplink channel instance of a device, with dynamic condition state.
///
/// What advances the condition is the [`ChannelDynamics`] seam: the default
/// [`ChannelDynamics::Markov`] chain over the link's [`FadingParams`]
/// (bit-for-bit the frozen oracle with default params), or a
/// [`ChannelDynamics::Trace`] replay installed by a scenario zone. The
/// scenario subsystem additionally controls `up` (does this channel exist
/// in the device's current zone?) and `bw_scale` (zone/phase congestion
/// multiplier); both are inert at their defaults (`true`, `1.0`).
#[derive(Clone, Debug)]
pub struct Link {
    pub ty: ChannelType,
    pub fading: Fading,
    /// Fading-chain constants (scenario zones override; Table-1 default).
    pub params: FadingParams,
    dynamics: ChannelDynamics,
    /// Zone/phase bandwidth multiplier in `(0, 1]`.
    bw_scale: f64,
    /// Phase multiplier on the dynamics source's loss probability (applies
    /// to Markov *and* trace dynamics; 1.0 = untouched).
    loss_scale: f64,
    /// Whether the channel exists in the device's current zone. A masked
    /// link reports zero effective bandwidth (the DRL state sees the mask)
    /// and never carries traffic (plans are projected off it).
    up: bool,
    rng: Rng,
}

impl Link {
    pub fn new(ty: ChannelType, seed_rng: &Rng, tag: u64) -> Self {
        Link {
            ty,
            fading: Fading::Good,
            params: FadingParams::default(),
            dynamics: ChannelDynamics::Markov,
            bw_scale: 1.0,
            loss_scale: 1.0,
            up: true,
            rng: seed_rng.fork(tag),
        }
    }

    /// Advance the link condition by one round/tick. Markov dynamics make
    /// exactly one `choice_weighted` draw from the link's private stream
    /// (the oracle sequence); trace replay advances its cursor and leaves
    /// the stream untouched.
    pub fn step_round(&mut self) {
        match &mut self.dynamics {
            ChannelDynamics::Markov => {
                self.fading = self.params.step(self.fading, &mut self.rng);
            }
            ChannelDynamics::Trace(tr) => tr.advance(),
        }
    }

    /// Current bandwidth multiplier from the dynamics source.
    fn gain(&self) -> f64 {
        match &self.dynamics {
            ChannelDynamics::Markov => self.params.gain_of(self.fading),
            ChannelDynamics::Trace(tr) => tr.bw(),
        }
    }

    /// Current whole-transfer erasure probability, with the phase loss
    /// scale applied uniformly to both dynamics sources. The scale is only
    /// multiplied in when it differs from 1.0, so the default path stays
    /// bitwise on the raw constants (and user-specified probabilities are
    /// never clamped without a phase asking for it).
    fn current_loss_prob(&self) -> f64 {
        let raw = match &self.dynamics {
            ChannelDynamics::Markov => self.params.loss_of(self.fading),
            ChannelDynamics::Trace(tr) => tr.loss(),
        };
        if self.loss_scale == 1.0 {
            raw
        } else {
            (raw * self.loss_scale).clamp(0.0, 0.95)
        }
    }

    /// Whether the channel exists in the device's current zone.
    pub fn is_up(&self) -> bool {
        self.up
    }

    /// Mask / unmask the channel (scenario handoff).
    pub fn set_up(&mut self, up: bool) {
        self.up = up;
    }

    /// Current zone/phase bandwidth multiplier.
    pub fn bw_scale(&self) -> f64 {
        self.bw_scale
    }

    /// Install a zone profile in one shot (scenario handoff / phase):
    /// mask, fading constants, dynamics source, bandwidth scale and loss
    /// scale. The fading *state* and the link's RNG stream are preserved.
    pub fn apply_profile(
        &mut self,
        up: bool,
        params: FadingParams,
        dynamics: ChannelDynamics,
        bw_scale: f64,
        loss_scale: f64,
    ) {
        assert!(bw_scale > 0.0 && bw_scale <= 1.0, "bw_scale {bw_scale} not in (0, 1]");
        assert!(
            loss_scale > 0.0 && loss_scale.is_finite(),
            "loss_scale {loss_scale} must be finite and > 0"
        );
        self.up = up;
        self.params = params;
        self.dynamics = dynamics;
        self.bw_scale = bw_scale;
        self.loss_scale = loss_scale;
    }

    /// Effective bandwidth right now (MB/s); zero while the channel is
    /// masked out of the device's zone.
    pub fn effective_bandwidth(&self) -> f64 {
        if !self.up {
            return 0.0;
        }
        self.ty.bandwidth_mb_s() * self.gain() * self.bw_scale
    }

    /// Sample the cost of uploading `bytes` over this link now.
    /// Zero-byte transfers cost nothing (channel stays silent).
    pub fn transfer(&mut self, bytes: u64) -> TransferCost {
        if bytes == 0 {
            return TransferCost::zero();
        }
        debug_assert!(self.up, "transfer over a channel masked out of the zone");
        let mb = bytes as f64 / (1024.0 * 1024.0);
        let e_per_mb = self
            .rng
            .gaussian(self.ty.energy_mean_j_per_mb(), ENERGY_SIGMA)
            .max(0.0);
        TransferCost {
            time_s: self.ty.latency_s() + mb / self.effective_bandwidth(),
            energy_j: e_per_mb * mb,
            money: self.ty.money_per_mb() * mb,
            bytes,
        }
    }

    /// Like [`Link::transfer`], but the payload may be erased: returns the
    /// cost (energy/money/airtime are spent either way — the radio
    /// transmitted) plus a delivery flag drawn from the fading state's
    /// erasure probability.
    pub fn transfer_lossy(&mut self, bytes: u64) -> (TransferCost, bool) {
        let cost = self.transfer(bytes);
        if bytes == 0 {
            return (cost, true);
        }
        let delivered = self.rng.uniform() >= self.current_loss_prob();
        (cost, delivered)
    }

    /// Deterministic expected cost (for planners / the DRL state).
    pub fn expected_cost(&self, bytes: u64) -> TransferCost {
        if bytes == 0 {
            return TransferCost::zero();
        }
        let mb = bytes as f64 / (1024.0 * 1024.0);
        TransferCost {
            time_s: self.ty.latency_s() + mb / self.effective_bandwidth(),
            energy_j: self.ty.energy_mean_j_per_mb() * mb,
            money: self.ty.money_per_mb() * mb,
            bytes,
        }
    }
}

/// All uplink channels of one device.
#[derive(Clone, Debug)]
pub struct DeviceChannels {
    pub links: Vec<Link>,
}

impl DeviceChannels {
    pub fn new(types: &[ChannelType], rng: &Rng, device_id: usize) -> Self {
        let links = types
            .iter()
            .enumerate()
            .map(|(i, &ty)| Link::new(ty, rng, (device_id as u64) << 16 | i as u64))
            .collect();
        DeviceChannels { links }
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// Advance every link's fading chain by one round.
    pub fn step_round(&mut self) {
        for l in &mut self.links {
            l.step_round();
        }
    }

    /// Upload `sizes[i]` bytes over link i **in parallel** (the paper's
    /// multi-channel upload): wall time is the max over channels, energy and
    /// money are sums. Returns (wall_time, per-channel costs).
    pub fn parallel_upload(&mut self, sizes: &[u64]) -> (f64, Vec<TransferCost>) {
        assert_eq!(sizes.len(), self.links.len(), "one size per channel");
        let costs: Vec<TransferCost> = self
            .links
            .iter_mut()
            .zip(sizes)
            .map(|(l, &b)| l.transfer(b))
            .collect();
        let wall = costs.iter().map(|c| c.time_s).fold(0.0, f64::max);
        (wall, costs)
    }

    /// Lossy variant of [`DeviceChannels::parallel_upload`]: per-channel
    /// costs plus delivery flags.
    pub fn parallel_upload_lossy(&mut self, sizes: &[u64]) -> (f64, Vec<(TransferCost, bool)>) {
        assert_eq!(sizes.len(), self.links.len(), "one size per channel");
        let costs: Vec<(TransferCost, bool)> = self
            .links
            .iter_mut()
            .zip(sizes)
            .map(|(l, &b)| l.transfer_lossy(b))
            .collect();
        let wall = costs.iter().map(|(c, _)| c.time_s).fold(0.0, f64::max);
        (wall, costs)
    }

    /// Index of the currently fastest link. Masked links report zero
    /// bandwidth, so they are never chosen while any channel is up.
    pub fn fastest(&self) -> usize {
        let mut best = 0;
        for (i, l) in self.links.iter().enumerate() {
            if l.effective_bandwidth() > self.links[best].effective_bandwidth() {
                best = i;
            }
        }
        best
    }

    /// Whether every channel exists in the device's current zone (the
    /// zero-cost default — plan projection is skipped entirely).
    pub fn all_up(&self) -> bool {
        self.links.iter().all(Link::is_up)
    }

    /// Index of the first (fastest-first, most reliable) available link.
    pub fn first_up(&self) -> Option<usize> {
        self.links.iter().position(Link::is_up)
    }

    /// Per-link availability mask, aligned with `links`.
    pub fn up_mask(&self) -> Vec<bool> {
        self.links.iter().map(Link::is_up).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_energy_means() {
        assert_eq!(ChannelType::G3.energy_mean_j_per_mb(), 1296.0);
        assert!((ChannelType::G4.energy_mean_j_per_mb() - 2851.2).abs() < 1e-9);
        assert!((ChannelType::G5.energy_mean_j_per_mb() - 7128.0).abs() < 1e-9);
    }

    #[test]
    fn sampled_energy_matches_table1_mean() {
        let rng = Rng::new(1);
        let mut link = Link::new(ChannelType::G3, &rng, 0);
        let mb = 1024 * 1024; // 1 MB
        let n = 2000;
        let mean = (0..n)
            .map(|_| link.transfer(mb).energy_j)
            .sum::<f64>()
            / n as f64;
        assert!((mean - 1296.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn transfer_cost_scales_linearly_in_bytes() {
        let rng = Rng::new(2);
        let link = Link::new(ChannelType::G4, &rng, 0);
        let c1 = link.expected_cost(1024 * 1024);
        let c4 = link.expected_cost(4 * 1024 * 1024);
        assert!((c4.energy_j / c1.energy_j - 4.0).abs() < 1e-9);
        assert!((c4.money / c1.money - 4.0).abs() < 1e-9);
        let t1 = c1.time_s - ChannelType::G4.latency_s();
        let t4 = c4.time_s - ChannelType::G4.latency_s();
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_is_free() {
        let rng = Rng::new(3);
        let mut link = Link::new(ChannelType::G5, &rng, 0);
        assert_eq!(link.transfer(0), TransferCost::zero());
    }

    #[test]
    fn fading_changes_bandwidth_over_time() {
        let rng = Rng::new(4);
        let mut link = Link::new(ChannelType::G4, &rng, 0);
        let mut states = std::collections::HashSet::new();
        for _ in 0..200 {
            link.step_round();
            states.insert(format!("{:?}", link.fading));
        }
        assert!(states.len() >= 2, "fading chain never moved: {states:?}");
        assert!(link.effective_bandwidth() <= link.ty.bandwidth_mb_s());
    }

    #[test]
    fn parallel_upload_wall_time_is_max() {
        let rng = Rng::new(5);
        let mut ch = DeviceChannels::new(
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &rng,
            0,
        );
        let (wall, costs) = ch.parallel_upload(&[1 << 20, 1 << 20, 1 << 20]);
        let max = costs.iter().map(|c| c.time_s).fold(0.0, f64::max);
        assert_eq!(wall, max);
        // the 3G leg should dominate
        assert_eq!(
            costs.iter().enumerate().max_by(|a, b| a.1.time_s.total_cmp(&b.1.time_s)).unwrap().0,
            2
        );
    }

    #[test]
    fn fastest_tracks_fading() {
        let rng = Rng::new(6);
        let ch = DeviceChannels::new(&[ChannelType::G3, ChannelType::G5], &rng, 1);
        assert_eq!(ch.fastest(), 1);
    }

    #[test]
    fn lossy_transfer_charges_even_when_lost() {
        let rng = Rng::new(9);
        let mut link = Link::new(ChannelType::G4, &rng, 0);
        link.fading = Fading::Bad;
        let mut lost = 0;
        let mut spent = 0.0;
        for _ in 0..2000 {
            let (cost, delivered) = link.transfer_lossy(1 << 20);
            spent += cost.energy_j;
            if !delivered {
                lost += 1;
            }
        }
        // ~20% loss in Bad fading, full energy charged regardless.
        assert!((lost as f64 / 2000.0 - 0.20).abs() < 0.04, "lost {lost}/2000");
        assert!(spent > 0.0);
    }

    #[test]
    fn good_fading_never_loses() {
        let rng = Rng::new(10);
        let mut link = Link::new(ChannelType::G5, &rng, 0);
        for _ in 0..500 {
            assert!(link.transfer_lossy(1024).1);
        }
    }

    #[test]
    fn money_ordering() {
        assert!(ChannelType::G5.money_per_mb() > ChannelType::G4.money_per_mb());
        assert!(ChannelType::G4.money_per_mb() > ChannelType::G3.money_per_mb());
    }

    #[test]
    fn fading_params_default_matches_legacy_constants() {
        let p = FadingParams::default();
        assert_eq!(p.gain_of(Fading::Good), 1.0);
        assert_eq!(p.gain_of(Fading::Mid), 0.45);
        assert_eq!(p.gain_of(Fading::Bad), 0.12);
        assert_eq!(p.loss_of(Fading::Good), 0.0);
        assert_eq!(p.loss_of(Fading::Mid), 0.03);
        assert_eq!(p.loss_of(Fading::Bad), 0.20);
        p.validate().unwrap();
        let mut bad = p;
        bad.transition[0] = [0.5, 0.0, 0.0];
        assert!(bad.validate().is_err());
        bad = p;
        bad.gain[1] = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn masked_link_reports_zero_bandwidth_and_is_skipped_by_fastest() {
        let rng = Rng::new(12);
        let mut ch = DeviceChannels::new(
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &rng,
            0,
        );
        assert!(ch.all_up());
        assert_eq!(ch.first_up(), Some(0));
        ch.links[0].set_up(false);
        assert!(!ch.all_up());
        assert_eq!(ch.links[0].effective_bandwidth(), 0.0);
        assert_eq!(ch.fastest(), 1, "masked 5G must lose to live 4G");
        assert_eq!(ch.first_up(), Some(1));
        assert_eq!(ch.up_mask(), vec![false, true, true]);
        // Zero bytes over a masked link still cost nothing (silent channel).
        assert_eq!(ch.links[0].transfer(0), TransferCost::zero());
    }

    #[test]
    fn trace_dynamics_drive_bandwidth_without_touching_the_rng_stream() {
        use crate::scenario::dynamics::{diurnal_trace, TraceReplay};
        let rng = Rng::new(13);
        let mut markov = Link::new(ChannelType::G4, &rng, 5);
        let mut traced = Link::new(ChannelType::G4, &rng, 5); // same stream
        let pts = diurnal_trace(16, 16, 0.25);
        traced.apply_profile(
            true,
            FadingParams::default(),
            ChannelDynamics::Trace(TraceReplay::new(pts.clone(), 0)),
            1.0,
            1.0,
        );
        let mut bws = std::collections::BTreeSet::new();
        for _ in 0..16 {
            traced.step_round();
            bws.insert(traced.effective_bandwidth().to_bits());
        }
        assert!(bws.len() > 4, "diurnal trace should sweep bandwidths");
        // The traced link never consumed its RNG: a transfer drawn now
        // matches the Markov twin's first transfer draw exactly.
        let a = traced.transfer(1 << 20);
        let b = markov.transfer(1 << 20);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    }

    #[test]
    fn bw_scale_throttles_effective_bandwidth() {
        let rng = Rng::new(14);
        let mut link = Link::new(ChannelType::G5, &rng, 0);
        let full = link.effective_bandwidth();
        link.apply_profile(true, FadingParams::default(), ChannelDynamics::Markov, 0.5, 1.0);
        assert!((link.effective_bandwidth() - 0.5 * full).abs() < 1e-12);
        assert_eq!(link.bw_scale(), 0.5);
    }

    #[test]
    fn loss_scale_applies_to_both_markov_and_trace_dynamics() {
        use crate::scenario::dynamics::{TracePoint, TraceReplay};
        let rng = Rng::new(21);
        // Markov: Bad-state loss 0.20 doubled -> ~0.40 observed loss rate.
        let mut link = Link::new(ChannelType::G4, &rng, 0);
        link.apply_profile(
            true,
            FadingParams::default(),
            ChannelDynamics::Markov,
            1.0,
            2.0,
        );
        link.fading = Fading::Bad;
        let lost = (0..2000)
            .filter(|_| !link.transfer_lossy(1 << 16).1)
            .count();
        assert!(
            (lost as f64 / 2000.0 - 0.40).abs() < 0.05,
            "scaled Markov loss rate: {lost}/2000"
        );
        // Trace: a constant-loss trace scales the same way (the stadium
        // preset's scripted loss spike must reach its trace-driven zone).
        let pts: std::sync::Arc<[TracePoint]> =
            vec![TracePoint { bw: 0.5, loss: 0.10 }].into();
        let mut traced = Link::new(ChannelType::G4, &rng, 1);
        traced.apply_profile(
            true,
            FadingParams::default(),
            ChannelDynamics::Trace(TraceReplay::new(pts, 0)),
            1.0,
            3.0,
        );
        let lost = (0..2000)
            .filter(|_| !traced.transfer_lossy(1 << 16).1)
            .count();
        assert!(
            (lost as f64 / 2000.0 - 0.30).abs() < 0.05,
            "scaled trace loss rate: {lost}/2000"
        );
    }
}
