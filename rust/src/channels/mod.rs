//! Multi-channel mobile-edge network simulator (paper Sec. 1, 4.1).
//!
//! Each device owns several uplink channels (3G / 4G / 5G). Per channel we
//! model:
//!
//! - **energy** (J/MB): Gaussian with the Table-1 parameters
//!   (3G mean 1296, 4G 2.2x, 5G 2.5x2.2x; sigma 3.3e-4), following
//!   Wang et al. 2019 as the paper does;
//! - **money** ($/MB): flat per-MB tariff per technology (5G data is the
//!   most expensive, 3G the cheapest — standard mobile pricing shape);
//! - **bandwidth** (MB/s): a 3-state Markov fading chain (Good / Mid / Bad)
//!   so conditions are *dynamic*, which is the premise of the DRL controller;
//! - **latency** (s): per-transfer setup time.
//!
//! [`Link`] samples a concrete `(time, energy, money)` for a transfer of a
//! given byte size; [`DeviceChannels`] is the per-device bundle the
//! coordinator and the DRL agent observe.

pub mod allocator;

pub use allocator::{allocate_budget, AllocationPlan};

use crate::util::Rng;

/// Channel technology, with Table-1 energy parameters.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ChannelType {
    G3,
    G4,
    G5,
}

/// Base energy cost of 3G in J/MB (paper Table 1).
pub const ENERGY_3G_J_PER_MB: f64 = 1296.0;
/// Table 1: sigma of the Gaussian energy model.
pub const ENERGY_SIGMA: f64 = 0.00033;

impl ChannelType {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "3g" | "g3" => Ok(ChannelType::G3),
            "4g" | "g4" | "lte" => Ok(ChannelType::G4),
            "5g" | "g5" => Ok(ChannelType::G5),
            other => Err(format!("unknown channel type `{other}`")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ChannelType::G3 => "3G",
            ChannelType::G4 => "4G",
            ChannelType::G5 => "5G",
        }
    }

    /// Mean energy per MB uploaded (Table 1).
    pub fn energy_mean_j_per_mb(&self) -> f64 {
        match self {
            ChannelType::G3 => ENERGY_3G_J_PER_MB,
            ChannelType::G4 => 2.2 * ENERGY_3G_J_PER_MB,
            ChannelType::G5 => 2.5 * 2.2 * ENERGY_3G_J_PER_MB,
        }
    }

    /// Money tariff per MB (currency units). The paper reports money cost but
    /// not the tariff table; we use a typical monotone-in-speed pricing.
    pub fn money_per_mb(&self) -> f64 {
        match self {
            ChannelType::G3 => 0.01,
            ChannelType::G4 => 0.02,
            ChannelType::G5 => 0.05,
        }
    }

    /// Nominal (good-state) uplink bandwidth in MB/s.
    pub fn bandwidth_mb_s(&self) -> f64 {
        match self {
            ChannelType::G3 => 0.25,  // ~2 Mbps
            ChannelType::G4 => 1.5,   // ~12 Mbps
            ChannelType::G5 => 12.0,  // ~100 Mbps
        }
    }

    /// Per-transfer latency (radio setup + RTT) in seconds.
    pub fn latency_s(&self) -> f64 {
        match self {
            ChannelType::G3 => 0.30,
            ChannelType::G4 => 0.08,
            ChannelType::G5 => 0.02,
        }
    }
}

/// Markov fading state of a link.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fading {
    Good,
    Mid,
    Bad,
}

impl Fading {
    /// Bandwidth multiplier for the state.
    pub fn gain(&self) -> f64 {
        match self {
            Fading::Good => 1.0,
            Fading::Mid => 0.45,
            Fading::Bad => 0.12,
        }
    }

    /// Probability that a whole transfer is lost in this state (layer-level
    /// erasure — the premise of layered coding: enhancement layers on shaky
    /// channels may vanish, the base layer on a good channel survives).
    pub fn loss_prob(&self) -> f64 {
        match self {
            Fading::Good => 0.0,
            Fading::Mid => 0.03,
            Fading::Bad => 0.20,
        }
    }

    /// Row-stochastic transition matrix (sticky chain; dwell ~5 rounds).
    fn transition(&self, rng: &mut Rng) -> Fading {
        let rows = match self {
            Fading::Good => [0.80, 0.15, 0.05],
            Fading::Mid => [0.20, 0.65, 0.15],
            Fading::Bad => [0.10, 0.30, 0.60],
        };
        match rng.choice_weighted(&rows) {
            0 => Fading::Good,
            1 => Fading::Mid,
            _ => Fading::Bad,
        }
    }
}

/// Cost sample for one transfer over one channel.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TransferCost {
    /// Wall-clock seconds (latency + bytes / effective bandwidth).
    pub time_s: f64,
    /// Joules consumed (Table-1 Gaussian x MB).
    pub energy_j: f64,
    /// Currency units.
    pub money: f64,
    /// Bytes moved.
    pub bytes: u64,
}

impl TransferCost {
    pub fn zero() -> Self {
        Self::default()
    }

    /// Running totals for **one channel across transfers**: all four fields
    /// sum, including `time_s` (cumulative airtime of this channel). This is
    /// *not* wall-clock composition — concurrent transfers overlap, so wall
    /// time comes from the event engine (`crate::sim`), which takes the max
    /// arrival over a device's parallel channels per upload.
    pub fn accumulate(&mut self, other: &TransferCost) {
        self.time_s += other.time_s;
        self.energy_j += other.energy_j;
        self.money += other.money;
        self.bytes += other.bytes;
    }

    /// One upload's `(energy_j, money, bytes)` totals across its per-channel
    /// costs (time excluded — wall time is the max, not the sum). The single
    /// fold shared by the event engine and the synchronous reference loop,
    /// so their accounting cannot drift.
    pub fn fold_totals(costs: &[TransferCost]) -> (f64, f64, u64) {
        costs.iter().fold((0.0, 0.0, 0u64), |acc, c| {
            (acc.0 + c.energy_j, acc.1 + c.money, acc.2 + c.bytes)
        })
    }
}

/// One uplink channel instance of a device, with dynamic fading state.
#[derive(Clone, Debug)]
pub struct Link {
    pub ty: ChannelType,
    pub fading: Fading,
    rng: Rng,
}

impl Link {
    pub fn new(ty: ChannelType, seed_rng: &Rng, tag: u64) -> Self {
        Link { ty, fading: Fading::Good, rng: seed_rng.fork(tag) }
    }

    /// Advance fading by one round (call once per FL round).
    pub fn step_round(&mut self) {
        self.fading = self.fading.transition(&mut self.rng);
    }

    /// Effective bandwidth right now (MB/s).
    pub fn effective_bandwidth(&self) -> f64 {
        self.ty.bandwidth_mb_s() * self.fading.gain()
    }

    /// Sample the cost of uploading `bytes` over this link now.
    /// Zero-byte transfers cost nothing (channel stays silent).
    pub fn transfer(&mut self, bytes: u64) -> TransferCost {
        if bytes == 0 {
            return TransferCost::zero();
        }
        let mb = bytes as f64 / (1024.0 * 1024.0);
        let e_per_mb = self
            .rng
            .gaussian(self.ty.energy_mean_j_per_mb(), ENERGY_SIGMA)
            .max(0.0);
        TransferCost {
            time_s: self.ty.latency_s() + mb / self.effective_bandwidth(),
            energy_j: e_per_mb * mb,
            money: self.ty.money_per_mb() * mb,
            bytes,
        }
    }

    /// Like [`Link::transfer`], but the payload may be erased: returns the
    /// cost (energy/money/airtime are spent either way — the radio
    /// transmitted) plus a delivery flag drawn from the fading state's
    /// erasure probability.
    pub fn transfer_lossy(&mut self, bytes: u64) -> (TransferCost, bool) {
        let cost = self.transfer(bytes);
        if bytes == 0 {
            return (cost, true);
        }
        let delivered = self.rng.uniform() >= self.fading.loss_prob();
        (cost, delivered)
    }

    /// Deterministic expected cost (for planners / the DRL state).
    pub fn expected_cost(&self, bytes: u64) -> TransferCost {
        if bytes == 0 {
            return TransferCost::zero();
        }
        let mb = bytes as f64 / (1024.0 * 1024.0);
        TransferCost {
            time_s: self.ty.latency_s() + mb / self.effective_bandwidth(),
            energy_j: self.ty.energy_mean_j_per_mb() * mb,
            money: self.ty.money_per_mb() * mb,
            bytes,
        }
    }
}

/// All uplink channels of one device.
#[derive(Clone, Debug)]
pub struct DeviceChannels {
    pub links: Vec<Link>,
}

impl DeviceChannels {
    pub fn new(types: &[ChannelType], rng: &Rng, device_id: usize) -> Self {
        let links = types
            .iter()
            .enumerate()
            .map(|(i, &ty)| Link::new(ty, rng, (device_id as u64) << 16 | i as u64))
            .collect();
        DeviceChannels { links }
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    /// Advance every link's fading chain by one round.
    pub fn step_round(&mut self) {
        for l in &mut self.links {
            l.step_round();
        }
    }

    /// Upload `sizes[i]` bytes over link i **in parallel** (the paper's
    /// multi-channel upload): wall time is the max over channels, energy and
    /// money are sums. Returns (wall_time, per-channel costs).
    pub fn parallel_upload(&mut self, sizes: &[u64]) -> (f64, Vec<TransferCost>) {
        assert_eq!(sizes.len(), self.links.len(), "one size per channel");
        let costs: Vec<TransferCost> = self
            .links
            .iter_mut()
            .zip(sizes)
            .map(|(l, &b)| l.transfer(b))
            .collect();
        let wall = costs.iter().map(|c| c.time_s).fold(0.0, f64::max);
        (wall, costs)
    }

    /// Lossy variant of [`DeviceChannels::parallel_upload`]: per-channel
    /// costs plus delivery flags.
    pub fn parallel_upload_lossy(&mut self, sizes: &[u64]) -> (f64, Vec<(TransferCost, bool)>) {
        assert_eq!(sizes.len(), self.links.len(), "one size per channel");
        let costs: Vec<(TransferCost, bool)> = self
            .links
            .iter_mut()
            .zip(sizes)
            .map(|(l, &b)| l.transfer_lossy(b))
            .collect();
        let wall = costs.iter().map(|(c, _)| c.time_s).fold(0.0, f64::max);
        (wall, costs)
    }

    /// Index of the currently fastest link.
    pub fn fastest(&self) -> usize {
        let mut best = 0;
        for (i, l) in self.links.iter().enumerate() {
            if l.effective_bandwidth() > self.links[best].effective_bandwidth() {
                best = i;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_energy_means() {
        assert_eq!(ChannelType::G3.energy_mean_j_per_mb(), 1296.0);
        assert!((ChannelType::G4.energy_mean_j_per_mb() - 2851.2).abs() < 1e-9);
        assert!((ChannelType::G5.energy_mean_j_per_mb() - 7128.0).abs() < 1e-9);
    }

    #[test]
    fn sampled_energy_matches_table1_mean() {
        let rng = Rng::new(1);
        let mut link = Link::new(ChannelType::G3, &rng, 0);
        let mb = 1024 * 1024; // 1 MB
        let n = 2000;
        let mean = (0..n)
            .map(|_| link.transfer(mb).energy_j)
            .sum::<f64>()
            / n as f64;
        assert!((mean - 1296.0).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn transfer_cost_scales_linearly_in_bytes() {
        let rng = Rng::new(2);
        let link = Link::new(ChannelType::G4, &rng, 0);
        let c1 = link.expected_cost(1024 * 1024);
        let c4 = link.expected_cost(4 * 1024 * 1024);
        assert!((c4.energy_j / c1.energy_j - 4.0).abs() < 1e-9);
        assert!((c4.money / c1.money - 4.0).abs() < 1e-9);
        let t1 = c1.time_s - ChannelType::G4.latency_s();
        let t4 = c4.time_s - ChannelType::G4.latency_s();
        assert!((t4 / t1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_is_free() {
        let rng = Rng::new(3);
        let mut link = Link::new(ChannelType::G5, &rng, 0);
        assert_eq!(link.transfer(0), TransferCost::zero());
    }

    #[test]
    fn fading_changes_bandwidth_over_time() {
        let rng = Rng::new(4);
        let mut link = Link::new(ChannelType::G4, &rng, 0);
        let mut states = std::collections::HashSet::new();
        for _ in 0..200 {
            link.step_round();
            states.insert(format!("{:?}", link.fading));
        }
        assert!(states.len() >= 2, "fading chain never moved: {states:?}");
        assert!(link.effective_bandwidth() <= link.ty.bandwidth_mb_s());
    }

    #[test]
    fn parallel_upload_wall_time_is_max() {
        let rng = Rng::new(5);
        let mut ch = DeviceChannels::new(
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &rng,
            0,
        );
        let (wall, costs) = ch.parallel_upload(&[1 << 20, 1 << 20, 1 << 20]);
        let max = costs.iter().map(|c| c.time_s).fold(0.0, f64::max);
        assert_eq!(wall, max);
        // the 3G leg should dominate
        assert_eq!(
            costs.iter().enumerate().max_by(|a, b| a.1.time_s.total_cmp(&b.1.time_s)).unwrap().0,
            2
        );
    }

    #[test]
    fn fastest_tracks_fading() {
        let rng = Rng::new(6);
        let ch = DeviceChannels::new(&[ChannelType::G3, ChannelType::G5], &rng, 1);
        assert_eq!(ch.fastest(), 1);
    }

    #[test]
    fn lossy_transfer_charges_even_when_lost() {
        let rng = Rng::new(9);
        let mut link = Link::new(ChannelType::G4, &rng, 0);
        link.fading = Fading::Bad;
        let mut lost = 0;
        let mut spent = 0.0;
        for _ in 0..2000 {
            let (cost, delivered) = link.transfer_lossy(1 << 20);
            spent += cost.energy_j;
            if !delivered {
                lost += 1;
            }
        }
        // ~20% loss in Bad fading, full energy charged regardless.
        assert!((lost as f64 / 2000.0 - 0.20).abs() < 0.04, "lost {lost}/2000");
        assert!(spent > 0.0);
    }

    #[test]
    fn good_fading_never_loses() {
        let rng = Rng::new(10);
        let mut link = Link::new(ChannelType::G5, &rng, 0);
        for _ in 0..500 {
            assert!(link.transfer_lossy(1024).1);
        }
    }

    #[test]
    fn money_ordering() {
        assert!(ChannelType::G5.money_per_mb() > ChannelType::G4.money_per_mb());
        assert!(ChannelType::G4.money_per_mb() > ChannelType::G3.money_per_mb());
    }
}
