//! Layer-to-channel traffic allocation (the `D_{m,n}` action, Eq. 13).
//!
//! Given a total coordinate budget `D` and the current per-channel state, an
//! [`AllocationPlan`] decides how many gradient entries each channel carries
//! this round. The DRL agent emits raw fractions; [`allocate_budget`]
//! projects them onto the feasible set (non-negative, sums to `<= D`,
//! Eq. 10b) and orders layers so that **the most important layer (largest
//! magnitudes, layer 0) rides the most reliable channel** — the layered-
//! coding analogy of the paper: base layer on the best link, enhancement
//! layers on the rest.

use crate::util::clamp;

/// Concrete per-channel coordinate counts for one round.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct AllocationPlan {
    /// `counts[n]` = number of gradient entries shipped on channel `n`.
    /// Index order matches `DeviceChannels::links`.
    pub counts: Vec<usize>,
}

impl AllocationPlan {
    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }

    /// True when every channel is silent (nothing to upload this round) —
    /// allocation-free, unlike checking `layer_channels().is_empty()`.
    pub fn is_silent(&self) -> bool {
        self.counts.iter().all(|&c| c == 0)
    }

    /// Layer budgets `ks` for the LGC encoder: drop zero-count channels and
    /// keep channel order (channel list is fastest-first by construction, so
    /// layer 0 = base layer = most reliable channel).
    pub fn layer_budgets(&self) -> Vec<usize> {
        self.counts.iter().copied().filter(|&c| c > 0).collect()
    }

    /// Maps layer index (in `layer_budgets` order) back to channel index.
    pub fn layer_channels(&self) -> Vec<usize> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, _)| i)
            .collect()
    }

    /// Project the plan onto the channels available in the device's current
    /// zone (`up[n]` = channel `n` exists): traffic budgeted for a masked
    /// channel moves to the **first available** channel (fastest-first
    /// order, so displaced coordinates join the most reliable layer — the
    /// layered-coding fallback). Returns `None` when every channel is up
    /// (the zero-cost default) so oracle-path plans are never reallocated.
    /// The projection preserves the total coordinate budget exactly.
    ///
    /// Panics if no channel is up — scenario validation guarantees every
    /// zone keeps at least one channel, so a handoff can never strand a
    /// device with zero channels.
    pub fn project_onto(&self, up: &[bool]) -> Option<AllocationPlan> {
        debug_assert_eq!(up.len(), self.counts.len(), "one mask entry per channel");
        if up.iter().all(|&u| u) {
            return None;
        }
        let target = up
            .iter()
            .position(|&u| u)
            .expect("zone validation guarantees at least one available channel");
        let mut counts = self.counts.clone();
        for i in 0..counts.len() {
            if !up.get(i).copied().unwrap_or(true) && counts[i] > 0 {
                counts[target] += counts[i];
                counts[i] = 0;
            }
        }
        Some(AllocationPlan { counts })
    }
}

/// Project raw per-channel fractions (any reals, e.g. raw DDPG actor output
/// in [-1, 1]) onto a feasible allocation of at most `d_total` coordinates,
/// with at least `min_total` coordinates overall so the update never
/// degenerates to zero traffic.
pub fn allocate_budget(
    raw_fracs: &[f64],
    d_total: usize,
    min_total: usize,
) -> AllocationPlan {
    assert!(!raw_fracs.is_empty());
    let n = raw_fracs.len();
    // Map raw in [-1,1] (or anything) to [0,1] shares.
    let shares: Vec<f64> = raw_fracs.iter().map(|&r| clamp(0.5 * (r + 1.0), 0.0, 1.0)).collect();
    let sum: f64 = shares.iter().sum();
    let mut counts: Vec<usize> = if sum <= 1e-12 {
        // Degenerate action: fall back to uniform minimal traffic.
        vec![min_total.max(n) / n; n]
    } else {
        // Interpret each share as a fraction of d_total, then rescale if the
        // total exceeds the Eq. 10b cap.
        let desired: Vec<f64> = shares.iter().map(|&s| s * d_total as f64).collect();
        let total: f64 = desired.iter().sum();
        let scale = if total > d_total as f64 { d_total as f64 / total } else { 1.0 };
        desired.iter().map(|&x| (x * scale).floor() as usize).collect()
    };
    // Enforce the floor so at least `min_total` coordinates flow.
    let mut total: usize = counts.iter().sum();
    if total < min_total {
        // Put the deficit on the first (most reliable) channel.
        counts[0] += min_total - total;
        total = min_total;
    }
    // Cap (flooring can't exceed, but the fallback path might).
    if total > d_total {
        let mut excess = total - d_total;
        for c in counts.iter_mut().rev() {
            let take = (*c).min(excess);
            *c -= take;
            excess -= take;
            if excess == 0 {
                break;
            }
        }
    }
    AllocationPlan { counts }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn respects_total_cap() {
        let plan = allocate_budget(&[1.0, 1.0, 1.0], 1000, 10);
        assert!(plan.total() <= 1000, "{plan:?}");
    }

    #[test]
    fn enforces_min_total() {
        let plan = allocate_budget(&[-1.0, -1.0, -1.0], 1000, 64);
        assert!(plan.total() >= 64, "{plan:?}");
        assert!(plan.total() <= 1000);
    }

    #[test]
    fn proportional_to_shares() {
        let plan = allocate_budget(&[0.0, -0.5, -1.0], 4000, 1);
        // shares 0.5, 0.25, 0.0 -> counts ~2000, 1000, 0 (< cap, no rescale)
        assert!((plan.counts[0] as i64 - 2000).abs() <= 1, "{plan:?}");
        assert!((plan.counts[1] as i64 - 1000).abs() <= 1, "{plan:?}");
        assert_eq!(plan.counts[2], 0);
    }

    #[test]
    fn layer_budgets_skip_silent_channels() {
        let plan = AllocationPlan { counts: vec![100, 0, 50] };
        assert_eq!(plan.layer_budgets(), vec![100, 50]);
        assert_eq!(plan.layer_channels(), vec![0, 2]);
    }

    #[test]
    fn projection_moves_masked_traffic_to_first_up_channel() {
        let plan = AllocationPlan { counts: vec![100, 50, 25] };
        // All channels up: no reallocation at all.
        assert!(plan.project_onto(&[true, true, true]).is_none());
        // Middle channel vanished: its budget joins channel 0.
        let p = plan.project_onto(&[true, false, true]).unwrap();
        assert_eq!(p.counts, vec![150, 0, 25]);
        assert_eq!(p.total(), plan.total());
        // Fastest vanished: everything lands on the first surviving link.
        let p = plan.project_onto(&[false, false, true]).unwrap();
        assert_eq!(p.counts, vec![0, 0, 175]);
        assert_eq!(p.total(), plan.total());
    }

    #[test]
    #[should_panic(expected = "at least one available channel")]
    fn projection_rejects_all_masked() {
        let plan = AllocationPlan { counts: vec![10, 10] };
        let _ = plan.project_onto(&[false, false]);
    }

    #[test]
    fn never_negative_and_never_empty() {
        for raw in [
            vec![-1.0; 3],
            vec![1.0; 3],
            vec![0.3, -0.9, 0.9],
            vec![f64::NAN.min(0.0); 3], // guarded by clamp
        ] {
            let plan = allocate_budget(&raw, 500, 16);
            assert!(plan.total() >= 16 && plan.total() <= 500, "{raw:?} -> {plan:?}");
        }
    }
}
