//! Pure-Rust reference models.
//!
//! [`NativeLr`] implements the exact same logistic-regression fwd/bwd as the
//! L2 JAX graph (softmax cross-entropy over 784->10). It serves three roles:
//! (1) an independent oracle for runtime integration tests (PJRT grad vs
//! native grad), (2) a no-artifact path so coordinator unit tests run
//! without compiled artifacts, and (3) the strongly-convex problem for the
//! Theorem-1 validation (with L2 regularization it is strongly convex).

use crate::kernels;
use crate::runtime::BatchX;

pub const IMG: usize = 784;
pub const NCLASS: usize = 10;
pub const LR_PARAMS: usize = IMG * NCLASS + NCLASS;

/// Native logistic regression with optional L2 regularization.
#[derive(Clone, Debug)]
pub struct NativeLr {
    /// L2 coefficient (0 = match the JAX graph exactly).
    pub l2: f32,
}

impl NativeLr {
    pub fn new() -> Self {
        NativeLr { l2: 0.0 }
    }

    pub fn with_l2(l2: f32) -> Self {
        NativeLr { l2 }
    }

    /// Mean softmax cross-entropy loss + gradient wrt flat params.
    /// `x` is `[b, 784]` row-major, `y` labels. `grad` must be LR_PARAMS long.
    pub fn loss_grad(&self, params: &[f32], x: &[f32], y: &[i32], grad: &mut [f32]) -> f64 {
        assert_eq!(params.len(), LR_PARAMS);
        assert_eq!(grad.len(), LR_PARAMS);
        let b = y.len();
        assert_eq!(x.len(), b * IMG);
        let (w, bias) = params.split_at(IMG * NCLASS);
        grad.iter_mut().for_each(|g| *g = 0.0);
        let (gw, gb) = grad.split_at_mut(IMG * NCLASS);

        let mut loss = 0.0f64;
        let mut logits = [0f32; NCLASS];
        let mut probs = [0f32; NCLASS];
        for bi in 0..b {
            let xr = &x[bi * IMG..(bi + 1) * IMG];
            // logits = x W + b  (W stored [IMG, NCLASS] row-major like jax):
            // the dense 4-bank GEMV kernel — branch-free, reassociated.
            kernels::lr::gemv_wide::<NCLASS>(w, bias, xr, &mut logits);
            // softmax + xent
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for c in 0..NCLASS {
                probs[c] = (logits[c] - maxl).exp();
                z += probs[c];
            }
            let label = y[bi] as usize;
            loss += -(((probs[label] / z).max(1e-30) as f64).ln());
            // dlogits = probs - onehot
            for c in 0..NCLASS {
                probs[c] = probs[c] / z - if c == label { 1.0 } else { 0.0 };
            }
            // Dense rank-1 backward — bitwise-identical to the old skip loop.
            kernels::lr::rank1_acc::<NCLASS>(gw, xr, &probs);
            for c in 0..NCLASS {
                gb[c] += probs[c];
            }
        }
        let scale = 1.0 / b as f32;
        kernels::scale(scale, grad);
        let mut total = loss / b as f64;
        if self.l2 > 0.0 {
            kernels::axpy(self.l2, params, grad);
            total += 0.5 * self.l2 as f64 * crate::util::norm2(params);
        }
        total
    }

    /// The seed's scalar `loss_grad` — sequential sums and `xi == 0.0`
    /// skip branches, kept verbatim as the reassociation oracle for the
    /// kernel-vs-scalar accuracy-equivalence test (`tests/kernels.rs`)
    /// and the `bench_kernels` speedup baseline. Not a production path.
    #[doc(hidden)]
    pub fn loss_grad_reference(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[i32],
        grad: &mut [f32],
    ) -> f64 {
        assert_eq!(params.len(), LR_PARAMS);
        assert_eq!(grad.len(), LR_PARAMS);
        let b = y.len();
        assert_eq!(x.len(), b * IMG);
        let (w, bias) = params.split_at(IMG * NCLASS);
        grad.iter_mut().for_each(|g| *g = 0.0);
        let (gw, gb) = grad.split_at_mut(IMG * NCLASS);

        let mut loss = 0.0f64;
        let mut logits = [0f32; NCLASS];
        let mut probs = [0f32; NCLASS];
        for bi in 0..b {
            let xr = &x[bi * IMG..(bi + 1) * IMG];
            kernels::reference::gemv_wide_skip::<NCLASS>(w, bias, xr, &mut logits);
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0f32;
            for c in 0..NCLASS {
                probs[c] = (logits[c] - maxl).exp();
                z += probs[c];
            }
            let label = y[bi] as usize;
            loss += -(((probs[label] / z).max(1e-30) as f64).ln());
            for c in 0..NCLASS {
                probs[c] = probs[c] / z - if c == label { 1.0 } else { 0.0 };
            }
            kernels::reference::rank1_skip::<NCLASS>(gw, xr, &probs);
            for c in 0..NCLASS {
                gb[c] += probs[c];
            }
        }
        let scale = 1.0 / b as f32;
        for g in grad.iter_mut() {
            *g *= scale;
        }
        let mut total = loss / b as f64;
        if self.l2 > 0.0 {
            for (g, &p) in grad.iter_mut().zip(params) {
                *g += self.l2 * p;
            }
            total += 0.5 * self.l2 as f64 * kernels::reference::norm2(params);
        }
        total
    }

    /// Eval: (loss_sum, correct) like the PJRT eval graph. Shares the
    /// forward GEMV kernel with [`NativeLr::loss_grad`] (the seed
    /// duplicated the logits loop here).
    pub fn eval(&self, params: &[f32], x: &[f32], y: &[i32]) -> (f64, f64) {
        let b = y.len();
        let (w, bias) = params.split_at(IMG * NCLASS);
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut logits = [0f32; NCLASS];
        for bi in 0..b {
            let xr = &x[bi * IMG..(bi + 1) * IMG];
            kernels::lr::gemv_wide::<NCLASS>(w, bias, xr, &mut logits);
            let maxl = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = logits.iter().map(|l| (l - maxl).exp()).sum();
            let label = y[bi] as usize;
            loss_sum += -(((logits[label] - maxl).exp() / z).max(1e-30) as f64).ln();
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if pred == label {
                correct += 1.0;
            }
        }
        (loss_sum, correct)
    }

    /// Convenience: matches the runtime BatchX ABI.
    pub fn loss_grad_bx(&self, params: &[f32], x: &BatchX, y: &[i32], grad: &mut [f32]) -> f64 {
        match x {
            BatchX::F32(v) => self.loss_grad(params, v, y, grad),
            BatchX::I32(_) => panic!("NativeLr takes f32 inputs"),
        }
    }
}

impl Default for NativeLr {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_batch(b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x: Vec<f32> = (0..b * IMG).map(|_| rng.uniform_f32()).collect();
        let y: Vec<i32> = (0..b).map(|_| rng.index(NCLASS) as i32).collect();
        (x, y)
    }

    #[test]
    fn grad_matches_finite_differences() {
        let mut rng = Rng::new(1);
        let params: Vec<f32> = (0..LR_PARAMS).map(|_| rng.normal() as f32 * 0.01).collect();
        let (x, y) = toy_batch(4, 2);
        let model = NativeLr::new();
        let mut grad = vec![0f32; LR_PARAMS];
        model.loss_grad(&params, &x, &y, &mut grad);
        let eps = 1e-3f32;
        for _ in 0..10 {
            let i = rng.index(LR_PARAMS);
            let mut pp = params.clone();
            pp[i] += eps;
            let mut pm = params.clone();
            pm[i] -= eps;
            let mut dump = vec![0f32; LR_PARAMS];
            let lp = model.loss_grad(&pp, &x, &y, &mut dump);
            let lm = model.loss_grad(&pm, &x, &y, &mut dump);
            let fd = (lp - lm) / (2.0 * eps as f64);
            assert!(
                (fd - grad[i] as f64).abs() < 1e-3 + 0.02 * fd.abs(),
                "param {i}: fd={fd} analytic={}",
                grad[i]
            );
        }
    }

    #[test]
    fn loss_at_zero_params_is_log_nclass() {
        let params = vec![0f32; LR_PARAMS];
        let (x, y) = toy_batch(8, 3);
        let model = NativeLr::new();
        let mut grad = vec![0f32; LR_PARAMS];
        let loss = model.loss_grad(&params, &x, &y, &mut grad);
        assert!((loss - (NCLASS as f64).ln()).abs() < 1e-5);
    }

    #[test]
    fn sgd_descends() {
        let mut params = vec![0f32; LR_PARAMS];
        let (x, y) = toy_batch(16, 4);
        let model = NativeLr::new();
        let mut grad = vec![0f32; LR_PARAMS];
        let l0 = model.loss_grad(&params, &x, &y, &mut grad);
        for _ in 0..30 {
            model.loss_grad(&params, &x, &y, &mut grad);
            for (p, &g) in params.iter_mut().zip(&grad) {
                *p -= 0.5 * g;
            }
        }
        let l1 = model.loss_grad(&params, &x, &y, &mut grad);
        assert!(l1 < l0 * 0.5, "loss {l0} -> {l1}");
    }

    #[test]
    fn eval_counts() {
        let mut params = vec![0f32; LR_PARAMS];
        let (x, y) = toy_batch(8, 5);
        let model = NativeLr::new();
        let (loss_sum, correct) = model.eval(&params, &x, &y);
        assert!((loss_sum / 8.0 - (NCLASS as f64).ln()).abs() < 1e-5);
        assert!((0.0..=8.0).contains(&correct));
        // after fitting, accuracy should rise
        let mut grad = vec![0f32; LR_PARAMS];
        for _ in 0..80 {
            model.loss_grad(&params, &x, &y, &mut grad);
            for (p, &g) in params.iter_mut().zip(&grad) {
                *p -= 0.5 * g;
            }
        }
        let (_, c2) = model.eval(&params, &x, &y);
        assert!(c2 >= 7.0, "correct={c2}");
    }

    #[test]
    fn kernel_grad_close_to_scalar_reference() {
        let mut rng = Rng::new(7);
        let params: Vec<f32> = (0..LR_PARAMS).map(|_| rng.normal() as f32 * 0.05).collect();
        let (x, y) = toy_batch(8, 8);
        let model = NativeLr::with_l2(0.01);
        let mut g = vec![0f32; LR_PARAMS];
        let mut gr = vec![0f32; LR_PARAMS];
        let l = model.loss_grad(&params, &x, &y, &mut g);
        let lr = model.loss_grad_reference(&params, &x, &y, &mut gr);
        assert!((l - lr).abs() < 1e-6 * (1.0 + lr.abs()), "loss {l} vs {lr}");
        for i in 0..LR_PARAMS {
            assert!(
                (g[i] - gr[i]).abs() < 1e-5,
                "grad {i}: kernel {} vs reference {}",
                g[i],
                gr[i]
            );
        }
    }

    #[test]
    fn l2_makes_gradient_at_zero_nonreg_equal() {
        // grad_l2(p) = grad(p) + l2*p; at p=0 they coincide
        let params = vec![0f32; LR_PARAMS];
        let (x, y) = toy_batch(4, 6);
        let m0 = NativeLr::new();
        let m1 = NativeLr::with_l2(0.1);
        let mut g0 = vec![0f32; LR_PARAMS];
        let mut g1 = vec![0f32; LR_PARAMS];
        m0.loss_grad(&params, &x, &y, &mut g0);
        m1.loss_grad(&params, &x, &y, &mut g1);
        assert_eq!(g0, g1);
    }
}
