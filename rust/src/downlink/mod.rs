//! The simulated downlink: layered broadcast of the global model over
//! fading channels, with delta compression and staleness tracking.
//!
//! The paper's loop ends with the server "send[ing] the result back to the
//! devices"; until this module, that broadcast was free and instantaneous —
//! every device resynced to the fresh global model at time zero. Here the
//! downlink is a first-class simulated path:
//!
//! - the server keeps a per-device **mirror** of what each device currently
//!   holds, and encodes the *delta* `global − mirror` through the existing
//!   [`Compressor`] machinery — [`DownlinkCompression::Dense`] ships the
//!   exact delta (lossless broadcast), [`DownlinkCompression::Layered`]
//!   ships magnitude-banded LGC layers (base + enhancement), so a device
//!   can proceed on a *partial* base model while enhancement layers trail;
//! - each layer rides a per-device downlink [`crate::channels::Link`]
//!   (the same fading/energy/money machinery as the uplink, with a
//!   downlink-specific money tariff scale) as its own in-flight transfer
//!   via [`crate::sim::Event::DownlinkLayerArrived`] /
//!   [`crate::sim::Event::SyncConfirmed`];
//! - download energy and money are charged to the device's
//!   [`crate::resources::ResourceMeter`] (Eq. 10 resources are spent in
//!   both directions), so `Budget` enforcement counts the downlink toward
//!   early stop;
//! - each [`crate::coordinator::Device`] carries a [`SyncState`] — last
//!   confirmed sync, layers still in flight, and the staleness gap at round
//!   start — which the DRL observation can consume as an extra state
//!   feature (only when the downlink is enabled, so the disabled
//!   configuration stays bit-for-bit equal to the frozen `step_round`
//!   oracle).
//!
//! Delta encoding is self-correcting: the mirror advances by exactly the
//! layers that were shipped, so whatever a layered (lossy) broadcast leaves
//! out is still present in the next round's delta — the downlink analogue
//! of error feedback, with no extra memory. Downlink transfers are modeled
//! as *reliable* (link-layer ARQ): fading shapes latency, energy and money,
//! never erasure — erasures would desynchronize mirror and device without
//! an ACK protocol, which the simulator does not model.
//!
//! Population (cohort) engines run the downlink in **accounting-only**
//! fidelity: a per-client dense mirror would make server memory
//! O(population × model), defeating the O(model + cohort) bound, so
//! materialization still hands the client the exact global model while the
//! broadcast's bytes/energy/money/time are charged from the compression
//! budget (layer sizes are budget-determined, not value-determined). This
//! is one of the documented divergences — see DESIGN.md §"Downlink &
//! staleness".

pub mod frame;

use crate::channels::{ChannelType, DeviceChannels, TransferCost};
use crate::compression::{lgc_compress, CompressScratch, Layer, LgcUpdate};
use crate::util::Rng;

/// How the server compresses the per-device model delta for broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DownlinkCompression {
    /// Ship the exact dense delta (one layer, 4 B/coordinate): the
    /// broadcast is lossless and a confirmed device equals the global
    /// model bitwise.
    Dense,
    /// Ship magnitude-banded LGC layers of the delta (base + enhancement,
    /// same per-layer budgets as the uplink's `layer_fracs`): partial
    /// broadcast, with the left-out mass self-correcting through the
    /// mirror into later deltas.
    Layered,
}

impl DownlinkCompression {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "dense" | "dense-noop" | "exact" => Ok(DownlinkCompression::Dense),
            "layered" | "lgc" => Ok(DownlinkCompression::Layered),
            other => Err(format!("unknown downlink compression `{other}`")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DownlinkCompression::Dense => "dense",
            DownlinkCompression::Layered => "layered",
        }
    }
}

/// Per-device downlink synchronization state — the sync-state machine of
/// DESIGN.md §"Downlink & staleness". Lives on
/// [`crate::coordinator::Device`] and persists across population
/// demobilization via [`crate::population::Population`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SyncState {
    /// Server model version of the last *fully* confirmed downlink (every
    /// layer of that broadcast applied).
    pub synced_version: u64,
    /// Round / record index of that confirmation.
    pub synced_round: usize,
    /// Downlink layers of the current broadcast still in flight toward
    /// this device (0 = fully synced to `synced_version`).
    pub pending_layers: usize,
    /// Version gap `server_version − device_version` observed when the
    /// device last started a round — the staleness the DRL state feature
    /// reports.
    pub staleness: u64,
}

/// Per-record-window downlink totals, drained into each
/// [`crate::metrics::RoundRecord`].
#[derive(Clone, Copy, Debug, Default)]
pub struct DownWindow {
    pub bytes: u64,
    pub energy_j: f64,
    pub money: f64,
}

impl DownWindow {
    /// Drain the window (returns the totals, resets to zero).
    pub fn take(&mut self) -> DownWindow {
        std::mem::take(self)
    }
}

/// One encoded broadcast ready to ride the event queue: the layered delta,
/// the per-layer downlink channel mapping, and the per-channel cost
/// samples (money already scaled by the downlink tariff).
#[derive(Clone, Debug)]
pub struct DownlinkTransfer {
    /// The compressed delta; layer 0 is the base layer.
    pub update: LgcUpdate,
    /// `channels[c]` = downlink link index layer `c` rides.
    pub channels: Vec<usize>,
    /// Per-link cost samples (same indexing as the device's downlink
    /// links; silent links cost zero).
    pub costs: Vec<TransferCost>,
    /// Wall-clock of the whole broadcast (max over links).
    pub wall_time_s: f64,
    /// Summed bytes across layers.
    pub bytes: u64,
    /// Summed energy / money across links (money tariff-scaled).
    pub energy_j: f64,
    pub money: f64,
}

/// The server-side downlink state: per-device links, per-device mirrors
/// (legacy engines), the delta compressor, and window accounting.
pub struct Downlink {
    compression: DownlinkCompression,
    tariff_scale: f64,
    /// Per-device/client downlink channel bundles (independent fading
    /// chains from the uplink's, forked off the experiment seed).
    links: Vec<DeviceChannels>,
    /// Per-device model mirrors: what the server believes each device
    /// currently holds. Empty in accounting-only (population) fidelity.
    mirrors: Vec<Vec<f32>>,
    /// Per-layer coordinate budgets for [`DownlinkCompression::Layered`]
    /// (the uplink's `layer_fracs` applied to the model dimension).
    layer_ks: Vec<usize>,
    scratch: CompressScratch,
    delta_buf: Vec<f32>,
    frame_buf: Vec<u8>,
    /// Consumed broadcast payloads handed back by the engines for reuse —
    /// the dense path refills a spare update in place, so the per-device
    /// per-round broadcast allocates nothing at steady state.
    spare: Vec<LgcUpdate>,
    /// Per-window totals for the metrics columns.
    pub window: DownWindow,
}

impl Downlink {
    /// Build the downlink for `n` devices/clients. `mirrors` carries one
    /// init-model clone per device for full-fidelity delta encoding
    /// (legacy engines), or is empty for accounting-only fidelity
    /// (population mode).
    pub fn new(
        n: usize,
        compression: DownlinkCompression,
        tariff_scale: f64,
        channel_types: &[ChannelType],
        rng: &Rng,
        layer_ks: Vec<usize>,
        mirrors: Vec<Vec<f32>>,
    ) -> Self {
        assert!(tariff_scale > 0.0 && tariff_scale.is_finite());
        assert!(mirrors.is_empty() || mirrors.len() == n, "one mirror per device");
        // A distinct fork tag keeps downlink fading streams independent of
        // every uplink stream, so enabling the downlink never perturbs
        // uplink RNG draws.
        let base = rng.fork(0xD0_17E5);
        let links = (0..n)
            .map(|id| DeviceChannels::new(channel_types, &base, id))
            .collect();
        Downlink {
            compression,
            tariff_scale,
            links,
            mirrors,
            layer_ks,
            scratch: CompressScratch::default(),
            delta_buf: Vec::new(),
            frame_buf: Vec::new(),
            spare: Vec::new(),
            window: DownWindow::default(),
        }
    }

    /// Hand a fully-applied broadcast payload back for buffer reuse (the
    /// engines call this when a transfer completes; bounded so a burst
    /// can't hoard memory).
    pub fn recycle(&mut self, update: LgcUpdate) {
        if self.spare.len() < 16 {
            self.spare.push(update);
        }
    }

    pub fn compression(&self) -> DownlinkCompression {
        self.compression
    }

    /// Whether this downlink runs in accounting-only fidelity (population
    /// mode: costs charged, no per-client mirror).
    pub fn accounting_only(&self) -> bool {
        self.mirrors.is_empty()
    }

    /// Mutable access to a device's downlink links (tests / scenario
    /// setup, e.g. pinning a device to a Bad-fading 3G downlink).
    pub fn links_mut(&mut self, id: usize) -> &mut DeviceChannels {
        &mut self.links[id]
    }

    /// Advance every downlink link's fading chain by one round/tick.
    pub fn step_round(&mut self) {
        for ch in &mut self.links {
            ch.step_round();
        }
    }

    /// Fresh FL episode: mirrors return to the init model, window clears.
    /// Fading chains keep their streams (like the uplink's
    /// `reset_episode`).
    pub fn reset_episode(&mut self, init: &[f32]) {
        for m in &mut self.mirrors {
            m.copy_from_slice(init);
        }
        self.window = DownWindow::default();
    }

    /// Layer sizes on the wire for a broadcast of a `dim`-sized model under
    /// the configured compression — budget-determined, value-independent
    /// (what accounting-only fidelity charges).
    fn layer_sizes(&self, dim: usize) -> Vec<u64> {
        match self.compression {
            // Dense delta: raw f32 stream, no index overhead.
            DownlinkCompression::Dense => vec![4 * dim as u64],
            DownlinkCompression::Layered => self
                .layer_ks
                .iter()
                .map(|&k| frame::frame_len(k.min(dim)) as u64)
                .collect(),
        }
    }

    /// Downlink link that carries layer `c` of a broadcast to device `id`:
    /// positional (layer c rides link c; the channel list is fastest-first,
    /// so the base layer takes the most reliable link — the same
    /// layered-coding mapping as the uplink), redirected to the first
    /// available link when the positional one is masked out of the
    /// device's current scenario zone (the uplink's projection rule; zone
    /// validation guarantees at least one live link). The single mapping
    /// shared by cost charging and arrival scheduling, so they cannot
    /// drift apart.
    fn layer_link(&self, id: usize, c: usize) -> usize {
        let links = &self.links[id];
        let tgt = c.min(links.len() - 1);
        if links.links[tgt].is_up() {
            tgt
        } else {
            links.first_up().unwrap_or(0)
        }
    }

    /// Charge `sizes[c]` bytes onto device `id`'s downlink link
    /// [`Downlink::layer_link`]`(id, c)`. Returns (wall, per-link costs)
    /// with money tariff-scaled, and folds the totals into the window.
    fn charge(&mut self, id: usize, sizes: &[u64]) -> (f64, Vec<TransferCost>) {
        let mut per_link = vec![0u64; self.links[id].len()];
        for (c, &b) in sizes.iter().enumerate() {
            per_link[self.layer_link(id, c)] += b;
        }
        let (wall, mut costs) = self.links[id].parallel_upload(&per_link);
        for c in &mut costs {
            c.money *= self.tariff_scale;
        }
        let (e, m, b) = TransferCost::fold_totals(&costs);
        self.window.bytes += b;
        self.window.energy_j += e;
        self.window.money += m;
        (wall, costs)
    }

    /// Accounting-only broadcast (population mode): charge the
    /// budget-determined layer sizes for client `id` and return
    /// `(wall_time, energy, money, bytes)` for the caller's meter.
    pub fn charge_broadcast(&mut self, id: usize, dim: usize) -> (f64, f64, f64, u64) {
        let sizes = self.layer_sizes(dim);
        let (wall, costs) = self.charge(id, &sizes);
        let (e, m, b) = TransferCost::fold_totals(&costs);
        (wall, e, m, b)
    }

    /// Full-fidelity broadcast encode for device `id`: compress the delta
    /// `global − mirror[id]`, advance the mirror by exactly the shipped
    /// layers (self-correcting encoding), round-trip every layer through
    /// the downlink frame format (stamped with the server model `version`
    /// and `round` this broadcast carries), charge the links, and return
    /// the transfer for the event engine to schedule.
    pub fn encode_for(
        &mut self,
        id: usize,
        global: &[f32],
        version: u64,
        round: usize,
    ) -> DownlinkTransfer {
        assert!(
            !self.accounting_only(),
            "encode_for needs per-device mirrors (legacy engines); population \
             mode charges via charge_broadcast"
        );
        let mirror = &self.mirrors[id];
        assert_eq!(mirror.len(), global.len(), "mirror dim mismatch");
        // delta = global − mirror via the blocked subtract — bitwise
        // identical to the old zipped `g - m` extend.
        self.delta_buf.clear();
        self.delta_buf.extend_from_slice(global);
        crate::kernels::sub_assign(&mut self.delta_buf, mirror);
        let dim = global.len();
        let update = match self.compression {
            DownlinkCompression::Dense => {
                // Refill a recycled update in place: zero steady-state
                // allocation once the engines start handing buffers back.
                let mut update = self
                    .spare
                    .pop()
                    .unwrap_or(LgcUpdate { dim: 0, layers: Vec::new() });
                update.dim = dim;
                update.layers.truncate(1);
                if update.layers.is_empty() {
                    update.layers.push(Layer { indices: Vec::new(), values: Vec::new() });
                }
                let layer = &mut update.layers[0];
                layer.indices.clear();
                layer.indices.extend(0..dim as u32);
                layer.values.clear();
                layer.values.extend_from_slice(&self.delta_buf);
                update
            }
            DownlinkCompression::Layered => {
                // Clamp the budget to the model dimension (small test
                // models), mirroring LayerBudget::from_plan.
                let ks: Vec<usize> = {
                    let mut ks: Vec<usize> =
                        self.layer_ks.iter().map(|&k| k.min(dim)).collect();
                    let total: usize = ks.iter().sum();
                    if total > dim {
                        for k in ks.iter_mut() {
                            *k = (*k * dim) / total.max(1);
                        }
                        if ks.iter().sum::<usize>() == 0 {
                            ks[0] = 1;
                        }
                    }
                    ks
                };
                lgc_compress(&self.delta_buf, &ks, &mut self.scratch)
            }
        };
        // Wire round-trip (layered only — the dense broadcast travels as a
        // raw f32 stream, like the DenseNoop uplink): what crosses the
        // channel is the frame encoding, so the frame decoder's hardening
        // is exercised on the hot path exactly like the uplink's wire
        // round-trip. The decode targets come from the recycled `spare`
        // pool, so the layered path is also allocation-free at steady
        // state.
        let update = if self.compression == DownlinkCompression::Layered {
            let n = update.layers.len();
            let mut rt = self
                .spare
                .pop()
                .unwrap_or(LgcUpdate { dim: 0, layers: Vec::new() });
            rt.dim = dim;
            rt.layers.truncate(n);
            while rt.layers.len() < n {
                rt.layers.push(Layer { indices: Vec::new(), values: Vec::new() });
            }
            for (c, layer) in update.layers.iter().enumerate() {
                frame::encode_frame(
                    version as u32,
                    round as u32,
                    c as u16,
                    n as u16,
                    dim,
                    layer,
                    &mut self.frame_buf,
                );
                let _hdr = frame::decode_frame(&self.frame_buf, &mut rt.layers[c])
                    .expect("self-encoded downlink frame must decode");
                debug_assert_eq!(_hdr.dim, dim);
            }
            rt
        } else {
            update
        };
        // Advance the mirror by exactly what shipped: the next delta
        // contains whatever this broadcast left out.
        let mirror = &mut self.mirrors[id];
        for layer in &update.layers {
            crate::kernels::scatter_add_unit(mirror, &layer.indices, &layer.values);
        }
        // Byte accounting matches the frame encoding per layer.
        let sizes: Vec<u64> = match self.compression {
            DownlinkCompression::Dense => vec![4 * dim as u64],
            DownlinkCompression::Layered => update
                .layers
                .iter()
                .map(|l| frame::frame_len(l.len()) as u64)
                .collect(),
        };
        // The same masked-link mapping `charge` uses, so each layer's
        // arrival is scheduled off the link that actually carried it.
        let channels: Vec<usize> =
            (0..update.layers.len()).map(|c| self.layer_link(id, c)).collect();
        let (wall, costs) = self.charge(id, &sizes);
        let (energy_j, money, bytes) = TransferCost::fold_totals(&costs);
        DownlinkTransfer {
            update,
            channels,
            costs,
            wall_time_s: wall,
            bytes,
            energy_j,
            money,
        }
    }

    /// The mirror the server keeps for device `id` (tests).
    pub fn mirror(&self, id: usize) -> &[f32] {
        &self.mirrors[id]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, comp: DownlinkCompression, dim: usize) -> Downlink {
        let rng = Rng::new(7);
        Downlink::new(
            n,
            comp,
            1.0,
            &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
            &rng,
            vec![4, 8, 16],
            (0..n).map(|_| vec![0f32; dim]).collect(),
        )
    }

    #[test]
    fn dense_broadcast_converges_mirror_to_global_exactly() {
        let mut dl = mk(2, DownlinkCompression::Dense, 64);
        let global: Vec<f32> = (0..64).map(|i| (i as f32) * 0.25 - 3.0).collect();
        let tr = dl.encode_for(0, &global, 1, 0);
        assert_eq!(tr.update.layers.len(), 1);
        assert_eq!(tr.bytes, 4 * 64);
        assert!(tr.energy_j > 0.0 && tr.money > 0.0 && tr.wall_time_s > 0.0);
        for (a, b) in dl.mirror(0).iter().zip(&global) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Second encode against the unchanged global ships a zero delta.
        let tr2 = dl.encode_for(0, &global, 1, 0);
        assert!(tr2.update.layers[0].values.iter().all(|&v| v == 0.0));
        // Device 1's mirror is untouched.
        assert!(dl.mirror(1).iter().all(|&x| x == 0.0));
    }

    #[test]
    fn layered_broadcast_is_partial_but_self_correcting() {
        let mut dl = mk(1, DownlinkCompression::Layered, 256);
        let global: Vec<f32> = (0..256).map(|i| ((i * 37) % 101) as f32 / 50.0 - 1.0).collect();
        let tr = dl.encode_for(0, &global, 1, 0);
        // 4+8+16 = 28 coordinates shipped — a strict subset of the delta.
        assert_eq!(tr.update.total_nnz(), 28);
        let gap0: f64 = dl
            .mirror(0)
            .iter()
            .zip(&global)
            .map(|(&m, &g)| ((g - m) as f64).powi(2))
            .sum();
        assert!(gap0 > 0.0, "layered broadcast must be partial");
        // Repeated broadcasts against the same global shrink the gap
        // monotonically: the mirror is the error-feedback memory.
        let mut prev = gap0;
        for _ in 0..20 {
            dl.encode_for(0, &global, 1, 0);
            let gap: f64 = dl
                .mirror(0)
                .iter()
                .zip(&global)
                .map(|(&m, &g)| ((g - m) as f64).powi(2))
                .sum();
            assert!(gap <= prev + 1e-12, "{gap} > {prev}");
            prev = gap;
        }
        assert!(prev < 1e-9, "mirror should converge, residual {prev}");
    }

    #[test]
    fn tariff_scale_multiplies_money_not_energy() {
        let rng = Rng::new(9);
        let build = |scale: f64| {
            Downlink::new(
                1,
                DownlinkCompression::Dense,
                scale,
                &[ChannelType::G4],
                &rng,
                vec![8],
                vec![vec![0f32; 128]],
            )
        };
        let global = vec![1.0f32; 128];
        let mut a = build(1.0);
        let mut b = build(3.0);
        let ta = a.encode_for(0, &global, 1, 0);
        let tb = b.encode_for(0, &global, 1, 0);
        assert!((tb.money / ta.money - 3.0).abs() < 1e-9);
        assert_eq!(ta.bytes, tb.bytes);
        // Energy draws come from the same forked stream ⇒ identical.
        assert_eq!(ta.energy_j.to_bits(), tb.energy_j.to_bits());
    }

    #[test]
    fn accounting_only_charges_budget_determined_sizes() {
        let rng = Rng::new(3);
        let mut dl = Downlink::new(
            2,
            DownlinkCompression::Layered,
            2.0,
            &[ChannelType::G5, ChannelType::G3],
            &rng,
            vec![10, 30],
            Vec::new(),
        );
        assert!(dl.accounting_only());
        let (wall, e, m, b) = dl.charge_broadcast(1, 1000);
        assert_eq!(
            b,
            (frame::frame_len(10) + frame::frame_len(30)) as u64
        );
        assert!(wall > 0.0 && e > 0.0 && m > 0.0);
        assert_eq!(dl.window.bytes, b);
        let w = dl.window.take();
        assert_eq!(w.bytes, b);
        assert_eq!(dl.window.bytes, 0);
    }

    #[test]
    fn base_layer_rides_the_first_link() {
        let mut dl = mk(1, DownlinkCompression::Layered, 512);
        let global: Vec<f32> = (0..512).map(|i| (i as f32 + 1.0) * 1e-3).collect();
        let tr = dl.encode_for(0, &global, 1, 0);
        assert_eq!(tr.channels[0], 0, "base layer on the fastest link");
        assert!(tr.channels.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn compression_parse_roundtrip() {
        for (s, c) in [
            ("dense", DownlinkCompression::Dense),
            ("lgc", DownlinkCompression::Layered),
            ("layered", DownlinkCompression::Layered),
        ] {
            assert_eq!(DownlinkCompression::parse(s).unwrap(), c);
        }
        assert!(DownlinkCompression::parse("warp").is_err());
        assert_eq!(DownlinkCompression::Dense.name(), "dense");
    }

    #[test]
    fn reset_episode_restores_mirrors() {
        let mut dl = mk(1, DownlinkCompression::Dense, 16);
        let global = vec![2.0f32; 16];
        dl.encode_for(0, &global, 1, 0);
        assert!(dl.mirror(0).iter().all(|&x| x == 2.0));
        let init = vec![0.5f32; 16];
        dl.reset_episode(&init);
        assert!(dl.mirror(0).iter().all(|&x| x == 0.5));
        assert_eq!(dl.window.bytes, 0);
    }
}
