//! Wire format of one downlink broadcast layer ("frame").
//!
//! Layout (little-endian, single contiguous buffer):
//!
//! ```text
//! [u32 magic "LGCD"] [u32 version] [u32 round] [u16 layer_idx] [u16 n_layers]
//! [u32 dim] [u32 nnz] [u32 delta_0 ..] [f32 v_0 ..]
//! ```
//!
//! The payload after the 16-byte frame header is exactly the uplink's
//! sparse chunk ([`crate::compression::wire`]), so the hardened decoder —
//! checked lengths, overflow-free index reconstruction, duplicate
//! detection — is reused rather than re-implemented. Like the uplink
//! format, decoding never panics however adversarial the buffer (the
//! `tests/properties.rs` fuzz sweep covers truncations and bit flips of
//! valid frames, mirroring the `wire.rs` sweep).

use crate::compression::wire::{self, DecodeError};
use crate::compression::Layer;

/// Frame magic: "LGCD" little-endian.
pub const FRAME_MAGIC: u32 = 0x4443_474C;
/// Frame header bytes ahead of the sparse-chunk payload.
pub const FRAME_HEADER: usize = 16;

/// Encoded frame size in bytes for `nnz` payload entries.
pub fn frame_len(nnz: usize) -> usize {
    FRAME_HEADER + wire::encoded_len(nnz)
}

/// Decoded frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    /// Server model version this broadcast brings the device to.
    pub version: u32,
    /// Round / record index at encode time.
    pub round: u32,
    /// Which layer of the broadcast this frame carries (0 = base layer).
    pub layer_idx: u16,
    /// Total layers in the broadcast.
    pub n_layers: u16,
    /// Model dimension.
    pub dim: usize,
}

/// Frame decode error — every malformed buffer maps here; no panic path.
#[derive(Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Buffer shorter than the frame header.
    Truncated,
    /// Wrong magic (not a downlink frame).
    BadMagic { got: u32 },
    /// `layer_idx >= n_layers` (or zero layers claimed).
    BadLayerIndex { layer_idx: u16, n_layers: u16 },
    /// The sparse payload failed the hardened wire decoder.
    Payload(DecodeError),
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated => write!(f, "truncated downlink frame"),
            FrameError::BadMagic { got } => {
                write!(f, "bad downlink frame magic {got:#010x}")
            }
            FrameError::BadLayerIndex { layer_idx, n_layers } => {
                write!(f, "layer index {layer_idx} out of range for {n_layers} layers")
            }
            FrameError::Payload(e) => write!(f, "downlink frame payload: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Encode one broadcast layer into `out` (cleared first); returns the
/// number of bytes written, which always equals
/// [`frame_len`]`(layer.len())` — the byte count the downlink charges.
pub fn encode_frame(
    version: u32,
    round: u32,
    layer_idx: u16,
    n_layers: u16,
    dim: usize,
    layer: &Layer,
    out: &mut Vec<u8>,
) -> usize {
    debug_assert!(layer_idx < n_layers, "layer_idx {layer_idx} >= n_layers {n_layers}");
    // `wire::encode_into` clears its buffer, so write the payload first
    // and rotate the header in front — allocation-free once `out`'s
    // capacity warms up (this runs per layer per device per broadcast).
    wire::encode_into(dim, layer, out);
    let mut header = [0u8; FRAME_HEADER];
    header[0..4].copy_from_slice(&FRAME_MAGIC.to_le_bytes());
    header[4..8].copy_from_slice(&version.to_le_bytes());
    header[8..12].copy_from_slice(&round.to_le_bytes());
    header[12..14].copy_from_slice(&layer_idx.to_le_bytes());
    header[14..16].copy_from_slice(&n_layers.to_le_bytes());
    out.extend_from_slice(&header);
    out.rotate_right(FRAME_HEADER);
    debug_assert_eq!(out.len(), frame_len(layer.len()));
    out.len()
}

/// Decode a frame into a reusable `Layer` (vectors cleared and refilled);
/// returns the frame header. On `Err`, `out`'s contents are unspecified.
pub fn decode_frame(b: &[u8], out: &mut Layer) -> Result<FrameHeader, FrameError> {
    if b.len() < FRAME_HEADER {
        return Err(FrameError::Truncated);
    }
    let magic = u32::from_le_bytes(b[0..4].try_into().expect("4-byte slice"));
    if magic != FRAME_MAGIC {
        return Err(FrameError::BadMagic { got: magic });
    }
    let version = u32::from_le_bytes(b[4..8].try_into().expect("4-byte slice"));
    let round = u32::from_le_bytes(b[8..12].try_into().expect("4-byte slice"));
    let layer_idx = u16::from_le_bytes(b[12..14].try_into().expect("2-byte slice"));
    let n_layers = u16::from_le_bytes(b[14..16].try_into().expect("2-byte slice"));
    if n_layers == 0 || layer_idx >= n_layers {
        return Err(FrameError::BadLayerIndex { layer_idx, n_layers });
    }
    let dim = wire::decode_into(&b[FRAME_HEADER..], out).map_err(FrameError::Payload)?;
    Ok(FrameHeader { version, round, layer_idx, n_layers, dim })
}

/// Apply a decoded delta layer to a parameter vector: `params += layer`.
/// The engine applies every downlink layer to *both* `params_hat` and
/// `params_sync`, so the device's pending progress `w_sync − ŵ` is
/// invariant under late-arriving enhancement layers (the error-feedback
/// path never double-counts).
pub fn apply_delta(params: &mut [f32], layer: &Layer) {
    crate::kernels::scatter_add_unit(params, &layer.indices, &layer.values);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::testing::{check, gen};
    use crate::util::Rng;

    fn random_layer(rng: &mut Rng, dim: usize) -> Layer {
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let k = 1 + rng.index(dim / 2);
        lgc_compress(&u, &[k], &mut CompressScratch::default())
            .layers
            .remove(0)
    }

    /// Property: encode→decode is the identity on layers and headers
    /// (driven by the in-tree `testing` harness).
    #[test]
    fn prop_roundtrip_identity() {
        check(
            0xD0,
            crate::testing::default_cases(),
            |rng| gen::usize_in(rng, 8, 2000),
            |&dim| {
                let mut rng = Rng::new(dim as u64 ^ 0xF0F0);
                let layer = random_layer(&mut rng, dim);
                let mut buf = Vec::new();
                let n = encode_frame(7, 42, 1, 3, dim, &layer, &mut buf);
                if n != frame_len(layer.len()) {
                    return Err(format!("byte accounting: {n} != {}", frame_len(layer.len())));
                }
                let mut out = Layer { indices: vec![], values: vec![] };
                let hdr = decode_frame(&buf, &mut out).map_err(|e| e.to_string())?;
                if hdr != (FrameHeader { version: 7, round: 42, layer_idx: 1, n_layers: 3, dim })
                {
                    return Err(format!("header mismatch: {hdr:?}"));
                }
                if out != layer {
                    return Err("layer mismatch".into());
                }
                Ok(())
            },
        );
    }

    /// Property: applying the decoded delta to the mirror is a fixed
    /// point — re-encoding the (dense) delta against the same global
    /// yields an all-zero payload, and re-applying that zero delta leaves
    /// the parameters bitwise unchanged (delta-apply idempotence).
    #[test]
    fn prop_delta_apply_idempotent() {
        check(
            0xD1,
            crate::testing::default_cases(),
            |rng| gen::f32_vec(rng, 512, 2.0),
            |global: &Vec<f32>| {
                let dim = global.len();
                let mut mirror = vec![0f32; dim];
                let delta = Layer {
                    indices: (0..dim as u32).collect(),
                    values: global.iter().zip(&mirror).map(|(&g, &m)| g - m).collect(),
                };
                let mut buf = Vec::new();
                encode_frame(1, 0, 0, 1, dim, &delta, &mut buf);
                let mut out = Layer { indices: vec![], values: vec![] };
                decode_frame(&buf, &mut out).map_err(|e| e.to_string())?;
                apply_delta(&mut mirror, &out);
                // Fixed point: the next delta is all-zero...
                let next: Vec<f32> =
                    global.iter().zip(&mirror).map(|(&g, &m)| g - m).collect();
                if next.iter().any(|&v| v != 0.0) {
                    return Err("delta not a fixed point after apply".into());
                }
                // ...and applying it changes nothing, bitwise.
                let snapshot: Vec<u32> = mirror.iter().map(|v| v.to_bits()).collect();
                let zero = Layer { indices: (0..dim as u32).collect(), values: next };
                apply_delta(&mut mirror, &zero);
                if mirror
                    .iter()
                    .zip(&snapshot)
                    .any(|(v, &s)| v.to_bits() != s)
                {
                    return Err("zero delta mutated parameters".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn bad_magic_and_bad_layer_index_detected() {
        let layer = Layer { indices: vec![1, 5], values: vec![0.5, -0.5] };
        let mut buf = Vec::new();
        encode_frame(0, 0, 0, 2, 10, &layer, &mut buf);
        let mut out = Layer { indices: vec![], values: vec![] };
        // Corrupt the magic.
        let mut bad = buf.clone();
        bad[0] ^= 0xFF;
        assert!(matches!(decode_frame(&bad, &mut out), Err(FrameError::BadMagic { .. })));
        // layer_idx >= n_layers.
        let mut bad = buf.clone();
        bad[12] = 9; // layer_idx
        bad[14] = 2; // n_layers
        assert_eq!(
            decode_frame(&bad, &mut out),
            Err(FrameError::BadLayerIndex { layer_idx: 9, n_layers: 2 })
        );
        // Zero layers claimed.
        let mut bad = buf.clone();
        bad[14] = 0;
        bad[15] = 0;
        assert!(matches!(
            decode_frame(&bad, &mut out),
            Err(FrameError::BadLayerIndex { n_layers: 0, .. })
        ));
        // Short buffer.
        assert_eq!(decode_frame(&buf[..10], &mut out), Err(FrameError::Truncated));
    }

    /// The wire.rs malformed-input sweep, extended to downlink frames:
    /// random buffers, truncations at every boundary, and single-byte
    /// mutations of valid frames must return `Ok` or `Err` — never panic,
    /// never yield an out-of-contract layer.
    #[test]
    fn malformed_frame_sweep_never_panics() {
        let mut rng = Rng::new(0xD0_BEEF);
        let mut out = Layer { indices: vec![], values: vec![] };
        for len in 0..80 {
            let b: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = decode_frame(&b, &mut out);
        }
        for seed in 0..6 {
            let dim = 32 + rng.index(400);
            let layer = random_layer(&mut rng, dim);
            let mut buf = Vec::new();
            encode_frame(seed, seed * 3, 0, 1, dim, &layer, &mut buf);
            for cut in 0..buf.len() {
                let _ = decode_frame(&buf[..cut], &mut out);
            }
            for _ in 0..200 {
                let mut mutated = buf.clone();
                let pos = rng.index(mutated.len());
                mutated[pos] ^= 1 << rng.index(8);
                if let Ok(hdr) = decode_frame(&mutated, &mut out) {
                    assert!(out.indices.windows(2).all(|w| w[0] < w[1]));
                    assert!(out.indices.iter().all(|&i| (i as usize) < hdr.dim));
                    assert_eq!(out.indices.len(), out.values.len());
                }
            }
        }
    }
}
