//! Data substrate: synthetic MNIST-class images, the Shakespeare char
//! corpus, and IID / Dirichlet non-IID partitioning across devices.
//!
//! No network access is available in this environment, so MNIST is replaced
//! by a deterministic class-conditional generator with the same shapes and
//! splits (see DESIGN.md §Substitutions): 10 structured 28x28 prototype
//! glyphs + per-sample jitter, elastic shift, and pixel noise. It is
//! learnable-but-not-trivial: LR plateaus below CNN, mirroring MNIST.

pub mod mnist;
pub mod partition;
pub mod shakespeare;

pub use mnist::{MnistGen, Sample};
pub use partition::{partition_dirichlet, partition_iid};
pub use shakespeare::{CharCorpus, VOCAB};

/// A classification dataset in flat-f32 form (x: n x 784, y: n).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Vec<f32>,
    pub y: Vec<i32>,
    pub features: usize,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.features..(i + 1) * self.features]
    }

    /// Gather a batch by indices into caller-provided buffers.
    pub fn gather(&self, idxs: &[usize], xb: &mut Vec<f32>, yb: &mut Vec<i32>) {
        xb.clear();
        yb.clear();
        for &i in idxs {
            xb.extend_from_slice(self.row(i));
            yb.push(self.y[i]);
        }
    }
}

/// Cycling mini-batch sampler over a fixed index set (one per device).
#[derive(Clone, Debug)]
pub struct BatchSampler {
    indices: Vec<usize>,
    cursor: usize,
    rng: crate::util::Rng,
}

impl BatchSampler {
    pub fn new(indices: Vec<usize>, rng: crate::util::Rng) -> Self {
        assert!(!indices.is_empty());
        let mut s = BatchSampler { indices, cursor: 0, rng };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        let mut idx = std::mem::take(&mut self.indices);
        self.rng.shuffle(&mut idx);
        self.indices = idx;
        self.cursor = 0;
    }

    /// Number of samples in this device's shard.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next `b` indices, reshuffling at epoch boundaries (with replacement
    /// across the boundary so batches are always full).
    pub fn next_batch(&mut self, b: usize, out: &mut Vec<usize>) {
        out.clear();
        while out.len() < b {
            if self.cursor >= self.indices.len() {
                self.reshuffle();
            }
            out.push(self.indices[self.cursor]);
            self.cursor += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn sampler_covers_all_indices_each_epoch() {
        let mut s = BatchSampler::new((0..10).collect(), Rng::new(1));
        let mut seen = std::collections::HashSet::new();
        let mut batch = Vec::new();
        for _ in 0..5 {
            s.next_batch(2, &mut batch);
            seen.extend(batch.iter().copied());
        }
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn sampler_always_full_batches() {
        let mut s = BatchSampler::new((0..7).collect(), Rng::new(2));
        let mut batch = Vec::new();
        for _ in 0..10 {
            s.next_batch(3, &mut batch);
            assert_eq!(batch.len(), 3);
        }
    }

    #[test]
    fn dataset_gather() {
        let ds = Dataset {
            x: (0..12).map(|i| i as f32).collect(),
            y: vec![0, 1, 2],
            features: 4,
        };
        let mut xb = Vec::new();
        let mut yb = Vec::new();
        ds.gather(&[2, 0], &mut xb, &mut yb);
        assert_eq!(yb, vec![2, 0]);
        assert_eq!(xb, vec![8.0, 9.0, 10.0, 11.0, 0.0, 1.0, 2.0, 3.0]);
    }
}
