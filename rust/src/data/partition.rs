//! Federated data partitioning: IID and Dirichlet non-IID splits.
//!
//! `partition_dirichlet(alpha)` draws per-class device proportions from a
//! symmetric Dirichlet — the standard FL non-IID benchmark protocol
//! (smaller alpha = more skewed label distributions per device).

use super::Dataset;
use crate::util::Rng;

/// Split `ds` indices into `m` IID shards (random permutation, equal sizes).
pub fn partition_iid(ds: &Dataset, m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..ds.len()).collect();
    rng.shuffle(&mut idx);
    let chunk = ds.len() / m;
    (0..m)
        .map(|i| {
            let lo = i * chunk;
            let hi = if i + 1 == m { ds.len() } else { lo + chunk };
            idx[lo..hi].to_vec()
        })
        .collect()
}

/// Dirichlet non-IID partition: for each class, device shares ~ Dir(alpha).
/// Guarantees every device receives at least one sample (re-assigning from
/// the largest shard if needed).
pub fn partition_dirichlet(
    ds: &Dataset,
    m: usize,
    alpha: f64,
    nclasses: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    if !alpha.is_finite() {
        return partition_iid(ds, m, rng);
    }
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); nclasses];
    for (i, &y) in ds.y.iter().enumerate() {
        by_class[y as usize].push(i);
    }
    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); m];
    for class_idx in by_class.into_iter() {
        if class_idx.is_empty() {
            continue;
        }
        let props = rng.dirichlet(alpha, m);
        // Convert proportions to counts that sum to the class size.
        let n = class_idx.len();
        let mut counts: Vec<usize> = props.iter().map(|&p| (p * n as f64).floor() as usize).collect();
        let mut assigned: usize = counts.iter().sum();
        while assigned < n {
            let i = rng.choice_weighted(&props);
            counts[i] += 1;
            assigned += 1;
        }
        let mut cursor = 0;
        let mut order = class_idx;
        rng.shuffle(&mut order);
        for (dev, &c) in counts.iter().enumerate() {
            shards[dev].extend_from_slice(&order[cursor..cursor + c]);
            cursor += c;
        }
    }
    // No empty shards: steal from the largest.
    for dev in 0..m {
        if shards[dev].is_empty() {
            let largest = (0..m).max_by_key(|&i| shards[i].len()).unwrap();
            let take = shards[largest].pop().expect("dataset too small to partition");
            shards[dev].push(take);
        }
    }
    shards
}

/// Label-distribution skew diagnostic: mean total-variation distance between
/// per-device label histograms and the global histogram. 0 = IID.
pub fn label_skew(ds: &Dataset, shards: &[Vec<usize>], nclasses: usize) -> f64 {
    let hist = |idxs: &[usize]| -> Vec<f64> {
        let mut h = vec![0f64; nclasses];
        for &i in idxs {
            h[ds.y[i] as usize] += 1.0;
        }
        let s: f64 = h.iter().sum();
        if s > 0.0 {
            for x in &mut h {
                *x /= s;
            }
        }
        h
    };
    let all: Vec<usize> = (0..ds.len()).collect();
    let global = hist(&all);
    let mut tv = 0.0;
    for shard in shards {
        let h = hist(shard);
        tv += h.iter().zip(&global).map(|(a, b)| (a - b).abs()).sum::<f64>() / 2.0;
    }
    tv / shards.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::mnist::MnistGen;

    fn toy(n: usize) -> Dataset {
        MnistGen::new(1).dataset(0, n)
    }

    #[test]
    fn iid_covers_everything_disjointly() {
        let ds = toy(300);
        let mut rng = Rng::new(1);
        let shards = partition_iid(&ds, 3, &mut rng);
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            for &i in s {
                assert!(seen.insert(i));
            }
        }
        assert_eq!(seen.len(), 300);
    }

    #[test]
    fn dirichlet_covers_everything_disjointly() {
        let ds = toy(400);
        let mut rng = Rng::new(2);
        let shards = partition_dirichlet(&ds, 4, 0.3, 10, &mut rng);
        let total: usize = shards.iter().map(Vec::len).sum();
        assert_eq!(total, 400);
        let mut seen = std::collections::HashSet::new();
        for s in &shards {
            assert!(!s.is_empty());
            for &i in s {
                assert!(seen.insert(i));
            }
        }
    }

    #[test]
    fn smaller_alpha_is_more_skewed() {
        let ds = toy(3000);
        let mut rng = Rng::new(3);
        let skew_small = label_skew(&ds, &partition_dirichlet(&ds, 5, 0.1, 10, &mut rng), 10);
        let skew_large = label_skew(&ds, &partition_dirichlet(&ds, 5, 100.0, 10, &mut rng), 10);
        assert!(
            skew_small > skew_large + 0.05,
            "alpha=0.1 skew {skew_small} should exceed alpha=100 skew {skew_large}"
        );
    }

    #[test]
    fn infinite_alpha_is_iid() {
        let ds = toy(200);
        let mut rng = Rng::new(4);
        let shards = partition_dirichlet(&ds, 2, f64::INFINITY, 10, &mut rng);
        assert_eq!(shards.iter().map(Vec::len).sum::<usize>(), 200);
    }
}
