//! Synthetic MNIST-class generator (28x28, 10 classes).
//!
//! Each class has a deterministic stroke-based prototype glyph (digit-like
//! line/arc patterns on the 28x28 grid). A sample is its class prototype
//! after (1) a random sub-pixel translation, (2) per-stroke intensity
//! jitter, (3) a light box blur, and (4) additive pixel noise — so samples
//! within a class vary and the Bayes classifier is not a lookup table.
//! Deterministic given (seed, sample index).

use super::Dataset;
use crate::util::Rng;

pub const SIDE: usize = 28;
pub const FEATURES: usize = SIDE * SIDE;
pub const CLASSES: usize = 10;

/// One generated sample.
#[derive(Clone, Debug)]
pub struct Sample {
    pub x: Vec<f32>,
    pub y: i32,
}

/// Stroke primitive in glyph space: line segment with thickness.
#[derive(Clone, Copy)]
struct Stroke {
    x0: f32,
    y0: f32,
    x1: f32,
    y1: f32,
    w: f32,
}

fn seg(x0: f32, y0: f32, x1: f32, y1: f32, w: f32) -> Stroke {
    Stroke { x0, y0, x1, y1, w }
}

/// Digit-like prototypes: rough stroke skeletons of 0..9 on a [4,24]^2 box.
fn prototype(class: usize) -> Vec<Stroke> {
    match class {
        0 => vec![
            seg(9.0, 6.0, 19.0, 6.0, 1.6),
            seg(19.0, 6.0, 19.0, 22.0, 1.6),
            seg(19.0, 22.0, 9.0, 22.0, 1.6),
            seg(9.0, 22.0, 9.0, 6.0, 1.6),
        ],
        1 => vec![seg(14.0, 5.0, 14.0, 23.0, 1.8), seg(11.0, 8.0, 14.0, 5.0, 1.4)],
        2 => vec![
            seg(9.0, 7.0, 18.0, 6.0, 1.6),
            seg(18.0, 6.0, 18.0, 13.0, 1.6),
            seg(18.0, 13.0, 9.0, 22.0, 1.6),
            seg(9.0, 22.0, 19.0, 22.0, 1.6),
        ],
        3 => vec![
            seg(9.0, 6.0, 18.0, 6.0, 1.5),
            seg(18.0, 6.0, 13.0, 13.0, 1.5),
            seg(13.0, 13.0, 18.0, 14.0, 1.5),
            seg(18.0, 14.0, 18.0, 21.0, 1.5),
            seg(18.0, 21.0, 9.0, 22.0, 1.5),
        ],
        4 => vec![
            seg(16.0, 5.0, 8.0, 16.0, 1.6),
            seg(8.0, 16.0, 20.0, 16.0, 1.6),
            seg(16.0, 5.0, 16.0, 23.0, 1.6),
        ],
        5 => vec![
            seg(19.0, 6.0, 9.0, 6.0, 1.6),
            seg(9.0, 6.0, 9.0, 13.0, 1.6),
            seg(9.0, 13.0, 18.0, 14.0, 1.6),
            seg(18.0, 14.0, 18.0, 21.0, 1.6),
            seg(18.0, 21.0, 9.0, 22.0, 1.6),
        ],
        6 => vec![
            seg(17.0, 5.0, 10.0, 12.0, 1.6),
            seg(10.0, 12.0, 9.0, 20.0, 1.6),
            seg(9.0, 20.0, 14.0, 23.0, 1.6),
            seg(14.0, 23.0, 18.0, 20.0, 1.6),
            seg(18.0, 20.0, 17.0, 15.0, 1.6),
            seg(17.0, 15.0, 10.0, 15.0, 1.6),
        ],
        7 => vec![seg(8.0, 6.0, 20.0, 6.0, 1.7), seg(20.0, 6.0, 12.0, 23.0, 1.7)],
        8 => vec![
            seg(13.5, 6.0, 9.5, 10.0, 1.5),
            seg(9.5, 10.0, 13.5, 14.0, 1.5),
            seg(13.5, 6.0, 17.5, 10.0, 1.5),
            seg(17.5, 10.0, 13.5, 14.0, 1.5),
            seg(13.5, 14.0, 9.0, 18.5, 1.5),
            seg(9.0, 18.5, 13.5, 23.0, 1.5),
            seg(13.5, 14.0, 18.0, 18.5, 1.5),
            seg(18.0, 18.5, 13.5, 23.0, 1.5),
        ],
        9 => vec![
            seg(17.0, 11.0, 13.0, 6.0, 1.6),
            seg(13.0, 6.0, 9.5, 10.0, 1.6),
            seg(9.5, 10.0, 13.0, 14.0, 1.6),
            seg(13.0, 14.0, 17.0, 11.0, 1.6),
            seg(17.0, 11.0, 17.0, 19.0, 1.6),
            seg(17.0, 19.0, 12.0, 23.0, 1.6),
        ],
        _ => panic!("class out of range"),
    }
}

/// Distance from point to segment.
fn seg_dist(px: f32, py: f32, s: &Stroke) -> f32 {
    let (dx, dy) = (s.x1 - s.x0, s.y1 - s.y0);
    let len2 = dx * dx + dy * dy;
    let t = if len2 == 0.0 {
        0.0
    } else {
        (((px - s.x0) * dx + (py - s.y0) * dy) / len2).clamp(0.0, 1.0)
    };
    let (cx, cy) = (s.x0 + t * dx, s.y0 + t * dy);
    ((px - cx).powi(2) + (py - cy).powi(2)).sqrt()
}

/// Deterministic generator.
#[derive(Clone, Debug)]
pub struct MnistGen {
    seed: u64,
}

impl MnistGen {
    pub fn new(seed: u64) -> Self {
        MnistGen { seed }
    }

    /// Render sample `index` (label chosen uniformly from the index stream).
    pub fn sample(&self, index: u64) -> Sample {
        let mut rng = Rng::new(self.seed ^ 0x5EED_BA5E).fork(index);
        let y = rng.index(CLASSES);
        let strokes = prototype(y);
        // Per-sample distortions.
        let tx = rng.range(-1.8, 1.8) as f32;
        let ty = rng.range(-1.8, 1.8) as f32;
        let rot = rng.range(-0.12, 0.12) as f32; // radians, about center
        let gain: Vec<f32> = strokes.iter().map(|_| rng.range(0.75, 1.0) as f32).collect();
        let (sin, cos) = (rot.sin(), rot.cos());
        let c = 14.0f32;

        let mut img = vec![0f32; FEATURES];
        for py in 0..SIDE {
            for px in 0..SIDE {
                // Inverse-transform the pixel into glyph space.
                let fx = px as f32 - tx - c;
                let fy = py as f32 - ty - c;
                let gx = cos * fx + sin * fy + c;
                let gy = -sin * fx + cos * fy + c;
                let mut v = 0f32;
                for (s, &g) in strokes.iter().zip(&gain) {
                    let d = seg_dist(gx, gy, s);
                    if d < s.w + 1.0 {
                        // Soft pen profile.
                        let a = (1.0 - (d / (s.w + 1.0)).powi(2)).max(0.0);
                        v = v.max(g * a);
                    }
                }
                img[py * SIDE + px] = v;
            }
        }
        // Light 3x3 box blur.
        let mut blurred = vec![0f32; FEATURES];
        for py in 0..SIDE {
            for px in 0..SIDE {
                let mut acc = 0f32;
                let mut n = 0f32;
                for dy in -1i32..=1 {
                    for dx in -1i32..=1 {
                        let (qx, qy) = (px as i32 + dx, py as i32 + dy);
                        if (0..SIDE as i32).contains(&qx) && (0..SIDE as i32).contains(&qy) {
                            acc += img[qy as usize * SIDE + qx as usize];
                            n += 1.0;
                        }
                    }
                }
                blurred[py * SIDE + px] = acc / n;
            }
        }
        // Pixel noise, clamp to [0,1].
        for v in &mut blurred {
            *v = (*v + rng.gaussian(0.0, 0.05) as f32).clamp(0.0, 1.0);
        }
        Sample { x: blurred, y: y as i32 }
    }

    /// Materialize `n` samples starting at `start` into a Dataset.
    pub fn dataset(&self, start: u64, n: usize) -> Dataset {
        let mut x = Vec::with_capacity(n * FEATURES);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let s = self.sample(start + i as u64);
            x.extend_from_slice(&s.x);
            y.push(s.y);
        }
        Dataset { x, y, features: FEATURES }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g = MnistGen::new(7);
        let a = g.sample(123);
        let b = g.sample(123);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_ne!(g.sample(124).x, a.x);
    }

    #[test]
    fn pixels_in_unit_range_and_nontrivial() {
        let g = MnistGen::new(1);
        for i in 0..20 {
            let s = g.sample(i);
            assert!(s.x.iter().all(|&v| (0.0..=1.0).contains(&v)));
            let ink: f32 = s.x.iter().sum();
            assert!(ink > 10.0, "sample {i} almost blank: ink={ink}");
            assert!(ink < 500.0, "sample {i} almost full: ink={ink}");
        }
    }

    #[test]
    fn labels_roughly_uniform() {
        let g = MnistGen::new(2);
        let ds = g.dataset(0, 2000);
        let mut counts = [0usize; CLASSES];
        for &y in &ds.y {
            counts[y as usize] += 1;
        }
        for (c, &n) in counts.iter().enumerate() {
            assert!(n > 120 && n < 280, "class {c}: {n}");
        }
    }

    #[test]
    fn within_class_variation_and_between_class_separation() {
        let g = MnistGen::new(3);
        // Collect a few samples of two classes.
        let mut by_class: std::collections::HashMap<i32, Vec<Vec<f32>>> = Default::default();
        let mut i = 0u64;
        while by_class.get(&0).map_or(0, |v| v.len()) < 5
            || by_class.get(&1).map_or(0, |v| v.len()) < 5
        {
            let s = g.sample(i);
            by_class.entry(s.y).or_default().push(s.x);
            i += 1;
        }
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        let c0 = &by_class[&0];
        let c1 = &by_class[&1];
        let within = d(&c0[0], &c0[1]);
        let between = d(&c0[0], &c1[0]);
        assert!(within > 0.1, "no within-class variation");
        assert!(between > within, "classes not separated: within={within} between={between}");
    }

    #[test]
    fn dataset_shapes() {
        let g = MnistGen::new(4);
        let ds = g.dataset(100, 32);
        assert_eq!(ds.len(), 32);
        assert_eq!(ds.x.len(), 32 * FEATURES);
        assert_eq!(ds.row(5).len(), FEATURES);
    }
}
