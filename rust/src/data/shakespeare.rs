//! Shakespeare character corpus for the RNN workload (paper Sec. 4.1).
//!
//! The paper uses the LEAF Shakespeare split (40k lines). With no network
//! access we embed a public-domain excerpt (several famous passages) and
//! tile batching over it; same task (next-char prediction), same vocabulary
//! pipeline. Characters are mapped into a fixed 64-symbol vocabulary
//! matching the AOT RNN artifact (`VOCAB` in python/compile/model.py).

use crate::util::Rng;

/// Vocabulary size — must equal `model.VOCAB` on the python side.
pub const VOCAB: usize = 64;

/// Embedded public-domain excerpt (~6 KB).
pub const CORPUS: &str = r#"to be, or not to be, that is the question:
whether 'tis nobler in the mind to suffer
the slings and arrows of outrageous fortune,
or to take arms against a sea of troubles
and by opposing end them. to die, to sleep;
no more; and by a sleep to say we end
the heart-ache and the thousand natural shocks
that flesh is heir to: 'tis a consummation
devoutly to be wish'd. to die, to sleep;
to sleep, perchance to dream. ay, there's the rub,
for in that sleep of death what dreams may come,
when we have shuffled off this mortal coil,
must give us pause. there's the respect
that makes calamity of so long life.

tomorrow, and tomorrow, and tomorrow,
creeps in this petty pace from day to day,
to the last syllable of recorded time;
and all our yesterdays have lighted fools
the way to dusty death. out, out, brief candle!
life's but a walking shadow, a poor player,
that struts and frets his hour upon the stage,
and then is heard no more. it is a tale
told by an idiot, full of sound and fury,
signifying nothing.

now is the winter of our discontent
made glorious summer by this sun of york;
and all the clouds that lour'd upon our house
in the deep bosom of the ocean buried.
now are our brows bound with victorious wreaths;
our bruised arms hung up for monuments;
our stern alarums changed to merry meetings,
our dreadful marches to delightful measures.

friends, romans, countrymen, lend me your ears;
i come to bury caesar, not to praise him.
the evil that men do lives after them;
the good is oft interred with their bones;
so let it be with caesar. the noble brutus
hath told you caesar was ambitious:
if it were so, it was a grievous fault,
and grievously hath caesar answer'd it.

two households, both alike in dignity,
in fair verona, where we lay our scene,
from ancient grudge break to new mutiny,
where civil blood makes civil hands unclean.
from forth the fatal loins of these two foes
a pair of star-cross'd lovers take their life;
whose misadventured piteous overthrows
do with their death bury their parents' strife.

shall i compare thee to a summer's day?
thou art more lovely and more temperate:
rough winds do shake the darling buds of may,
and summer's lease hath all too short a date;
sometime too hot the eye of heaven shines,
and often is his gold complexion dimm'd;
and every fair from fair sometime declines,
by chance or nature's changing course untrimm'd;
but thy eternal summer shall not fade,
nor lose possession of that fair thou ow'st;
nor shall death brag thou wander'st in his shade,
when in eternal lines to time thou grow'st:
so long as men can breathe or eyes can see,
so long lives this, and this gives life to thee.

once more unto the breach, dear friends, once more;
or close the wall up with our english dead.
in peace there's nothing so becomes a man
as modest stillness and humility:
but when the blast of war blows in our ears,
then imitate the action of the tiger;
stiffen the sinews, summon up the blood,
disguise fair nature with hard-favour'd rage.

all the world's a stage,
and all the men and women merely players:
they have their exits and their entrances;
and one man in his time plays many parts,
his acts being seven ages. at first the infant,
mewling and puking in the nurse's arms.
and then the whining school-boy, with his satchel
and shining morning face, creeping like snail
unwillingly to school.

the quality of mercy is not strain'd,
it droppeth as the gentle rain from heaven
upon the place beneath: it is twice blest;
it blesseth him that gives and him that takes:
'tis mightiest in the mightiest: it becomes
the throned monarch better than his crown;
his sceptre shows the force of temporal power,
the attribute to awe and majesty,
wherein doth sit the dread and fear of kings;
but mercy is above this sceptred sway;
it is enthroned in the hearts of kings,
it is an attribute to god himself.

if music be the food of love, play on;
give me excess of it, that, surfeiting,
the appetite may sicken, and so die.
that strain again! it had a dying fall:
o, it came o'er my ear like the sweet sound,
that breathes upon a bank of violets,
stealing and giving odour!

is this a dagger which i see before me,
the handle toward my hand? come, let me clutch thee.
i have thee not, and yet i see thee still.
art thou not, fatal vision, sensible
to feeling as to sight? or art thou but
a dagger of the mind, a false creation,
proceeding from the heat-oppressed brain?

our revels now are ended. these our actors,
as i foretold you, were all spirits and
are melted into air, into thin air:
and, like the baseless fabric of this vision,
the cloud-capp'd towers, the gorgeous palaces,
the solemn temples, the great globe itself,
yea, all which it inherit, shall dissolve
and, like this insubstantial pageant faded,
leave not a rack behind. we are such stuff
as dreams are made on, and our little life
is rounded with a sleep.
"#;

/// Char -> vocab id. Lowercase letters, digits, common punctuation; id 0 is
/// the catch-all/unknown symbol (also space's neighbor class).
pub fn char_to_id(c: char) -> i32 {
    let c = c.to_ascii_lowercase();
    match c {
        'a'..='z' => 1 + (c as u8 - b'a') as i32, // 1..=26
        '0'..='9' => 27 + (c as u8 - b'0') as i32, // 27..=36
        ' ' => 37,
        '\n' => 38,
        '.' => 39,
        ',' => 40,
        ';' => 41,
        ':' => 42,
        '\'' => 43,
        '!' => 44,
        '?' => 45,
        '-' => 46,
        '"' => 47,
        '(' => 48,
        ')' => 49,
        _ => 0,
    }
}

/// Tokenized corpus with sequence batching for the RNN artifact.
#[derive(Clone, Debug)]
pub struct CharCorpus {
    pub ids: Vec<i32>,
    pub seq: usize,
}

impl CharCorpus {
    /// Tokenize the embedded corpus (or any text) for sequences of length
    /// `seq + 1` (inputs + next-char targets).
    pub fn new(text: &str, seq: usize) -> Self {
        let ids: Vec<i32> = text.chars().map(char_to_id).collect();
        assert!(ids.len() > seq + 1, "corpus shorter than one sequence");
        CharCorpus { ids, seq }
    }

    pub fn embedded(seq: usize) -> Self {
        Self::new(CORPUS, seq)
    }

    /// Number of distinct sequence start positions.
    pub fn num_positions(&self) -> usize {
        self.ids.len() - (self.seq + 1)
    }

    /// Fill a batch of `b` sequences (each `seq + 1` ids) chosen from the
    /// device's assigned span, deterministic in `rng`.
    pub fn fill_batch(
        &self,
        rng: &mut Rng,
        span: (usize, usize),
        b: usize,
        out: &mut Vec<i32>,
    ) {
        let (lo, hi) = span;
        let hi = hi.min(self.num_positions());
        assert!(lo < hi, "empty span {span:?}");
        out.clear();
        for _ in 0..b {
            let start = lo + rng.index(hi - lo);
            out.extend_from_slice(&self.ids[start..start + self.seq + 1]);
        }
    }

    /// Split positions into `m` contiguous device spans (non-IID by locality:
    /// different devices hold different plays/passages).
    pub fn device_spans(&self, m: usize) -> Vec<(usize, usize)> {
        let n = self.num_positions();
        let chunk = n / m;
        (0..m)
            .map(|i| (i * chunk, if i + 1 == m { n } else { (i + 1) * chunk }))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_ids_in_range() {
        for c in CORPUS.chars() {
            let id = char_to_id(c);
            assert!((0..VOCAB as i32).contains(&id), "{c:?} -> {id}");
        }
    }

    #[test]
    fn corpus_is_substantial() {
        assert!(CORPUS.len() > 4000, "corpus too small: {}", CORPUS.len());
        let distinct: std::collections::HashSet<i32> =
            CORPUS.chars().map(char_to_id).collect();
        assert!(distinct.len() > 25, "vocab coverage too small: {}", distinct.len());
    }

    #[test]
    fn batch_shapes() {
        let corpus = CharCorpus::embedded(24);
        let mut rng = Rng::new(1);
        let mut batch = Vec::new();
        let spans = corpus.device_spans(3);
        corpus.fill_batch(&mut rng, spans[1], 64, &mut batch);
        assert_eq!(batch.len(), 64 * 25);
        assert!(batch.iter().all(|&i| (0..VOCAB as i32).contains(&i)));
    }

    #[test]
    fn device_spans_cover_disjointly() {
        let corpus = CharCorpus::embedded(24);
        let spans = corpus.device_spans(3);
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].0, 0);
        assert_eq!(spans[2].1, corpus.num_positions());
        for w in spans.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    #[test]
    fn batches_from_span_stay_in_span() {
        let corpus = CharCorpus::embedded(8);
        let mut rng = Rng::new(2);
        let mut batch = Vec::new();
        // Span over a known region; check sequences match corpus content.
        corpus.fill_batch(&mut rng, (0, 10), 4, &mut batch);
        for s in batch.chunks(9) {
            // each sequence must appear verbatim in the first 19 ids
            let found = (0..10).any(|st| &corpus.ids[st..st + 9] == s);
            assert!(found);
        }
    }
}
