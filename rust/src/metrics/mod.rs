//! Metrics & telemetry: per-round training records, curve assembly, and CSV
//! output — the plumbing every figure-bench prints its series through.

use std::fmt::Write as _;
use std::path::Path;

/// One round's record for a whole experiment (server view).
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Mean training loss across devices (as reported by local steps).
    pub train_loss: f64,
    /// Held-out eval loss (NaN when not evaluated this round).
    pub eval_loss: f64,
    /// Held-out accuracy in [0,1] (NaN when not evaluated).
    pub eval_acc: f64,
    /// Cumulative totals across devices.
    pub energy_j: f64,
    pub money: f64,
    /// Simulated wall-clock of the round (slowest device) and cumulative.
    pub round_time_s: f64,
    pub total_time_s: f64,
    /// Bytes uploaded this round (all devices, all channels).
    pub bytes_up: u64,
    /// Mean DRL reward across devices (NaN for non-DRL mechanisms).
    pub drl_reward: f64,
    /// Median per-device finish time of the round's contributions (barrier:
    /// compute+upload wall per active device; async: upload durations of
    /// the aggregated updates). NaN when nothing finished.
    pub finish_p50_s: f64,
    /// 95th-percentile finish time — the straggler profile the async sync
    /// modes exist to beat.
    pub finish_p95_s: f64,
    /// Updates applied with staleness > 0 this round (async modes; always 0
    /// under barrier sync).
    pub stale_updates: u64,
    /// Clients that actually ran the round (barrier: the active devices;
    /// population mode: the materialized cohort members that trained; async
    /// modes: the uploads contributing to this aggregation).
    pub sampled: u64,
    /// Uploads that reached the server and entered aggregation.
    pub completed: u64,
    /// Uploads lost because the client churned offline mid-upload
    /// (population mode with availability churn; 0 elsewhere).
    pub dropped_offline: u64,
    /// Median staleness (server-version gap) of the updates aggregated
    /// this round. Always 0 under barrier sync; NaN when nothing
    /// contributed.
    pub staleness_p50: f64,
    /// 95th-percentile staleness — the stale-client profile the downlink
    /// and async modes surface.
    pub staleness_p95: f64,
    /// Downlink (model broadcast) bytes this round/window. 0 when the
    /// downlink is disabled (the default: broadcast is free and instant).
    pub down_bytes: u64,
    /// Downlink energy charged to device meters this round/window (J).
    pub down_energy_j: f64,
    /// Downlink money charged this round/window.
    pub down_money: f64,
    /// Zone changes (mobility + phase-forced relocations) this
    /// round/window. 0 when no scenario is configured.
    pub handoffs: u64,
    /// In-flight uplink layers dropped because a handoff removed their
    /// channel (restituted into error-feedback memory, never destroyed).
    pub dropped_handoff: u64,
    /// Median zone id across the population at record time (scenario
    /// mobility telemetry; 0 when no scenario is configured).
    pub zone_p50: f64,
    /// Edge-tier backhaul bytes this round/window (partial-aggregate
    /// frames plus edge-cached downlink fetches; 0 when the edge tier is
    /// disabled).
    pub backhaul_bytes: u64,
    /// 95th-percentile backhaul transfer wall this round/window (0 when
    /// nothing crossed the backhaul).
    pub backhaul_p95_s: f64,
    /// Held edge contributions migrated edge-to-edge on handoff this
    /// round/window (the migration upgrade over drop-to-restitution).
    pub migrated_handoff: u64,
    /// 1 when this record was backhaul-bound: `backhaul_p95_s` exceeded
    /// the access-link `finish_p95_s`.
    pub edge_rounds_bound: u64,
    /// The dominant round-time component per the attribution pass
    /// (`compute` / `uplink` / `backhaul` / `downlink` / `wait`; empty for
    /// the frozen reference loop, which predates attribution).
    pub bound_by: &'static str,
    /// The critical-path client of this round/window (-1 when none — no
    /// participants, or the frozen reference loop).
    pub crit_client: i64,
    /// The slowest uplink channel of the critical-path client (-1 none).
    pub crit_channel: i64,
}

/// The single source of truth for per-round CSV column names, shared by
/// the writer ([`RunLog::to_csv`]), the tests, and every bench that prints
/// record series — so headers cannot drift between producers.
pub mod columns {
    /// Column names of one [`super::RoundRecord`] row, in write order.
    pub const ROUND: &[&str] = &[
        "round",
        "train_loss",
        "eval_loss",
        "eval_acc",
        "energy_j",
        "money",
        "round_time_s",
        "total_time_s",
        "bytes_up",
        "drl_reward",
        "finish_p50_s",
        "finish_p95_s",
        "stale_updates",
        "sampled",
        "completed",
        "dropped_offline",
        "staleness_p50",
        "staleness_p95",
        "down_bytes",
        "down_energy_j",
        "down_money",
        "handoffs",
        "dropped_handoff",
        "zone_p50",
        "backhaul_bytes",
        "backhaul_p95_s",
        "migrated_handoff",
        "edge_rounds_bound",
        "bound_by",
        "crit_client",
        "crit_channel",
    ];

    /// The CSV header line (no trailing newline).
    pub fn header() -> String {
        ROUND.join(",")
    }
}

/// Nearest-rank percentile (`p` in [0, 100]); sorts `xs` in place. NaN
/// inputs are ignored (they sort to the end under `total_cmp` and are
/// excluded from the rank, so a single NaN sample no longer poisons the
/// high percentiles); NaN is returned only when no finite sample exists.
/// Shared by the engine, the synchronous reference loop, and `lgc report`
/// so straggler stats agree bit-for-bit.
pub fn percentile(xs: &mut [f64], p: f64) -> f64 {
    xs.sort_by(f64::total_cmp);
    let valid = xs.len() - xs.iter().rev().take_while(|x| x.is_nan()).count();
    if valid == 0 {
        return f64::NAN;
    }
    let rank = ((p.clamp(0.0, 100.0) / 100.0) * valid as f64).ceil() as usize;
    xs[rank.clamp(1, valid) - 1]
}

/// Fixed-width histogram over the finite entries of `xs`: returns
/// (per-bin counts, lo, hi) with `bins` equal-width buckets spanning
/// `[lo, hi]` = the finite min/max. Degenerate inputs are well-defined:
/// no finite samples → all-zero counts with `lo = hi = 0`; a single
/// distinct value → everything in bin 0 with `lo = hi`. Shared by
/// `lgc report`'s utilization sections.
pub fn histogram(xs: &[f64], bins: usize) -> (Vec<u64>, f64, f64) {
    let bins = bins.max(1);
    let mut counts = vec![0u64; bins];
    let finite = xs.iter().copied().filter(|x| x.is_finite());
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for x in finite {
        lo = lo.min(x);
        hi = hi.max(x);
    }
    if lo > hi {
        return (counts, 0.0, 0.0);
    }
    for &x in xs.iter().filter(|x| x.is_finite()) {
        let idx = if hi > lo {
            (((x - lo) / (hi - lo)) * bins as f64) as usize
        } else {
            0
        };
        counts[idx.min(bins - 1)] += 1;
    }
    (counts, lo, hi)
}

/// A whole training run's log.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub name: String,
    pub records: Vec<RoundRecord>,
}

impl RunLog {
    pub fn new(name: &str) -> Self {
        RunLog { name: name.to_string(), records: Vec::new() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.records.push(r);
    }

    pub fn last(&self) -> Option<&RoundRecord> {
        self.records.last()
    }

    /// Final evaluated accuracy (last non-NaN).
    pub fn final_acc(&self) -> f64 {
        self.records
            .iter()
            .rev()
            .find(|r| !r.eval_acc.is_nan())
            .map_or(f64::NAN, |r| r.eval_acc)
    }

    /// Best evaluated accuracy.
    pub fn best_acc(&self) -> f64 {
        self.records
            .iter()
            .filter(|r| !r.eval_acc.is_nan())
            .map(|r| r.eval_acc)
            .fold(f64::NAN, f64::max)
    }

    /// Cumulative resource use at the first round reaching `target_acc`.
    /// Returns (round, energy, money, time) or None if never reached.
    pub fn cost_to_accuracy(&self, target_acc: f64) -> Option<(usize, f64, f64, f64)> {
        self.records
            .iter()
            .find(|r| !r.eval_acc.is_nan() && r.eval_acc >= target_acc)
            .map(|r| (r.round, r.energy_j, r.money, r.total_time_s))
    }

    /// Best accuracy achieved while cumulative `resource <= budget`.
    /// `resource`: 0 = energy, 1 = money, 2 = time.
    pub fn acc_under_budget(&self, resource: usize, budget: f64) -> f64 {
        self.records
            .iter()
            .filter(|r| {
                let used = match resource {
                    0 => r.energy_j,
                    1 => r.money,
                    _ => r.total_time_s,
                };
                used <= budget && !r.eval_acc.is_nan()
            })
            .map(|r| r.eval_acc)
            .fold(f64::NAN, f64::max)
    }

    /// Render as CSV (header from [`columns::ROUND`]).
    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&columns::header());
        s.push('\n');
        for r in &self.records {
            let _ = writeln!(
                s,
                "{},{:.6},{:.6},{:.6},{:.3},{:.6},{:.3},{:.3},{},{:.4},{:.4},{:.4},{},{},{},{},{:.4},{:.4},{},{:.3},{:.6},{},{},{:.2},{},{:.4},{},{},{},{},{}",
                r.round,
                r.train_loss,
                r.eval_loss,
                r.eval_acc,
                r.energy_j,
                r.money,
                r.round_time_s,
                r.total_time_s,
                r.bytes_up,
                r.drl_reward,
                r.finish_p50_s,
                r.finish_p95_s,
                r.stale_updates,
                r.sampled,
                r.completed,
                r.dropped_offline,
                r.staleness_p50,
                r.staleness_p95,
                r.down_bytes,
                r.down_energy_j,
                r.down_money,
                r.handoffs,
                r.dropped_handoff,
                r.zone_p50,
                r.backhaul_bytes,
                r.backhaul_p95_s,
                r.migrated_handoff,
                r.edge_rounds_bound,
                r.bound_by,
                r.crit_client,
                r.crit_channel
            );
        }
        s
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, acc: f64, energy: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            eval_loss: 1.0,
            eval_acc: acc,
            energy_j: energy,
            money: energy / 100.0,
            round_time_s: 1.0,
            total_time_s: round as f64,
            bytes_up: 100,
            drl_reward: 0.0,
            ..RoundRecord::default()
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let mut xs = vec![3.0, 1.0, 2.0, 5.0, 4.0];
        assert_eq!(percentile(&mut xs, 50.0), 3.0);
        assert_eq!(percentile(&mut xs, 95.0), 5.0);
        assert_eq!(percentile(&mut xs, 100.0), 5.0);
        assert_eq!(percentile(&mut xs, 0.0), 1.0);
        let mut one = vec![7.5];
        assert_eq!(percentile(&mut one, 50.0), 7.5);
        assert!(percentile(&mut [], 50.0).is_nan());
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // A NaN straggler (e.g. a client that never finished) must not
        // poison the high percentiles: NaNs sort last and are excluded.
        let mut xs = vec![3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&mut xs, 95.0), 3.0);
        assert_eq!(percentile(&mut xs, 50.0), 2.0);
        let mut all_nan = vec![f64::NAN, f64::NAN];
        assert!(percentile(&mut all_nan, 50.0).is_nan());
        // Out-of-range p clamps instead of indexing out of bounds.
        let mut xs = vec![1.0, 2.0];
        assert_eq!(percentile(&mut xs, 150.0), 2.0);
        assert_eq!(percentile(&mut xs, -5.0), 1.0);
    }

    #[test]
    fn histogram_edge_cases() {
        // Empty / all-NaN input: zero counts, zero range.
        assert_eq!(histogram(&[], 4), (vec![0, 0, 0, 0], 0.0, 0.0));
        assert_eq!(histogram(&[f64::NAN], 4), (vec![0, 0, 0, 0], 0.0, 0.0));
        // Single sample: one bucket, degenerate range.
        assert_eq!(histogram(&[2.5], 4), (vec![1, 0, 0, 0], 2.5, 2.5));
        // NaN entries are skipped, max lands in the last bin.
        let (counts, lo, hi) = histogram(&[0.0, f64::NAN, 1.0, 1.0, 0.49], 2);
        assert_eq!((lo, hi), (0.0, 1.0));
        assert_eq!(counts, vec![2, 2]);
    }

    #[test]
    fn final_and_best_acc() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 0.2, 10.0));
        log.push(rec(1, 0.9, 20.0));
        log.push(rec(2, 0.6, 30.0));
        assert_eq!(log.final_acc(), 0.6);
        assert_eq!(log.best_acc(), 0.9);
    }

    #[test]
    fn cost_to_accuracy_finds_first_crossing() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 0.2, 10.0));
        log.push(rec(5, 0.75, 50.0));
        log.push(rec(9, 0.8, 90.0));
        let (round, energy, _, _) = log.cost_to_accuracy(0.7).unwrap();
        assert_eq!(round, 5);
        assert_eq!(energy, 50.0);
        assert!(log.cost_to_accuracy(0.95).is_none());
    }

    #[test]
    fn acc_under_budget() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 0.3, 10.0));
        log.push(rec(1, 0.7, 40.0));
        log.push(rec(2, 0.9, 200.0));
        assert_eq!(log.acc_under_budget(0, 50.0), 0.7);
        assert_eq!(log.acc_under_budget(0, 1000.0), 0.9);
        assert!(log.acc_under_budget(0, 1.0).is_nan());
    }

    #[test]
    fn csv_shape() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 0.5, 1.0));
        let csv = log.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("round,"));
    }

    #[test]
    fn csv_header_is_the_columns_constant() {
        let mut log = RunLog::new("t");
        log.push(rec(0, 0.5, 1.0));
        let csv = log.to_csv();
        assert_eq!(csv.lines().next().unwrap(), columns::header());
        // Every data row has exactly one field per declared column — the
        // writer and the columns list cannot drift apart.
        let row = csv.lines().nth(1).unwrap();
        assert_eq!(row.split(',').count(), columns::ROUND.len(), "{row}");
    }

    #[test]
    fn csv_has_participation_and_downlink_columns() {
        let mut log = RunLog::new("t");
        let mut r = rec(0, 0.5, 1.0);
        r.sampled = 5;
        r.completed = 4;
        r.dropped_offline = 1;
        r.staleness_p50 = 1.0;
        r.staleness_p95 = 3.0;
        r.down_bytes = 4096;
        r.down_energy_j = 12.5;
        r.down_money = 0.125;
        r.handoffs = 7;
        r.dropped_handoff = 2;
        r.zone_p50 = 1.0;
        r.backhaul_bytes = 2080;
        r.backhaul_p95_s = 0.75;
        r.migrated_handoff = 3;
        r.edge_rounds_bound = 1;
        r.bound_by = "uplink";
        r.crit_client = 2;
        r.crit_channel = 1;
        log.push(r);
        let csv = log.to_csv();
        let header = csv.lines().next().unwrap();
        for col in ["sampled", "completed", "dropped_offline", "staleness_p50",
                    "staleness_p95", "down_bytes", "down_energy_j", "down_money",
                    "handoffs", "dropped_handoff", "zone_p50", "backhaul_bytes",
                    "backhaul_p95_s", "migrated_handoff", "edge_rounds_bound",
                    "bound_by", "crit_client", "crit_channel"] {
            assert!(header.split(',').any(|c| c == col), "missing {col}: {header}");
        }
        assert!(
            csv.lines().nth(1).unwrap().ends_with(
                ",5,4,1,1.0000,3.0000,4096,12.500,0.125000,7,2,1.00,2080,0.7500,3,1,uplink,2,1"
            ),
            "{csv}"
        );
    }

    #[test]
    fn nan_acc_skipped() {
        let mut log = RunLog::new("t");
        let mut r = rec(0, f64::NAN, 1.0);
        log.push(r.clone());
        assert!(log.final_acc().is_nan());
        r.eval_acc = 0.4;
        r.round = 1;
        log.push(r);
        assert_eq!(log.final_acc(), 0.4);
    }
}
