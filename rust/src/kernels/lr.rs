//! The fused logistic-regression micro-kernels: the `[b, 784] × [784, 10]`
//! GEMM forward and its rank-1 backward.
//!
//! The seed's loops guarded every input coordinate with `if xi == 0.0 {
//! continue; }` — a data-dependent branch that defeats auto-vectorization
//! and mispredicts badly on ~50%-dense synthetic MNIST. The kernels here
//! are dense and branch-free:
//!
//! * [`gemv_wide`] replaces the forward skip loop with a 4-bank
//!   accumulator grid (4 × C partial sums, combined by a fixed tree).
//!   Banking breaks the serial add dependency chain so four independent
//!   C-wide vector FMAs are in flight per cycle, but it **reassociates**
//!   the sum vs. the sequential scalar loop — this is the GEMM analogue of
//!   the 8-lane [`super::dot`].
//! * [`rank1_acc`] replaces the backward skip loop densely. Unlike the
//!   forward, it is **bitwise-identical** to the skip version for finite
//!   inputs: the elided iterations only ever added `±0.0 · d[c]`, and a
//!   `+0.0` accumulator never leaves `+0.0` under such adds (IEEE-754
//!   round-to-nearest returns `+0.0` for exact cancellation), so skipping
//!   them was already a no-op.
//!
//! A CSR batch form (precompute nonzero indices once per dataset) was
//! considered and rejected: at the ~50% density of the synthetic MNIST
//! generator the index indirection costs more than the multiplies it
//! saves, and the dense path needs no per-dataset preprocessing.

/// Number of independent accumulator banks in [`gemv_wide`].
pub const GEMM_BANKS: usize = 4;

/// `out[c] = bias[c] + Σ_i x[i] · w[i*C + c]` — one sample's logits.
///
/// `w` is `[n, C]` row-major (the JAX layout), `x` is the dense input row.
/// Inputs `i` are processed in banks of [`GEMM_BANKS`]; the remainder
/// (`n % 4` rows) folds into banks `0..rem`; banks combine as
/// `(b0 + b1) + (b2 + b3)`. Deterministic, reassociated.
pub fn gemv_wide<const C: usize>(w: &[f32], bias: &[f32], x: &[f32], out: &mut [f32; C]) {
    assert_eq!(w.len(), x.len() * C);
    assert_eq!(bias.len(), C);
    let mut acc = [[0.0f32; C]; GEMM_BANKS];
    let n = x.len() - x.len() % GEMM_BANKS;
    for (xc, wc) in x[..n]
        .chunks_exact(GEMM_BANKS)
        .zip(w[..n * C].chunks_exact(GEMM_BANKS * C))
    {
        for bk in 0..GEMM_BANKS {
            let xi = xc[bk];
            let wrow = &wc[bk * C..(bk + 1) * C];
            let a = &mut acc[bk];
            for c in 0..C {
                a[c] += xi * wrow[c];
            }
        }
    }
    for (r, &xi) in x[n..].iter().enumerate() {
        let wrow = &w[(n + r) * C..(n + r + 1) * C];
        let a = &mut acc[r];
        for c in 0..C {
            a[c] += xi * wrow[c];
        }
    }
    for c in 0..C {
        out[c] = bias[c] + ((acc[0][c] + acc[1][c]) + (acc[2][c] + acc[3][c]));
    }
}

/// `gw[i*C + c] += x[i] · d[c]` for every `i` — the dense rank-1 backward
/// of [`gemv_wide`]. Bitwise-identical to the `xi == 0.0` skip loop it
/// replaced (see module docs).
pub fn rank1_acc<const C: usize>(gw: &mut [f32], x: &[f32], d: &[f32; C]) {
    assert_eq!(gw.len(), x.len() * C);
    for (gr, &xi) in gw.chunks_exact_mut(C).zip(x) {
        for c in 0..C {
            gr[c] += xi * d[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::reference;
    use crate::util::Rng;

    #[test]
    fn gemv_matches_reference_closely_any_remainder() {
        let mut rng = Rng::new(9);
        for n in [1usize, 3, 4, 5, 7, 8, 31, 784] {
            let w: Vec<f32> = (0..n * 10).map(|_| rng.normal() as f32 * 0.1).collect();
            let bias: Vec<f32> = (0..10).map(|_| rng.normal() as f32 * 0.1).collect();
            let x: Vec<f32> = (0..n)
                .map(|_| if rng.index(2) == 0 { 0.0 } else { rng.uniform_f32() })
                .collect();
            let mut out = [0f32; 10];
            gemv_wide::<10>(&w, &bias, &x, &mut out);
            let mut expect = [0f32; 10];
            reference::gemv_wide_skip::<10>(&w, &bias, &x, &mut expect);
            for c in 0..10 {
                assert!(
                    (out[c] - expect[c]).abs() <= 1e-5 * (1.0 + expect[c].abs()),
                    "n={n} c={c}: {} vs {}",
                    out[c],
                    expect[c]
                );
            }
        }
    }

    #[test]
    fn rank1_matches_skip_reference_bitwise() {
        let mut rng = Rng::new(11);
        for n in [1usize, 4, 7, 8, 13, 784] {
            let x: Vec<f32> = (0..n)
                .map(|_| if rng.index(2) == 0 { 0.0 } else { rng.uniform_f32() })
                .collect();
            let mut d = [0f32; 10];
            for dc in d.iter_mut() {
                *dc = rng.normal() as f32;
            }
            let mut gw = vec![0f32; n * 10];
            let mut gw_ref = vec![0f32; n * 10];
            rank1_acc::<10>(&mut gw, &x, &d);
            reference::rank1_skip::<10>(&mut gw_ref, &x, &d);
            for i in 0..n * 10 {
                assert_eq!(gw[i].to_bits(), gw_ref[i].to_bits(), "n={n} i={i}");
            }
        }
    }
}
