//! Deterministic chunked reductions, sequential or parallel.
//!
//! The discipline (same as PR 7's fading sweeps): the input is cut at
//! **fixed** [`CHUNK`]-sized boundaries, each chunk's partial is computed
//! by the same 8-lane kernel regardless of who computes it, and partials
//! are combined strictly in ascending chunk order. Thread count only
//! decides *which worker* computes a partial, never the value of any
//! partial or the combine order — so `par_*` with any `threads` (0 =
//! auto) is bit-identical to the sequential `*_chunked` form.

use super::blocked;
use super::LANES;

/// Fixed reduction chunk: 4096 f32 = 16 KiB per chunk, small enough to
/// stay in L1 while a worker folds it, large enough to amortize spawn
/// bookkeeping. Never derived from thread count.
pub const CHUNK: usize = 4096;

fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// Chunked 8-lane dot product: per-chunk [`blocked::dot`] partials summed
/// in chunk order. Reassociated vs. a sequential scalar sum, deterministic
/// for a given input.
pub fn dot_chunked(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let mut total = 0.0f32;
    for (xc, yc) in x.chunks(CHUNK).zip(y.chunks(CHUNK)) {
        total += blocked::dot(xc, yc);
    }
    total
}

/// Parallel [`dot_chunked`]: bit-identical to it for every `threads`
/// value (0 = auto).
pub fn par_dot(x: &[f32], y: &[f32], threads: usize) -> f32 {
    assert_eq!(x.len(), y.len());
    let nchunks = x.len().div_ceil(CHUNK);
    let t = resolve_threads(threads).min(nchunks.max(1));
    if t <= 1 {
        return dot_chunked(x, y);
    }
    let mut partials = vec![0f32; nchunks];
    fill_partials(&mut partials, t, x.len(), |lo, hi, band| {
        for ((xc, yc), p) in x[lo..hi]
            .chunks(CHUNK)
            .zip(y[lo..hi].chunks(CHUNK))
            .zip(band.iter_mut())
        {
            *p = blocked::dot(xc, yc);
        }
    });
    let mut total = 0.0f32;
    for p in partials {
        total += p;
    }
    total
}

/// Chunked squared L2 norm in f64 (each chunk: 8 f64 lanes, fixed tree;
/// chunks summed in order). Reassociated vs. the old sequential
/// `util::norm2`, deterministic for a given input.
pub fn norm2_chunked(x: &[f32]) -> f64 {
    let mut total = 0.0f64;
    for xc in x.chunks(CHUNK) {
        total += norm2_lanes(xc);
    }
    total
}

/// Parallel [`norm2_chunked`]: bit-identical to it for every `threads`
/// value (0 = auto).
pub fn par_norm2(x: &[f32], threads: usize) -> f64 {
    let nchunks = x.len().div_ceil(CHUNK);
    let t = resolve_threads(threads).min(nchunks.max(1));
    if t <= 1 {
        return norm2_chunked(x);
    }
    let mut partials = vec![0f64; nchunks];
    fill_partials(&mut partials, t, x.len(), |lo, hi, band| {
        for (xc, p) in x[lo..hi].chunks(CHUNK).zip(band.iter_mut()) {
            *p = norm2_lanes(xc);
        }
    });
    let mut total = 0.0f64;
    for p in partials {
        total += p;
    }
    total
}

/// 8-lane f64 sum of squares over one chunk, fixed combine tree.
fn norm2_lanes(x: &[f32]) -> f64 {
    let mut acc = [0.0f64; LANES];
    let n = x.len() - x.len() % LANES;
    for xc in x[..n].chunks_exact(LANES) {
        for l in 0..LANES {
            let v = xc[l] as f64;
            acc[l] += v * v;
        }
    }
    for (l, &xi) in x[n..].iter().enumerate() {
        let v = xi as f64;
        acc[l] += v * v;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Split `partials` (one slot per chunk) into `t` contiguous bands of
/// whole chunks and let scoped workers fill them. Each band covers the
/// element range `[band_start * CHUNK, min(band_end * CHUNK, len))` —
/// boundaries depend only on [`CHUNK`] and the band split, and every slot
/// is written with the same per-chunk kernel, so the values are
/// independent of `t`.
fn fill_partials<T: Send>(
    partials: &mut [T],
    t: usize,
    len: usize,
    work: impl Fn(usize, usize, &mut [T]) + Sync,
) {
    let per = partials.len().div_ceil(t);
    std::thread::scope(|s| {
        let mut rest = &mut *partials;
        let mut chunk_off = 0usize;
        let work = &work;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (band, tail) = rest.split_at_mut(take);
            rest = tail;
            let lo = chunk_off * CHUNK;
            let hi = ((chunk_off + take) * CHUNK).min(len);
            chunk_off += take;
            s.spawn(move || work(lo, hi, band));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn par_matches_sequential_bitwise_across_threads() {
        let mut rng = Rng::new(42);
        for len in [0usize, 5, CHUNK - 1, CHUNK, CHUNK + 1, 3 * CHUNK + 17] {
            let x: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let y: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let d1 = dot_chunked(&x, &y);
            let n1 = norm2_chunked(&x);
            for threads in [1usize, 2, 8, 0] {
                assert_eq!(par_dot(&x, &y, threads).to_bits(), d1.to_bits(), "len {len}");
                assert_eq!(par_norm2(&x, threads).to_bits(), n1.to_bits(), "len {len}");
            }
        }
    }

    #[test]
    fn norm2_matches_simple_cases() {
        assert_eq!(norm2_chunked(&[3.0, 4.0]), 25.0);
        assert_eq!(norm2_chunked(&[]), 0.0);
        assert_eq!(par_norm2(&[3.0, 4.0], 0), 25.0);
    }
}
