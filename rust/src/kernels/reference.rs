//! Scalar reference implementations — the exact loops the blocked kernels
//! replaced, kept (not deleted) for three consumers:
//!
//! 1. `tests/kernels.rs` contract tests: per-coordinate kernels must match
//!    these **bitwise**; reduction kernels must match within tolerance.
//! 2. `benches/bench_kernels.rs`: the scalar-vs-blocked speedup rows.
//! 3. `NativeLr::loss_grad_reference`: the scalar-oracle training path
//!    behind the kernel-vs-scalar accuracy-equivalence test.
//!
//! Nothing in the production path calls these. They are deliberately the
//! *old* idiom — sequential sums, `xi == 0.0` skip branches — so they keep
//! measuring what we moved away from.

/// Sequential scalar dot product (the reassociation baseline for
/// [`super::dot`]).
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let mut acc = 0.0f32;
    for (&xi, &yi) in x.iter().zip(y) {
        acc += xi * yi;
    }
    acc
}

/// `y += a*x`, plain loop — bitwise target for [`super::axpy`].
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// `x *= a`, plain loop — bitwise target for [`super::scale`].
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// `y = a*y + b*x`, plain loop — bitwise target for [`super::scale_add`].
pub fn scale_add(a: f32, y: &mut [f32], b: f32, x: &[f32]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = a * *yi + b * xi;
    }
}

/// The seed's forward logits loop, skip branch and all: sequential
/// accumulation over nonzero inputs only.
pub fn gemv_wide_skip<const C: usize>(w: &[f32], bias: &[f32], x: &[f32], out: &mut [f32; C]) {
    assert_eq!(w.len(), x.len() * C);
    out.copy_from_slice(&bias[..C]);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let wrow = &w[i * C..(i + 1) * C];
        for c in 0..C {
            out[c] += xi * wrow[c];
        }
    }
}

/// The seed's backward rank-1 loop with the skip branch — bitwise target
/// for [`super::lr::rank1_acc`] on finite inputs.
pub fn rank1_skip<const C: usize>(gw: &mut [f32], x: &[f32], d: &[f32; C]) {
    assert_eq!(gw.len(), x.len() * C);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let gwrow = &mut gw[i * C..(i + 1) * C];
        for c in 0..C {
            gwrow[c] += xi * d[c];
        }
    }
}

/// Sequential f64 squared norm (the old `util::norm2` body) — the
/// reassociation baseline for [`super::reduce::norm2_chunked`].
pub fn norm2(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
}
