//! Portable blocked numeric kernels — the single home for every f32 hot
//! loop in the train/compress/aggregate path.
//!
//! Everything here is safe, dependency-free Rust written so LLVM's
//! auto-vectorizer can do the work: fixed-width lane accumulators
//! ([`LANES`] = 8), `chunks_exact` bodies with no bounds checks and no
//! data-dependent branches, and remainders handled in a separate scalar
//! tail. No `unsafe`, no intrinsics, no feature detection — the same
//! source is correct on every target and fast wherever LLVM has vector
//! units to aim at.
//!
//! # Determinism policy (see DESIGN.md §"Numeric kernels")
//!
//! Kernels fall into two classes, and the split is load-bearing for the
//! golden traces and the frozen `step_round` oracle:
//!
//! * **Per-coordinate kernels** ([`axpy`], [`scale`], [`scale_add`],
//!   [`add_assign`], [`sub_assign`], [`fill`], [`adam_step`], the
//!   `scatter_*` family, [`lr::rank1_acc`]) touch each output coordinate
//!   with exactly the arithmetic expression of the scalar loop they
//!   replaced — same ops, same order per coordinate — so they are
//!   **bitwise-identical** to their predecessors. Contract tests in
//!   `tests/kernels.rs` pin this with `to_bits` equality against the
//!   [`reference`] implementations.
//! * **Reduction kernels** ([`dot`], [`lr::gemv_wide`], the
//!   [`reduce`] chunked reductions) reassociate: partial sums live in a
//!   fixed lane/bank array and are combined by a fixed tree. The result is
//!   a *different* (but fully deterministic) rounding than the sequential
//!   scalar sum. Lane count, chunk boundaries ([`reduce::CHUNK`]), and the
//!   combine order are compile-time constants — never a function of thread
//!   count, shard count, or input values — so every engine stays
//!   bit-identical across `compute_threads`/`shards` settings.
//!
//! The reassociating kernels changed the LR/DRL numeric streams once, at
//! the PR that introduced this module; golden traces were re-blessed at
//! that point and `tests/kernels.rs::kernel_and_scalar_training_agree`
//! guards the re-bless (scalar-vs-kernel final accuracy within 1e-3).
//!
//! Note `f32::mul_add` is deliberately never used: fused multiply-add
//! rounds once instead of twice, which would silently change results
//! between targets with and without FMA units. Separate mul + add is
//! bit-stable everywhere.
#![forbid(unsafe_code)]

pub mod blocked;
pub mod lr;
pub mod reduce;
pub mod reference;
pub mod sparse;

pub use blocked::{add_assign, adam_step, axpy, dot, fill, scale, scale_add, sub_assign};
pub use sparse::{scatter_add, scatter_add_unit, scatter_set_pairs, scatter_sub, scatter_zero};

/// Lane width of the fixed accumulator arrays. Eight f32 lanes fill one
/// AVX2 register (or two NEON quads); wider targets simply unroll.
pub const LANES: usize = 8;
