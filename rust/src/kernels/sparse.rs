//! Sparse scatter kernels — the index/value fan-out shared by update
//! decode (`LgcUpdate::add_into`), error-feedback absorb, downlink delta
//! apply / mirror advance, and the population residual arena.
//!
//! All of these are per-coordinate and **bitwise-identical** to the loops
//! they replaced; centralizing them buys bounds-check-free bodies and one
//! place to reason about aliasing (indices within one call are unique by
//! construction of the compressors, but the kernels stay correct — last
//! write / accumulated add wins — even if they were not).

/// `out[indices[k]] += scale * values[k]`.
pub fn scatter_add(out: &mut [f32], indices: &[u32], values: &[f32], scale: f32) {
    assert_eq!(indices.len(), values.len());
    for (&i, &v) in indices.iter().zip(values) {
        out[i as usize] += scale * v;
    }
}

/// `out[indices[k]] += values[k]` — the unscaled form. Kept separate from
/// [`scatter_add`] with `scale == 1.0` so call sites that were plain `+= v`
/// stay literally the same expression (no `1.0 * v`, which differs only
/// for signaling NaNs but costs a multiply everywhere).
pub fn scatter_add_unit(out: &mut [f32], indices: &[u32], values: &[f32]) {
    assert_eq!(indices.len(), values.len());
    for (&i, &v) in indices.iter().zip(values) {
        out[i as usize] += v;
    }
}

/// `out[indices[k]] -= values[k]` — the error-feedback residual absorb.
pub fn scatter_sub(out: &mut [f32], indices: &[u32], values: &[f32]) {
    assert_eq!(indices.len(), values.len());
    for (&i, &v) in indices.iter().zip(values) {
        out[i as usize] -= v;
    }
}

/// `out[indices[k]] = 0.0` — the exact telescoping absorb.
pub fn scatter_zero(out: &mut [f32], indices: &[u32]) {
    for &i in indices {
        out[i as usize] = 0.0;
    }
}

/// `out[i] = v` for every `(i, v)` pair — the residual-arena restore shape.
pub fn scatter_set_pairs(out: &mut [f32], pairs: &[(u32, f32)]) {
    for &(i, v) in pairs {
        out[i as usize] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_roundtrip() {
        let mut out = vec![0f32; 16];
        let idx = [3u32, 7, 3, 15];
        let vals = [1.0f32, -2.0, 0.5, 4.0];
        scatter_add_unit(&mut out, &idx, &vals);
        assert_eq!(out[3], 1.5);
        assert_eq!(out[7], -2.0);
        assert_eq!(out[15], 4.0);
        scatter_sub(&mut out, &idx, &vals);
        assert!(out.iter().all(|&v| v == 0.0));
        scatter_add(&mut out, &idx, &vals, 2.0);
        assert_eq!(out[7], -4.0);
        scatter_zero(&mut out, &idx);
        assert!(out.iter().all(|&v| v.to_bits() == 0));
        scatter_set_pairs(&mut out, &[(2, 9.0), (2, 8.0)]);
        assert_eq!(out[2], 8.0); // last write wins
    }
}
