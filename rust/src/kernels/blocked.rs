//! Dense blocked kernels over contiguous f32 slices.
//!
//! Per-coordinate kernels (everything except [`dot`]) are bitwise-identical
//! to the naive scalar loop: blocking only removes bounds checks and lets
//! LLVM vectorize; the arithmetic per output coordinate is unchanged.
//! [`dot`] is a reduction and reassociates — see the module docs in
//! [`crate::kernels`].

use super::LANES;

/// `y[i] += a * x[i]`.
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    let n = x.len() - x.len() % LANES;
    for (yc, xc) in y[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for l in 0..LANES {
            yc[l] += a * xc[l];
        }
    }
    for (yi, &xi) in y[n..].iter_mut().zip(&x[n..]) {
        *yi += a * xi;
    }
}

/// `x[i] *= a`.
pub fn scale(a: f32, x: &mut [f32]) {
    let n = x.len() - x.len() % LANES;
    for xc in x[..n].chunks_exact_mut(LANES) {
        for l in 0..LANES {
            xc[l] *= a;
        }
    }
    for xi in &mut x[n..] {
        *xi *= a;
    }
}

/// `y[i] = a * y[i] + b * x[i]` — the soft-update / Polyak shape.
pub fn scale_add(a: f32, y: &mut [f32], b: f32, x: &[f32]) {
    assert_eq!(x.len(), y.len());
    let n = x.len() - x.len() % LANES;
    for (yc, xc) in y[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for l in 0..LANES {
            yc[l] = a * yc[l] + b * xc[l];
        }
    }
    for (yi, &xi) in y[n..].iter_mut().zip(&x[n..]) {
        *yi = a * *yi + b * xi;
    }
}

/// `y[i] += x[i]`.
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(x.len(), y.len());
    let n = x.len() - x.len() % LANES;
    for (yc, xc) in y[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for l in 0..LANES {
            yc[l] += xc[l];
        }
    }
    for (yi, &xi) in y[n..].iter_mut().zip(&x[n..]) {
        *yi += xi;
    }
}

/// `y[i] -= x[i]`.
pub fn sub_assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(x.len(), y.len());
    let n = x.len() - x.len() % LANES;
    for (yc, xc) in y[..n].chunks_exact_mut(LANES).zip(x[..n].chunks_exact(LANES)) {
        for l in 0..LANES {
            yc[l] -= xc[l];
        }
    }
    for (yi, &xi) in y[n..].iter_mut().zip(&x[n..]) {
        *yi -= xi;
    }
}

/// `x[i] = v`.
pub fn fill(x: &mut [f32], v: f32) {
    for xi in x.iter_mut() {
        *xi = v;
    }
}

/// 8-lane dot product with a fixed pairwise combine tree.
///
/// Deterministic for a given input, but **reassociated** vs. the
/// sequential scalar sum: lane `l` accumulates coordinates `i ≡ l
/// (mod 8)`, the tail (`len % 8` coordinates) folds into lanes `0..rem`,
/// and the eight lanes combine as
/// `((l0+l1)+(l2+l3)) + ((l4+l5)+(l6+l7))` — never a function of thread
/// count or call context.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let mut acc = [0.0f32; LANES];
    let n = x.len() - x.len() % LANES;
    for (xc, yc) in x[..n].chunks_exact(LANES).zip(y[..n].chunks_exact(LANES)) {
        for l in 0..LANES {
            acc[l] += xc[l] * yc[l];
        }
    }
    for (l, (&xi, &yi)) in x[n..].iter().zip(&y[n..]).enumerate() {
        acc[l] += xi * yi;
    }
    fold_lanes(&acc)
}

/// The fixed combine tree shared by every 8-lane reduction in this crate.
#[inline]
pub(crate) fn fold_lanes(acc: &[f32; LANES]) -> f32 {
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// One Adam step over a flat tensor — the exact per-coordinate expression
/// the DRL optimizer has always used (bitwise), hoisted here so the update
/// loop vectorizes.
#[allow(clippy::too_many_arguments)]
pub fn adam_step(
    p: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    lr: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
    b1t: f32,
    b2t: f32,
) {
    assert_eq!(p.len(), g.len());
    assert_eq!(p.len(), m.len());
    assert_eq!(p.len(), v.len());
    for i in 0..p.len() {
        m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
        v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
        let mhat = m[i] / b1t;
        let vhat = v[i] / b2t;
        p[i] -= lr * mhat / (vhat.sqrt() + eps);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_matches_scalar_bitwise() {
        for len in [0usize, 1, 7, 8, 9, 16, 23] {
            let x: Vec<f32> = (0..len).map(|i| (i as f32 + 0.25) * 1.7).collect();
            let mut y: Vec<f32> = (0..len).map(|i| i as f32 * -0.3).collect();
            let mut yr = y.clone();
            axpy(0.37, &x, &mut y);
            for (yi, &xi) in yr.iter_mut().zip(&x) {
                *yi += 0.37 * xi;
            }
            assert_eq!(
                y.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                yr.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "len {len}"
            );
        }
    }

    #[test]
    fn dot_tail_folds_into_low_lanes() {
        // len = 11: lanes 0..3 get two terms, lanes 3..8 one.
        let x: Vec<f32> = (0..11).map(|i| i as f32 + 1.0).collect();
        let y = vec![1.0f32; 11];
        // Reconstruct the documented lane order by hand.
        let mut acc = [0.0f32; LANES];
        for l in 0..LANES {
            acc[l] += x[l];
        }
        for (l, &xi) in x[8..].iter().enumerate() {
            acc[l] += xi;
        }
        assert_eq!(dot(&x, &y).to_bits(), fold_lanes(&acc).to_bits());
    }

    #[test]
    fn fill_and_scale() {
        let mut x = vec![3.0f32; 13];
        scale(2.0, &mut x);
        assert!(x.iter().all(|&v| v == 6.0));
        fill(&mut x, 0.0);
        assert!(x.iter().all(|&v| v.to_bits() == 0));
    }
}
