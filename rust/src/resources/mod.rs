//! Resource accounting and budgets (paper Sec. 2.3, Eq. 10).
//!
//! Two resource types `r ∈ R = {Energy, Money}` (the paper's evaluation
//! metrics) plus wall-clock time tracked separately. Every device carries a
//! [`ResourceMeter`]: per-round consumption split into *computation*
//! (`E_comp · H`) and *communication* (`E_comm · D`) components — exactly
//! the state the DRL agent observes (Eq. 11–12) — and a [`Budget`] that
//! enforces Eq. 10a (stop when any budget is exhausted).

/// Resource kinds tracked per Eq. 10 (R = 2 in the experiments).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Resource {
    /// Battery energy in joules.
    Energy,
    /// Monetary cost in currency units.
    Money,
}

pub const RESOURCES: [Resource; 2] = [Resource::Energy, Resource::Money];

/// Per-round, per-resource consumption split (Eq. 15b terms).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundConsumption {
    /// Computation component: `E_{m,r,comp} · H_m` .
    pub comp: f64,
    /// Communication component: `Σ_n E_{m,r,comm} · D_{m,n}`.
    pub comm: f64,
}

impl RoundConsumption {
    pub fn total(&self) -> f64 {
        self.comp + self.comm
    }
}

/// Energy model of local computation: joules per local SGD step, per device.
/// (Phone-class SoC running a small model: ~0.5–3 J per mini-batch step; the
/// exact constant only shifts the energy axis, the *ratios* between
/// mechanisms are what the figures compare.)
#[derive(Clone, Copy, Debug)]
pub struct ComputeCostModel {
    pub joules_per_step: f64,
    pub seconds_per_step: f64,
}

impl ComputeCostModel {
    /// Reasonable defaults per workload size (steps of batch 64).
    pub fn for_params(nparams: usize) -> Self {
        // Scale with model size: LR (8k) light, CNN (207k) heavy.
        let scale = (nparams as f64 / 10_000.0).max(0.2);
        ComputeCostModel {
            joules_per_step: 0.8 * scale.min(25.0),
            seconds_per_step: 0.02 * scale.min(25.0),
        }
    }
}

/// Running totals + budget enforcement for one device.
#[derive(Clone, Debug)]
pub struct ResourceMeter {
    pub energy_budget: f64,
    pub money_budget: f64,
    pub energy_used: f64,
    pub money_used: f64,
    pub time_used: f64,
    /// Downlink (model download) share of `energy_used` — Eq. 10 resources
    /// are spent in both directions once the downlink is simulated.
    pub down_energy_used: f64,
    /// Downlink share of `money_used`.
    pub down_money_used: f64,
    /// Last round's split, per resource — the DRL state (Eq. 11).
    pub last_round: [RoundConsumption; 2],
}

impl ResourceMeter {
    pub fn new(energy_budget: f64, money_budget: f64) -> Self {
        ResourceMeter {
            energy_budget,
            money_budget,
            energy_used: 0.0,
            money_used: 0.0,
            time_used: 0.0,
            down_energy_used: 0.0,
            down_money_used: 0.0,
            last_round: [RoundConsumption::default(); 2],
        }
    }

    /// Record one round. `comp_energy`/`comp_time` from the compute model,
    /// `comm_*` from the channel simulator.
    pub fn record_round(
        &mut self,
        comp_energy: f64,
        comm_energy: f64,
        comm_money: f64,
        wall_time: f64,
    ) {
        self.energy_used += comp_energy + comm_energy;
        self.money_used += comm_money;
        self.time_used += wall_time;
        self.last_round[0] = RoundConsumption { comp: comp_energy, comm: comm_energy };
        // Money has no computation component in the model (airtime only).
        self.last_round[1] = RoundConsumption { comp: 0.0, comm: comm_money };
    }

    /// Charge one downlink broadcast (model download). Counts toward the
    /// same Eq. 10a budgets as the uplink — a device that spends its whole
    /// energy budget *receiving* stops participating just the same — and is
    /// additionally tracked in the `down_*` split for the metrics columns.
    pub fn record_downlink(&mut self, energy: f64, money: f64) {
        self.energy_used += energy;
        self.money_used += money;
        self.down_energy_used += energy;
        self.down_money_used += money;
    }

    pub fn used(&self, r: Resource) -> f64 {
        match r {
            Resource::Energy => self.energy_used,
            Resource::Money => self.money_used,
        }
    }

    pub fn budget(&self, r: Resource) -> f64 {
        match r {
            Resource::Energy => self.energy_budget,
            Resource::Money => self.money_budget,
        }
    }

    /// Fraction of budget remaining in [0, 1]; 1.0 when unlimited.
    pub fn remaining_frac(&self, r: Resource) -> f64 {
        let b = self.budget(r);
        if !b.is_finite() {
            return 1.0;
        }
        ((b - self.used(r)) / b).clamp(0.0, 1.0)
    }

    /// Eq. 10a: true when every budget still has headroom.
    pub fn within_budget(&self) -> bool {
        self.energy_used <= self.energy_budget && self.money_used <= self.money_budget
    }

    /// True if the *next* round with estimated costs would break a budget.
    pub fn can_afford(&self, est_energy: f64, est_money: f64) -> bool {
        self.energy_used + est_energy <= self.energy_budget
            && self.money_used + est_money <= self.money_budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_splits() {
        let mut m = ResourceMeter::new(100.0, 10.0);
        m.record_round(2.0, 3.0, 0.5, 1.5);
        assert_eq!(m.energy_used, 5.0);
        assert_eq!(m.money_used, 0.5);
        assert_eq!(m.time_used, 1.5);
        assert_eq!(m.last_round[0].comp, 2.0);
        assert_eq!(m.last_round[0].comm, 3.0);
        assert_eq!(m.last_round[1].comm, 0.5);
        assert!(m.within_budget());
    }

    #[test]
    fn budget_exhaustion() {
        let mut m = ResourceMeter::new(10.0, f64::INFINITY);
        m.record_round(6.0, 5.0, 0.0, 1.0);
        assert!(!m.within_budget());
        assert_eq!(m.remaining_frac(Resource::Energy), 0.0);
        assert_eq!(m.remaining_frac(Resource::Money), 1.0);
    }

    #[test]
    fn can_afford_lookahead() {
        let mut m = ResourceMeter::new(10.0, 1.0);
        m.record_round(4.0, 0.0, 0.5, 0.0);
        assert!(m.can_afford(6.0, 0.5));
        assert!(!m.can_afford(6.1, 0.0));
        assert!(!m.can_afford(0.0, 0.6));
    }

    #[test]
    fn downlink_counts_toward_budget_and_is_split_out() {
        let mut m = ResourceMeter::new(10.0, 1.0);
        m.record_round(2.0, 3.0, 0.2, 1.0);
        m.record_downlink(4.0, 0.3);
        assert_eq!(m.energy_used, 9.0);
        assert_eq!(m.money_used, 0.5);
        assert_eq!(m.down_energy_used, 4.0);
        assert_eq!(m.down_money_used, 0.3);
        assert!(m.within_budget());
        m.record_downlink(2.0, 0.0); // download alone exhausts the budget
        assert!(!m.within_budget());
    }

    #[test]
    fn compute_model_scales_with_params() {
        let lr = ComputeCostModel::for_params(7_850);
        let cnn = ComputeCostModel::for_params(206_922);
        assert!(cnn.joules_per_step > lr.joules_per_step);
        assert!(cnn.seconds_per_step > lr.seconds_per_step);
    }

    #[test]
    fn remaining_frac_clamped() {
        let mut m = ResourceMeter::new(1.0, f64::INFINITY);
        m.record_round(5.0, 0.0, 0.0, 0.0);
        assert_eq!(m.remaining_frac(Resource::Energy), 0.0);
    }
}
