//! Mini property-testing harness (no `proptest` crate offline).
//!
//! [`check`] runs a property over `n` generated cases; on failure it
//! performs greedy shrinking via the case's [`Shrink`] implementation and
//! reports the minimal failing case. Deterministic from the run seed, and
//! honors `LGC_PROPTEST_CASES` to widen sweeps in CI.

use crate::util::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, roughly ordered most-aggressive-first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0 {
            return vec![];
        }
        let mut v = vec![0, self / 2];
        if *self > 1 {
            v.push(self - 1);
        }
        v.dedup();
        v
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        vec![0.0, self / 2.0]
    }
}

impl Shrink for Vec<f32> {
    fn shrink(&self) -> Vec<Self> {
        if self.is_empty() {
            return vec![];
        }
        let mut out = Vec::new();
        // halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // zero the values
        if self.iter().any(|&x| x != 0.0) {
            out.push(vec![0.0; self.len()]);
        }
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Number of cases per property (env-overridable).
pub fn default_cases() -> usize {
    std::env::var("LGC_PROPTEST_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` over `n` cases from `gen`; shrink + panic on first failure.
pub fn check<T, G, P>(seed: u64, n: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    for case in 0..n {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first shrinking candidate
            // that still fails, up to a budget.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut budget = 200;
            'outer: while budget > 0 {
                for cand in best.shrink() {
                    budget -= 1;
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                    if budget == 0 {
                        break;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}/{n}, seed {seed}):\n  minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use crate::util::Rng;

    pub fn f32_vec(rng: &mut Rng, max_len: usize, scale: f32) -> Vec<f32> {
        let n = 1 + rng.index(max_len);
        (0..n).map(|_| (rng.normal() as f32) * scale).collect()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.index(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(
            1,
            50,
            |rng| gen::usize_in(rng, 0, 100),
            |_| {
                count += 1;
                Ok(())
            },
        );
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "minimal input")]
    fn failing_property_panics_with_shrunk_input() {
        check(
            2,
            100,
            |rng| gen::usize_in(rng, 0, 1000),
            |&x| {
                if x < 10 {
                    Ok(())
                } else {
                    Err(format!("{x} too big"))
                }
            },
        );
    }

    #[test]
    fn shrinking_reaches_small_reprs() {
        // Verify the shrinker drives a Vec<f32> failure toward small size.
        let caught = std::panic::catch_unwind(|| {
            check(
                3,
                100,
                |rng| gen::f32_vec(rng, 64, 1.0),
                |v: &Vec<f32>| {
                    if v.len() < 4 {
                        Ok(())
                    } else {
                        Err("len >= 4".into())
                    }
                },
            );
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // minimal failing vec should have exactly 4..8 elements after shrink
        let start = msg.find('[').unwrap();
        let end = msg.find(']').unwrap();
        let items = msg[start + 1..end].split(',').count();
        assert!(items <= 8, "shrinker left {items} items: {msg}");
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check(7, 10, |rng| gen::usize_in(rng, 0, 1_000_000), |&x| {
            a.push(x);
            Ok(())
        });
        check(7, 10, |rng| gen::usize_in(rng, 0, 1_000_000), |&x| {
            b.push(x);
            Ok(())
        });
        assert_eq!(a, b);
    }
}
