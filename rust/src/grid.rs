//! One-command comparison grid over mechanism × scenario × sync-mode.
//!
//! `lgc compare-grid` drives every cell from the [`MechanismRegistry`]
//! (no hard-coded mechanism list — new presets join the grid the moment
//! they register), runs them all from one seed, and emits a ranked table
//! to stdout plus CSV and an EXPERIMENTS.md-ready markdown block.
//!
//! Ranking metrics (see DESIGN.md §"Competitor mechanisms & comparison
//! grid"):
//!
//! - **acc@budget** — best eval accuracy reached while cumulative energy
//!   stays within a shared joule budget (`--budget_j=F`, defaulting to the
//!   smallest total spend across the grid so every cell is scored on a
//!   budget all of them reached).
//! - **time-to-target** — simulated seconds until eval accuracy first
//!   reaches `--target_acc=F` (cells that never reach it sort last).
//! - **J/round** — total energy divided by rounds run, the steady-state
//!   per-round cost.
//!
//! Cells are ranked by acc@budget (desc), then time-to-target (asc),
//! then J/round (asc), then name — all on simulated quantities, so the
//! ranked output is bit-identical across repeat runs of the same seed.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::bench::Table;
use crate::config::ExperimentConfig;
use crate::coordinator::{ExperimentBuilder, LocalTrainer, MechanismRegistry};
use crate::metrics::RunLog;

/// Which cells to run. Built by the CLI from `--mechanisms=`,
/// `--scenarios=`, `--sync_modes=`, `--target_acc=`, `--budget_j=`.
#[derive(Clone, Debug)]
pub struct GridSpec {
    /// Mechanism registry keys (canonical spelling).
    pub mechanisms: Vec<String>,
    /// Scenario names (`"none"` is the static reference world).
    pub scenarios: Vec<String>,
    /// Sync modes, as config `sync_mode` values.
    pub sync_modes: Vec<String>,
    /// Accuracy target for the time-to-target column.
    pub target_acc: f64,
    /// Shared energy budget for acc@budget; `None` defaults to the
    /// smallest total spend across the grid.
    pub budget_j: Option<f64>,
}

impl GridSpec {
    /// The default grid: every registered mechanism, the static world plus
    /// one mobile/fading world, both synchronous sync modes.
    pub fn default_for(registry: &MechanismRegistry) -> Self {
        GridSpec {
            mechanisms: select_mechanisms(None, registry).expect("full registry is valid"),
            scenarios: vec!["none".to_string(), "diurnal".to_string()],
            sync_modes: vec!["barrier".to_string(), "semi-async".to_string()],
            target_acc: 0.8,
            budget_j: None,
        }
    }
}

/// Resolve a `--mechanisms=a,b,c` subset against the registry, or
/// enumerate every registered preset when no subset is given.
///
/// This is the single source of truth for "run all mechanisms": both
/// `lgc compare` and `lgc compare-grid` call it, so the covered set can
/// never drift from the registry again.
pub fn select_mechanisms(
    subset: Option<&str>,
    registry: &MechanismRegistry,
) -> Result<Vec<String>, String> {
    match subset {
        None => Ok(registry.names().iter().map(|s| s.to_string()).collect()),
        Some(csv) => {
            let mut out = Vec::new();
            for raw in csv.split(',') {
                let name = raw.trim();
                if name.is_empty() {
                    continue;
                }
                let preset = registry.get(name).ok_or_else(|| {
                    format!(
                        "unknown mechanism `{name}` (registered: {})",
                        registry.names().join(", ")
                    )
                })?;
                if !out.contains(&preset.key) {
                    out.push(preset.key.clone());
                }
            }
            if out.is_empty() {
                return Err("empty --mechanisms= list".to_string());
            }
            Ok(out)
        }
    }
}

/// One finished grid cell with its ranking metrics.
#[derive(Clone, Debug)]
pub struct GridCell {
    pub mechanism: String,
    pub scenario: String,
    pub sync_mode: String,
    pub rounds: usize,
    pub final_acc: f64,
    pub best_acc: f64,
    /// Best eval accuracy within the shared energy budget (NaN if the
    /// first evaluated round already overshot it).
    pub acc_at_budget: f64,
    /// Simulated seconds to first reach the target accuracy.
    pub time_to_target_s: Option<f64>,
    pub j_per_round: f64,
    pub total_energy_j: f64,
    pub total_time_s: f64,
    pub upload_mb: f64,
}

/// The full grid result, cells already in ranked order.
#[derive(Clone, Debug)]
pub struct GridReport {
    pub cells: Vec<GridCell>,
    pub budget_j: f64,
    pub target_acc: f64,
}

/// Run every cell of `spec` (same seed per cell — only `mechanism`,
/// `scenario`, `sync_mode` differ), score, and rank. `make_trainer` is
/// injected so the CLI's PJRT-or-native choice applies per cell.
pub fn run_grid<F>(
    spec: &GridSpec,
    config: Option<&Path>,
    overrides: &[String],
    make_trainer: F,
) -> Result<GridReport>
where
    F: Fn(&ExperimentConfig) -> Result<Box<dyn LocalTrainer>>,
{
    let mut runs: Vec<(String, String, String, RunLog)> = Vec::new();
    for mech in &spec.mechanisms {
        for scen in &spec.scenarios {
            for mode in &spec.sync_modes {
                let mut ov = overrides.to_vec();
                ov.push(format!("--mechanism={mech}"));
                ov.push(format!("--scenario={scen}"));
                ov.push(format!("--sync_mode={mode}"));
                let cell = format!("{mech}/{scen}/{mode}");
                let cfg = ExperimentConfig::load(config, &ov)
                    .map_err(|e| anyhow!("grid cell {cell}: {e}"))?;
                let mut trainer = make_trainer(&cfg)?;
                let mut exp = ExperimentBuilder::new(cfg).trainer(trainer.as_ref()).build()?;
                let log = exp.run(trainer.as_mut())?;
                runs.push((mech.clone(), scen.clone(), mode.clone(), log));
            }
        }
    }
    if runs.is_empty() {
        return Err(anyhow!("empty grid: no mechanism/scenario/sync_mode cells"));
    }

    // Score every cell on the budget all of them reached, unless the
    // caller pinned one.
    let budget_j = spec.budget_j.unwrap_or_else(|| {
        runs.iter()
            .filter_map(|(_, _, _, log)| log.last().map(|r| r.energy_j))
            .fold(f64::INFINITY, f64::min)
    });

    let mut cells: Vec<GridCell> = runs
        .into_iter()
        .map(|(mechanism, scenario, sync_mode, log)| {
            let rounds = log.records.len();
            let last_energy = log.last().map_or(0.0, |r| r.energy_j);
            GridCell {
                final_acc: log.final_acc(),
                best_acc: log.best_acc(),
                acc_at_budget: log.acc_under_budget(0, budget_j),
                time_to_target_s: log.cost_to_accuracy(spec.target_acc).map(|t| t.3),
                j_per_round: if rounds > 0 { last_energy / rounds as f64 } else { 0.0 },
                total_energy_j: last_energy,
                total_time_s: log.last().map_or(0.0, |r| r.total_time_s),
                upload_mb: log.records.iter().map(|r| r.bytes_up).sum::<u64>() as f64
                    / (1024.0 * 1024.0),
                rounds,
                mechanism,
                scenario,
                sync_mode,
            }
        })
        .collect();

    cells.sort_by(rank_cmp);

    Ok(GridReport { cells, budget_j, target_acc: spec.target_acc })
}

/// The ranking contract: acc@budget (desc, NaN last), then time-to-target
/// (asc, unreached last), then J/round (asc), then name — a total order,
/// so equal metrics still rank deterministically.
pub fn rank_cmp(a: &GridCell, b: &GridCell) -> std::cmp::Ordering {
    let acc = |c: &GridCell| {
        if c.acc_at_budget.is_nan() { f64::NEG_INFINITY } else { c.acc_at_budget }
    };
    acc(b)
        .total_cmp(&acc(a))
        .then_with(|| {
            a.time_to_target_s
                .unwrap_or(f64::INFINITY)
                .total_cmp(&b.time_to_target_s.unwrap_or(f64::INFINITY))
        })
        .then_with(|| a.j_per_round.total_cmp(&b.j_per_round))
        .then_with(|| {
            (&a.mechanism, &a.scenario, &a.sync_mode)
                .cmp(&(&b.mechanism, &b.scenario, &b.sync_mode))
        })
}

/// NaN-aware fixed-precision float cell ("-" for NaN).
fn fmt_f(v: f64, prec: usize) -> String {
    if v.is_nan() {
        "-".to_string()
    } else {
        format!("{v:.prec$}")
    }
}

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    v.map_or_else(|| "-".to_string(), |v| format!("{v:.prec$}"))
}

impl GridReport {
    /// Ranked table on stdout. Every quantity is simulated (no wall clock,
    /// no RSS), so two runs of the same grid print identical bytes — CI
    /// diffs this output to pin rank determinism.
    pub fn print_table(&self) {
        println!(
            "== compare-grid: {} cells | budget {:.1} J | target acc {:.2} ==",
            self.cells.len(),
            self.budget_j,
            self.target_acc
        );
        let mut t = Table::new(&[
            "rank",
            "mechanism",
            "scenario",
            "sync",
            "acc@budget",
            "final_acc",
            "best_acc",
            "t_target_s",
            "J/round",
            "total_J",
            "sim_s",
            "up_MB",
        ]);
        for (i, c) in self.cells.iter().enumerate() {
            t.row(&[
                (i + 1).to_string(),
                c.mechanism.clone(),
                c.scenario.clone(),
                c.sync_mode.clone(),
                fmt_f(c.acc_at_budget, 4),
                fmt_f(c.final_acc, 4),
                fmt_f(c.best_acc, 4),
                fmt_opt(c.time_to_target_s, 1),
                fmt_f(c.j_per_round, 2),
                fmt_f(c.total_energy_j, 1),
                fmt_f(c.total_time_s, 1),
                fmt_f(c.upload_mb, 2),
            ]);
        }
        t.print();
    }

    /// CSV with one row per ranked cell.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "rank,mechanism,scenario,sync_mode,acc_at_budget,final_acc,best_acc,\
             time_to_target_s,j_per_round,total_energy_j,total_time_s,upload_mb,rounds\n",
        );
        for (i, c) in self.cells.iter().enumerate() {
            let _ = writeln!(
                s,
                "{},{},{},{},{},{},{},{},{},{},{},{},{}",
                i + 1,
                c.mechanism,
                c.scenario,
                c.sync_mode,
                fmt_f(c.acc_at_budget, 6),
                fmt_f(c.final_acc, 6),
                fmt_f(c.best_acc, 6),
                fmt_opt(c.time_to_target_s, 3),
                fmt_f(c.j_per_round, 4),
                fmt_f(c.total_energy_j, 3),
                fmt_f(c.total_time_s, 3),
                fmt_f(c.upload_mb, 4),
                c.rounds,
            );
        }
        s
    }

    /// EXPERIMENTS.md-ready markdown block (ranked table + metric caption).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "| rank | mechanism | scenario | sync | acc@budget | final acc | \
             time-to-target (s) | J/round |"
        );
        let _ = writeln!(s, "|---:|---|---|---|---:|---:|---:|---:|");
        for (i, c) in self.cells.iter().enumerate() {
            let _ = writeln!(
                s,
                "| {} | {} | {} | {} | {} | {} | {} | {} |",
                i + 1,
                c.mechanism,
                c.scenario,
                c.sync_mode,
                fmt_f(c.acc_at_budget, 4),
                fmt_f(c.final_acc, 4),
                fmt_opt(c.time_to_target_s, 1),
                fmt_f(c.j_per_round, 2),
            );
        }
        let _ = writeln!(
            s,
            "\nacc@budget at {:.1} J shared energy budget; time-to-target at eval \
             accuracy ≥ {:.2}; all quantities simulated (deterministic per seed).",
            self.budget_j, self.target_acc
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::NativeLrTrainer;

    fn registry() -> MechanismRegistry {
        MechanismRegistry::builtin()
    }

    /// Regression for the `lgc compare` drift bug: with no subset, the
    /// selection IS the registry enumeration — every registered preset is
    /// covered, including ones registered after this test was written.
    #[test]
    fn select_none_covers_every_registered_preset() {
        let reg = registry();
        let selected = select_mechanisms(None, &reg).unwrap();
        let registered: Vec<String> = reg.names().iter().map(|s| s.to_string()).collect();
        assert_eq!(selected, registered);
        assert!(selected.len() >= 15, "registry shrank? {selected:?}");
        for key in ["energy-adaptive", "fedgreen", "lgc-divergence", "lgc-noma"] {
            assert!(selected.contains(&key.to_string()), "missing {key}");
        }
    }

    #[test]
    fn select_subset_canonicalizes_and_rejects_unknown() {
        let reg = registry();
        let got = select_mechanisms(Some("fedavg, LGC-STATIC,fedavg"), &reg).unwrap();
        assert_eq!(got, vec!["fedavg".to_string(), "lgc-static".to_string()]);
        let err = select_mechanisms(Some("warp-drive"), &reg).unwrap_err();
        assert!(err.contains("warp-drive") && err.contains("fedavg"), "{err}");
        assert!(select_mechanisms(Some(" , "), &reg).is_err());
    }

    fn tiny_overrides() -> Vec<String> {
        [
            "--workload=lr",
            "--rounds=2",
            "--devices=2",
            "--samples_per_device=64",
            "--eval_samples=64",
            "--eval_every=1",
            "--use_runtime=false",
            "--seed=42",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect()
    }

    #[test]
    fn grid_runs_every_cell_and_ranks_deterministically() {
        let spec = GridSpec {
            mechanisms: vec!["fedavg".to_string(), "lgc-static".to_string()],
            scenarios: vec!["none".to_string()],
            sync_modes: vec!["barrier".to_string(), "semi-async".to_string()],
            target_acc: 0.5,
            budget_j: None,
        };
        let run = || {
            run_grid(&spec, None, &tiny_overrides(), |cfg| {
                Ok(Box::new(NativeLrTrainer::new(cfg)) as Box<dyn LocalTrainer>)
            })
            .unwrap()
        };
        let a = run();
        assert_eq!(a.cells.len(), 4);
        // Budget defaults to the cheapest cell's total spend, so at least
        // one cell scored the full budget.
        assert!(a.budget_j.is_finite() && a.budget_j > 0.0);
        assert!(a
            .cells
            .iter()
            .any(|c| (c.total_energy_j - a.budget_j).abs() < 1e-9));
        // Ranked order is a permutation of the requested cells.
        let mut names: Vec<String> = a
            .cells
            .iter()
            .map(|c| format!("{}/{}/{}", c.mechanism, c.scenario, c.sync_mode))
            .collect();
        names.sort();
        assert_eq!(
            names,
            vec![
                "fedavg/none/barrier",
                "fedavg/none/semi-async",
                "lgc-static/none/barrier",
                "lgc-static/none/semi-async"
            ]
        );
        // Same spec, same seed → bit-identical report (CSV covers every
        // rendered quantity).
        let b = run();
        assert_eq!(a.to_csv(), b.to_csv());
        assert_eq!(a.to_markdown(), b.to_markdown());
    }

    #[test]
    fn ranking_orders_nan_and_missing_targets_last() {
        let cell = |m: &str, acc: f64, t: Option<f64>, j: f64| GridCell {
            mechanism: m.to_string(),
            scenario: "none".to_string(),
            sync_mode: "barrier".to_string(),
            rounds: 1,
            final_acc: acc,
            best_acc: acc,
            acc_at_budget: acc,
            time_to_target_s: t,
            j_per_round: j,
            total_energy_j: j,
            total_time_s: 1.0,
            upload_mb: 1.0,
        };
        let mut cells = vec![
            cell("never-evaluated", f64::NAN, None, 1.0),
            cell("slow-but-best", 0.9, Some(10.0), 5.0),
            cell("tied-acc-faster", 0.8, Some(3.0), 5.0),
            cell("tied-acc-slower", 0.8, Some(7.0), 1.0),
            cell("tied-all-but-cheaper", 0.8, Some(7.0), 0.5),
            cell("no-target", 0.7, None, 1.0),
        ];
        cells.sort_by(rank_cmp);
        let order: Vec<&str> = cells.iter().map(|c| c.mechanism.as_str()).collect();
        assert_eq!(
            order,
            vec![
                "slow-but-best",
                "tied-acc-faster",
                "tied-all-but-cheaper",
                "tied-acc-slower",
                "no-target",
                "never-evaluated"
            ]
        );
        let report = GridReport { cells, budget_j: 10.0, target_acc: 0.8 };
        assert!(report.to_csv().lines().next().unwrap().contains("acc_at_budget"));
        assert!(report.to_markdown().contains("| rank |"));
    }
}
