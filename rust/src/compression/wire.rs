//! Sparse wire format for one layer of an `LgcUpdate`.
//!
//! Layout (little-endian, single contiguous buffer):
//!
//! ```text
//! [u32 dim] [u32 nnz] [u32 delta_0 .. delta_{nnz-1}] [f32 v_0 .. v_{nnz-1}]
//! ```
//!
//! Indices are delta-encoded (ascending input order) — with 4-byte deltas
//! this does not shrink the payload by itself, but it keeps decode branch-
//! free and makes the format trivially splittable; the byte accounting the
//! channel simulator charges is `encoded_len(nnz)`. (The paper charges
//! 8 B/coordinate for sparsified gradients, same as index+value here.)

use super::Layer;

/// Bytes per (index, value) entry on the wire.
pub const WIRE_BYTES_PER_ENTRY: usize = 8;
/// Header bytes (dim + nnz).
pub const WIRE_HEADER: usize = 8;

/// Encoded size in bytes for `nnz` entries.
pub fn encoded_len(nnz: usize) -> usize {
    WIRE_HEADER + nnz * WIRE_BYTES_PER_ENTRY
}

/// A serialized layer.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseChunk {
    pub bytes: Vec<u8>,
}

/// Encode one layer (indices must be ascending — `lgc_compress` guarantees).
pub fn encode(dim: usize, layer: &Layer) -> SparseChunk {
    let mut bytes = Vec::new();
    encode_into(dim, layer, &mut bytes);
    SparseChunk { bytes }
}

/// Encode one layer into a reusable buffer (cleared first); returns the
/// number of bytes written, which always equals [`encoded_len`]`(layer.len())`
/// — the byte count the channel simulator charges.
pub fn encode_into(dim: usize, layer: &Layer, bytes: &mut Vec<u8>) -> usize {
    debug_assert!(layer.indices.windows(2).all(|w| w[0] < w[1]));
    let nnz = layer.len();
    bytes.clear();
    bytes.reserve(encoded_len(nnz));
    bytes.extend_from_slice(&(dim as u32).to_le_bytes());
    bytes.extend_from_slice(&(nnz as u32).to_le_bytes());
    let mut prev = 0u32;
    for &i in &layer.indices {
        bytes.extend_from_slice(&(i - prev).to_le_bytes());
        prev = i;
    }
    for &v in &layer.values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes.len()
}

/// Decode error.
#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    Truncated,
    IndexOutOfRange { index: u32, dim: u32 },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated => write!(f, "truncated sparse chunk"),
            DecodeError::IndexOutOfRange { index, dim } => {
                write!(f, "index {index} out of range for dim {dim}")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Decode a chunk back into `(dim, Layer)`.
pub fn decode(chunk: &SparseChunk) -> Result<(usize, Layer), DecodeError> {
    let mut layer = Layer { indices: Vec::new(), values: Vec::new() };
    let dim = decode_into(&chunk.bytes, &mut layer)?;
    Ok((dim, layer))
}

/// Decode raw wire bytes into a reusable `Layer` (its vectors are cleared
/// and refilled, reusing their allocations); returns the encoded dimension.
pub fn decode_into(b: &[u8], out: &mut Layer) -> Result<usize, DecodeError> {
    if b.len() < WIRE_HEADER {
        return Err(DecodeError::Truncated);
    }
    let dim = u32::from_le_bytes(b[0..4].try_into().unwrap());
    let nnz = u32::from_le_bytes(b[4..8].try_into().unwrap()) as usize;
    if b.len() != encoded_len(nnz) {
        return Err(DecodeError::Truncated);
    }
    out.indices.clear();
    out.values.clear();
    out.indices.reserve(nnz);
    out.values.reserve(nnz);
    let mut prev = 0u32;
    for e in 0..nnz {
        let off = WIRE_HEADER + 4 * e;
        let delta = u32::from_le_bytes(b[off..off + 4].try_into().unwrap());
        let idx = prev + delta;
        if idx >= dim {
            return Err(DecodeError::IndexOutOfRange { index: idx, dim });
        }
        out.indices.push(idx);
        prev = idx;
    }
    let vbase = WIRE_HEADER + 4 * nnz;
    for e in 0..nnz {
        let off = vbase + 4 * e;
        out.values.push(f32::from_le_bytes(b[off..off + 4].try_into().unwrap()));
    }
    Ok(dim as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    #[test]
    fn roundtrip_random_layers() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let d = 64 + rng.index(2000);
            let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let k = 1 + rng.index(d / 2);
            let upd = lgc_compress(&u, &[k], &mut CompressScratch::default());
            let chunk = encode(d, &upd.layers[0]);
            assert_eq!(chunk.bytes.len(), encoded_len(k));
            let (dim, layer) = decode(&chunk).unwrap();
            assert_eq!(dim, d);
            assert_eq!(layer, upd.layers[0]);
        }
    }

    #[test]
    fn empty_layer_roundtrips() {
        let layer = Layer { indices: vec![], values: vec![] };
        let chunk = encode(100, &layer);
        assert_eq!(chunk.bytes.len(), WIRE_HEADER);
        let (dim, out) = decode(&chunk).unwrap();
        assert_eq!(dim, 100);
        assert!(out.is_empty());
    }

    #[test]
    fn truncation_detected() {
        let layer = Layer { indices: vec![1, 5], values: vec![0.5, -0.5] };
        let mut chunk = encode(10, &layer);
        chunk.bytes.pop();
        assert_eq!(decode(&chunk), Err(DecodeError::Truncated));
        assert_eq!(
            decode(&SparseChunk { bytes: vec![0, 1, 2] }),
            Err(DecodeError::Truncated)
        );
    }

    #[test]
    fn out_of_range_detected() {
        // dim=4 but index 7 encoded
        let layer = Layer { indices: vec![7], values: vec![1.0] };
        let chunk = encode(4, &layer);
        assert!(matches!(
            decode(&chunk),
            Err(DecodeError::IndexOutOfRange { index: 7, dim: 4 })
        ));
    }

    #[test]
    fn wire_accounting_matches_paper_8_bytes_per_entry() {
        assert_eq!(WIRE_BYTES_PER_ENTRY, 8);
        assert_eq!(encoded_len(1000) - WIRE_HEADER, 8000);
    }
}
