//! Sparse wire format for one layer of an `LgcUpdate`.
//!
//! Layout (little-endian, single contiguous buffer):
//!
//! ```text
//! [u32 dim] [u32 nnz] [u32 delta_0 .. delta_{nnz-1}] [f32 v_0 .. v_{nnz-1}]
//! ```
//!
//! Indices are delta-encoded (ascending input order) — with 4-byte deltas
//! this does not shrink the payload by itself, but it keeps decode branch-
//! free and makes the format trivially splittable; the byte accounting the
//! channel simulator charges is `encoded_len(nnz)`. (The paper charges
//! 8 B/coordinate for sparsified gradients, same as index+value here.)

use super::Layer;

/// Bytes per (index, value) entry on the wire.
pub const WIRE_BYTES_PER_ENTRY: usize = 8;
/// Header bytes (dim + nnz).
pub const WIRE_HEADER: usize = 8;

/// Encoded size in bytes for `nnz` entries.
pub fn encoded_len(nnz: usize) -> usize {
    WIRE_HEADER + nnz * WIRE_BYTES_PER_ENTRY
}

/// A serialized layer.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseChunk {
    pub bytes: Vec<u8>,
}

/// Encode one layer (indices must be ascending — `lgc_compress` guarantees).
pub fn encode(dim: usize, layer: &Layer) -> SparseChunk {
    let mut bytes = Vec::new();
    encode_into(dim, layer, &mut bytes);
    SparseChunk { bytes }
}

/// Encode one layer into a reusable buffer (cleared first); returns the
/// number of bytes written, which always equals [`encoded_len`]`(layer.len())`
/// — the byte count the channel simulator charges.
pub fn encode_into(dim: usize, layer: &Layer, bytes: &mut Vec<u8>) -> usize {
    debug_assert!(layer.indices.windows(2).all(|w| w[0] < w[1]));
    let nnz = layer.len();
    bytes.clear();
    bytes.reserve(encoded_len(nnz));
    bytes.extend_from_slice(&(dim as u32).to_le_bytes());
    bytes.extend_from_slice(&(nnz as u32).to_le_bytes());
    let mut prev = 0u32;
    for &i in &layer.indices {
        bytes.extend_from_slice(&(i - prev).to_le_bytes());
        prev = i;
    }
    for &v in &layer.values {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes.len()
}

/// Decode error. Every malformed buffer maps to one of these — decoding
/// never panics, whatever bytes arrive off the wire (`tests` below sweep
/// truncations, bit flips and adversarial headers).
#[derive(Debug, PartialEq, Eq)]
pub enum DecodeError {
    /// Buffer length disagrees with the header's entry count (short header,
    /// truncated payload, or trailing garbage).
    Truncated,
    IndexOutOfRange { index: u32, dim: u32 },
    /// The delta stream wrapped past `u32::MAX` — impossible for any
    /// well-formed encoding.
    IndexOverflow { prev: u32, delta: u32 },
    /// A zero delta after the first entry: duplicate coordinate.
    DuplicateIndex { index: u32 },
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeError::Truncated => write!(f, "truncated sparse chunk"),
            DecodeError::IndexOutOfRange { index, dim } => {
                write!(f, "index {index} out of range for dim {dim}")
            }
            DecodeError::IndexOverflow { prev, delta } => {
                write!(f, "index overflow: {prev} + delta {delta} exceeds u32")
            }
            DecodeError::DuplicateIndex { index } => {
                write!(f, "duplicate coordinate {index} (zero delta)")
            }
        }
    }
}

impl std::error::Error for DecodeError {}

/// Decode a chunk back into `(dim, Layer)`.
pub fn decode(chunk: &SparseChunk) -> Result<(usize, Layer), DecodeError> {
    let mut layer = Layer { indices: Vec::new(), values: Vec::new() };
    let dim = decode_into(&chunk.bytes, &mut layer)?;
    Ok((dim, layer))
}

/// Decode raw wire bytes into a reusable `Layer` (its vectors are cleared
/// and refilled, reusing their allocations); returns the encoded dimension.
///
/// Hardened against malformed input: every length/overflow/ordering check
/// returns an [`Err`] — there is no panic path, however adversarial the
/// buffer. On `Err`, `out`'s contents are unspecified (cleared plus however
/// many entries decoded before the fault).
pub fn decode_into(b: &[u8], out: &mut Layer) -> Result<usize, DecodeError> {
    if b.len() < WIRE_HEADER {
        return Err(DecodeError::Truncated);
    }
    let dim = u32::from_le_bytes(b[0..4].try_into().expect("4-byte slice"));
    let nnz = u32::from_le_bytes(b[4..8].try_into().expect("4-byte slice")) as usize;
    // Checked length arithmetic: a hostile nnz header must not overflow the
    // expected-size computation (usize is 32-bit on some targets).
    let expect = nnz
        .checked_mul(WIRE_BYTES_PER_ENTRY)
        .and_then(|x| x.checked_add(WIRE_HEADER));
    if expect != Some(b.len()) {
        return Err(DecodeError::Truncated);
    }
    out.indices.clear();
    out.values.clear();
    out.indices.reserve(nnz);
    out.values.reserve(nnz);
    let mut prev = 0u32;
    for e in 0..nnz {
        let off = WIRE_HEADER + 4 * e;
        let delta = u32::from_le_bytes(b[off..off + 4].try_into().expect("4-byte slice"));
        if e > 0 && delta == 0 {
            return Err(DecodeError::DuplicateIndex { index: prev });
        }
        let idx = prev
            .checked_add(delta)
            .ok_or(DecodeError::IndexOverflow { prev, delta })?;
        if idx >= dim {
            return Err(DecodeError::IndexOutOfRange { index: idx, dim });
        }
        out.indices.push(idx);
        prev = idx;
    }
    let vbase = WIRE_HEADER + 4 * nnz;
    for e in 0..nnz {
        let off = vbase + 4 * e;
        out.values
            .push(f32::from_le_bytes(b[off..off + 4].try_into().expect("4-byte slice")));
    }
    Ok(dim as usize)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    #[test]
    fn roundtrip_random_layers() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let d = 64 + rng.index(2000);
            let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let k = 1 + rng.index(d / 2);
            let upd = lgc_compress(&u, &[k], &mut CompressScratch::default());
            let chunk = encode(d, &upd.layers[0]);
            assert_eq!(chunk.bytes.len(), encoded_len(k));
            let (dim, layer) = decode(&chunk).unwrap();
            assert_eq!(dim, d);
            assert_eq!(layer, upd.layers[0]);
        }
    }

    #[test]
    fn empty_layer_roundtrips() {
        let layer = Layer { indices: vec![], values: vec![] };
        let chunk = encode(100, &layer);
        assert_eq!(chunk.bytes.len(), WIRE_HEADER);
        let (dim, out) = decode(&chunk).unwrap();
        assert_eq!(dim, 100);
        assert!(out.is_empty());
    }

    #[test]
    fn truncation_detected() {
        let layer = Layer { indices: vec![1, 5], values: vec![0.5, -0.5] };
        let mut chunk = encode(10, &layer);
        chunk.bytes.pop();
        assert_eq!(decode(&chunk), Err(DecodeError::Truncated));
        assert_eq!(
            decode(&SparseChunk { bytes: vec![0, 1, 2] }),
            Err(DecodeError::Truncated)
        );
    }

    #[test]
    fn out_of_range_detected() {
        // dim=4 but index 7 encoded
        let layer = Layer { indices: vec![7], values: vec![1.0] };
        let chunk = encode(4, &layer);
        assert!(matches!(
            decode(&chunk),
            Err(DecodeError::IndexOutOfRange { index: 7, dim: 4 })
        ));
    }

    #[test]
    fn wire_accounting_matches_paper_8_bytes_per_entry() {
        assert_eq!(WIRE_BYTES_PER_ENTRY, 8);
        assert_eq!(encoded_len(1000) - WIRE_HEADER, 8000);
    }

    #[test]
    fn duplicate_index_detected() {
        // Hand-craft: dim=10, nnz=2, deltas [3, 0] (index 3 twice).
        let mut b = Vec::new();
        b.extend_from_slice(&10u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&3u32.to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes());
        b.extend_from_slice(&1.0f32.to_le_bytes());
        b.extend_from_slice(&2.0f32.to_le_bytes());
        let mut out = Layer { indices: vec![], values: vec![] };
        assert_eq!(
            decode_into(&b, &mut out),
            Err(DecodeError::DuplicateIndex { index: 3 })
        );
        // A leading zero delta is index 0 — legal.
        let layer = Layer { indices: vec![0, 1], values: vec![0.5, 0.25] };
        let chunk = encode(4, &layer);
        assert_eq!(decode(&chunk).unwrap().1, layer);
    }

    #[test]
    fn index_overflow_detected() {
        // dim=u32::MAX, two deltas of 2^31 each: the second add wraps u32.
        let half = 1u32 << 31;
        let mut b = Vec::new();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&half.to_le_bytes());
        b.extend_from_slice(&half.to_le_bytes());
        b.extend_from_slice(&0.0f32.to_le_bytes());
        b.extend_from_slice(&0.0f32.to_le_bytes());
        let mut out = Layer { indices: vec![], values: vec![] };
        assert_eq!(
            decode_into(&b, &mut out),
            Err(DecodeError::IndexOverflow { prev: half, delta: half })
        );
    }

    #[test]
    fn hostile_nnz_header_is_rejected_not_allocated() {
        // nnz = u32::MAX with an 8-byte buffer: length check must fail
        // before any reserve; checked arithmetic guards 32-bit targets.
        let mut b = Vec::new();
        b.extend_from_slice(&100u32.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut out = Layer { indices: vec![], values: vec![] };
        assert_eq!(decode_into(&b, &mut out), Err(DecodeError::Truncated));
        // Trailing garbage is a length mismatch too.
        let layer = Layer { indices: vec![1, 5], values: vec![0.5, -0.5] };
        let mut chunk = encode(10, &layer);
        chunk.bytes.push(0xAB);
        assert_eq!(decode(&chunk), Err(DecodeError::Truncated));
    }

    /// The satellite sweep: random buffers, truncations and single-byte
    /// mutations of valid encodings must all return `Ok` or `Err` — never
    /// panic, never produce an out-of-contract layer.
    #[test]
    fn malformed_input_sweep_never_panics() {
        let mut rng = Rng::new(0xBAD_BEEF);
        let mut out = Layer { indices: vec![], values: vec![] };
        // Pure-noise buffers of every small length.
        for len in 0..64 {
            let b: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = decode_into(&b, &mut out);
        }
        // Valid encodings, then truncate at every boundary and flip bytes.
        for seed in 0..8 {
            let d = 32 + rng.index(500);
            let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let k = 1 + rng.index(d / 2);
            let upd = lgc_compress(&u, &[k], &mut CompressScratch::default());
            let chunk = encode(d, &upd.layers[0]);
            for cut in 0..chunk.bytes.len() {
                let _ = decode_into(&chunk.bytes[..cut], &mut out);
            }
            for _ in 0..200 {
                let mut mutated = chunk.bytes.clone();
                let pos = rng.index(mutated.len());
                mutated[pos] ^= 1 << rng.index(8);
                if let Ok(dim) = decode_into(&mutated, &mut out) {
                    // Whatever decoded must honor the format invariants.
                    assert!(out.indices.windows(2).all(|w| w[0] < w[1]), "seed {seed}");
                    assert!(out.indices.iter().all(|&i| (i as usize) < dim));
                    assert_eq!(out.indices.len(), out.values.len());
                }
            }
        }
    }
}
