//! The [`Compressor`] trait — the pluggable compression seam of the round
//! loop.
//!
//! The paper treats the compression operator as a *policy point*: banded
//! `Top_{α,β}` → layered `LGC_k` today, but related work swaps in random
//! sparsification, quantization, or no compression at all (FedGreen,
//! arXiv:2111.06146; "To Talk or to Work", arXiv:2012.11804). This module
//! turns that into an open API: anything implementing [`Compressor`] plugs
//! into [`crate::coordinator::Device`] unchanged, and error feedback is a
//! composable [`ErrorCompensated`] wrapper rather than device-side code.
//!
//! Built-in implementations:
//!
//! | type           | strategy                                   | wire format |
//! |----------------|--------------------------------------------|-------------|
//! | [`LgcTopAB`]   | banded top-K partition (production path)   | sparse      |
//! | [`LgcRadix`]   | radix-select variant (documented §Perf)    | sparse      |
//! | [`RandK`]      | uniform random-K (Wangni et al. 2017)      | sparse      |
//! | [`Qsgd`]       | stochastic quantizer (Alistarh et al. 2017)| packed      |
//! | [`DenseNoop`]  | identity (FedAvg-style dense reference)    | dense f32   |
//!
//! See DESIGN.md §"Extension points" for a worked example of registering a
//! new compressor end to end.

use super::error_feedback::ErrorFeedback;
use super::quantize::{wire_bits, QsgdQuantizer};
use super::rand_k::RandK;
use super::{lgc_compress, lgc_compress_radix, CompressScratch, Layer, LgcUpdate};
use crate::channels::AllocationPlan;
use crate::util::Rng;

/// Compact cross-round compressor state, exported when a population client
/// is demobilized so the store keeps O(1) bytes per client instead of a
/// resident `Box<dyn Compressor>` (the error memory travels separately, as
/// the population's [`Residual`](crate::population::Residual)).
#[derive(Clone, Debug, Default)]
pub enum CompressorSeed {
    /// No cross-round state beyond the (separately drained) error memory.
    #[default]
    Stateless,
    /// A private RNG stream: the current position plus the episode-reset
    /// base, so both the next draw and a future `reset` replay exactly.
    Stream { cur: Rng, base: Rng },
}

impl CompressorSeed {
    /// Episode reset without a live compressor box: rewind the stream to
    /// its construction state (the seed-side mirror of
    /// [`Compressor::reset`]).
    pub fn reset(&mut self) {
        if let CompressorSeed::Stream { cur, base } = self {
            *cur = base.clone();
        }
    }
}

/// Per-round coordinate budget, one entry per layer (Eq. 2's `K_c`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerBudget {
    ks: Vec<usize>,
}

impl LayerBudget {
    pub fn new(ks: Vec<usize>) -> Self {
        assert!(!ks.is_empty(), "a budget needs at least one layer");
        LayerBudget { ks }
    }

    /// Per-layer coordinate counts.
    pub fn ks(&self) -> &[usize] {
        &self.ks
    }

    /// Total coordinates across layers.
    pub fn total(&self) -> usize {
        self.ks.iter().sum()
    }

    /// Build a feasible budget from an allocation plan for a `dim`-sized
    /// model: per-layer counts are clamped to `dim`, and an oversized total
    /// is rescaled proportionally (never to all-zero).
    pub fn from_plan(plan: &AllocationPlan, dim: usize) -> Self {
        let ks: Vec<usize> = plan.layer_budgets().iter().map(|&k| k.min(dim)).collect();
        if ks.is_empty() {
            return LayerBudget { ks: vec![0] };
        }
        let total: usize = ks.iter().sum();
        if total <= dim {
            return LayerBudget { ks };
        }
        let mut scaled: Vec<usize> = ks.iter().map(|&k| (k * dim) / total.max(1)).collect();
        if scaled.iter().sum::<usize>() == 0 {
            scaled[0] = 1;
        }
        LayerBudget { ks: scaled }
    }
}

/// A pluggable gradient compressor. One instance lives per device and may
/// hold cross-round state (RNG streams, error memory via
/// [`ErrorCompensated`], adaptive thresholds, ...).
///
/// Contract (enforced for every registered impl by
/// `tests/compressor_contract.rs`):
///
/// - the decoded update's support is a subset of the input's support;
/// - `total_nnz() <= budget.total()` whenever [`Compressor::respects_budget`]
///   is true;
/// - two instances built from the same seed produce identical output
///   (determinism — the simulator's reproducibility depends on it).
pub trait Compressor: Send {
    /// Short human-readable name for logs and registry listings.
    fn name(&self) -> String;

    /// Compress `u` under `budget` into a layered update. `scratch` is the
    /// caller's reusable workspace (no steady-state allocation). Emit at
    /// most one layer per budget entry — the device maps layer `c` onto the
    /// plan's `c`-th active channel and rejects over-long updates.
    fn compress(
        &mut self,
        u: &[f32],
        budget: &LayerBudget,
        scratch: &mut CompressScratch,
    ) -> LgcUpdate;

    /// Bytes one layer of a `dim`-sized update occupies on the wire.
    /// Default: the sparse index+value format ([`Layer::wire_bytes`]).
    fn layer_wire_bytes(&self, layer: &Layer, dim: usize) -> u64 {
        let _ = dim;
        layer.wire_bytes()
    }

    /// Total wire bytes of an update under this compressor's format.
    fn wire_bytes(&self, update: &LgcUpdate) -> u64 {
        update
            .layers
            .iter()
            .map(|l| self.layer_wire_bytes(l, update.dim))
            .sum()
    }

    /// Whether updates travel in the sparse index+value wire format (and so
    /// should be round-tripped through `wire::encode`/`decode` by the
    /// server). Dense/packed formats return false.
    fn sparse_wire(&self) -> bool {
        true
    }

    /// Whether `total_nnz() <= budget.total()` is guaranteed. Quantizers and
    /// the dense baseline return false.
    fn respects_budget(&self) -> bool {
        true
    }

    /// Whether shipped values equal the input coordinates exactly (true for
    /// top-K-style selection; false for quantized or rescaled values). Used
    /// by [`ErrorCompensated`] to pick the exact zeroing-based residual.
    fn exact_values(&self) -> bool {
        true
    }

    /// The error-feedback memory, if this compressor maintains one.
    fn error_memory(&self) -> Option<&ErrorFeedback> {
        None
    }

    fn error_memory_mut(&mut self) -> Option<&mut ErrorFeedback> {
        None
    }

    /// Reset cross-round state (new episode / fresh FL problem).
    fn reset(&mut self) {}

    /// Release O(model-dim) working buffers while keeping cross-round
    /// statistical state (RNG streams, adaptive thresholds). The population
    /// store calls this when a client is demobilized back to its spec —
    /// after draining the error memory separately — so a parked compressor
    /// costs O(1) in the model dimension. Default: no-op (stateless
    /// compressors hold nothing).
    fn trim_working_memory(&mut self) {}

    /// Export the compact cross-round state for seed-based rehydration.
    /// The population store keeps one [`CompressorSeed`] per client and a
    /// small shared pool of boxes (≤ cohort per distinct
    /// [`Compressor::name`]) instead of a resident box per client.
    ///
    /// Contract for `Some`: two instances reporting the same `name()` must
    /// be configuration-identical up to the seed — after
    /// [`Compressor::restore_seed`] their future output is bitwise equal.
    /// The error memory is NOT part of the seed (it is drained separately
    /// into the population's residual store).
    ///
    /// Return `None` to opt out: the store then keeps this client's box
    /// resident, exactly like the pre-seed behavior — for working state
    /// that cannot be captured compactly (e.g. [`RandK`]'s reused
    /// partial-Fisher-Yates permutation, whose content is history-
    /// dependent across rounds).
    fn export_seed(&self) -> Option<CompressorSeed> {
        Some(CompressorSeed::Stateless)
    }

    /// Restore state exported by [`Compressor::export_seed`] onto a
    /// configuration-identical instance (the rehydration half of the
    /// pooling contract). Default: no-op (stateless).
    fn restore_seed(&mut self, seed: &CompressorSeed) {
        let _ = seed;
    }
}

/// Banded `Top_{α,β}` via the partition hot path — the paper's production
/// compressor (wraps [`lgc_compress`]).
#[derive(Clone, Debug, Default)]
pub struct LgcTopAB;

impl Compressor for LgcTopAB {
    fn name(&self) -> String {
        "lgc-top-ab".to_string()
    }

    fn compress(
        &mut self,
        u: &[f32],
        budget: &LayerBudget,
        scratch: &mut CompressScratch,
    ) -> LgcUpdate {
        lgc_compress(u, budget.ks(), scratch)
    }
}

/// Banded `Top_{α,β}` via the radix-select variant (documented §Perf
/// iteration; bit-identical output to [`LgcTopAB`]).
#[derive(Clone, Debug, Default)]
pub struct LgcRadix;

impl Compressor for LgcRadix {
    fn name(&self) -> String {
        "lgc-radix".to_string()
    }

    fn compress(
        &mut self,
        u: &[f32],
        budget: &LayerBudget,
        scratch: &mut CompressScratch,
    ) -> LgcUpdate {
        lgc_compress_radix(u, budget.ks(), scratch)
    }
}

/// Identity "compressor": ships the full dense vector as one layer. The
/// FedAvg-style uncompressed reference run, and the worked example of
/// DESIGN.md §"Extension points". Wire accounting is 4 B/coordinate (a raw
/// f32 stream — no index overhead).
#[derive(Clone, Debug, Default)]
pub struct DenseNoop;

impl Compressor for DenseNoop {
    fn name(&self) -> String {
        "dense".to_string()
    }

    fn compress(
        &mut self,
        u: &[f32],
        _budget: &LayerBudget,
        _scratch: &mut CompressScratch,
    ) -> LgcUpdate {
        let layer = Layer {
            indices: (0..u.len() as u32).collect(),
            values: u.to_vec(),
        };
        LgcUpdate { dim: u.len(), layers: vec![layer] }
    }

    fn layer_wire_bytes(&self, layer: &Layer, _dim: usize) -> u64 {
        4 * layer.len() as u64
    }

    fn sparse_wire(&self) -> bool {
        false
    }

    fn respects_budget(&self) -> bool {
        false
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        if self.unbiased { "rand-k(unbiased)".to_string() } else { "rand-k".to_string() }
    }

    fn compress(
        &mut self,
        u: &[f32],
        budget: &LayerBudget,
        _scratch: &mut CompressScratch,
    ) -> LgcUpdate {
        self.sparsify(u, budget.total())
    }

    /// Unbiased mode rescales kept values by D/K.
    fn exact_values(&self) -> bool {
        !self.unbiased
    }

    /// A fresh episode rewinds the mask stream so multi-episode runs are
    /// reproducible against a single-episode run with the same seed.
    fn reset(&mut self) {
        self.reset_stream();
    }

    /// RandK's partial-Fisher-Yates permutation is reused (not rebuilt)
    /// between rounds, so its content is part of the per-client draw
    /// history — no compact seed can capture it without changing the
    /// blessed golden traces. Opt out: the population store keeps RandK
    /// boxes resident per client.
    fn export_seed(&self) -> Option<CompressorSeed> {
        None
    }
}

/// QSGD stochastic quantization adapted to the layered-update interface:
/// the dequantized nonzeros travel as one layer, and wire accounting uses
/// the packed format (norm + `ceil(log2(2s+1))` bits/coordinate over the
/// full dimension) rather than the sparse index+value format.
#[derive(Clone, Debug)]
pub struct Qsgd {
    quantizer: QsgdQuantizer,
}

impl Qsgd {
    pub fn new(quantizer: QsgdQuantizer) -> Self {
        Qsgd { quantizer }
    }

    pub fn levels(&self) -> u8 {
        self.quantizer.levels
    }
}

impl Compressor for Qsgd {
    fn name(&self) -> String {
        format!("qsgd{}", self.quantizer.levels)
    }

    fn compress(
        &mut self,
        u: &[f32],
        _budget: &LayerBudget,
        _scratch: &mut CompressScratch,
    ) -> LgcUpdate {
        let q = self.quantizer.quantize(u);
        let dq = q.dequantize();
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &v) in dq.iter().enumerate() {
            if v != 0.0 {
                indices.push(i as u32);
                values.push(v);
            }
        }
        LgcUpdate { dim: u.len(), layers: vec![Layer { indices, values }] }
    }

    fn layer_wire_bytes(&self, _layer: &Layer, dim: usize) -> u64 {
        let bits = wire_bits(self.quantizer.levels);
        4 + (dim as u64 * bits as u64).div_ceil(8)
    }

    fn sparse_wire(&self) -> bool {
        false
    }

    fn respects_budget(&self) -> bool {
        false
    }

    fn exact_values(&self) -> bool {
        false
    }

    /// A fresh episode rewinds the quantization noise stream (see
    /// [`RandK`]'s reset for the rationale).
    fn reset(&mut self) {
        self.quantizer.reset_stream();
    }

    fn export_seed(&self) -> Option<CompressorSeed> {
        let (cur, base) = self.quantizer.export_streams();
        Some(CompressorSeed::Stream { cur, base })
    }

    fn restore_seed(&mut self, seed: &CompressorSeed) {
        if let CompressorSeed::Stream { cur, base } = seed {
            self.quantizer.restore_streams(cur.clone(), base.clone());
        }
    }
}

/// Composable error-feedback wrapper (Alg. 1 lines 8 & 11): maintains the
/// memory `e`, compresses `e + u`, and absorbs what the inner compressor
/// dropped. Replaces the open-coded error handling that used to live in
/// `Device`.
pub struct ErrorCompensated<C: Compressor> {
    inner: C,
    error: ErrorFeedback,
    u_buf: Vec<f32>,
}

impl<C: Compressor> ErrorCompensated<C> {
    pub fn new(inner: C) -> Self {
        ErrorCompensated { inner, error: ErrorFeedback::new(0), u_buf: Vec::new() }
    }

    pub fn inner(&self) -> &C {
        &self.inner
    }
}

impl<C: Compressor> Compressor for ErrorCompensated<C> {
    fn name(&self) -> String {
        format!("ef({})", self.inner.name())
    }

    fn compress(
        &mut self,
        u: &[f32],
        budget: &LayerBudget,
        scratch: &mut CompressScratch,
    ) -> LgcUpdate {
        if self.error.dim() != u.len() {
            self.error = ErrorFeedback::new(u.len());
        }
        // u' = e + u (line 8)
        self.error.compensate(u, &mut self.u_buf);
        // g = C(u') (line 9)
        let g = self.inner.compress(&self.u_buf, budget, scratch);
        // e' = u' − g (line 11); zeroing-based when values ship verbatim so
        // the telescoping invariant holds bitwise.
        if self.inner.exact_values() {
            self.error.absorb(&self.u_buf, &g);
        } else {
            self.error.absorb_residual(&self.u_buf, &g);
        }
        g
    }

    fn layer_wire_bytes(&self, layer: &Layer, dim: usize) -> u64 {
        self.inner.layer_wire_bytes(layer, dim)
    }

    fn wire_bytes(&self, update: &LgcUpdate) -> u64 {
        self.inner.wire_bytes(update)
    }

    fn sparse_wire(&self) -> bool {
        self.inner.sparse_wire()
    }

    fn respects_budget(&self) -> bool {
        self.inner.respects_budget()
    }

    fn exact_values(&self) -> bool {
        self.inner.exact_values()
    }

    fn error_memory(&self) -> Option<&ErrorFeedback> {
        Some(&self.error)
    }

    fn error_memory_mut(&mut self) -> Option<&mut ErrorFeedback> {
        Some(&mut self.error)
    }

    fn reset(&mut self) {
        self.error.reset();
        self.inner.reset();
    }

    fn trim_working_memory(&mut self) {
        self.u_buf = Vec::new();
        self.inner.trim_working_memory();
    }

    /// The wrapper adds no seed state of its own: the error memory travels
    /// as the population residual, `u_buf` is per-compress scratch.
    fn export_seed(&self) -> Option<CompressorSeed> {
        self.inner.export_seed()
    }

    fn restore_seed(&mut self, seed: &CompressorSeed) {
        self.inner.restore_seed(seed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randu(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn lgc_top_ab_matches_free_function() {
        let u = randu(512, 1);
        let mut s1 = CompressScratch::default();
        let mut s2 = CompressScratch::default();
        let budget = LayerBudget::new(vec![8, 24, 96]);
        let a = LgcTopAB.compress(&u, &budget, &mut s1);
        let b = lgc_compress(&u, &[8, 24, 96], &mut s2);
        assert_eq!(a, b);
    }

    #[test]
    fn radix_and_partition_compressors_agree() {
        let u = randu(1024, 2);
        let mut s1 = CompressScratch::default();
        let mut s2 = CompressScratch::default();
        let budget = LayerBudget::new(vec![10, 40, 150]);
        assert_eq!(
            LgcTopAB.compress(&u, &budget, &mut s1),
            LgcRadix.compress(&u, &budget, &mut s2)
        );
    }

    #[test]
    fn dense_noop_is_identity() {
        let u = randu(128, 3);
        let mut s = CompressScratch::default();
        let g = DenseNoop.compress(&u, &LayerBudget::new(vec![1]), &mut s);
        assert_eq!(g.decode(), u);
        assert_eq!(DenseNoop.wire_bytes(&g), 4 * 128);
        assert!(!DenseNoop.sparse_wire());
    }

    #[test]
    fn error_compensated_telescopes_like_device_loop() {
        // The wrapper must reproduce the exact compensate/absorb sequence.
        let mut ec = ErrorCompensated::new(LgcTopAB);
        let mut ef = ErrorFeedback::new(256);
        let mut s1 = CompressScratch::default();
        let mut s2 = CompressScratch::default();
        let budget = LayerBudget::new(vec![8, 24]);
        let mut u_buf = Vec::new();
        for round in 0..6 {
            let progress = randu(256, 100 + round);
            let a = ec.compress(&progress, &budget, &mut s1);
            // reference: the old open-coded sequence
            ef.compensate(&progress, &mut u_buf);
            let b = lgc_compress(&u_buf, &[8, 24], &mut s2);
            ef.absorb(&u_buf, &b);
            assert_eq!(a, b, "round {round}");
            assert_eq!(ec.error_memory().unwrap().memory(), ef.memory());
        }
    }

    #[test]
    fn error_compensated_with_inexact_inner_conserves_mass() {
        let mut ec = ErrorCompensated::new(Qsgd::new(QsgdQuantizer::new(4, Rng::new(9))));
        let u = randu(64, 7);
        let mut s = CompressScratch::default();
        let g = ec.compress(&u, &LayerBudget::new(vec![64]), &mut s);
        let dec = g.decode();
        let e = ec.error_memory().unwrap().memory();
        for i in 0..64 {
            assert!((e[i] + dec[i] - u[i]).abs() < 1e-5, "residual wrong at {i}");
        }
    }

    #[test]
    fn budget_from_plan_clamps_and_rescales() {
        let plan = AllocationPlan { counts: vec![80, 0, 80] };
        let b = LayerBudget::from_plan(&plan, 100);
        assert_eq!(b.ks().len(), 2); // silent channel dropped
        assert!(b.total() <= 100);
        assert!(b.total() > 0);
        let plan = AllocationPlan { counts: vec![10, 20] };
        let b = LayerBudget::from_plan(&plan, 1000);
        assert_eq!(b.ks(), &[10, 20]);
    }

    #[test]
    fn qsgd_support_subset_and_packed_bytes() {
        let mut q = Qsgd::new(QsgdQuantizer::new(2, Rng::new(4)));
        let mut u = randu(256, 5);
        for i in (0..256).step_by(2) {
            u[i] = 0.0;
        }
        let mut s = CompressScratch::default();
        let g = q.compress(&u, &LayerBudget::new(vec![256]), &mut s);
        let dec = g.decode();
        for i in 0..256 {
            if dec[i] != 0.0 {
                assert!(u[i] != 0.0, "qsgd shipped a zero coordinate {i}");
            }
        }
        // packed: 4-byte norm + 3 bits/coordinate (2s+1 = 5 -> 8 -> 3 bits)
        assert_eq!(q.wire_bytes(&g), 4 + (256 * 3_u64).div_ceil(8));
    }

    #[test]
    fn rand_k_respects_budget_through_trait() {
        let mut rk = RandK::new(Rng::new(11), false);
        let u = randu(300, 12);
        let mut s = CompressScratch::default();
        let g = Compressor::compress(&mut rk, &u, &LayerBudget::new(vec![10, 20]), &mut s);
        assert_eq!(g.total_nnz(), 30);
        assert!(rk.respects_budget());
    }
}
