//! Random-K sparsification baseline (Wangni et al. 2017, cited in the
//! paper's related work): keep K uniformly random coordinates, scaled by
//! D/K so the estimate is unbiased. Contrasts with Top-K/LGC in the
//! ablation benches: unbiased but much higher variance at equal K.

use super::{Layer, LgcUpdate};
use crate::util::Rng;

/// Random-K sparsifier with its own RNG stream.
#[derive(Clone, Debug)]
pub struct RandK {
    rng: Rng,
    /// Snapshot of the RNG at construction, so `reset_stream` restores a
    /// fresh episode to the exact same draw sequence.
    rng0: Rng,
    /// If true, scale kept values by D/K (unbiased); plain masking otherwise.
    pub unbiased: bool,
    perm: Vec<u32>,
}

impl RandK {
    pub fn new(rng: Rng, unbiased: bool) -> Self {
        RandK { rng0: rng.clone(), rng, unbiased, perm: Vec::new() }
    }

    /// Rewind the RNG to its construction state (new episode).
    pub fn reset_stream(&mut self) {
        self.rng = self.rng0.clone();
        self.perm.clear();
    }

    /// Keep `k` uniformly random coordinates of `u` (partial Fisher-Yates,
    /// single layer). The [`crate::compression::Compressor`] impl routes
    /// here with `k = budget.total()`.
    pub fn sparsify(&mut self, u: &[f32], k: usize) -> LgcUpdate {
        let d = u.len();
        let k = k.min(d);
        // Partial Fisher-Yates: first k entries of a fresh permutation.
        if self.perm.len() != d {
            self.perm.clear();
            self.perm.extend(0..d as u32);
        }
        for i in 0..k {
            let j = i + self.rng.index(d - i);
            self.perm.swap(i, j);
        }
        let mut indices: Vec<u32> = self.perm[..k].to_vec();
        indices.sort_unstable();
        let scale = if self.unbiased { d as f32 / k as f32 } else { 1.0 };
        let values: Vec<f32> = indices.iter().map(|&i| u[i as usize] * scale).collect();
        LgcUpdate { dim: d, layers: vec![Layer { indices, values }] }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::norm2;

    #[test]
    fn keeps_exactly_k_random_coordinates() {
        let mut rk = RandK::new(Rng::new(1), false);
        let u: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let a = rk.sparsify(&u, 10);
        let b = rk.sparsify(&u, 10);
        assert_eq!(a.total_nnz(), 10);
        assert_eq!(b.total_nnz(), 10);
        assert_ne!(a, b, "two draws should differ");
        // kept values match u (no scaling)
        for (i, v) in a.layers[0].indices.iter().zip(&a.layers[0].values) {
            assert_eq!(*v, u[*i as usize]);
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let u: Vec<f32> = vec![2.0, -1.0, 0.5, 3.0, -0.25, 1.5, 0.0, -2.5];
        let mut rk = RandK::new(Rng::new(2), true);
        let n = 20_000;
        let mut acc = vec![0f64; u.len()];
        for _ in 0..n {
            let dec = rk.sparsify(&u, 3).decode();
            for (a, &x) in acc.iter_mut().zip(&dec) {
                *a += x as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = a / n as f64;
            assert!(
                (mean - u[i] as f64).abs() < 0.05,
                "coord {i}: {mean} vs {}",
                u[i]
            );
        }
    }

    #[test]
    fn higher_variance_than_topk_at_equal_k() {
        // Residual energy of rand-k (biased mask form) exceeds top-k's.
        let mut rng = Rng::new(3);
        let u: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let mut rk = RandK::new(Rng::new(4), false);
        let mut scratch = super::super::CompressScratch::default();
        let topk = super::super::top_k(&u, 64, &mut scratch).decode();
        let res_top: Vec<f32> = u.iter().zip(&topk).map(|(a, b)| a - b).collect();
        let mut worse = 0;
        for _ in 0..20 {
            let dec = rk.sparsify(&u, 64).decode();
            let res: Vec<f32> = u.iter().zip(&dec).map(|(a, b)| a - b).collect();
            if norm2(&res) > norm2(&res_top) {
                worse += 1;
            }
        }
        assert!(worse >= 19, "rand-k beat top-k {}/20 times", 20 - worse);
    }

    #[test]
    fn k_equals_d_identity_when_biased() {
        let u: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let mut rk = RandK::new(Rng::new(5), false);
        assert_eq!(rk.sparsify(&u, 32).decode(), u);
    }
}
