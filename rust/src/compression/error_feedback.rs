//! Error-feedback memory `e_m` (Alg. 1 lines 8 & 11).
//!
//! The device accumulates everything compression dropped:
//!
//! ```text
//! u^(t)     = e^(t) + (w^(t) − ŵ^(t+1/2))          (line 8)
//! g^(t)     = LGC(u^(t))                            (line 9)
//! e^(t+1)   = u^(t) − g^(t)                         (line 11)
//! ```
//!
//! The telescoping invariant `e^(t+1) + g^(t) == u^(t)` holds exactly in
//! floating point because we compute `e` by zeroing the shipped coordinates
//! of `u` (not by subtraction): gradient mass is never lost or duplicated.

use super::LgcUpdate;

/// Per-device error-feedback state.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    e: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(dim: usize) -> Self {
        ErrorFeedback { e: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.e.len()
    }

    pub fn memory(&self) -> &[f32] {
        &self.e
    }

    /// Squared norm of the memory (Lemma 1 diagnostics).
    pub fn norm2(&self) -> f64 {
        crate::util::norm2(&self.e)
    }

    /// Build the error-compensated update `u = e + progress` in-place into
    /// `u_buf` (line 8). `progress = w^(t) − ŵ^(t+1/2)` is the net local
    /// descent since the last sync.
    pub fn compensate(&self, progress: &[f32], u_buf: &mut Vec<f32>) {
        assert_eq!(progress.len(), self.e.len());
        u_buf.clear();
        u_buf.extend_from_slice(&self.e);
        // u = e + progress via the blocked add — bitwise-identical to the
        // old zipped `e + p` extend.
        crate::kernels::add_assign(u_buf, progress);
    }

    /// Absorb what the compressor dropped (line 11): `e' = u − decode(g)`,
    /// computed exactly by copying `u` and zeroing the shipped coordinates.
    pub fn absorb(&mut self, u: &[f32], shipped: &LgcUpdate) {
        assert_eq!(u.len(), self.e.len());
        assert_eq!(shipped.dim, self.e.len());
        self.e.copy_from_slice(u);
        for layer in &shipped.layers {
            crate::kernels::scatter_zero(&mut self.e, &layer.indices);
        }
    }

    /// General residual `e' = u − decode(g)` by subtraction — for
    /// compressors whose shipped values are *not* the input coordinates
    /// verbatim (quantizers, unbiased rescaling). The zeroing-based
    /// [`ErrorFeedback::absorb`] is exact for top-K-style selection; this is
    /// the fallback that stays correct for everything else.
    pub fn absorb_residual(&mut self, u: &[f32], shipped: &LgcUpdate) {
        assert_eq!(u.len(), self.e.len());
        assert_eq!(shipped.dim, self.e.len());
        self.e.copy_from_slice(u);
        for layer in &shipped.layers {
            crate::kernels::scatter_sub(&mut self.e, &layer.indices, &layer.values);
        }
    }

    /// Move the dense memory out (leaving a dim-0 memory behind) — the
    /// population store drains it into a compact
    /// [`Residual`](crate::population::Residual) when a client is
    /// demobilized. `ErrorCompensated` recreates a zeroed memory on the
    /// next compress if nothing is restored first.
    pub fn take_memory(&mut self) -> Vec<f32> {
        std::mem::take(&mut self.e)
    }

    /// Install a dense memory wholesale (the restore half of
    /// [`ErrorFeedback::take_memory`]).
    pub fn set_memory(&mut self, e: Vec<f32>) {
        self.e = e;
    }

    /// Resize to `dim` (zero-filled) unless already there — lets callers
    /// fold values into a memory that may never have been allocated.
    pub fn ensure_dim(&mut self, dim: usize) {
        if self.e.len() != dim {
            self.e = vec![0.0; dim];
        }
    }

    /// Put a shipped coordinate's mass back into the memory — used when a
    /// shipped layer is lost in transit (the erasure-channel path).
    /// Restitution *adds* the shipped value: after the zeroing-based
    /// [`ErrorFeedback::absorb`] the slot holds 0 (so `0 + v == u_i`
    /// exactly), and after [`ErrorFeedback::absorb_residual`] it holds
    /// `u_i − v` (so `(u_i − v) + v == u_i`) — either way the invariant
    /// `e' + delivered == u` is restored and nothing is destroyed.
    pub fn restitute(&mut self, i: usize, value: f32) {
        self.e[i] += value;
    }

    /// Reset (e.g., FedAvg has no memory).
    pub fn reset(&mut self) {
        crate::kernels::fill(&mut self.e, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    fn randu(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn telescoping_exact() {
        let mut ef = ErrorFeedback::new(256);
        let mut scratch = CompressScratch::default();
        let mut u = Vec::new();
        for round in 0..10 {
            let progress = randu(256, round);
            ef.compensate(&progress, &mut u);
            let g = lgc_compress(&u, &[8, 24], &mut scratch);
            let dec = g.decode();
            ef.absorb(&u, &g);
            // e' + decode(g) == u exactly (bitwise)
            for i in 0..256 {
                assert_eq!(ef.memory()[i] + dec[i], u[i]);
            }
        }
    }

    #[test]
    fn memory_zero_when_no_compression() {
        let mut ef = ErrorFeedback::new(64);
        let mut scratch = CompressScratch::default();
        let mut u = Vec::new();
        let progress = randu(64, 5);
        ef.compensate(&progress, &mut u);
        let g = lgc_compress(&u, &[64], &mut scratch);
        ef.absorb(&u, &g);
        assert_eq!(ef.norm2(), 0.0);
    }

    #[test]
    fn memory_accumulates_dropped_mass() {
        let mut ef = ErrorFeedback::new(128);
        let mut scratch = CompressScratch::default();
        let mut u = Vec::new();
        let progress = vec![1.0f32; 128];
        ef.compensate(&progress, &mut u);
        let g = lgc_compress(&u, &[16], &mut scratch);
        ef.absorb(&u, &g);
        // 112 coordinates of magnitude 1 dropped
        assert_eq!(ef.norm2(), 112.0);
        // next round the dropped coordinates are compensated
        ef.compensate(&vec![0.0; 128], &mut u);
        assert_eq!(u.iter().filter(|&&x| x == 1.0).count(), 112);
    }

    #[test]
    fn reset_clears() {
        let mut ef = ErrorFeedback::new(8);
        let mut u = Vec::new();
        ef.compensate(&vec![1.0; 8], &mut u);
        let g = crate::compression::lgc_compress(&u, &[1], &mut CompressScratch::default());
        ef.absorb(&u, &g);
        assert!(ef.norm2() > 0.0);
        ef.reset();
        assert_eq!(ef.norm2(), 0.0);
    }
}
