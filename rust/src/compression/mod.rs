//! Gradient compression: `Top_k`, banded `Top_{α,β}` (Eq. 1), the layered
//! `LGC_k` encoder/decoder (Eq. 2), error-feedback memory (Alg. 1), a sparse
//! wire format, a QSGD-style quantizer baseline — and the pluggable
//! [`Compressor`] trait ([`compressor`]) the round loop dispatches through,
//! with [`ErrorCompensated`] as the composable error-feedback wrapper.
//!
//! This is the Rust-native hot path used by the round loop (A2 in DESIGN.md
//! benches it against the AOT `lgc_compress` artifact). Selection is a
//! single O(D) `select_nth_unstable` pass over |u| with reusable scratch —
//! no allocation at steady state; the dyn-dispatch seam costs ≤ 2% on the
//! 1M-param CNN shape (EXPERIMENTS.md §Perf).

pub mod compressor;
pub mod error_feedback;
pub mod quantize;
pub mod rand_k;
pub mod wire;

pub use compressor::{
    Compressor, CompressorSeed, DenseNoop, ErrorCompensated, LayerBudget, LgcRadix, LgcTopAB, Qsgd,
};
pub use error_feedback::ErrorFeedback;
pub use rand_k::RandK;
pub use wire::{SparseChunk, WIRE_BYTES_PER_ENTRY};

/// One magnitude-banded layer of a compressed update.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    /// Coordinate indices (ascending).
    pub indices: Vec<u32>,
    /// Values at those coordinates.
    pub values: Vec<f32>,
}

impl Layer {
    pub fn len(&self) -> usize {
        self.indices.len()
    }
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }
    /// Wire size in bytes (delta-encoded index + value per entry).
    pub fn wire_bytes(&self) -> u64 {
        wire::encoded_len(self.len()) as u64
    }
}

/// Layered compressed update: `layers[0]` is the base layer (largest
/// magnitudes), `layers[c]` the c-th enhancement layer.
#[derive(Clone, Debug, PartialEq)]
pub struct LgcUpdate {
    pub dim: usize,
    pub layers: Vec<Layer>,
}

impl LgcUpdate {
    pub fn total_nnz(&self) -> usize {
        self.layers.iter().map(Layer::len).sum()
    }

    /// Dense decode: `LGC_k(u) = Σ_c layer_c` (Eq. 2). Any subset of layers
    /// decodes (graceful degradation when a channel drops).
    pub fn decode(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.dim];
        self.add_into(&mut out, 1.0);
        out
    }

    /// Accumulate `scale * decode(self)` into `out` without allocating —
    /// the streaming-aggregation hot path, on the sparse scatter kernel
    /// (bitwise-identical to the old inline loop).
    pub fn add_into(&self, out: &mut [f32], scale: f32) {
        assert_eq!(out.len(), self.dim);
        for layer in &self.layers {
            crate::kernels::scatter_add(out, &layer.indices, &layer.values, scale);
        }
    }
}

/// Reusable scratch for compression so the round loop never allocates.
#[derive(Default, Clone)]
pub struct CompressScratch {
    /// Packed sort keys: `(|u_i| bit pattern) << 32 | i`.
    keys: Vec<u64>,
    /// Top-byte histogram for the radix-select fast path.
    hist: Vec<u32>,
    /// Gathered boundary-bucket keys (one vec per distinct boundary bucket).
    buckets: Vec<(u8, Vec<u64>)>,
}

/// Pack `(magnitude, index)` into one u64 key. For non-NaN f32, the ordering
/// of `bits & 0x7FFF_FFFF` equals the ordering of `|x|`, so comparing keys
/// compares magnitudes first and breaks ties by coordinate index — a single
/// primitive `u64` comparison instead of two indirect float loads — the
/// §Perf optimization that halved `lgc_compress` time vs the indirect
/// `total_cmp` version (see EXPERIMENTS.md §Perf iteration log).
#[inline]
fn pack_key(x: f32, i: usize) -> u64 {
    (((x.to_bits() & 0x7FFF_FFFF) as u64) << 32) | i as u64
}

#[inline]
fn key_index(k: u64) -> usize {
    (k & 0xFFFF_FFFF) as usize
}

/// Radix-select variant of [`lgc_compress`] — kept as a documented §Perf
/// iteration (measured ~2x slower than the partition path on gradient-like
/// data because float exponent buckets are massively non-uniform; see
/// EXPERIMENTS.md §Perf). Because every packed key is unique
/// (index in the low bits), band membership is a total order with no ties:
///
/// 1. one pass histograms the top magnitude byte (`bits >> 23`),
/// 2. each cumulative boundary `K_c` resolves to a bucket; one gather pass
///    collects only the boundary buckets' keys (≈ D/256 each), which are
///    sorted to read off the *exact* K_c-th largest key as the threshold,
/// 3. one final pass assigns every element to its band by comparing its key
///    against the C thresholds — emitting indices already in ascending
///    order, so no per-band sort is needed.
///
/// Three linear passes + tiny sorts ≈ memory-bound; see EXPERIMENTS.md
/// §Perf for the measured before/after vs the partition-based variant.
pub fn lgc_compress_radix(u: &[f32], ks: &[usize], scratch: &mut CompressScratch) -> LgcUpdate {
    let d = u.len();
    let ktot: usize = ks.iter().sum();
    assert!(ktot <= d, "sum(ks)={ktot} > D={d}");
    assert!(!ks.is_empty());
    if ktot == 0 {
        return LgcUpdate {
            dim: d,
            layers: ks.iter().map(|_| Layer { indices: vec![], values: vec![] }).collect(),
        };
    }

    // Pass 1: histogram of the top magnitude byte.
    scratch.hist.clear();
    scratch.hist.resize(256, 0);
    for &x in u {
        scratch.hist[((x.to_bits() & 0x7FFF_FFFF) >> 23) as usize] += 1;
    }
    // above[b] = #elements in buckets strictly greater than b.
    let mut above = [0u64; 256];
    let mut acc = 0u64;
    for b in (0..256).rev() {
        above[b] = acc;
        acc += scratch.hist[b] as u64;
    }

    // Locate each cumulative boundary K_c's bucket and within-bucket rank.
    // rank == 0 marks a degenerate K_c == 0 boundary (empty leading band).
    let mut cum = 0usize;
    let mut boundaries: Vec<(u8, usize)> = Vec::with_capacity(ks.len()); // (bucket, rank)
    for &k in ks {
        cum += k;
        let kc = cum as u64;
        if kc == 0 {
            boundaries.push((0, 0));
            continue;
        }
        let mut b = 255usize;
        loop {
            if above[b] < kc && kc <= above[b] + scratch.hist[b] as u64 {
                break;
            }
            debug_assert!(b > 0, "boundary bucket not found for K={kc}");
            b -= 1;
        }
        boundaries.push((b as u8, (kc - above[b]) as usize));
    }

    // Pass 2: gather keys of the distinct boundary buckets, sort descending.
    for (_, v) in scratch.buckets.iter_mut() {
        v.clear();
    }
    let mut distinct: Vec<u8> = boundaries
        .iter()
        .filter(|&&(_, rank)| rank > 0)
        .map(|&(b, _)| b)
        .collect();
    distinct.sort_unstable();
    distinct.dedup();
    // Keep scratch.buckets aligned with the distinct set (reuse allocations).
    while scratch.buckets.len() < distinct.len() {
        scratch.buckets.push((0, Vec::new()));
    }
    for (slot, &b) in distinct.iter().enumerate() {
        scratch.buckets[slot].0 = b;
    }
    let nslots = distinct.len();
    // Single gather pass: small linear scan over <=C slots per element whose
    // top byte matches a boundary bucket.
    for (i, &x) in u.iter().enumerate() {
        let bits = x.to_bits() & 0x7FFF_FFFF;
        let tb = (bits >> 23) as u8;
        for slot in 0..nslots {
            if scratch.buckets[slot].0 == tb {
                scratch.buckets[slot].1.push(((bits as u64) << 32) | i as u64);
                break;
            }
        }
    }
    // Exact per-boundary threshold keys (the K_c-th largest key overall).
    // Float exponent buckets are highly non-uniform (half of all
    // normal-magnitude values share one exponent), so a boundary bucket can
    // hold a large fraction of D — never sort it; `select_nth_unstable` each
    // needed rank, processing ranks largest-first on a shrinking prefix so a
    // bucket shared by several boundaries costs one partition per boundary.
    let mut thr: Vec<u64> = vec![u64::MAX; ks.len()]; // MAX = degenerate K_c == 0
    for (slot, &b) in distinct.iter().enumerate() {
        let mut ranks: Vec<(usize, usize)> = boundaries
            .iter()
            .enumerate()
            .filter(|(_, &(bb, rank))| bb == b && rank > 0)
            .map(|(bi, &(_, rank))| (bi, rank))
            .collect();
        ranks.sort_unstable_by(|a, b| b.1.cmp(&a.1)); // largest rank first
        let keys = &mut scratch.buckets[slot].1;
        let mut hi = keys.len();
        let mut prev_rank = usize::MAX;
        let mut prev_thr = u64::MAX;
        for (bi, rank) in ranks {
            if rank == prev_rank {
                thr[bi] = prev_thr; // duplicate cumulative boundary
                continue;
            }
            let slice = &mut keys[..hi];
            slice.select_nth_unstable_by(rank - 1, |a, b| b.cmp(a));
            thr[bi] = slice[rank - 1];
            prev_rank = rank;
            prev_thr = thr[bi];
            // The next (strictly smaller) rank lies within the top rank-1
            // prefix left by the partition; rank == 1 has no smaller rank.
            hi = (rank - 1).max(1);
        }
    }

    // Pass 3: band assignment. Keys are unique, so `key >= thr[c]` <=>
    // rank(key) <= K_c; the first matching band wins. Scan order emits
    // ascending indices for free.
    let mut layers: Vec<Layer> = ks
        .iter()
        .map(|&k| Layer {
            indices: Vec::with_capacity(k),
            values: Vec::with_capacity(k),
        })
        .collect();
    let nb = thr.len();
    for (i, &x) in u.iter().enumerate() {
        let key = pack_key(x, i);
        if key < thr[nb - 1] {
            continue; // dropped coordinate (the common case)
        }
        for c in 0..nb {
            if key >= thr[c] {
                layers[c].indices.push(i as u32);
                layers[c].values.push(x);
                break;
            }
        }
    }
    debug_assert_eq!(layers.iter().map(Layer::len).sum::<usize>(), ktot);
    LgcUpdate { dim: d, layers }
}

/// Compress `u` into `C = ks.len()` magnitude-banded layers (Eq. 2) — the
/// production hot path: one `select_nth_unstable` partition over packed
/// `u64` keys per band boundary, O(D + Σ K_c log k_c), zero steady-state
/// allocation beyond the output layers. Cross-checked against
/// [`lgc_compress_radix`] (an independent implementation) in tests.
pub fn lgc_compress(u: &[f32], ks: &[usize], scratch: &mut CompressScratch) -> LgcUpdate {
    let d = u.len();
    let ktot: usize = ks.iter().sum();
    assert!(ktot <= d, "sum(ks)={ktot} > D={d}");
    assert!(!ks.is_empty());

    scratch.keys.clear();
    scratch.keys.reserve(d);
    for (i, &x) in u.iter().enumerate() {
        scratch.keys.push(pack_key(x, i));
    }

    // Partition so the first ktot keys are the top-K by magnitude
    // (descending => compare reversed).
    if ktot < d {
        scratch.keys.select_nth_unstable_by(ktot, |a, b| b.cmp(a));
    }
    let top = &mut scratch.keys[..ktot];

    // Carve the top-K region into bands at each cumulative boundary.
    let mut layers = Vec::with_capacity(ks.len());
    let mut start = 0usize;
    let mut acc = 0usize;
    for (c, &k) in ks.iter().enumerate() {
        acc += k;
        if k > 0 && acc < ktot && c + 1 < ks.len() {
            top[start..].select_nth_unstable_by(k, |a, b| b.cmp(a));
        }
        let band = &mut top[start..acc];
        // Ascending index order == ascending low-32-bits; a band never holds
        // duplicate indices, and index order is what the wire format wants.
        let mut indices: Vec<u32> = band.iter().map(|&k| key_index(k) as u32).collect();
        indices.sort_unstable();
        let values: Vec<f32> = indices.iter().map(|&i| u[i as usize]).collect();
        layers.push(Layer { indices, values });
        start = acc;
    }
    LgcUpdate { dim: d, layers }
}

/// Plain dense `Top_k` (single layer). Used by the Top-k baseline (A1).
pub fn top_k(u: &[f32], k: usize, scratch: &mut CompressScratch) -> LgcUpdate {
    lgc_compress(u, &[k.min(u.len())], scratch)
}

/// Banded `Top_{α,β}` by explicit thresholds (Eq. 1): keep
/// `thr_hi >= |x| > thr_lo`. Mirrors the L1 Pallas kernel semantics exactly;
/// used to cross-check the artifact path.
pub fn band_by_threshold(u: &[f32], thr_hi: f32, thr_lo: f32) -> Layer {
    let mut indices = Vec::new();
    let mut values = Vec::new();
    for (i, &x) in u.iter().enumerate() {
        let a = x.abs();
        if a <= thr_hi && a > thr_lo {
            indices.push(i as u32);
            values.push(x);
        }
    }
    Layer { indices, values }
}

/// Compression contraction factor `γ = K/D` for the constants of Theorem 1:
/// `E‖u − C(u)‖² ≤ (1 − γ)‖u‖²` for Top-K-type compressors.
pub fn gamma(ks: &[usize], d: usize) -> f64 {
    (ks.iter().sum::<usize>() as f64 / d as f64).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{norm2, Rng};

    fn randu(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn decode_recovers_topk_support() {
        let u = randu(512, 1);
        let mut s = CompressScratch::default();
        let upd = lgc_compress(&u, &[8, 24, 96], &mut s);
        assert_eq!(upd.total_nnz(), 128);
        let dec = upd.decode();
        // Each nonzero of dec equals u there; count matches.
        let nnz = dec.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nnz, 128);
        for (i, &x) in dec.iter().enumerate() {
            if x != 0.0 {
                assert_eq!(x, u[i]);
            }
        }
        // The kept coordinates are exactly the 128 largest by |.|
        let mut mags: Vec<(usize, f32)> = u.iter().cloned().enumerate().map(|(i, x)| (i, x.abs())).collect();
        mags.sort_by(|a, b| b.1.total_cmp(&a.1));
        for (i, _) in mags[..128].iter() {
            assert_ne!(dec[*i], 0.0, "coordinate {i} should be kept");
        }
    }

    #[test]
    fn layers_are_disjoint_and_ordered() {
        let u = randu(2048, 2);
        let mut s = CompressScratch::default();
        let upd = lgc_compress(&u, &[20, 80, 300], &mut s);
        let mut seen = std::collections::HashSet::new();
        for layer in &upd.layers {
            for &i in &layer.indices {
                assert!(seen.insert(i), "index {i} appears in two layers");
            }
        }
        // min |value| of layer c >= max |value| of layer c+1
        for c in 0..upd.layers.len() - 1 {
            let lo_c = upd.layers[c].values.iter().map(|v| v.abs()).fold(f32::INFINITY, f32::min);
            let hi_n = upd.layers[c + 1].values.iter().map(|v| v.abs()).fold(0.0, f32::max);
            assert!(lo_c >= hi_n, "band ordering violated at layer {c}");
        }
    }

    #[test]
    fn k_equals_d_is_identity() {
        let u = randu(100, 3);
        let mut s = CompressScratch::default();
        let upd = lgc_compress(&u, &[40, 60], &mut s);
        assert_eq!(upd.decode(), u);
    }

    #[test]
    fn contraction_property() {
        // ‖u − LGC_k(u)‖² ≤ (1 − K/D)‖u‖² — Top-K is the best K-sparse
        // approximation, so this holds deterministically in expectation form.
        for seed in 0..5 {
            let u = randu(1000, seed);
            let mut s = CompressScratch::default();
            let ks = [10, 40, 150];
            let upd = lgc_compress(&u, &ks, &mut s);
            let dec = upd.decode();
            let res: Vec<f32> = u.iter().zip(&dec).map(|(a, b)| a - b).collect();
            let g = gamma(&ks, 1000);
            assert!(norm2(&res) <= (1.0 - g) * norm2(&u) + 1e-6);
        }
    }

    #[test]
    fn single_layer_equals_topk() {
        let u = randu(256, 7);
        let mut s = CompressScratch::default();
        let a = lgc_compress(&u, &[32], &mut s);
        let b = top_k(&u, 32, &mut s);
        assert_eq!(a, b);
    }

    #[test]
    fn band_by_threshold_matches_kernel_semantics() {
        let u = [0.1f32, -0.5, 2.0, -3.0, 0.9];
        let layer = band_by_threshold(&u, 2.0, 0.5);
        assert_eq!(layer.indices, vec![2, 4]);
        assert_eq!(layer.values, vec![2.0, 0.9]);
    }

    #[test]
    fn decode_partial_layers_degrades_gracefully() {
        let u = randu(512, 9);
        let mut s = CompressScratch::default();
        let mut upd = lgc_compress(&u, &[16, 64], &mut s);
        let full = upd.decode();
        upd.layers.pop(); // drop the enhancement layer (channel failure)
        let base = upd.decode();
        // base-only is still the best-16 approximation: closer to u than zero
        assert!(norm2(&base.iter().zip(&u).map(|(a, b)| a - b).collect::<Vec<_>>())
            >= norm2(&full.iter().zip(&u).map(|(a, b)| a - b).collect::<Vec<_>>()));
        assert!(norm2(&base) > 0.0);
    }

    #[test]
    fn indices_sorted_ascending() {
        let u = randu(300, 11);
        let mut s = CompressScratch::default();
        let upd = lgc_compress(&u, &[10, 30], &mut s);
        for layer in &upd.layers {
            assert!(layer.indices.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    #[should_panic(expected = "sum(ks)")]
    fn rejects_oversized_budget() {
        let u = randu(10, 0);
        let mut s = CompressScratch::default();
        lgc_compress(&u, &[11], &mut s);
    }

    #[test]
    fn ties_are_stable_total_count() {
        // All-equal magnitudes: still returns exactly K entries.
        let u = vec![1.0f32; 64];
        let mut s = CompressScratch::default();
        let upd = lgc_compress(&u, &[5, 10], &mut s);
        assert_eq!(upd.total_nnz(), 15);
    }

    #[test]
    fn radix_and_partition_paths_agree_exactly() {
        // The radix fast path and the select_nth partition oracle must emit
        // identical layers (keys are unique, so there is one right answer).
        let mut s1 = CompressScratch::default();
        let mut s2 = CompressScratch::default();
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let d = 64 + rng.index(4000);
            let u: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let ks = [
                1 + rng.index(d / 8),
                rng.index(d / 8),
                1 + rng.index(d / 8),
            ];
            let a = lgc_compress(&u, &ks, &mut s1);
            let b = lgc_compress_radix(&u, &ks, &mut s2);
            assert_eq!(a, b, "seed {seed} d {d} ks {ks:?}");
        }
    }

    #[test]
    fn radix_handles_duplicates_zeros_and_extremes() {
        let mut s = CompressScratch::default();
        // duplicates + zeros
        let u = [0.0f32, 1.0, -1.0, 1.0, 0.0, 2.0, -2.0, 2.0];
        let upd = lgc_compress(&u, &[2, 3], &mut s);
        assert_eq!(upd.total_nnz(), 5);
        let mut s2 = CompressScratch::default();
        assert_eq!(upd, lgc_compress_radix(&u, &[2, 3], &mut s2));
        // subnormals and huge values
        let u = [f32::MIN_POSITIVE / 2.0, 1e38, -1e-38, 3.0];
        let upd = lgc_compress(&u, &[1, 2], &mut s);
        assert_eq!(upd.layers[0].indices, vec![1]);
        assert_eq!(upd.total_nnz(), 3);
        // leading zero-width band
        let upd = lgc_compress(&u, &[0, 2], &mut s);
        assert!(upd.layers[0].is_empty());
        assert_eq!(upd.layers[1].len(), 2);
    }
}
