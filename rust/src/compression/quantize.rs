//! QSGD-style stochastic quantizer (Alistarh et al. 2017) — a *quantization*
//! baseline next to the paper's sparsification lineage. Used by ablation
//! benches to place LGC on the quantize-vs-sparsify tradeoff curve.
//!
//! `QsgdQuantizer { levels }` maps each coordinate to
//! `‖u‖₂ · sign(u_i) · ξ_i(u, s)` where `ξ_i` is one of `s` levels chosen
//! stochastically so the estimate is unbiased.

use crate::util::Rng;

/// Bits per coordinate of the packed wire format for `levels` positive
/// levels: `ceil(log2(2s+1))`, at least 1.
pub fn wire_bits(levels: u8) -> u32 {
    (2 * levels as u32 + 1).next_power_of_two().trailing_zeros().max(1)
}

/// Quantized vector: norm + per-coordinate (sign, level) pairs.
#[derive(Clone, Debug)]
pub struct QuantizedVec {
    pub norm: f32,
    pub levels: u8,
    /// Per-coordinate signed level in [-levels, levels].
    pub q: Vec<i8>,
}

impl QuantizedVec {
    /// Wire bytes: norm + ceil(log2(2s+1)) bits/coord, byte-packed here.
    pub fn wire_bytes(&self) -> u64 {
        let bits = wire_bits(self.levels);
        4 + (self.q.len() as u64 * bits as u64).div_ceil(8)
    }

    pub fn dequantize(&self) -> Vec<f32> {
        let s = self.levels as f32;
        self.q
            .iter()
            .map(|&qi| self.norm * (qi as f32) / s)
            .collect()
    }
}

/// Stochastic uniform quantizer with `levels` positive levels.
#[derive(Clone, Debug)]
pub struct QsgdQuantizer {
    pub levels: u8,
    rng: Rng,
    /// Snapshot of the RNG at construction (see [`QsgdQuantizer::reset_stream`]).
    rng0: Rng,
}

impl QsgdQuantizer {
    pub fn new(levels: u8, rng: Rng) -> Self {
        // Levels are stored as signed per-coordinate i8s in QuantizedVec;
        // beyond 127 the cast would silently saturate and bias the estimate.
        assert!((1..=127).contains(&levels), "levels must be in [1, 127], got {levels}");
        QsgdQuantizer { levels, rng0: rng.clone(), rng }
    }

    /// Rewind the RNG to its construction state (new episode).
    pub fn reset_stream(&mut self) {
        self.rng = self.rng0.clone();
    }

    /// Snapshot both RNG streams `(current, base)` so a demobilized client's
    /// quantizer can be rebuilt from a compact seed (see `CompressorSeed`).
    pub(crate) fn export_streams(&self) -> (Rng, Rng) {
        (self.rng.clone(), self.rng0.clone())
    }

    /// Restore both RNG streams from a seed snapshot.
    pub(crate) fn restore_streams(&mut self, cur: Rng, base: Rng) {
        self.rng = cur;
        self.rng0 = base;
    }

    pub fn quantize(&mut self, u: &[f32]) -> QuantizedVec {
        let norm = (crate::util::norm2(u) as f32).sqrt();
        let s = self.levels as f32;
        let q = u
            .iter()
            .map(|&x| {
                if norm == 0.0 {
                    return 0i8;
                }
                let a = x.abs() / norm * s; // in [0, s]
                let lo = a.floor();
                let p = a - lo; // probability of rounding up
                let level = lo + if (self.rng.uniform() as f32) < p { 1.0 } else { 0.0 };
                (level as i8) * x.signum() as i8
            })
            .collect();
        QuantizedVec { norm, levels: self.levels, q }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unbiased_in_expectation() {
        let u: Vec<f32> = vec![0.5, -0.25, 0.1, -0.05, 0.0];
        let mut qz = QsgdQuantizer::new(4, Rng::new(1));
        let n = 4000;
        let mut acc = vec![0f64; u.len()];
        for _ in 0..n {
            let dq = qz.quantize(&u).dequantize();
            for (a, &x) in acc.iter_mut().zip(&dq) {
                *a += x as f64;
            }
        }
        for (i, a) in acc.iter().enumerate() {
            let mean = a / n as f64;
            assert!(
                (mean - u[i] as f64).abs() < 0.01,
                "coord {i}: mean {mean} vs {}",
                u[i]
            );
        }
    }

    #[test]
    fn zero_vector_quantizes_to_zero() {
        let mut qz = QsgdQuantizer::new(4, Rng::new(2));
        let q = qz.quantize(&[0.0; 16]);
        assert!(q.dequantize().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn wire_bytes_smaller_than_dense() {
        let u = vec![0.1f32; 1000];
        let mut qz = QsgdQuantizer::new(4, Rng::new(3));
        let q = qz.quantize(&u);
        assert!(q.wire_bytes() < 4 * 1000, "{}", q.wire_bytes());
    }

    #[test]
    fn levels_bounded() {
        let mut rng = Rng::new(4);
        let u: Vec<f32> = (0..512).map(|_| rng.normal() as f32).collect();
        let mut qz = QsgdQuantizer::new(2, Rng::new(5));
        let q = qz.quantize(&u);
        assert!(q.q.iter().all(|&l| l.abs() <= 2));
    }
}
