//! Config system: TOML-subset files + `--key=value` CLI overrides -> typed
//! [`ExperimentConfig`]. This is the launcher's single source of truth; every
//! example and bench builds its runs from one of these.

pub mod toml;

use std::path::Path;

pub use toml::{Document, Value};

use crate::channels::ChannelType;
use crate::downlink::DownlinkCompression;
use crate::edge::{BackhaulDynamics, EdgeSettings};
use crate::population::SamplerKind;
use crate::scenario::{ScenarioRegistry, ScenarioSpec};
use crate::sim::SyncMode;

/// Which FL mechanism to run — a *name* that the coordinator's mechanism
/// registry resolves to a preset of (compressor, aggregator, policy). The
/// enum carries the built-in names plus [`Mechanism::Custom`] for presets
/// registered at runtime; nothing in the round loop branches on it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mechanism {
    /// FedAvg (McMahan et al. 2017): fixed H, full dense model upload on the
    /// single fastest channel.
    FedAvg,
    /// LGC with fixed local computation and fixed layer allocation.
    LgcStatic,
    /// LGC with the per-device DDPG controller choosing (H_m, D_{m,n}).
    LgcDrl,
    /// Single-channel Top-k with error feedback (ablation A1).
    TopK,
    /// Single-channel random-K with error feedback (Wangni et al. 2017).
    RandK,
    /// QSGD stochastic quantization with error feedback (Alistarh et al.).
    Qsgd,
    /// A runtime-registered mechanism preset, addressed by its registry key.
    Custom(&'static str),
}

impl Mechanism {
    /// Parse a mechanism name. Built-in aliases resolve (case-insensitively)
    /// to their enum variant; any other name becomes [`Mechanism::Custom`]
    /// with its original spelling preserved, validated against the registry
    /// when the experiment is built (so config files can name presets
    /// registered by downstream code).
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fedavg" => Mechanism::FedAvg,
            "lgc-static" | "lgc_static" | "lgcstatic" | "lgc-nodrl" => Mechanism::LgcStatic,
            "lgc" | "lgc-drl" | "lgc_drl" => Mechanism::LgcDrl,
            "topk" | "top-k" => Mechanism::TopK,
            "randk" | "rand-k" | "rand_k" => Mechanism::RandK,
            "qsgd" => Mechanism::Qsgd,
            _ => Mechanism::custom(s),
        })
    }

    /// A custom mechanism by registry key. Keys are interned in a
    /// process-wide table (so `Mechanism` stays `Copy` and repeated parses
    /// of the same name don't grow memory).
    pub fn custom(key: &str) -> Self {
        use std::collections::BTreeSet;
        use std::sync::{Mutex, OnceLock};
        static INTERNED: OnceLock<Mutex<BTreeSet<&'static str>>> = OnceLock::new();
        let table = INTERNED.get_or_init(|| Mutex::new(BTreeSet::new()));
        let mut table = table.lock().expect("mechanism intern table poisoned");
        if let Some(&existing) = table.get(key) {
            return Mechanism::Custom(existing);
        }
        let leaked: &'static str = Box::leak(key.to_string().into_boxed_str());
        table.insert(leaked);
        Mechanism::Custom(leaked)
    }

    /// The registry key / display name.
    pub fn name(&self) -> &'static str {
        match *self {
            Mechanism::FedAvg => "fedavg",
            Mechanism::LgcStatic => "lgc-static",
            Mechanism::LgcDrl => "lgc-drl",
            Mechanism::TopK => "topk",
            Mechanism::RandK => "rand-k",
            Mechanism::Qsgd => "qsgd",
            Mechanism::Custom(key) => key,
        }
    }
}

/// Which model/dataset workload (paper Sec. 4.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Workload {
    /// Logistic regression on MNIST-class data.
    LrMnist,
    /// CNN on MNIST-class data.
    CnnMnist,
    /// Char-GRU on Shakespeare.
    RnnShakespeare,
}

impl Workload {
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "lr" | "lr-mnist" => Ok(Workload::LrMnist),
            "cnn" | "cnn-mnist" => Ok(Workload::CnnMnist),
            "rnn" | "rnn-shakespeare" | "shakespeare" => Ok(Workload::RnnShakespeare),
            other => Err(format!("unknown workload `{other}`")),
        }
    }

    /// The model name used in artifact file names.
    pub fn model_name(&self) -> &'static str {
        match self {
            Workload::LrMnist => "lr",
            Workload::CnnMnist => "cnn",
            Workload::RnnShakespeare => "rnn",
        }
    }
}

/// Full experiment configuration with paper-default values.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub mechanism: Mechanism,
    pub workload: Workload,
    /// Number of devices M (paper default 3).
    pub devices: usize,
    /// Communication rounds T.
    pub rounds: usize,
    /// Learning rate (paper: 0.01).
    pub lr: f32,
    /// Mini-batch size b (paper: 64).
    pub batch: usize,
    /// Max local steps H (Alg. 1 gap bound).
    pub h_max: usize,
    /// Default/fixed local steps for non-DRL mechanisms.
    pub h_fixed: usize,
    /// Per-layer coordinate budgets as fractions of D (static LGC).
    pub layer_fracs: Vec<f64>,
    /// Channel types available at each device, fastest-first.
    pub channel_types: Vec<ChannelType>,
    /// Per-device energy budget in joules (Eq. 10a); f64::INFINITY = none.
    pub energy_budget: f64,
    /// Per-device money budget in currency units; f64::INFINITY = none.
    pub money_budget: f64,
    /// Non-IID Dirichlet alpha for partitioning (inf => IID).
    pub dirichlet_alpha: f64,
    /// Training examples per device.
    pub samples_per_device: usize,
    /// Held-out eval examples.
    pub eval_samples: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Evaluate every `eval_every` rounds.
    pub eval_every: usize,
    /// Use the PJRT runtime (false => pure-Rust LR path, tests only).
    pub use_runtime: bool,
    /// Directory with AOT artifacts.
    pub artifacts_dir: String,
    /// Server synchronization discipline for the event engine. `None` defers
    /// to the mechanism preset's default (and ultimately `Barrier`). TOML:
    /// `sync_mode = "barrier" | "semi-async" | "fully-async"` with
    /// parameters `buffer_k` / `staleness_decay`.
    pub sync_mode: Option<SyncMode>,
    /// Standalone `buffer_k` override: applies to whichever semi-async mode
    /// ends up resolved (explicit `sync_mode` or a preset default like
    /// `lgc-semi-async`), so `--buffer_k=4` works without restating the
    /// mode.
    pub buffer_k: Option<usize>,
    /// Standalone `staleness_decay` override (see `buffer_k`).
    pub staleness_decay: Option<f64>,
    /// Worker threads for device local compute (barrier mode): 1 =
    /// sequential, 0 = one per available core, n = n. Thread count never
    /// changes results (per-device forked RNG streams).
    pub compute_threads: usize,
    /// Event-queue shards and population-sweep worker threads: 0 = one per
    /// available core (auto), n = n. Device events hash to `client %
    /// (shards − 1)` with control-plane events on a dedicated shard; the
    /// merge key `(time, shard, seq)` keeps pop order identical to a single
    /// heap, so the shard count never changes results.
    pub shards: usize,
    /// Virtual period of channel-fading transitions in the async sync modes
    /// (barrier mode keeps the one-transition-per-round semantics).
    pub fading_tick_s: f64,
    /// Total client population (population mode). Demobilized clients are
    /// cheap per-client columns of the struct-of-arrays
    /// [`crate::population::Population`] store, mapped onto the trainer's
    /// `devices` data shards (`id % devices`); a full `Device` is
    /// materialized only while a client sits in the round's cohort. `None`
    /// (default) keeps the legacy fully-materialized path with `devices`
    /// permanent devices. Setting any of `population` / `cohort` / `sampler`
    /// switches the experiment into population mode.
    pub population: Option<usize>,
    /// Clients sampled per round (population mode). Default: the whole
    /// population (full participation).
    pub cohort: Option<usize>,
    /// Cohort selection rule. Default: `uniform-k` when `cohort <
    /// population`, else `full` (bit-for-bit the legacy loop). TOML:
    /// `sampler = "full" | "uniform-k" | "weighted-by-samples" |
    /// "availability-markov"`.
    pub sampler: Option<SamplerKind>,
    /// Per-round/tick probability an online client churns offline (also the
    /// mid-upload dropout rate). 0 disables churn.
    pub churn_down: f64,
    /// Per-round/tick probability an offline client comes back online.
    pub churn_up: f64,
    /// Simulate the downlink (layered model broadcast over fading channels
    /// with delta compression, staleness tracking, and download
    /// energy/money charging). `None` defers to the mechanism preset's
    /// default (e.g. `lgc-downlink` enables it) and ultimately to
    /// disabled — the free-instant-broadcast legacy semantics, bit-for-bit
    /// equal to the frozen `step_round` oracle.
    pub downlink: Option<bool>,
    /// How the server compresses each device's model delta for broadcast:
    /// `dense` (exact) or `layered` (LGC base + enhancement layers).
    /// `None` defers to the preset default, then `dense`. Setting this key
    /// switches the downlink on (unless `downlink = false` says
    /// otherwise), mirroring how the population keys enable population
    /// mode.
    pub downlink_compression: Option<DownlinkCompression>,
    /// Money-tariff multiplier for downlink traffic relative to the uplink
    /// tariff table (operators price downlink data differently; energy is
    /// charged unscaled — the radio's receive chain draws what it draws).
    pub downlink_tariff_scale: f64,
    /// Network scenario: trace-driven channel dynamics, zone mobility &
    /// handoff, and the scripted phase timeline. Resolved from (exactly one
    /// of) the `scenario = "preset"` key, `scenario_file = "world.toml"`,
    /// or an inline `[scenario]` tree; `scenario = "none"` forces it off.
    /// `None` (default) is the static single-world oracle — every engine
    /// stays bit-for-bit on the frozen `step_round` reference.
    pub scenario: Option<ScenarioSpec>,
    /// NOMA shared-uplink mode (arXiv 2003.01344): co-zone devices contend
    /// for one carrier per technology — each link's bandwidth is divided by
    /// the zone's current population. `None` defers to the mechanism
    /// preset's default (`lgc-noma` enables it), then to the scenario
    /// spec's own `noma` key, and ultimately to off — the independent-links
    /// model, bit-for-bit equal to the frozen `step_round` oracle. Enabling
    /// it with no scenario configured synthesizes a single shared-cell
    /// world.
    pub noma: Option<bool>,
    /// Hierarchical edge aggregation: one edge node per scenario zone
    /// terminates device uplinks locally and streams partial-aggregate
    /// frames to the cloud over its own backhaul link (`[edge]` tree).
    /// `None` defers to the mechanism preset's default (`lgc-edge` enables
    /// it) and ultimately to disabled — the flat single-server topology,
    /// bit-for-bit equal to the frozen `step_round` oracle. Setting any
    /// `[edge]` parameter key switches the tier on (unless `edge = false`),
    /// mirroring how the population/downlink keys enable their seams.
    pub edge: Option<bool>,
    /// `[edge]` parameters: `backhaul` (channel technology), `bw_scale`,
    /// `flush_k`, `cache_downlink`, `dynamics`. `None` = no `[edge]` key
    /// was set (defaults apply if a preset enables the tier).
    pub edge_settings: Option<EdgeSettings>,
    /// Server-side streaming aggregation: fold each upload into the running
    /// aggregate on arrival (O(model) server state) instead of buffering
    /// every decoded update until aggregation. Applies to the population
    /// cohort engines and the semi-/fully-async modes; results match batch
    /// aggregation to the documented float tolerance. Default false (the
    /// batch path is the bit-for-bit reference).
    pub streaming: bool,
    /// Structured event tracing ([`crate::obs::Recorder`]): record the
    /// full per-event lifecycle (compute, per-layer uplink, downlink,
    /// edge/backhaul, handoff, churn, aggregation) as JSONL in virtual sim
    /// time. Default off — and then strictly zero-cost: every engine stays
    /// bit-for-bit on the frozen `step_round` oracle with an unchanged
    /// warm-round allocation count.
    pub trace: bool,
    /// Trace destination path. Setting this key implies `trace = true`;
    /// bare `trace = true` defaults to `trace.jsonl`.
    pub trace_file: Option<String>,
    /// Wall-clock phase timers (event-loop / train / compress /
    /// aggregate), reported as `profile/<phase>_ms` lines and
    /// bench-compatible JSON rows. Independent of `trace` and never part
    /// of the deterministic JSONL stream.
    pub profile: bool,
    /// DRL hyperparameters.
    pub drl: DrlConfig,
}

/// DDPG hyperparameters (Sec. 3.3; Lillicrap et al. 2015 defaults scaled
/// down to the simulator's episode length).
#[derive(Clone, Debug)]
pub struct DrlConfig {
    pub actor_lr: f64,
    pub critic_lr: f64,
    pub gamma: f64,
    pub tau: f64,
    pub replay_capacity: usize,
    pub batch: usize,
    pub hidden: usize,
    pub noise_sigma: f64,
    pub noise_theta: f64,
    /// Steps of pure exploration before the actor drives.
    pub warmup: usize,
}

impl Default for DrlConfig {
    fn default() -> Self {
        DrlConfig {
            actor_lr: 1e-3,
            critic_lr: 1e-2,
            gamma: 0.95,
            tau: 0.01,
            replay_capacity: 10_000,
            batch: 64,
            hidden: 64,
            noise_sigma: 0.2,
            noise_theta: 0.15,
            warmup: 32,
        }
    }
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            mechanism: Mechanism::LgcDrl,
            workload: Workload::LrMnist,
            devices: 3,
            rounds: 100,
            lr: 0.01,
            batch: 64,
            h_max: 8,
            h_fixed: 4,
            layer_fracs: vec![0.01, 0.04, 0.15],
            channel_types: vec![ChannelType::G5, ChannelType::G4, ChannelType::G3],
            energy_budget: f64::INFINITY,
            money_budget: f64::INFINITY,
            dirichlet_alpha: 0.5,
            samples_per_device: 2048,
            eval_samples: 1024,
            seed: 42,
            eval_every: 5,
            use_runtime: true,
            artifacts_dir: "artifacts".to_string(),
            sync_mode: None,
            buffer_k: None,
            staleness_decay: None,
            compute_threads: 1,
            shards: 0,
            fading_tick_s: 0.5,
            population: None,
            cohort: None,
            sampler: None,
            churn_down: 0.0,
            churn_up: 0.0,
            downlink: None,
            downlink_compression: None,
            downlink_tariff_scale: 1.0,
            scenario: None,
            noma: None,
            edge: None,
            edge_settings: None,
            streaming: false,
            trace: false,
            trace_file: None,
            profile: false,
            drl: DrlConfig::default(),
        }
    }
}

impl ExperimentConfig {
    /// Load from a TOML-subset file, then apply `--key=value` overrides.
    pub fn load(path: Option<&Path>, overrides: &[String]) -> Result<Self, String> {
        let mut doc = match path {
            Some(p) => {
                let text = std::fs::read_to_string(p)
                    .map_err(|e| format!("read {}: {e}", p.display()))?;
                Document::parse(&text).map_err(|e| e.to_string())?
            }
            None => Document::new(),
        };
        apply_overrides(&mut doc, overrides)?;
        Self::from_document(&doc)
    }

    /// Build from a parsed document; unset keys keep defaults.
    pub fn from_document(doc: &Document) -> Result<Self, String> {
        let mut cfg = ExperimentConfig::default();
        if let Some(s) = doc.get_str("", "mechanism") {
            cfg.mechanism = Mechanism::parse(s)?;
        }
        if let Some(s) = doc.get_str("", "workload") {
            cfg.workload = Workload::parse(s)?;
        }
        if let Some(v) = doc.get_i64("", "devices") {
            cfg.devices = v as usize;
        }
        if let Some(v) = doc.get_i64("", "rounds") {
            cfg.rounds = v as usize;
        }
        if let Some(v) = doc.get_f64("", "lr") {
            cfg.lr = v as f32;
        }
        if let Some(v) = doc.get_i64("", "batch") {
            cfg.batch = v as usize;
        }
        if let Some(v) = doc.get_i64("", "h_max") {
            cfg.h_max = v as usize;
        }
        if let Some(v) = doc.get_i64("", "h_fixed") {
            cfg.h_fixed = v as usize;
        }
        if let Some(v) = doc.get_vec_f64("", "layer_fracs") {
            cfg.layer_fracs = v;
        }
        if let Some(v) = doc.get("", "channels").and_then(Value::as_array) {
            let mut types = Vec::new();
            for item in v {
                let s = item.as_str().ok_or("channels must be strings")?;
                types.push(ChannelType::parse(s)?);
            }
            cfg.channel_types = types;
        }
        if let Some(v) = doc.get_f64("", "energy_budget") {
            cfg.energy_budget = v;
        }
        if let Some(v) = doc.get_f64("", "money_budget") {
            cfg.money_budget = v;
        }
        if let Some(v) = doc.get_f64("", "dirichlet_alpha") {
            cfg.dirichlet_alpha = v;
        }
        if let Some(v) = doc.get_i64("", "samples_per_device") {
            cfg.samples_per_device = v as usize;
        }
        if let Some(v) = doc.get_i64("", "eval_samples") {
            cfg.eval_samples = v as usize;
        }
        if let Some(v) = doc.get_i64("", "seed") {
            cfg.seed = v as u64;
        }
        if let Some(v) = doc.get_i64("", "eval_every") {
            cfg.eval_every = (v as usize).max(1);
        }
        if let Some(v) = doc.get_bool("", "use_runtime") {
            cfg.use_runtime = v;
        }
        if let Some(v) = doc.get_str("", "artifacts_dir") {
            cfg.artifacts_dir = v.to_string();
        }
        if let Some(v) = doc.get_i64("", "buffer_k") {
            cfg.buffer_k = Some(
                usize::try_from(v).map_err(|_| format!("buffer_k must be >= 1, got {v}"))?,
            );
        }
        if let Some(v) = doc.get_f64("", "staleness_decay") {
            cfg.staleness_decay = Some(v);
        }
        if let Some(kind) = doc.get_str("", "sync_mode") {
            cfg.sync_mode = Some(SyncMode::parse(
                kind,
                cfg.buffer_k.unwrap_or(2),
                cfg.staleness_decay.unwrap_or(0.5),
            )?);
        }
        if let Some(v) = doc.get_i64("", "compute_threads") {
            cfg.compute_threads = usize::try_from(v)
                .map_err(|_| format!("compute_threads must be >= 0 (0 = all cores), got {v}"))?;
        }
        if let Some(v) = doc.get_i64("", "shards") {
            cfg.shards = usize::try_from(v)
                .map_err(|_| format!("shards must be >= 0 (0 = auto), got {v}"))?;
        }
        if let Some(v) = doc.get_f64("", "fading_tick_s") {
            cfg.fading_tick_s = v;
        }
        if let Some(v) = doc.get_i64("", "population") {
            cfg.population = Some(
                usize::try_from(v).map_err(|_| format!("population must be >= 1, got {v}"))?,
            );
        }
        if let Some(v) = doc.get_i64("", "cohort") {
            cfg.cohort =
                Some(usize::try_from(v).map_err(|_| format!("cohort must be >= 1, got {v}"))?);
        }
        if let Some(s) = doc.get_str("", "sampler") {
            cfg.sampler = Some(SamplerKind::parse(s)?);
        }
        if let Some(v) = doc.get_f64("", "churn_down") {
            cfg.churn_down = v;
        }
        if let Some(v) = doc.get_f64("", "churn_up") {
            cfg.churn_up = v;
        }
        if let Some(v) = doc.get_bool("", "streaming") {
            cfg.streaming = v;
        }
        if let Some(v) = doc.get_bool("", "trace") {
            cfg.trace = v;
        }
        if let Some(s) = doc.get_str("", "trace_file") {
            // Naming a destination implies tracing (unless `trace = false`
            // was set explicitly), mirroring the enable-on-parameter
            // convention of the downlink/edge/population keys.
            cfg.trace_file = Some(s.to_string());
            if doc.get_bool("", "trace").is_none() {
                cfg.trace = true;
            }
        }
        if let Some(v) = doc.get_bool("", "profile") {
            cfg.profile = v;
        }
        if let Some(v) = doc.get_bool("", "downlink") {
            cfg.downlink = Some(v);
        }
        if let Some(v) = doc.get_bool("", "noma") {
            cfg.noma = Some(v);
        }
        if let Some(s) = doc.get_str("", "downlink_compression") {
            cfg.downlink_compression = Some(DownlinkCompression::parse(s)?);
        }
        if let Some(v) = doc.get_f64("", "downlink_tariff_scale") {
            cfg.downlink_tariff_scale = v;
        }
        cfg.scenario = resolve_scenario(doc)?;
        // Edge tier: top-level `edge = bool` plus the `[edge]` tree. Any
        // parameter key materializes the settings (which switches the tier
        // on unless `edge = false`), mirroring the downlink convention.
        if let Some(v) = doc.get_bool("", "edge") {
            cfg.edge = Some(v);
        }
        {
            let mut settings = EdgeSettings::default();
            let mut any = false;
            if let Some(s) = doc.get_str("edge", "backhaul") {
                settings.backhaul = ChannelType::parse(s)?;
                any = true;
            }
            if let Some(v) = doc.get_f64("edge", "bw_scale") {
                settings.bw_scale = v;
                any = true;
            }
            if let Some(v) = doc.get_i64("edge", "flush_k") {
                settings.flush_k = usize::try_from(v)
                    .map_err(|_| format!("edge flush_k must be >= 1, got {v}"))?;
                any = true;
            }
            if let Some(v) = doc.get_bool("edge", "cache_downlink") {
                settings.cache_downlink = v;
                any = true;
            }
            if let Some(s) = doc.get_str("edge", "dynamics") {
                settings.dynamics = BackhaulDynamics::parse(s)?;
                any = true;
            }
            if any {
                cfg.edge_settings = Some(settings);
            }
        }
        // [drl]
        if let Some(v) = doc.get_f64("drl", "actor_lr") {
            cfg.drl.actor_lr = v;
        }
        if let Some(v) = doc.get_f64("drl", "critic_lr") {
            cfg.drl.critic_lr = v;
        }
        if let Some(v) = doc.get_f64("drl", "gamma") {
            cfg.drl.gamma = v;
        }
        if let Some(v) = doc.get_f64("drl", "tau") {
            cfg.drl.tau = v;
        }
        if let Some(v) = doc.get_i64("drl", "replay_capacity") {
            cfg.drl.replay_capacity = v as usize;
        }
        if let Some(v) = doc.get_i64("drl", "batch") {
            cfg.drl.batch = v as usize;
        }
        if let Some(v) = doc.get_i64("drl", "hidden") {
            cfg.drl.hidden = v as usize;
        }
        if let Some(v) = doc.get_f64("drl", "noise_sigma") {
            cfg.drl.noise_sigma = v;
        }
        if let Some(v) = doc.get_i64("drl", "warmup") {
            cfg.drl.warmup = v as usize;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.devices == 0 {
            return Err("devices must be >= 1".into());
        }
        if self.rounds == 0 {
            return Err("rounds must be >= 1".into());
        }
        if !(self.lr > 0.0) {
            return Err("lr must be > 0".into());
        }
        if self.h_fixed == 0 || self.h_max == 0 || self.h_fixed > self.h_max {
            return Err(format!(
                "invalid local step bounds: h_fixed={} h_max={}",
                self.h_fixed, self.h_max
            ));
        }
        if self.layer_fracs.is_empty() {
            return Err("layer_fracs must be non-empty".into());
        }
        let total: f64 = self.layer_fracs.iter().sum();
        if self.layer_fracs.iter().any(|&f| f <= 0.0) || total > 1.0 {
            return Err(format!("layer_fracs must be positive and sum <= 1, got {total}"));
        }
        if self.channel_types.is_empty() {
            return Err("at least one channel required".into());
        }
        if self.layer_fracs.len() > self.channel_types.len() {
            return Err(format!(
                "{} layers but only {} channels (one layer per channel, Eq. 2)",
                self.layer_fracs.len(),
                self.channel_types.len()
            ));
        }
        if let Some(mode) = self.sync_mode {
            mode.validate()?;
        }
        if let Some(k) = self.buffer_k {
            SyncMode::SemiAsync { buffer_k: k }.validate()?;
        }
        if let Some(d) = self.staleness_decay {
            SyncMode::FullyAsync { staleness_decay: d }.validate()?;
        }
        if !(self.fading_tick_s > 0.0) {
            return Err(format!("fading_tick_s must be > 0, got {}", self.fading_tick_s));
        }
        if let Some(p) = self.population {
            if p == 0 {
                return Err("population must be >= 1".into());
            }
        }
        let pop_n = self.population.unwrap_or(self.devices);
        if let Some(c) = self.cohort {
            if c == 0 {
                return Err("cohort must be >= 1".into());
            }
            if c > pop_n {
                return Err(format!("cohort {c} exceeds population {pop_n}"));
            }
        }
        if !(0.0..=1.0).contains(&self.churn_down) {
            return Err(format!("churn_down must lie in [0, 1], got {}", self.churn_down));
        }
        if !(0.0..=1.0).contains(&self.churn_up) {
            return Err(format!("churn_up must lie in [0, 1], got {}", self.churn_up));
        }
        if !(self.downlink_tariff_scale > 0.0 && self.downlink_tariff_scale.is_finite()) {
            return Err(format!(
                "downlink_tariff_scale must be finite and > 0, got {}",
                self.downlink_tariff_scale
            ));
        }
        if let Some(spec) = &self.scenario {
            spec.validate(&self.channel_types)
                .map_err(|e| format!("scenario `{}`: {e}", spec.name))?;
        }
        if let Some(settings) = &self.edge_settings {
            settings.validate()?;
        }
        Ok(())
    }
}

/// Resolve the scenario from a config document. Exactly one source may be
/// used: the `scenario = "preset"` key (registry lookup; `"none"`/`"off"`
/// force-disables), `scenario_file = "world.toml"` (that file's
/// `[scenario]` tree), or an inline `[scenario]` tree in the same
/// document — mixing them is an error rather than a silent precedence.
fn resolve_scenario(doc: &Document) -> Result<Option<ScenarioSpec>, String> {
    let inline = ScenarioSpec::from_document(doc)?;
    let named = doc.get_str("", "scenario");
    let file = doc.get_str("", "scenario_file");
    if let Some(name) = named {
        if matches!(name.to_ascii_lowercase().as_str(), "none" | "off") {
            return Ok(None);
        }
        if file.is_some() || inline.is_some() {
            return Err(
                "set only one of scenario, scenario_file, or an inline [scenario] tree".into(),
            );
        }
        return ScenarioRegistry::resolve(name).map(Some);
    }
    if let Some(path) = file {
        if inline.is_some() {
            return Err(
                "set only one of scenario, scenario_file, or an inline [scenario] tree".into(),
            );
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read scenario_file {path}: {e}"))?;
        let fdoc = Document::parse(&text).map_err(|e| format!("scenario_file {path}: {e}"))?;
        return ScenarioSpec::from_document(&fdoc)?
            .map(Some)
            .ok_or_else(|| format!("scenario_file {path} has no [scenario] tree"));
    }
    Ok(inline)
}

/// Apply `--key=value` / `--section.key=value` overrides onto a document.
pub fn apply_overrides(doc: &mut Document, overrides: &[String]) -> Result<(), String> {
    for ov in overrides {
        let ov = ov.strip_prefix("--").unwrap_or(ov);
        let (key, val) = ov
            .split_once('=')
            .ok_or_else(|| format!("override `{ov}` must be key=value"))?;
        let val = toml::parse_value(val)
            .or_else(|_| toml::parse_value(&format!("\"{val}\"")))
            .map_err(|e| format!("override `{ov}`: {e}"))?;
        match key.split_once('.') {
            Some((sec, k)) => doc.set(sec, k, val),
            None => doc.set("", key, val),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn from_document_overrides_defaults() {
        let doc = Document::parse(
            "mechanism = \"fedavg\"\nworkload = \"cnn\"\nrounds = 7\nlr = 0.1\n[drl]\ngamma = 0.9\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.mechanism, Mechanism::FedAvg);
        assert_eq!(cfg.workload, Workload::CnnMnist);
        assert_eq!(cfg.rounds, 7);
        assert!((cfg.lr - 0.1).abs() < 1e-9);
        assert!((cfg.drl.gamma - 0.9).abs() < 1e-12);
    }

    #[test]
    fn cli_overrides() {
        let mut doc = Document::new();
        apply_overrides(
            &mut doc,
            &[
                "--rounds=5".to_string(),
                "--mechanism=lgc".to_string(),
                "drl.tau=0.5".to_string(),
            ],
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.rounds, 5);
        assert_eq!(cfg.mechanism, Mechanism::LgcDrl);
        assert!((cfg.drl.tau - 0.5).abs() < 1e-12);
    }

    #[test]
    fn trace_keys_parse() {
        let cfg = ExperimentConfig::default();
        assert!(!cfg.trace && cfg.trace_file.is_none() && !cfg.profile);
        // Naming a destination implies tracing...
        let doc = Document::parse("trace_file = \"run.jsonl\"\nprofile = true\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert!(cfg.trace);
        assert_eq!(cfg.trace_file.as_deref(), Some("run.jsonl"));
        assert!(cfg.profile);
        // ...unless `trace = false` says otherwise.
        let doc = Document::parse("trace = false\ntrace_file = \"run.jsonl\"\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert!(!cfg.trace);
        // Bare `trace = true` defaults the destination.
        let doc = Document::parse("trace = true\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert!(cfg.trace && cfg.trace_file.is_none());
    }

    #[test]
    fn invalid_configs_rejected() {
        let bad = [
            "devices = 0",
            "rounds = 0",
            "h_fixed = 9\nh_max = 4",
            "layer_fracs = [0.9, 0.9]",
            "layer_fracs = [0.1, 0.1, 0.1, 0.1]\nchannels = [\"5g\"]",
        ];
        for text in bad {
            let doc = Document::parse(text).unwrap();
            assert!(ExperimentConfig::from_document(&doc).is_err(), "{text}");
        }
    }

    #[test]
    fn sync_mode_keys_parse() {
        let doc = Document::parse(
            "sync_mode = \"semi-async\"\nbuffer_k = 3\ncompute_threads = 4\nshards = 8\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.sync_mode, Some(SyncMode::SemiAsync { buffer_k: 3 }));
        assert_eq!(cfg.compute_threads, 4);
        assert_eq!(cfg.shards, 8);
        let doc = Document::parse("sync_mode = \"fully-async\"\nstaleness_decay = 0.7\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.sync_mode, Some(SyncMode::FullyAsync { staleness_decay: 0.7 }));
        assert!(ExperimentConfig::from_document(&doc).unwrap().fading_tick_s > 0.0);
        // Standalone parameter keys survive without sync_mode (the builder
        // overlays them on the mechanism preset's default mode).
        let doc = Document::parse("buffer_k = 4\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.sync_mode, None);
        assert_eq!(cfg.buffer_k, Some(4));
        for bad in [
            "sync_mode = \"warp\"",
            "sync_mode = \"semi-async\"\nbuffer_k = 0",
            "sync_mode = \"fully-async\"\nstaleness_decay = 1.5",
            "buffer_k = 0",
            "staleness_decay = 0.0",
            "fading_tick_s = 0.0",
            "compute_threads = -1",
            "shards = -2",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(ExperimentConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn population_keys_parse() {
        let doc = Document::parse(
            "population = 10000\ncohort = 64\nsampler = \"uniform-k\"\nchurn_down = 0.1\nchurn_up = 0.5\nstreaming = true\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.population, Some(10_000));
        assert_eq!(cfg.cohort, Some(64));
        assert_eq!(cfg.sampler, Some(SamplerKind::UniformK));
        assert!((cfg.churn_down - 0.1).abs() < 1e-12);
        assert!((cfg.churn_up - 0.5).abs() < 1e-12);
        assert!(cfg.streaming);
        for name in ["full", "weighted-by-samples", "availability-markov"] {
            let doc = Document::parse(&format!("sampler = \"{name}\"\n")).unwrap();
            let cfg = ExperimentConfig::from_document(&doc).unwrap();
            assert_eq!(cfg.sampler.unwrap().name(), name);
        }
        for bad in [
            "population = 0",
            "cohort = 0",
            "population = 100\ncohort = 101",
            "cohort = 4", // devices defaults to 3: cohort beyond population
            "sampler = \"lottery\"",
            "churn_down = 1.5",
            "churn_up = -0.1",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(ExperimentConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn downlink_keys_parse() {
        let doc = Document::parse(
            "downlink = true\ndownlink_compression = \"layered\"\ndownlink_tariff_scale = 0.5\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.downlink, Some(true));
        assert_eq!(cfg.downlink_compression, Some(DownlinkCompression::Layered));
        assert!((cfg.downlink_tariff_scale - 0.5).abs() < 1e-12);
        // Unset keys keep the deferred defaults.
        let cfg = ExperimentConfig::from_document(&Document::new()).unwrap();
        assert_eq!(cfg.downlink, None);
        assert_eq!(cfg.downlink_compression, None);
        assert_eq!(cfg.downlink_tariff_scale, 1.0);
        for bad in [
            "downlink_compression = \"zip\"",
            "downlink_tariff_scale = 0.0",
            "downlink_tariff_scale = -2.0",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(ExperimentConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn noma_key_parses() {
        let doc = Document::parse("noma = true\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.noma, Some(true));
        let doc = Document::parse("noma = false\n").unwrap();
        assert_eq!(ExperimentConfig::from_document(&doc).unwrap().noma, Some(false));
        // Unset keeps the deferred default (preset, then scenario spec).
        let cfg = ExperimentConfig::from_document(&Document::new()).unwrap();
        assert_eq!(cfg.noma, None);
        // CLI override path.
        let mut doc = Document::new();
        apply_overrides(&mut doc, &["--noma=true".to_string()]).unwrap();
        assert_eq!(ExperimentConfig::from_document(&doc).unwrap().noma, Some(true));
    }

    #[test]
    fn edge_keys_parse() {
        let doc = Document::parse(
            "edge = true\n[edge]\nbackhaul = \"4g\"\nbw_scale = 0.25\nflush_k = 2\ncache_downlink = true\ndynamics = \"diurnal\"\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.edge, Some(true));
        let s = cfg.edge_settings.expect("edge tree parsed");
        assert_eq!(s.backhaul, ChannelType::G4);
        assert!((s.bw_scale - 0.25).abs() < 1e-12);
        assert_eq!(s.flush_k, 2);
        assert!(s.cache_downlink);
        assert_eq!(s.dynamics, BackhaulDynamics::Diurnal);
        // A parameter key alone materializes settings (enable-on-parameter,
        // like the downlink/population keys); `edge` itself stays deferred.
        let doc = Document::parse("[edge]\nflush_k = 8\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.edge, None);
        assert_eq!(cfg.edge_settings.unwrap().flush_k, 8);
        // Unset keys keep the deferred defaults.
        let cfg = ExperimentConfig::from_document(&Document::new()).unwrap();
        assert_eq!(cfg.edge, None);
        assert!(cfg.edge_settings.is_none());
        // CLI overrides reach the [edge] section.
        let mut doc = Document::new();
        apply_overrides(
            &mut doc,
            &["--edge=true".to_string(), "--edge.bw_scale=0.5".to_string()],
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.edge, Some(true));
        assert!((cfg.edge_settings.unwrap().bw_scale - 0.5).abs() < 1e-12);
        for bad in [
            "[edge]\nbw_scale = 0.0",
            "[edge]\nbw_scale = 1.5",
            "[edge]\nflush_k = 0",
            "[edge]\ndynamics = \"warp\"",
            "[edge]\nbackhaul = \"6g\"",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(ExperimentConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn scenario_keys_parse() {
        // Preset by name.
        let doc = Document::parse("scenario = \"stadium-flash-crowd\"\n").unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        let spec = cfg.scenario.expect("preset resolved");
        assert_eq!(spec.name, "stadium-flash-crowd");
        assert_eq!(spec.zones.len(), 2);
        // Explicit off.
        let doc = Document::parse("scenario = \"none\"\n").unwrap();
        assert!(ExperimentConfig::from_document(&doc).unwrap().scenario.is_none());
        // Inline tree.
        let doc = Document::parse(
            "[scenario]\nname = \"inline\"\n[scenario.zone.0]\nchannels = [\"5g\", \"3g\"]\n",
        )
        .unwrap();
        let cfg = ExperimentConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.scenario.unwrap().name, "inline");
        // Unset -> None (the oracle world).
        assert!(ExperimentConfig::from_document(&Document::new()).unwrap().scenario.is_none());
        for bad in [
            "scenario = \"warp\"",
            // Mixing sources is an error, not a precedence.
            "scenario = \"diurnal\"\n[scenario.zone.0]\nchannels = [\"5g\"]",
            "scenario = \"diurnal\"\nscenario_file = \"x.toml\"",
            "scenario_file = \"/definitely/not/here.toml\"",
            // Inline zone referencing a channel the experiment lacks.
            "channels = [\"3g\"]\n[scenario.zone.0]\nchannels = [\"5g\"]\nname = \"x\"",
        ] {
            let doc = Document::parse(bad).unwrap();
            assert!(ExperimentConfig::from_document(&doc).is_err(), "{bad}");
        }
    }

    #[test]
    fn mechanism_and_workload_names_roundtrip() {
        for m in [
            Mechanism::FedAvg,
            Mechanism::LgcStatic,
            Mechanism::LgcDrl,
            Mechanism::TopK,
            Mechanism::RandK,
            Mechanism::Qsgd,
        ] {
            assert_eq!(Mechanism::parse(m.name()).unwrap(), m);
        }
        // unknown names become Custom keys, resolved by the registry later
        assert_eq!(
            Mechanism::parse("my-registered-mech").unwrap().name(),
            "my-registered-mech"
        );
        for w in [Workload::LrMnist, Workload::CnnMnist, Workload::RnnShakespeare] {
            assert_eq!(Workload::parse(w.model_name()).unwrap(), w);
        }
    }
}
