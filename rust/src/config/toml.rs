//! Minimal TOML-subset parser (no `serde`/`toml` available offline).
//!
//! Supported grammar — exactly what this repo's config files and the AOT
//! manifest use:
//!
//! ```text
//! # comment
//! key = 42 | 3.14 | true | "string" | [1, 2, 3] | ["a", "b"]
//! [section]
//! key = ...
//! ```
//!
//! Values are typed (`Value`); documents preserve insertion order and
//! round-trip through `Document::to_string`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{x:.1}")
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Bool(b) => write!(f, "{b}"),
            Value::Str(s) => write!(f, "\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Value::Array(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: `sections[""]` holds top-level keys.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Document {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
    /// Section order as encountered (for stable printing).
    order: Vec<String>,
}

impl Document {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn parse(text: &str) -> Result<Document, ParseError> {
        let mut doc = Document::new();
        let mut section = String::new();
        doc.touch_section("");
        for (lineno, raw) in text.lines().enumerate() {
            let lineno = lineno + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix("[[") {
                // Array-of-tables: `[[scenario.phase]]` appends a fresh
                // numbered section `scenario.phase.<k>` in document order,
                // readable back via `Document::array_sections`.
                let name = name.strip_suffix("]]").ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated [[array]] header".into(),
                })?;
                let base = name.trim();
                if base.is_empty() {
                    return Err(ParseError { line: lineno, msg: "empty section name".into() });
                }
                // Next index = one past the highest existing number (not
                // the count), so an explicit `[base.N]` with a gap can
                // never silently merge with a later `[[base]]` entry.
                let idx = doc
                    .array_sections(base)
                    .last()
                    .map(|(n, _)| n + 1)
                    .unwrap_or(0);
                section = format!("{base}.{idx}");
                doc.touch_section(&section);
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(ParseError { line: lineno, msg: "empty section name".into() });
                }
                doc.touch_section(&section);
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: lineno,
                msg: format!("expected `key = value`, got `{line}`"),
            })?;
            let key = line[..eq].trim().to_string();
            if key.is_empty() {
                return Err(ParseError { line: lineno, msg: "empty key".into() });
            }
            let val = parse_value(line[eq + 1..].trim())
                .map_err(|msg| ParseError { line: lineno, msg })?;
            doc.set(&section, &key, val);
        }
        Ok(doc)
    }

    fn touch_section(&mut self, name: &str) {
        if !self.sections.contains_key(name) {
            self.sections.insert(name.to_string(), BTreeMap::new());
            self.order.push(name.to_string());
        }
    }

    pub fn set(&mut self, section: &str, key: &str, val: Value) {
        self.touch_section(section);
        self.sections
            .get_mut(section)
            .unwrap()
            .insert(key.to_string(), val);
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn get_i64(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key).and_then(Value::as_i64)
    }
    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key).and_then(Value::as_f64)
    }
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key).and_then(Value::as_bool)
    }
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key).and_then(Value::as_str)
    }
    pub fn get_vec_i64(&self, section: &str, key: &str) -> Option<Vec<i64>> {
        self.get(section, key)
            .and_then(Value::as_array)
            .map(|a| a.iter().filter_map(Value::as_i64).collect())
    }
    pub fn get_vec_f64(&self, section: &str, key: &str) -> Option<Vec<f64>> {
        self.get(section, key)
            .and_then(Value::as_array)
            .map(|a| a.iter().filter_map(Value::as_f64).collect())
    }

    pub fn sections_in_order(&self) -> impl Iterator<Item = (&str, &BTreeMap<String, Value>)> {
        self.order
            .iter()
            .filter_map(|n| self.sections.get(n).map(|s| (n.as_str(), s)))
    }

    /// The numbered sections `{prefix}.<n>`, sorted by `n` — the read side
    /// of `[[prefix]]` array-of-tables (explicit `[prefix.2]` headers land
    /// in the same namespace).
    pub fn array_sections(&self, prefix: &str) -> Vec<(usize, &BTreeMap<String, Value>)> {
        let mut out: Vec<(usize, &BTreeMap<String, Value>)> = self
            .sections
            .iter()
            .filter_map(|(name, kvs)| {
                let rest = name.strip_prefix(prefix)?.strip_prefix('.')?;
                rest.parse::<usize>().ok().map(|n| (n, kvs))
            })
            .collect();
        out.sort_by_key(|(n, _)| *n);
        out
    }
}

impl fmt::Display for Document {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (name, kvs) in self.sections_in_order() {
            if kvs.is_empty() && name.is_empty() {
                continue;
            }
            if !name.is_empty() {
                writeln!(f, "[{name}]")?;
            }
            for (k, v) in kvs {
                writeln!(f, "{k} = {v}")?;
            }
        }
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a single scalar or array value.
pub fn parse_value(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if s.is_empty() {
        return Err("empty value".into());
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or("unterminated string")?;
        return Ok(Value::Str(unescape(inner)));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part)?);
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(format!("cannot parse value `{s}`"))
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some(other) => out.push(other),
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse_value("42").unwrap(), Value::Int(42));
        assert_eq!(parse_value("-3").unwrap(), Value::Int(-3));
        assert_eq!(parse_value("3.5").unwrap(), Value::Float(3.5));
        assert_eq!(parse_value("1e-3").unwrap(), Value::Float(1e-3));
        assert_eq!(parse_value("true").unwrap(), Value::Bool(true));
        assert_eq!(parse_value("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_arrays() {
        assert_eq!(
            parse_value("[1, 2, 3]").unwrap(),
            Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
        assert_eq!(
            parse_value("[\"a\", \"b,c\"]").unwrap(),
            Value::Array(vec![Value::Str("a".into()), Value::Str("b,c".into())])
        );
    }

    #[test]
    fn parse_document_with_sections_and_comments() {
        let text = r#"
# top comment
rounds = 100            # trailing comment
lr = 0.01
name = "lgc # not a comment"

[server]
aggregate = "mean"
layers = [655, 2621, 9830]
"#;
        let doc = Document::parse(text).unwrap();
        assert_eq!(doc.get_i64("", "rounds"), Some(100));
        assert_eq!(doc.get_f64("", "lr"), Some(0.01));
        assert_eq!(doc.get_str("", "name"), Some("lgc # not a comment"));
        assert_eq!(doc.get_str("server", "aggregate"), Some("mean"));
        assert_eq!(doc.get_vec_i64("server", "layers"), Some(vec![655, 2621, 9830]));
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = Document::parse("x = 5").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(5.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Document::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Document::parse("[unterminated").unwrap_err();
        assert_eq!(err.line, 1);
        let err = Document::parse("x = ").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn roundtrip_print_parse() {
        let text = "a = 1\nb = 2.5\n[s]\nc = \"x\"\nd = [1, 2]\n";
        let doc = Document::parse(text).unwrap();
        let printed = doc.to_string();
        let doc2 = Document::parse(&printed).unwrap();
        assert_eq!(doc, doc2);
    }

    #[test]
    fn array_of_tables_parse_and_read_back() {
        let text = "[scenario]\nname = \"x\"\n\
                    [[scenario.phase]]\nat_s = 10.0\nzone = 1\n\
                    [[scenario.phase]]\nat_s = 20.0\n\
                    [scenario.zone.0]\nchannels = [\"5g\"]\n";
        let doc = Document::parse(text).unwrap();
        let phases = doc.array_sections("scenario.phase");
        assert_eq!(phases.len(), 2);
        assert_eq!(phases[0].0, 0);
        assert_eq!(phases[0].1.get("at_s").and_then(Value::as_f64), Some(10.0));
        assert_eq!(phases[1].1.get("at_s").and_then(Value::as_f64), Some(20.0));
        // Explicit numbered headers land in the same namespace.
        assert_eq!(doc.array_sections("scenario.zone").len(), 1);
        // A [[...]] entry after an explicit numbered header continues past
        // the highest number instead of merging into it.
        let mixed = Document::parse(
            "[p.1]\na = 1\n[[p]]\na = 2\n",
        )
        .unwrap();
        let ps = mixed.array_sections("p");
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].0, 1);
        assert_eq!(ps[1].0, 2);
        assert_eq!(ps[0].1.get("a").and_then(Value::as_i64), Some(1));
        assert_eq!(ps[1].1.get("a").and_then(Value::as_i64), Some(2));
        // `[[x]` unterminated is an error, and the mangled form round-trips.
        assert!(Document::parse("[[oops]").is_err());
        let printed = doc.to_string();
        let doc2 = Document::parse(&printed).unwrap();
        assert_eq!(doc, doc2);
        // Unrelated sections don't leak into the array view.
        assert!(doc.array_sections("scenario").iter().all(|(_, kvs)| !kvs.is_empty()));
        assert_eq!(doc.array_sections("nope").len(), 0);
    }

    #[test]
    fn parses_aot_manifest_format() {
        let text = "batch = 64\ncompress_ks = [655, 2621, 9830]\n\n[lr]\nparams = 7850\nx_shape = \"64x784\"\nx_dtype = \"f32\"\n";
        let doc = Document::parse(text).unwrap();
        assert_eq!(doc.get_i64("lr", "params"), Some(7850));
        assert_eq!(doc.get_str("lr", "x_dtype"), Some("f32"));
    }
}
