//! String-keyed mechanism registry: a mechanism is a named preset of
//! (compressor factory, aggregator factory, policy factory). The builder
//! resolves `cfg.mechanism` here, so adding a mechanism is a one-file
//! registration — no enum branches in the round loop, the device, or the
//! CLI (see DESIGN.md §"Extension points").

use std::collections::BTreeMap;
use std::sync::Arc;

use super::aggregator::{Aggregator, LayerDivergence, MeanAggregator};
use super::policy::{
    DdpgPolicy, EnergyAdaptive, FastestSingle, FedGreen, RoundPolicy, StaticLayered,
};
use crate::compression::{
    Compressor, DenseNoop, ErrorCompensated, LgcRadix, LgcTopAB, Qsgd, RandK,
};
use crate::compression::quantize::QsgdQuantizer;
use crate::config::ExperimentConfig;
use crate::downlink::DownlinkCompression;
use crate::sim::SyncMode;
use crate::util::Rng;

/// Everything a factory may need to build per-experiment parts.
pub struct BuildCtx<'a> {
    pub cfg: &'a ExperimentConfig,
    /// Flat model parameter count P.
    pub nparams: usize,
    /// Static per-layer budgets derived from `cfg.layer_fracs`.
    pub static_ks: &'a [usize],
    /// The experiment's base RNG; fork it (never consume it) so builds stay
    /// deterministic and order-independent.
    pub rng: &'a Rng,
}

/// Builds the compressor for device `id` (one instance per device — it may
/// carry per-device state such as error memory or RNG streams).
pub type CompressorFactory = Arc<dyn Fn(&BuildCtx, usize) -> Box<dyn Compressor> + Send + Sync>;
/// Builds the server-side aggregation rule.
pub type AggregatorFactory = Arc<dyn Fn(&BuildCtx) -> Box<dyn Aggregator> + Send + Sync>;
/// Builds the per-round control policy.
pub type PolicyFactory = Arc<dyn Fn(&BuildCtx) -> Box<dyn RoundPolicy> + Send + Sync>;
/// Builds the population client sampler (population mode) — an
/// [`ExperimentBuilder::sampler`](super::ExperimentBuilder::sampler)
/// override; the built-ins resolve from the config's `sampler` key via
/// [`crate::population::build_sampler`].
pub type SamplerFactory =
    Arc<dyn Fn(&BuildCtx) -> Box<dyn crate::population::ClientSampler> + Send + Sync>;

/// A named mechanism preset.
#[derive(Clone)]
pub struct MechanismPreset {
    pub key: String,
    pub summary: String,
    pub compressor: CompressorFactory,
    pub aggregator: AggregatorFactory,
    pub policy: PolicyFactory,
    /// Sync-mode default applied when the config leaves `sync_mode` unset
    /// (`cfg.sync_mode` always wins; `None` here means `Barrier`).
    pub default_sync: Option<SyncMode>,
    /// Downlink default applied when the config leaves `downlink` unset:
    /// `Some(compression)` enables the simulated downlink with that delta
    /// compression (`cfg.downlink` / `cfg.downlink_compression` always
    /// win; `None` here means disabled — free instant broadcast).
    pub default_downlink: Option<DownlinkCompression>,
    /// Edge-tier default applied when the config leaves `edge` unset:
    /// `true` runs the preset with the hierarchical edge aggregation tier
    /// (`cfg.edge` / any `[edge]` key always wins; `false` here means the
    /// flat single-server topology).
    pub default_edge: bool,
    /// NOMA shared-uplink default applied when the config leaves `noma`
    /// unset: `true` runs the preset with co-zone carrier contention
    /// (`cfg.noma` always wins; `false` here means independent links).
    pub default_noma: bool,
}

impl MechanismPreset {
    pub fn new(
        key: &str,
        summary: &str,
        compressor: CompressorFactory,
        aggregator: AggregatorFactory,
        policy: PolicyFactory,
    ) -> Self {
        MechanismPreset {
            key: key.to_string(),
            summary: summary.to_string(),
            compressor,
            aggregator,
            policy,
            default_sync: None,
            default_downlink: None,
            default_edge: false,
            default_noma: false,
        }
    }

    /// Attach a sync-mode default (builder style).
    pub fn with_default_sync(mut self, mode: SyncMode) -> Self {
        self.default_sync = Some(mode);
        self
    }

    /// Attach a downlink default (builder style): the preset runs with the
    /// simulated downlink enabled under `compression` unless the config
    /// says otherwise.
    pub fn with_default_downlink(mut self, compression: DownlinkCompression) -> Self {
        self.default_downlink = Some(compression);
        self
    }

    /// Attach an edge-tier default (builder style): the preset runs with
    /// hierarchical edge aggregation enabled unless the config says
    /// otherwise.
    pub fn with_default_edge(mut self) -> Self {
        self.default_edge = true;
        self
    }

    /// Attach a NOMA default (builder style): the preset runs with the
    /// shared-uplink carrier-contention model unless the config says
    /// otherwise.
    pub fn with_default_noma(mut self) -> Self {
        self.default_noma = true;
        self
    }
}

/// The registry: preset lookup by mechanism key (`Mechanism::name()` or any
/// custom string).
pub struct MechanismRegistry {
    presets: BTreeMap<String, MechanismPreset>,
}

fn mean_aggregator() -> AggregatorFactory {
    Arc::new(|_ctx| Box::new(MeanAggregator))
}

fn ef_lgc_compressor() -> CompressorFactory {
    Arc::new(|_ctx, _id| Box::new(ErrorCompensated::new(LgcTopAB)))
}

fn static_layered_policy() -> PolicyFactory {
    Arc::new(|ctx| {
        let mut counts = vec![0usize; ctx.cfg.channel_types.len()];
        for (c, &k) in ctx.static_ks.iter().enumerate() {
            counts[c] = k;
        }
        Box::new(StaticLayered { h: ctx.cfg.h_fixed, counts })
    })
}

fn fastest_single_policy(total_of: fn(&BuildCtx) -> usize) -> PolicyFactory {
    Arc::new(move |ctx| {
        Box::new(FastestSingle { h: ctx.cfg.h_fixed, total: total_of(ctx) })
    })
}

impl MechanismRegistry {
    /// Empty registry (extension tests / fully custom stacks).
    pub fn empty() -> Self {
        MechanismRegistry { presets: BTreeMap::new() }
    }

    /// The built-in mechanisms (paper Sec. 4.1 + baselines from related
    /// work).
    pub fn builtin() -> Self {
        let mut reg = Self::empty();

        reg.register(MechanismPreset::new(
            "fedavg",
            "FedAvg: dense upload on the fastest channel, mean aggregation",
            Arc::new(|_ctx, _id| Box::new(DenseNoop)),
            mean_aggregator(),
            fastest_single_policy(|ctx| ctx.nparams),
        ));

        reg.register(MechanismPreset::new(
            "lgc-static",
            "LGC with fixed H and fixed layer-to-channel allocation",
            ef_lgc_compressor(),
            mean_aggregator(),
            static_layered_policy(),
        ));

        reg.register(MechanismPreset::new(
            "lgc-drl",
            "LGC with the per-device DDPG controller choosing (H, D_{m,n})",
            ef_lgc_compressor(),
            mean_aggregator(),
            Arc::new(|_ctx| Box::new(DdpgPolicy)),
        ));

        reg.register(MechanismPreset::new(
            "topk",
            "single-channel Top-k with error feedback (ablation A1)",
            ef_lgc_compressor(),
            mean_aggregator(),
            fastest_single_policy(|ctx| ctx.static_ks.iter().sum()),
        ));

        reg.register(MechanismPreset::new(
            "lgc-radix",
            "LGC via the radix-select encoder variant (perf ablation)",
            Arc::new(|_ctx, _id| Box::new(ErrorCompensated::new(LgcRadix))),
            mean_aggregator(),
            static_layered_policy(),
        ));

        reg.register(MechanismPreset::new(
            "rand-k",
            "single-channel random-K with error feedback (Wangni et al.)",
            Arc::new(|ctx, id| {
                let rng = ctx.rng.fork(0xBADC0DE ^ ((id as u64) << 8));
                Box::new(ErrorCompensated::new(RandK::new(rng, false)))
            }),
            mean_aggregator(),
            fastest_single_policy(|ctx| ctx.static_ks.iter().sum()),
        ));

        reg.register(MechanismPreset::new(
            "qsgd",
            "QSGD stochastic quantization with error feedback (Alistarh et al.)",
            Arc::new(|ctx, id| {
                let rng = ctx.rng.fork(0x0561D ^ ((id as u64) << 8));
                Box::new(ErrorCompensated::new(Qsgd::new(QsgdQuantizer::new(4, rng))))
            }),
            mean_aggregator(),
            fastest_single_policy(|ctx| ctx.nparams),
        ));

        reg.register(
            MechanismPreset::new(
                "lgc-semi-async",
                "LGC (static allocation) under FedBuff-style buffered aggregation",
                ef_lgc_compressor(),
                mean_aggregator(),
                static_layered_policy(),
            )
            .with_default_sync(SyncMode::SemiAsync { buffer_k: 2 }),
        );

        reg.register(
            MechanismPreset::new(
                "lgc-downlink",
                "LGC (static allocation) with the simulated layered downlink broadcast",
                ef_lgc_compressor(),
                mean_aggregator(),
                static_layered_policy(),
            )
            .with_default_downlink(DownlinkCompression::Layered),
        );

        reg.register(
            MechanismPreset::new(
                "lgc-edge",
                "LGC (static allocation) over the hierarchical per-zone edge tier \
                 with backhaul links, under semi-async buffered aggregation",
                ef_lgc_compressor(),
                mean_aggregator(),
                static_layered_policy(),
            )
            .with_default_sync(SyncMode::SemiAsync { buffer_k: 2 })
            .with_default_edge(),
        );

        reg.register(
            MechanismPreset::new(
                "lgc-async",
                "LGC (static allocation) under FedAsync staleness-weighted application",
                ef_lgc_compressor(),
                mean_aggregator(),
                static_layered_policy(),
            )
            .with_default_sync(SyncMode::FullyAsync { staleness_decay: 0.5 }),
        );

        reg.register(MechanismPreset::new(
            "energy-adaptive",
            "LGC with the upload budget scaled by remaining energy \
             (\"To Talk or to Work\", arXiv 2012.11804)",
            ef_lgc_compressor(),
            mean_aggregator(),
            Arc::new(|ctx| {
                let mut counts = vec![0usize; ctx.cfg.channel_types.len()];
                for (c, &k) in ctx.static_ks.iter().enumerate() {
                    counts[c] = k;
                }
                Box::new(EnergyAdaptive { h: ctx.cfg.h_fixed, counts, floor: 0.1 })
            }),
        ));

        reg.register(MechanismPreset::new(
            "fedgreen",
            "LGC with per-device per-channel compression levels picked from \
             local link quality (FedGreen, arXiv 2111.06146)",
            ef_lgc_compressor(),
            mean_aggregator(),
            Arc::new(|ctx| {
                let mut counts = vec![0usize; ctx.cfg.channel_types.len()];
                for (c, &k) in ctx.static_ks.iter().enumerate() {
                    counts[c] = k;
                }
                Box::new(FedGreen { h: ctx.cfg.h_fixed, counts, levels: 4 })
            }),
        ));

        reg.register(MechanismPreset::new(
            "lgc-divergence",
            "LGC with server-side layer-divergence-feedback reweighting \
             (arXiv 2404.08324)",
            ef_lgc_compressor(),
            Arc::new(|_ctx| Box::new(LayerDivergence::new())),
            static_layered_policy(),
        ));

        reg.register(
            MechanismPreset::new(
                "lgc-noma",
                "LGC (static allocation) over a NOMA shared uplink: co-zone \
                 devices contend for one carrier (arXiv 2003.01344)",
                ef_lgc_compressor(),
                mean_aggregator(),
                static_layered_policy(),
            )
            .with_default_noma(),
        );

        reg
    }

    /// Register (or replace) a preset under its key.
    pub fn register(&mut self, preset: MechanismPreset) {
        self.presets.insert(preset.key.clone(), preset);
    }

    /// Look up a preset: exact key first, then case-insensitively (so
    /// config-file spellings like `"Lgc-Radix"` resolve the same way the
    /// built-in enum aliases do).
    pub fn get(&self, key: &str) -> Option<&MechanismPreset> {
        if let Some(p) = self.presets.get(key) {
            return Some(p);
        }
        self.presets
            .values()
            .find(|p| p.key.eq_ignore_ascii_case(key))
    }

    /// Registered keys, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.presets.keys().map(String::as_str).collect()
    }
}

impl Default for MechanismRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_cover_all_enum_mechanisms() {
        use crate::config::Mechanism;
        let reg = MechanismRegistry::builtin();
        for m in [
            Mechanism::FedAvg,
            Mechanism::LgcStatic,
            Mechanism::LgcDrl,
            Mechanism::TopK,
            Mechanism::RandK,
            Mechanism::Qsgd,
        ] {
            assert!(reg.get(m.name()).is_some(), "no preset for {}", m.name());
        }
    }

    #[test]
    fn async_presets_carry_sync_defaults() {
        let reg = MechanismRegistry::builtin();
        assert_eq!(
            reg.get("lgc-semi-async").unwrap().default_sync,
            Some(SyncMode::SemiAsync { buffer_k: 2 })
        );
        assert_eq!(
            reg.get("lgc-async").unwrap().default_sync,
            Some(SyncMode::FullyAsync { staleness_decay: 0.5 })
        );
        assert_eq!(reg.get("lgc-static").unwrap().default_sync, None);
    }

    #[test]
    fn downlink_preset_carries_downlink_default() {
        let reg = MechanismRegistry::builtin();
        assert_eq!(
            reg.get("lgc-downlink").unwrap().default_downlink,
            Some(DownlinkCompression::Layered)
        );
        assert_eq!(reg.get("lgc-static").unwrap().default_downlink, None);
        assert_eq!(reg.get("fedavg").unwrap().default_downlink, None);
    }

    #[test]
    fn edge_preset_carries_edge_default() {
        let reg = MechanismRegistry::builtin();
        let p = reg.get("lgc-edge").unwrap();
        assert!(p.default_edge);
        assert_eq!(p.default_sync, Some(SyncMode::SemiAsync { buffer_k: 2 }));
        assert!(!reg.get("lgc-static").unwrap().default_edge);
        assert!(!reg.get("lgc-downlink").unwrap().default_edge);
    }

    #[test]
    fn competitor_presets_registered_with_expected_parts() {
        let reg = MechanismRegistry::builtin();
        for key in ["energy-adaptive", "fedgreen", "lgc-divergence", "lgc-noma"] {
            assert!(reg.get(key).is_some(), "no preset for {key}");
        }
        assert!(reg.get("lgc-noma").unwrap().default_noma);
        for key in ["lgc-static", "energy-adaptive", "fedgreen", "lgc-divergence"] {
            assert!(!reg.get(key).unwrap().default_noma, "{key} must not default noma on");
        }
        // The full registry carries at least the 11 originals + 4 new ones.
        assert!(reg.names().len() >= 15, "registry shrank: {:?}", reg.names());
    }

    #[test]
    fn register_and_lookup_custom() {
        let mut reg = MechanismRegistry::builtin();
        let preset = MechanismPreset::new(
            "my-mech",
            "custom",
            Arc::new(|_ctx, _id| Box::new(DenseNoop)),
            mean_aggregator(),
            fastest_single_policy(|ctx| ctx.nparams),
        );
        reg.register(preset);
        assert!(reg.get("my-mech").is_some());
        assert!(reg.names().contains(&"my-mech"));
    }
}
