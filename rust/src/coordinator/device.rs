//! Edge-device state and the per-round device procedure (Alg. 1, lines
//! 4–17): local SGD, pluggable compression of the net progress, and the
//! multi-channel upload.
//!
//! The device is mechanism-agnostic: *what* gets compressed and charged is
//! decided entirely by its [`Compressor`] (error feedback included, via the
//! [`crate::compression::ErrorCompensated`] wrapper) and by the
//! [`crate::channels::AllocationPlan`] the round policy hands in.

use anyhow::Result;

use super::trainer::{DeviceTrainer, LocalTrainer};
use crate::channels::{AllocationPlan, DeviceChannels, TransferCost};
use crate::compression::{
    CompressScratch, Compressor, ErrorFeedback, Layer, LayerBudget, LgcUpdate,
};
use crate::downlink::SyncState;
use crate::resources::{ComputeCostModel, ResourceMeter};

/// Fate of one emitted layer of an upload (parallel to the emitted layer
/// order: entry 0 describes the base layer).
#[derive(Clone, Copy, Debug)]
pub struct LayerTransfer {
    /// Channel the layer rode (index into `DeviceChannels::links`).
    pub channel: usize,
    /// Whether it survived the erasure draw (always true on the lossless
    /// path).
    pub delivered: bool,
}

/// Everything the event engine needs to turn one upload into per-layer
/// in-flight transfers: the delivered payload, the per-layer channel
/// mapping, and the per-channel cost samples.
#[derive(Clone, Debug)]
pub struct UploadOutcome {
    /// The layers that reached the server (lost layers removed, order
    /// preserved). Pair with the `delivered` entries of `transfers` to
    /// recover each delivered layer's channel.
    pub update: LgcUpdate,
    /// One entry per *emitted* layer, including lost ones.
    pub transfers: Vec<LayerTransfer>,
    /// Max over channels of the transfer time (the paper's parallel
    /// multi-channel upload).
    pub wall_time_s: f64,
    /// Per-channel cost samples (energy/money/airtime are charged whether
    /// or not the payload survived — the radio transmitted either way).
    pub costs: Vec<TransferCost>,
    /// Number of emitted layers that were erased in transit.
    pub lost_layers: usize,
}

/// What a device hands the server after its round.
#[derive(Clone, Debug)]
pub struct DeviceUpload {
    pub device: usize,
    /// The layered update g_m (already "received": the simulator charges the
    /// channels and the server decodes from the wire bytes).
    pub update: LgcUpdate,
    /// Simulated wall time of this device's round (compute + slowest layer).
    pub wall_time_s: f64,
    /// Mean training loss over the local steps.
    pub train_loss: f64,
    /// Per-resource round consumption [energy, money] (Eq. 15b).
    pub eps: [f64; 2],
    /// Total bytes pushed across all channels.
    pub bytes_up: u64,
    /// Local steps actually run.
    pub local_steps: usize,
}

/// What [`Device::into_parts`] hands the population store when a client is
/// demobilized (see [`crate::population::Population::demobilize`]).
pub struct DeviceParts {
    pub id: usize,
    pub params_hat: Vec<f32>,
    pub params_sync: Vec<f32>,
    pub compressor: Box<dyn Compressor>,
    pub channels: DeviceChannels,
    pub meter: ResourceMeter,
    pub prev_loss: f64,
    pub last_delta: f64,
    pub sync_state: SyncState,
    /// Compression workspace, returned so the population store can recycle
    /// it into the next materialization (zero-alloc steady state).
    pub scratch: CompressScratch,
    /// Net-progress staging buffer, recycled the same way.
    pub progress_buf: Vec<f32>,
}

/// Persistent device state across rounds.
pub struct Device {
    pub id: usize,
    /// ŵ_m — the local model being descended.
    pub params_hat: Vec<f32>,
    /// w_m — snapshot at the last synchronization.
    pub params_sync: Vec<f32>,
    /// The pluggable compression operator (owns any error-feedback memory).
    pub compressor: Box<dyn Compressor>,
    pub channels: DeviceChannels,
    pub meter: ResourceMeter,
    pub compute: ComputeCostModel,
    /// Training-loss of the previous round (for the DRL δ).
    pub prev_loss: f64,
    /// Last round's loss improvement δ (DRL state feature).
    pub last_delta: f64,
    /// Downlink synchronization state (last confirmed sync, layers still
    /// in flight, staleness gap at round start). Inert — all zeros — when
    /// the downlink is disabled, so the legacy paths are unaffected.
    pub sync_state: SyncState,
    scratch: CompressScratch,
    progress_buf: Vec<f32>,
}

impl Device {
    pub fn new(
        id: usize,
        init_params: Vec<f32>,
        compressor: Box<dyn Compressor>,
        channels: DeviceChannels,
        meter: ResourceMeter,
        compute: ComputeCostModel,
    ) -> Self {
        Device {
            id,
            params_hat: init_params.clone(),
            params_sync: init_params,
            compressor,
            channels,
            meter,
            compute,
            prev_loss: f64::NAN,
            last_delta: 0.0,
            sync_state: SyncState::default(),
            scratch: CompressScratch::default(),
            progress_buf: Vec::new(),
        }
    }

    /// [`Device::new`] with the two replicas provided separately — the
    /// population store's entry point, which fills both from recycled
    /// buffers instead of cloning one allocation into the other.
    pub(crate) fn from_replicas(
        id: usize,
        params_hat: Vec<f32>,
        params_sync: Vec<f32>,
        compressor: Box<dyn Compressor>,
        channels: DeviceChannels,
        meter: ResourceMeter,
        compute: ComputeCostModel,
    ) -> Self {
        debug_assert_eq!(params_hat.len(), params_sync.len());
        Device {
            id,
            params_hat,
            params_sync,
            compressor,
            channels,
            meter,
            compute,
            prev_loss: f64::NAN,
            last_delta: 0.0,
            sync_state: SyncState::default(),
            scratch: CompressScratch::default(),
            progress_buf: Vec::new(),
        }
    }

    /// Install a recycled compression workspace (population store pool) in
    /// place of the empty defaults — capacity carries over, contents are
    /// rebuilt from scratch on every compress call.
    pub(crate) fn install_scratch(&mut self, scratch: CompressScratch, progress_buf: Vec<f32>) {
        self.scratch = scratch;
        self.progress_buf = progress_buf;
    }

    /// The compressor's display name (for logs/tests).
    pub fn compressor_name(&self) -> String {
        self.compressor.name()
    }

    /// Whether this device's updates travel in the sparse index+value wire
    /// format (and should be round-tripped through it by the server).
    pub fn sparse_wire(&self) -> bool {
        self.compressor.sparse_wire()
    }

    /// The compressor's error-feedback memory, if it keeps one.
    pub fn error_memory(&self) -> Option<&ErrorFeedback> {
        self.compressor.error_memory()
    }

    /// Reset the compressor's cross-round state (new episode).
    pub fn reset_compressor(&mut self) {
        self.compressor.reset();
    }

    /// The one mean-loss accumulation loop both step entry points share —
    /// keeping the "parallel is bit-identical to sequential" contract in a
    /// single place.
    fn run_steps<F>(&mut self, h: usize, mut step: F) -> Result<f64>
    where
        F: FnMut(&mut Vec<f32>) -> Result<f64>,
    {
        let mut acc = 0.0;
        for _ in 0..h {
            acc += step(&mut self.params_hat)?;
        }
        Ok(acc / h.max(1) as f64)
    }

    /// Run `h` local SGD steps (Alg. 1 lines 5–7). Returns mean step loss.
    pub fn local_steps(
        &mut self,
        trainer: &mut dyn LocalTrainer,
        h: usize,
        lr: f32,
    ) -> Result<f64> {
        let id = self.id;
        self.local_steps_sharded(trainer, id, h, lr)
    }

    /// [`Device::local_steps`] against an explicit trainer data shard —
    /// population mode maps many clients onto `cfg.devices` shards
    /// ([`crate::population::SpecSeed::shard`]); the legacy path is the
    /// identity mapping `shard == id`.
    pub fn local_steps_sharded(
        &mut self,
        trainer: &mut dyn LocalTrainer,
        shard: usize,
        h: usize,
        lr: f32,
    ) -> Result<f64> {
        self.run_steps(h, move |params| trainer.local_step(shard, params, lr))
    }

    /// [`Device::local_steps`] over an independently-owned per-device
    /// trainer handle (the parallel compute path).
    pub fn local_steps_split(
        &mut self,
        trainer: &mut dyn DeviceTrainer,
        h: usize,
        lr: f32,
    ) -> Result<f64> {
        self.run_steps(h, move |params| trainer.local_step(params, lr))
    }

    /// Net local progress `w_m − ŵ^{t+1/2}` followed by the compressor
    /// (which applies its own error compensation, lines 8–11). An all-silent
    /// plan (every channel at zero) means "nothing to upload this round":
    /// the compressor is not invoked and an empty update ships for free —
    /// local progress simply keeps accumulating until the next real upload.
    fn compress_progress(&mut self, plan: &AllocationPlan) -> LgcUpdate {
        let dim = self.params_hat.len();
        if plan.is_silent() {
            return LgcUpdate { dim, layers: Vec::new() };
        }
        // progress = w_sync − ŵ via the blocked subtract — bitwise
        // identical to the old zipped `w - wh` extend.
        self.progress_buf.clear();
        self.progress_buf.extend_from_slice(&self.params_sync);
        crate::kernels::sub_assign(&mut self.progress_buf, &self.params_hat);
        let budget = LayerBudget::from_plan(plan, dim);
        self.compressor
            .compress(&self.progress_buf, &budget, &mut self.scratch)
    }

    /// Per-channel wire sizes of `update` under `plan` (layer `c` rides
    /// channel `plan.layer_channels()[c]`), using the compressor's byte
    /// accounting. The mapping is positional: a compressor that emits fewer
    /// layers than active channels uses only the first ones (e.g. the dense
    /// baseline rides a single channel regardless of the plan, exactly like
    /// the classic FedAvg upload). Emitting *more* layers than nonzero plan
    /// channels is a hard error — extra layers would otherwise travel
    /// uncharged (and be silently dropped by the lossy path).
    fn upload_sizes(&self, update: &LgcUpdate, plan: &AllocationPlan) -> Vec<u64> {
        let channels = plan.layer_channels();
        assert!(
            update.layers.len() <= channels.len(),
            "compressor `{}` emitted {} layers for a plan with {} active channels",
            self.compressor.name(),
            update.layers.len(),
            channels.len()
        );
        let mut sizes = vec![0u64; self.channels.len()];
        for (layer, &ch) in update.layers.iter().zip(&channels) {
            sizes[ch] += self.compressor.layer_wire_bytes(layer, update.dim);
        }
        sizes
    }

    /// Project `plan` onto the channels that exist in the device's current
    /// scenario zone. `None` when every channel is up — the zero-cost
    /// default, so oracle-path plans are never touched. See
    /// [`AllocationPlan::project_onto`].
    fn project_plan(&self, plan: &AllocationPlan) -> Option<AllocationPlan> {
        if self.channels.all_up() {
            return None;
        }
        plan.project_onto(&self.channels.up_mask())
    }

    /// The per-layer channel mapping an upload under `plan` actually uses —
    /// `plan.layer_channels()` after the same zone projection
    /// [`Device::compress_and_upload`] / [`Device::upload_lossy`] apply
    /// internally. Engines scheduling per-layer arrival events must use
    /// this, not the raw plan's mapping, or a scenario mask would leave
    /// them pointing at silent channels with zero transfer times.
    pub fn effective_layer_channels(&self, plan: &AllocationPlan) -> Vec<usize> {
        match self.project_plan(plan) {
            Some(p) => p.layer_channels(),
            None => plan.layer_channels(),
        }
    }

    /// Compress the net progress into layers (lines 8–11) and charge the
    /// channels for the upload (line 10). `plan` maps layer budgets to
    /// channels; layer c rides channel `plan.layer_channels()[c]`. Budgets
    /// on channels masked out of the device's zone are first projected onto
    /// the surviving channels.
    pub fn compress_and_upload(
        &mut self,
        plan: &AllocationPlan,
    ) -> (LgcUpdate, f64, Vec<TransferCost>) {
        let projected = self.project_plan(plan);
        let plan = projected.as_ref().unwrap_or(plan);
        let update = self.compress_progress(plan);
        let sizes = self.upload_sizes(&update, plan);
        let (wall, costs) = self.channels.parallel_upload(&sizes);
        (update, wall, costs)
    }

    /// Lossy upload with the full per-layer outcome — the event engine's
    /// entry point (async sync modes). Layers ride erasure channels; a lost
    /// layer's coordinates are **restituted into the error memory** (the
    /// device learns of the loss via the missing server ACK), so gradient
    /// mass is never destroyed — only delayed. A compressor without error
    /// memory genuinely loses the layer (dense/quantized baselines without
    /// the `ErrorCompensated` wrapper); the built-in presets all wrap.
    ///
    /// Note for callers: once the compressor ran, the round's net progress
    /// lives in `delivered layers + error memory` — the device must be
    /// `sync`ed to the next broadcast model even if *everything* was lost,
    /// or the restituted mass would be double-counted next round.
    pub fn upload_lossy(&mut self, plan: &AllocationPlan) -> UploadOutcome {
        let projected = self.project_plan(plan);
        let plan = projected.as_ref().unwrap_or(plan);
        let dim = self.params_hat.len();
        let update = self.compress_progress(plan);
        let sizes = self.upload_sizes(&update, plan);
        let (wall, lossy_costs) = self.channels.parallel_upload_lossy(&sizes);
        // Split delivered vs lost layers by their channel's delivery flag.
        let channels = plan.layer_channels();
        let mut delivered = Vec::new();
        let mut transfers = Vec::with_capacity(update.layers.len());
        let mut lost = 0usize;
        for (layer, &ch) in update.layers.into_iter().zip(&channels) {
            if lossy_costs[ch].1 {
                transfers.push(LayerTransfer { channel: ch, delivered: true });
                delivered.push(layer);
            } else {
                // Restitute: the error memory absorbed this layer as if
                // delivered; add the shipped values back so
                // e' + delivered == u exactly (correct for both the
                // zeroing-based and the residual-based absorb).
                self.restitute_layer(&layer);
                transfers.push(LayerTransfer { channel: ch, delivered: false });
                lost += 1;
            }
        }
        let costs = lossy_costs.into_iter().map(|(c, _)| c).collect();
        UploadOutcome {
            update: LgcUpdate { dim, layers: delivered },
            transfers,
            wall_time_s: wall,
            costs,
            lost_layers: lost,
        }
    }

    /// Lossy variant of [`Device::compress_and_upload`]: a thin wrapper over
    /// [`Device::upload_lossy`] returning the *delivered* update (what the
    /// server sees), the wall time, per-channel costs, and the number of
    /// lost layers.
    pub fn compress_and_upload_lossy(
        &mut self,
        plan: &AllocationPlan,
    ) -> (LgcUpdate, f64, Vec<TransferCost>, usize) {
        let o = self.upload_lossy(plan);
        (o.update, o.wall_time_s, o.costs, o.lost_layers)
    }

    /// Receive the new global model (Alg. 1 lines 12–13).
    pub fn sync(&mut self, global: &[f32]) {
        self.params_hat.copy_from_slice(global);
        self.params_sync.copy_from_slice(global);
    }

    /// Begin a downlink resynchronization: collapse `ŵ` back onto
    /// `w_sync`, discarding the local progress the preceding upload
    /// already shipped (it lives in `delivered layers + error memory`
    /// now) — the downlink analogue of the wipe [`Device::sync`] performs
    /// on the free-broadcast path. Without this, the next round would
    /// re-upload the same mass the server already aggregated. The engines
    /// call it exactly when the legacy path would have called `sync`:
    /// when a post-upload broadcast starts for this device.
    pub fn begin_downlink_sync(&mut self) {
        self.params_hat.copy_from_slice(&self.params_sync);
    }

    /// Apply one arrived downlink delta layer: `params += layer`, to
    /// **both** replicas — so any *new* local progress `w_sync − ŵ`
    /// (accumulated after the device restarted on the base layer) is
    /// invariant (up to f32 rounding) under late-arriving enhancement
    /// layers. The error-feedback path never double-counts either way,
    /// because the compressor always reads the *live* `w_sync − ŵ` at
    /// upload time. Decrements `sync_state.pending_layers`.
    pub fn apply_downlink_layer(&mut self, layer: &Layer) {
        crate::downlink::frame::apply_delta(&mut self.params_hat, layer);
        crate::downlink::frame::apply_delta(&mut self.params_sync, layer);
        self.sync_state.pending_layers = self.sync_state.pending_layers.saturating_sub(1);
    }

    /// Restitute every coordinate of an already-compressed `update` into the
    /// error memory — the whole-upload analogue of the per-layer loss branch
    /// of [`Device::upload_lossy`]. Used when a client churns offline
    /// mid-upload (population mode): the server never ACKs, so the shipped
    /// mass returns to the memory and is merely delayed. No-op for
    /// compressors without error memory (dense baselines genuinely lose the
    /// payload, same as their erasure path).
    pub fn restitute_update(&mut self, update: &LgcUpdate) {
        for layer in &update.layers {
            self.restitute_layer(layer);
        }
    }

    /// Restitute a single already-compressed layer into the error memory —
    /// the per-layer form of [`Device::restitute_update`], used when a
    /// scenario handoff removes the channel an in-flight layer was riding
    /// (the association is torn down, so the server never receives it; the
    /// mass is delayed into the next upload, never destroyed).
    pub fn restitute_layer(&mut self, layer: &Layer) {
        let dim = self.params_hat.len();
        if let Some(err) = self.compressor.error_memory_mut() {
            err.ensure_dim(dim);
            for (&i, &v) in layer.indices.iter().zip(&layer.values) {
                err.restitute(i as usize, v);
            }
        }
    }

    /// Decompose into the parts the population store persists
    /// (see [`crate::population::Population::demobilize`]). The dense
    /// `params_hat`/`params_sync` replicas ride along so the store can fold
    /// un-compressed pending progress into the error memory before
    /// recycling them; the compression scratch rides along to be pooled for
    /// the next materialization.
    pub fn into_parts(self) -> DeviceParts {
        DeviceParts {
            id: self.id,
            params_hat: self.params_hat,
            params_sync: self.params_sync,
            compressor: self.compressor,
            channels: self.channels,
            meter: self.meter,
            prev_loss: self.prev_loss,
            last_delta: self.last_delta,
            sync_state: self.sync_state,
            scratch: self.scratch,
            progress_buf: self.progress_buf,
        }
    }

    /// Compute-side cost of `h` local steps.
    pub fn compute_cost(&self, h: usize) -> (f64, f64) {
        (
            self.compute.joules_per_step * h as f64,
            self.compute.seconds_per_step * h as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{allocate_budget, ChannelType};
    use crate::compression::{ErrorCompensated, LgcTopAB};
    use crate::config::ExperimentConfig;
    use crate::coordinator::trainer::{LocalTrainer, NativeLrTrainer};
    use crate::util::Rng;

    fn mk_device(dim: usize) -> Device {
        let rng = Rng::new(1);
        Device::new(
            0,
            vec![0f32; dim],
            Box::new(ErrorCompensated::new(LgcTopAB)),
            DeviceChannels::new(
                &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
                &rng,
                0,
            ),
            ResourceMeter::new(f64::INFINITY, f64::INFINITY),
            ComputeCostModel::for_params(dim),
        )
    }

    fn error_norm2(dev: &Device) -> f64 {
        dev.error_memory().expect("EF compressor").norm2()
    }

    #[test]
    fn upload_charges_only_assigned_channels() {
        let mut dev = mk_device(1000);
        // make some progress so u != 0
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = (i as f32) * 1e-3;
        }
        let plan = AllocationPlan { counts: vec![10, 0, 40] };
        let (update, wall, costs) = dev.compress_and_upload(&plan);
        assert_eq!(update.layers.len(), 2); // silent channel dropped
        assert_eq!(update.total_nnz(), 50);
        assert!(wall > 0.0);
        assert!(costs[0].bytes > 0);
        assert_eq!(costs[1].bytes, 0);
        assert!(costs[2].bytes > 0);
    }

    #[test]
    fn error_feedback_carries_over_rounds() {
        let cfg = ExperimentConfig {
            samples_per_device: 64,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };
        let mut tr = NativeLrTrainer::new(&cfg);
        let mut dev = mk_device(tr.nparams());
        dev.local_steps(&mut tr, 2, 0.1).unwrap();
        let plan = allocate_budget(&[0.0, 0.0, 0.0], 200, 50);
        let (_, _, _) = dev.compress_and_upload(&plan);
        assert!(error_norm2(&dev) > 0.0, "memory should hold dropped mass");
    }

    #[test]
    fn sync_resets_local_state() {
        let mut dev = mk_device(100);
        dev.params_hat.iter_mut().for_each(|p| *p = 1.0);
        let global = vec![0.5f32; 100];
        dev.sync(&global);
        assert_eq!(dev.params_hat, global);
        assert_eq!(dev.params_sync, global);
    }

    #[test]
    fn oversized_plan_rescaled_to_dim() {
        let mut dev = mk_device(100);
        dev.params_hat.iter_mut().enumerate().for_each(|(i, p)| *p = i as f32);
        let plan = AllocationPlan { counts: vec![80, 80, 80] };
        let (update, _, _) = dev.compress_and_upload(&plan);
        assert!(update.total_nnz() <= 100);
        assert!(update.total_nnz() > 0);
    }

    #[test]
    fn lossy_upload_restitutes_lost_layers() {
        // Force all channels into Bad fading so losses occur, then verify
        // e' + delivered == u (mass conservation under erasure).
        let mut dev = mk_device(500);
        for l in dev.channels.links.iter_mut() {
            l.fading = crate::channels::Fading::Bad;
        }
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = (i as f32 + 1.0) * 1e-3;
        }
        let u_expected: Vec<f32> = dev
            .params_sync
            .iter()
            .zip(&dev.params_hat)
            .map(|(&w, &wh)| w - wh)
            .collect(); // error memory starts at zero
        let plan = AllocationPlan { counts: vec![20, 30, 50] };
        let mut saw_loss = false;
        for trial in 0..40 {
            // reset memory each trial so u is identical every time
            dev.reset_compressor();
            let (delivered, _, _, lost) = dev.compress_and_upload_lossy(&plan);
            saw_loss |= lost > 0;
            let dec = delivered.decode();
            let mem = dev.error_memory().unwrap().memory().to_vec();
            for i in 0..500 {
                let total = mem[i] + dec[i];
                assert!(
                    (total - u_expected[i]).abs() < 1e-7,
                    "mass not conserved at {i} (trial {trial})"
                );
            }
        }
        assert!(saw_loss, "40 trials in Bad fading should lose something");
    }

    #[test]
    fn begin_downlink_sync_wipes_shipped_progress_like_sync() {
        // After an upload, the progress u = w_sync − ŵ was shipped
        // (delivered layers + error memory); starting the downlink resync
        // must wipe it from the replicas, or the next round re-uploads it.
        let mut dev = mk_device(200);
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = (i as f32) * 1e-3;
        }
        let plan = AllocationPlan { counts: vec![10, 20, 30] };
        let _ = dev.compress_and_upload(&plan);
        assert!(dev
            .params_hat
            .iter()
            .zip(&dev.params_sync)
            .any(|(a, b)| a != b));
        dev.begin_downlink_sync();
        for (a, b) in dev.params_hat.iter().zip(&dev.params_sync) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Delta layers now move both replicas together: no residual
        // progress exists to double-count.
        let layer = Layer { indices: vec![3], values: vec![0.5] };
        dev.apply_downlink_layer(&layer);
        assert_eq!(dev.params_hat[3].to_bits(), dev.params_sync[3].to_bits());
    }

    #[test]
    fn downlink_layer_applies_to_both_replicas() {
        let mut dev = mk_device(100);
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = i as f32 * 1e-2;
        }
        dev.sync_state.pending_layers = 2;
        let layer = Layer { indices: vec![0, 7, 99], values: vec![1.0, -2.0, 0.5] };
        let hat0 = dev.params_hat[7];
        let sync0 = dev.params_sync[7];
        dev.apply_downlink_layer(&layer);
        assert_eq!(dev.sync_state.pending_layers, 1);
        assert_eq!(dev.params_hat[7], hat0 - 2.0);
        assert_eq!(dev.params_sync[7], sync0 - 2.0);
        assert_eq!(dev.params_hat[1], 1e-2); // untouched coordinate
        dev.apply_downlink_layer(&layer);
        dev.apply_downlink_layer(&layer); // saturates at zero, no panic
        assert_eq!(dev.sync_state.pending_layers, 0);
    }

    #[test]
    fn masked_channel_traffic_projects_onto_surviving_links() {
        // A zone without 3G: the plan's 3G budget must ride the first
        // surviving channel instead, and the masked link stays silent.
        let mut dev = mk_device(1000);
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = (i as f32 + 1.0) * 1e-3;
        }
        dev.channels.links[2].set_up(false); // 3G vanished in a handoff
        let plan = AllocationPlan { counts: vec![10, 20, 40] };
        let (update, _, costs) = dev.compress_and_upload(&plan);
        assert_eq!(update.total_nnz(), 70, "projection preserves the budget");
        assert_eq!(update.layers.len(), 2, "two surviving channels, two layers");
        assert_eq!(costs[2].bytes, 0, "masked channel carries nothing");
        assert!(costs[0].bytes > 0);
        // Lossy path projects identically.
        dev.reset_compressor();
        let outcome = dev.upload_lossy(&plan);
        assert!(outcome.transfers.iter().all(|t| t.channel != 2));
    }

    #[test]
    fn restitute_layer_returns_mass_to_error_memory() {
        let mut dev = mk_device(100);
        let layer = Layer { indices: vec![1, 50], values: vec![0.5, -0.25] };
        dev.restitute_layer(&layer);
        let mem = dev.error_memory().unwrap().memory();
        assert_eq!(mem[1], 0.5);
        assert_eq!(mem[50], -0.25);
    }

    #[test]
    fn dense_upload_full_model_bytes() {
        // The dense (FedAvg) reference is now just the DenseNoop compressor:
        // one layer, 4 B/param, no index overhead.
        let rng = Rng::new(2);
        let mut dev = Device::new(
            0,
            vec![0f32; 1000],
            Box::new(crate::compression::DenseNoop),
            DeviceChannels::new(&[ChannelType::G5, ChannelType::G4], &rng, 0),
            ResourceMeter::new(f64::INFINITY, f64::INFINITY),
            ComputeCostModel::for_params(1000),
        );
        let plan = AllocationPlan { counts: vec![1000, 0] };
        let (update, _, costs) = dev.compress_and_upload(&plan);
        assert_eq!(update.total_nnz(), 1000);
        assert_eq!(costs[0].bytes, 4000);
        assert_eq!(costs[1].bytes, 0);
        assert!(!dev.sparse_wire());
    }
}
