//! Edge-device state and the per-round device procedure (Alg. 1, lines
//! 4–17): local SGD, error-compensated layered compression, and the
//! multi-channel upload.

use anyhow::Result;

use super::trainer::LocalTrainer;
use crate::channels::{AllocationPlan, DeviceChannels, TransferCost};
use crate::compression::{lgc_compress, CompressScratch, ErrorFeedback, LgcUpdate};
use crate::resources::{ComputeCostModel, ResourceMeter};

/// What a device hands the server after its round.
#[derive(Clone, Debug)]
pub struct DeviceUpload {
    pub device: usize,
    /// The layered update g_m (already "received": the simulator charges the
    /// channels and the server decodes from the wire bytes).
    pub update: LgcUpdate,
    /// Simulated wall time of this device's round (compute + slowest layer).
    pub wall_time_s: f64,
    /// Mean training loss over the local steps.
    pub train_loss: f64,
    /// Per-resource round consumption [energy, money] (Eq. 15b).
    pub eps: [f64; 2],
    /// Total bytes pushed across all channels.
    pub bytes_up: u64,
    /// Local steps actually run.
    pub local_steps: usize,
}

/// Persistent device state across rounds.
pub struct Device {
    pub id: usize,
    /// ŵ_m — the local model being descended.
    pub params_hat: Vec<f32>,
    /// w_m — snapshot at the last synchronization.
    pub params_sync: Vec<f32>,
    pub error: ErrorFeedback,
    pub channels: DeviceChannels,
    pub meter: ResourceMeter,
    pub compute: ComputeCostModel,
    /// Training-loss of the previous round (for the DRL δ).
    pub prev_loss: f64,
    /// Last round's loss improvement δ (DRL state feature).
    pub last_delta: f64,
    scratch: CompressScratch,
    u_buf: Vec<f32>,
    progress_buf: Vec<f32>,
}

impl Device {
    pub fn new(
        id: usize,
        init_params: Vec<f32>,
        channels: DeviceChannels,
        meter: ResourceMeter,
        compute: ComputeCostModel,
    ) -> Self {
        let dim = init_params.len();
        Device {
            id,
            params_hat: init_params.clone(),
            params_sync: init_params,
            error: ErrorFeedback::new(dim),
            channels,
            meter,
            compute,
            prev_loss: f64::NAN,
            last_delta: 0.0,
            scratch: CompressScratch::default(),
            u_buf: Vec::new(),
            progress_buf: Vec::new(),
        }
    }

    /// Run `h` local SGD steps (Alg. 1 lines 5–7). Returns mean step loss.
    pub fn local_steps(
        &mut self,
        trainer: &mut dyn LocalTrainer,
        h: usize,
        lr: f32,
    ) -> Result<f64> {
        let mut acc = 0.0;
        for _ in 0..h {
            acc += trainer.local_step(self.id, &mut self.params_hat, lr)?;
        }
        Ok(acc / h.max(1) as f64)
    }

    /// Compress the error-compensated net progress into layers (lines 8–11)
    /// and charge the channels for the upload (line 10). `plan` maps layer
    /// budgets to channels; layer c rides channel `plan.layer_channels()[c]`.
    pub fn compress_and_upload(&mut self, plan: &AllocationPlan) -> (LgcUpdate, f64, Vec<TransferCost>) {
        let dim = self.params_hat.len();
        // progress = w_m − ŵ^{t+1/2}
        self.progress_buf.clear();
        self.progress_buf.extend(
            self.params_sync
                .iter()
                .zip(&self.params_hat)
                .map(|(&w, &wh)| w - wh),
        );
        // u = e + progress (line 8)
        let (error, progress_buf, u_buf) = (&self.error, &self.progress_buf, &mut self.u_buf);
        error.compensate(progress_buf, u_buf);
        // g = LGC(u) (line 9)
        let ks = plan.layer_budgets();
        let ks: Vec<usize> = ks.iter().map(|&k| k.min(dim)).collect();
        let total: usize = ks.iter().sum();
        let ks = if total > dim {
            // Rescale proportionally if the plan exceeds P.
            let mut scaled: Vec<usize> =
                ks.iter().map(|&k| (k * dim) / total.max(1)).collect();
            if scaled.iter().sum::<usize>() == 0 {
                scaled[0] = 1;
            }
            scaled
        } else {
            ks
        };
        let update = lgc_compress(&self.u_buf, &ks, &mut self.scratch);
        // e' = u − g (line 11)
        self.error.absorb(&self.u_buf, &update);
        // Upload layer c on its assigned channel, others silent.
        let mut sizes = vec![0u64; self.channels.len()];
        for (layer, &ch) in update.layers.iter().zip(&plan.layer_channels()) {
            sizes[ch] += layer.wire_bytes();
        }
        let (wall, costs) = self.channels.parallel_upload(&sizes);
        (update, wall, costs)
    }

    /// Lossy variant of [`Device::compress_and_upload`]: layers ride erasure
    /// channels; a lost layer's coordinates are **restituted into the error
    /// memory** (the device learns of the loss via the missing server ACK),
    /// so gradient mass is never destroyed — only delayed. Returns the
    /// *delivered* update (what the server sees), the wall time, per-channel
    /// costs, and the number of lost layers.
    pub fn compress_and_upload_lossy(
        &mut self,
        plan: &AllocationPlan,
    ) -> (LgcUpdate, f64, Vec<TransferCost>, usize) {
        // Encode exactly as the lossless path (shares its rescaling logic).
        let dim = self.params_hat.len();
        self.progress_buf.clear();
        self.progress_buf.extend(
            self.params_sync
                .iter()
                .zip(&self.params_hat)
                .map(|(&w, &wh)| w - wh),
        );
        let (error, progress_buf, u_buf) = (&self.error, &self.progress_buf, &mut self.u_buf);
        error.compensate(progress_buf, u_buf);
        let ks: Vec<usize> = plan.layer_budgets().iter().map(|&k| k.min(dim)).collect();
        let update = lgc_compress(&self.u_buf, &ks, &mut self.scratch);
        self.error.absorb(&self.u_buf, &update);

        let mut sizes = vec![0u64; self.channels.len()];
        for (layer, &ch) in update.layers.iter().zip(&plan.layer_channels()) {
            sizes[ch] += layer.wire_bytes();
        }
        let (wall, lossy_costs) = self.channels.parallel_upload_lossy(&sizes);
        // Split delivered vs lost layers by their channel's delivery flag.
        let channels = plan.layer_channels();
        let mut delivered = Vec::new();
        let mut lost = 0usize;
        for (layer, &ch) in update.layers.into_iter().zip(&channels) {
            if lossy_costs[ch].1 {
                delivered.push(layer);
            } else {
                // Restitute: these coordinates were zeroed by absorb() as if
                // shipped; put them back so e' + delivered == u exactly.
                for (&i, &v) in layer.indices.iter().zip(&layer.values) {
                    self.error.restitute(i as usize, v);
                }
                lost += 1;
            }
        }
        let costs = lossy_costs.into_iter().map(|(c, _)| c).collect();
        (LgcUpdate { dim, layers: delivered }, wall, costs, lost)
    }

    /// Dense upload (FedAvg baseline): the full model on one channel.
    pub fn dense_upload(&mut self, channel: usize) -> (f64, Vec<TransferCost>) {
        let mut sizes = vec![0u64; self.channels.len()];
        sizes[channel] = (self.params_hat.len() * 4) as u64;
        self.channels.parallel_upload(&sizes)
    }

    /// Receive the new global model (Alg. 1 lines 12–13).
    pub fn sync(&mut self, global: &[f32]) {
        self.params_hat.copy_from_slice(global);
        self.params_sync.copy_from_slice(global);
    }

    /// Compute-side cost of `h` local steps.
    pub fn compute_cost(&self, h: usize) -> (f64, f64) {
        (
            self.compute.joules_per_step * h as f64,
            self.compute.seconds_per_step * h as f64,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{allocate_budget, ChannelType};
    use crate::config::ExperimentConfig;
    use crate::coordinator::trainer::{LocalTrainer, NativeLrTrainer};
    use crate::util::Rng;

    fn mk_device(dim: usize) -> Device {
        let rng = Rng::new(1);
        Device::new(
            0,
            vec![0f32; dim],
            DeviceChannels::new(
                &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
                &rng,
                0,
            ),
            ResourceMeter::new(f64::INFINITY, f64::INFINITY),
            ComputeCostModel::for_params(dim),
        )
    }

    #[test]
    fn upload_charges_only_assigned_channels() {
        let mut dev = mk_device(1000);
        // make some progress so u != 0
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = (i as f32) * 1e-3;
        }
        let plan = AllocationPlan { counts: vec![10, 0, 40] };
        let (update, wall, costs) = dev.compress_and_upload(&plan);
        assert_eq!(update.layers.len(), 2); // silent channel dropped
        assert_eq!(update.total_nnz(), 50);
        assert!(wall > 0.0);
        assert!(costs[0].bytes > 0);
        assert_eq!(costs[1].bytes, 0);
        assert!(costs[2].bytes > 0);
    }

    #[test]
    fn error_feedback_carries_over_rounds() {
        let cfg = ExperimentConfig {
            samples_per_device: 64,
            eval_samples: 64,
            ..ExperimentConfig::default()
        };
        let mut tr = NativeLrTrainer::new(&cfg);
        let mut dev = mk_device(tr.nparams());
        dev.local_steps(&mut tr, 2, 0.1).unwrap();
        let plan = allocate_budget(&[0.0, 0.0, 0.0], 200, 50);
        let (_, _, _) = dev.compress_and_upload(&plan);
        assert!(dev.error.norm2() > 0.0, "memory should hold dropped mass");
    }

    #[test]
    fn sync_resets_local_state() {
        let mut dev = mk_device(100);
        dev.params_hat.iter_mut().for_each(|p| *p = 1.0);
        let global = vec![0.5f32; 100];
        dev.sync(&global);
        assert_eq!(dev.params_hat, global);
        assert_eq!(dev.params_sync, global);
    }

    #[test]
    fn oversized_plan_rescaled_to_dim() {
        let mut dev = mk_device(100);
        dev.params_hat.iter_mut().enumerate().for_each(|(i, p)| *p = i as f32);
        let plan = AllocationPlan { counts: vec![80, 80, 80] };
        let (update, _, _) = dev.compress_and_upload(&plan);
        assert!(update.total_nnz() <= 100);
        assert!(update.total_nnz() > 0);
    }

    #[test]
    fn lossy_upload_restitutes_lost_layers() {
        // Force all channels into Bad fading so losses occur, then verify
        // e' + delivered == u (mass conservation under erasure).
        let mut dev = mk_device(500);
        for l in dev.channels.links.iter_mut() {
            l.fading = crate::channels::Fading::Bad;
        }
        for (i, p) in dev.params_hat.iter_mut().enumerate() {
            *p = (i as f32 + 1.0) * 1e-3;
        }
        let u_expected: Vec<f32> = dev
            .params_sync
            .iter()
            .zip(&dev.params_hat)
            .map(|(&w, &wh)| w - wh)
            .collect(); // error memory starts at zero
        let plan = AllocationPlan { counts: vec![20, 30, 50] };
        let mut saw_loss = false;
        for trial in 0..40 {
            // reset memory each trial so u is identical every time
            dev.error.reset();
            let (delivered, _, _, lost) = dev.compress_and_upload_lossy(&plan);
            saw_loss |= lost > 0;
            let dec = delivered.decode();
            for i in 0..500 {
                let total = dev.error.memory()[i] + dec[i];
                assert!(
                    (total - u_expected[i]).abs() < 1e-7,
                    "mass not conserved at {i} (trial {trial})"
                );
            }
        }
        assert!(saw_loss, "40 trials in Bad fading should lose something");
    }

    #[test]
    fn dense_upload_full_model_bytes() {
        let mut dev = mk_device(1000);
        let (_, costs) = dev.dense_upload(0);
        assert_eq!(costs[0].bytes, 4000);
        assert_eq!(costs[1].bytes, 0);
    }
}
