//! [`ExperimentBuilder`] — assemble an [`Experiment`] from a config, a
//! trainer, and the three pluggable seams (compressor / aggregator /
//! policy). Unset seams resolve through the [`MechanismRegistry`] preset
//! named by `cfg.mechanism`; explicit builder calls win over the preset.
//!
//! ```no_run
//! use lgc::config::ExperimentConfig;
//! use lgc::coordinator::{ExperimentBuilder, NativeLrTrainer};
//!
//! let cfg = ExperimentConfig { use_runtime: false, ..Default::default() };
//! let mut trainer = NativeLrTrainer::new(&cfg);
//! let mut exp = ExperimentBuilder::new(cfg)
//!     .trainer(&trainer)
//!     .build()
//!     .expect("build experiment");
//! let log = exp.run(&mut trainer).unwrap();
//! ```

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::aggregator::Aggregator;
use super::device::Device;
use super::experiment::Experiment;
use super::policy::RoundPolicy;
use super::registry::{
    AggregatorFactory, BuildCtx, CompressorFactory, MechanismRegistry, PolicyFactory,
    SamplerFactory,
};
use super::server::Server;
use super::trainer::LocalTrainer;
use crate::channels::{DeviceChannels, FadingParams};
use crate::compression::{Compressor, LgcUpdate};
use crate::config::ExperimentConfig;
use crate::downlink::{Downlink, DownlinkCompression};
use crate::drl::DeviceAgent;
use crate::edge::Edge;
use crate::population::{self, ClientSampler, Population, SamplerKind, SpecSeed};
use crate::resources::{ComputeCostModel, ResourceMeter};
use crate::scenario::{DynamicsKind, Scenario, ScenarioSpec, ZoneSpec};
use crate::sim::{SimStats, SyncMode};
use crate::util::Rng;

/// Builder for [`Experiment`] (see the module docs for the flow).
pub struct ExperimentBuilder<'a> {
    cfg: ExperimentConfig,
    registry: MechanismRegistry,
    trainer: Option<&'a dyn LocalTrainer>,
    compressor: Option<CompressorFactory>,
    aggregator: Option<AggregatorFactory>,
    policy: Option<PolicyFactory>,
    sampler: Option<SamplerFactory>,
    sync_gaps: Option<Vec<usize>>,
}

impl<'a> ExperimentBuilder<'a> {
    pub fn new(cfg: ExperimentConfig) -> Self {
        ExperimentBuilder {
            cfg,
            registry: MechanismRegistry::builtin(),
            trainer: None,
            compressor: None,
            aggregator: None,
            policy: None,
            sampler: None,
            sync_gaps: None,
        }
    }

    /// Swap the mechanism registry (e.g. after registering custom presets).
    pub fn registry(mut self, registry: MechanismRegistry) -> Self {
        self.registry = registry;
        self
    }

    /// The local-training backend. Required before [`ExperimentBuilder::build`].
    pub fn trainer(mut self, trainer: &'a dyn LocalTrainer) -> Self {
        self.trainer = Some(trainer);
        self
    }

    /// Override the per-device compressor factory (wins over the preset).
    pub fn compressor<F>(mut self, factory: F) -> Self
    where
        F: Fn(&BuildCtx, usize) -> Box<dyn Compressor> + Send + Sync + 'static,
    {
        self.compressor = Some(Arc::new(factory));
        self
    }

    /// Override the server aggregation rule (wins over the preset).
    pub fn aggregator<F>(mut self, factory: F) -> Self
    where
        F: Fn(&BuildCtx) -> Box<dyn Aggregator> + Send + Sync + 'static,
    {
        self.aggregator = Some(Arc::new(factory));
        self
    }

    /// Override the round policy (wins over the preset).
    pub fn policy<F>(mut self, factory: F) -> Self
    where
        F: Fn(&BuildCtx) -> Box<dyn RoundPolicy> + Send + Sync + 'static,
    {
        self.policy = Some(Arc::new(factory));
        self
    }

    /// Override the population cohort sampler (wins over the `sampler`
    /// config key). Setting it switches the experiment into population mode
    /// even without the config keys.
    pub fn sampler<F>(mut self, factory: F) -> Self
    where
        F: Fn(&BuildCtx) -> Box<dyn ClientSampler> + Send + Sync + 'static,
    {
        self.sampler = Some(Arc::new(factory));
        self
    }

    /// Asynchronous sync sets: device m syncs every `gaps[m]` rounds
    /// (each in `[1, h_max]`, the Alg. 1 gap bound).
    pub fn sync_gaps(mut self, gaps: Vec<usize>) -> Self {
        self.sync_gaps = Some(gaps);
        self
    }

    /// Pin the server sync mode (wins over the mechanism preset's default).
    pub fn sync_mode(mut self, mode: SyncMode) -> Self {
        self.cfg.sync_mode = Some(mode);
        self
    }

    /// Install a network scenario spec directly (tests / programmatic
    /// worlds) — equivalent to setting `cfg.scenario`.
    pub fn scenario(mut self, spec: ScenarioSpec) -> Self {
        self.cfg.scenario = Some(spec);
        self
    }

    pub fn build(self) -> Result<Experiment> {
        let cfg = self.cfg;
        cfg.validate().map_err(|e| anyhow!("invalid config: {e}"))?;
        let trainer = self
            .trainer
            .ok_or_else(|| anyhow!("ExperimentBuilder needs a trainer (builder.trainer(&t))"))?;

        // Resolve the three seams: explicit override, else registry preset.
        let preset = self.registry.get(cfg.mechanism.name());
        let need_preset =
            self.compressor.is_none() || self.aggregator.is_none() || self.policy.is_none();
        if need_preset && preset.is_none() {
            return Err(anyhow!(
                "unknown mechanism `{}` — registered: {}",
                cfg.mechanism.name(),
                self.registry.names().join(", ")
            ));
        }
        let compressor_f = self
            .compressor
            .unwrap_or_else(|| preset.unwrap().compressor.clone());
        let aggregator_f = self
            .aggregator
            .unwrap_or_else(|| preset.unwrap().aggregator.clone());
        let policy_f = self.policy.unwrap_or_else(|| preset.unwrap().policy.clone());
        // Sync-mode resolution: explicit config > preset default > barrier,
        // then standalone parameter overrides (`--buffer_k=4` works against
        // a preset-provided mode without restating `sync_mode`).
        let sync_mode = cfg
            .sync_mode
            .or_else(|| preset.and_then(|p| p.default_sync))
            .unwrap_or(SyncMode::Barrier);
        let sync_mode = match sync_mode {
            SyncMode::SemiAsync { buffer_k } => {
                SyncMode::SemiAsync { buffer_k: cfg.buffer_k.unwrap_or(buffer_k) }
            }
            SyncMode::FullyAsync { staleness_decay } => SyncMode::FullyAsync {
                staleness_decay: cfg.staleness_decay.unwrap_or(staleness_decay),
            },
            SyncMode::Barrier => SyncMode::Barrier,
        };
        sync_mode.validate().map_err(|e| anyhow!("invalid sync mode: {e}"))?;
        // Downlink resolution, same precedence shape as the sync mode:
        // explicit config > preset default > disabled, with the standalone
        // compression key overriding a preset-provided compression. Setting
        // `downlink_compression` alone enables the downlink (same
        // convention as the population keys) — a compression choice on a
        // disabled downlink would otherwise be silently ignored.
        let preset_downlink = preset.and_then(|p| p.default_downlink);
        let downlink_enabled = cfg
            .downlink
            .unwrap_or(preset_downlink.is_some() || cfg.downlink_compression.is_some());
        let downlink_compression = cfg
            .downlink_compression
            .or(preset_downlink)
            .unwrap_or(DownlinkCompression::Dense);

        let rng = Rng::new(cfg.seed);
        let init = trainer.init_params();
        let nparams = trainer.nparams();
        let compute = ComputeCostModel::for_params(nparams);
        let static_ks: Vec<usize> = cfg
            .layer_fracs
            .iter()
            .map(|&f| ((f * nparams as f64).round() as usize).max(1))
            .collect();
        // DRL action space: up to 2x the static total traffic, floor of 64.
        let d_total = (2 * static_ks.iter().sum::<usize>()).min(nparams);
        let d_min = 64.min(nparams);

        let ctx = BuildCtx { cfg: &cfg, nparams, static_ks: &static_ks, rng: &rng };
        let policy = policy_f(&ctx);

        // Population mode: any of the population/cohort/sampler knobs (or a
        // sampler override) switches from the permanently-materialized
        // device fleet to the lazy cohort store.
        let population_mode = cfg.population.is_some()
            || cfg.cohort.is_some()
            || cfg.sampler.is_some()
            || self.sampler.is_some();
        let pop_n = cfg.population.unwrap_or(cfg.devices);
        let n_clients = if population_mode { pop_n } else { cfg.devices };

        let (devices, population, client_sampler) = if population_mode {
            if self.sync_gaps.is_some() {
                return Err(anyhow!(
                    "sync_gaps pace a permanently-materialized fleet; population mode \
                     paces clients by cohort sampling instead"
                ));
            }
            let cohort_n = cfg.cohort.unwrap_or(pop_n);
            let kind = cfg.sampler.unwrap_or(if cohort_n < pop_n {
                SamplerKind::UniformK
            } else {
                SamplerKind::Full
            });
            let sampler: Box<dyn ClientSampler> = match &self.sampler {
                Some(f) => f(&ctx),
                None => population::build_sampler(kind, cohort_n, rng.fork(0x5A3D_17E5)),
            };
            // Seeds are built with the exact same per-id construction calls
            // (and per-id RNG draw order: channels → compressor → churn
            // fork) as the legacy device loop below, so FullParticipation
            // over a population of size `devices` replays the reference
            // loop bit for bit (tests/population.rs). The iterator is lazy:
            // the store admits seeds one at a time, pooling or dropping
            // each compressor box immediately, so build-time memory stays
            // O(model + cohort) even at a million clients.
            let pop = Population::new(
                (0..pop_n).map(|id| {
                    let shard = id % cfg.devices;
                    SpecSeed::new(
                        id,
                        DeviceChannels::new(&cfg.channel_types, &rng, id),
                        compressor_f(&ctx, id),
                        rng.fork(0xC4EA_0000 ^ (id as u64).wrapping_mul(0x9E37_79B9)),
                    )
                    .shard(shard)
                    .samples(trainer.device_samples(shard))
                    .meter(ResourceMeter::new(cfg.energy_budget, cfg.money_budget))
                    .compute(compute)
                }),
                cohort_n,
                cfg.churn_down,
                cfg.churn_up,
            );
            (Vec::new(), Some(pop), Some(sampler))
        } else {
            let devices: Vec<Device> = (0..cfg.devices)
                .map(|id| {
                    Device::new(
                        id,
                        init.clone(),
                        compressor_f(&ctx, id),
                        DeviceChannels::new(&cfg.channel_types, &rng, id),
                        ResourceMeter::new(cfg.energy_budget, cfg.money_budget),
                        compute,
                    )
                })
                .collect();
            (devices, None, None)
        };
        // Population mode defers DRL agent creation to first participation
        // (`sim::engine` materializes them with the identical seeded fork),
        // because an eager DDPG agent per client — MLPs, optimizer state, a
        // pre-reserved replay buffer — would make build-time memory
        // O(population × agent) and defeat the O(model + cohort) bound.
        let agents: Vec<Option<DeviceAgent>> = (0..n_clients)
            .map(|id| {
                if policy.needs_agents() && !population_mode {
                    Some(DeviceAgent::new_with(
                        cfg.channel_types.len(),
                        cfg.h_max,
                        d_total,
                        d_min,
                        cfg.drl.clone(),
                        rng.fork(0xD_00 + id as u64),
                        downlink_enabled,
                    ))
                } else {
                    None
                }
            })
            .collect();
        // The downlink: per-client fading links forked off an independent
        // stream, plus (legacy engines) one init-model mirror per device
        // for full-fidelity delta encoding. Population mode runs
        // accounting-only (see downlink module docs), so no mirrors.
        let mut downlink = if downlink_enabled {
            let mirrors = if population_mode {
                Vec::new()
            } else {
                (0..n_clients).map(|_| init.clone()).collect()
            };
            Some(Downlink::new(
                n_clients,
                downlink_compression,
                cfg.downlink_tariff_scale,
                &cfg.channel_types,
                &rng,
                static_ks.clone(),
                mirrors,
            ))
        } else {
            None
        };
        // The network scenario: forked-stream runtime plus the initial zone
        // configuration for every pre-materialized channel bundle (uplink
        // and downlink). Population-mode clients pick their configuration
        // up at materialization instead.
        let mut devices = devices;
        // NOMA shared-uplink resolution, same precedence shape as the
        // downlink/edge seams: explicit config > preset default > the
        // scenario spec's own `noma` key > off. Enabling NOMA without a
        // scenario synthesizes a trivial single-zone "shared-cell" world so
        // the contention divisor (the zone population) exists.
        let noma = cfg.noma.unwrap_or(
            preset.map_or(false, |p| p.default_noma)
                || cfg.scenario.as_ref().map_or(false, |s| s.noma),
        );
        let effective_scenario = match &cfg.scenario {
            Some(spec) => {
                let mut spec = spec.clone();
                spec.noma = noma;
                Some(spec)
            }
            None if noma => Some(ScenarioSpec {
                name: "shared-cell".to_string(),
                move_prob: 0.0,
                start_spread: false,
                trace_len: 1024,
                zones: vec![ZoneSpec {
                    name: "cell".to_string(),
                    channels: cfg.channel_types.clone(),
                    bw_scale: 1.0,
                    fading: FadingParams::default(),
                    dynamics: DynamicsKind::Markov,
                }],
                phases: Vec::new(),
                noma: true,
            }),
            None => None,
        };
        let scenario = match effective_scenario {
            Some(spec) => {
                let sc = Scenario::new(spec, n_clients, &cfg.channel_types, &rng)
                    .map_err(|e| anyhow!("invalid scenario: {e}"))?;
                for dev in &mut devices {
                    sc.configure(dev.id, &mut dev.channels);
                }
                if !population_mode {
                    if let Some(dl) = downlink.as_mut() {
                        for id in 0..n_clients {
                            sc.configure(id, dl.links_mut(id));
                        }
                    }
                }
                Some(sc)
            }
            None => None,
        };
        // The edge tier, resolved with the same precedence shape as the
        // downlink: explicit config > preset default > disabled, with any
        // `[edge]` key enabling the tier (a backhaul tuned on a disabled
        // edge would otherwise be silently ignored). One node per scenario
        // zone; a scenario-less world is a single zone behind one backhaul.
        let edge_enabled = cfg.edge.unwrap_or(
            preset.map_or(false, |p| p.default_edge) || cfg.edge_settings.is_some(),
        );
        let edge = if edge_enabled {
            let settings = cfg.edge_settings.clone().unwrap_or_default();
            let n_zones = scenario.as_ref().map_or(1, |sc| sc.n_zones());
            Some(Edge::new(settings, n_zones, n_clients, nparams, &rng))
        } else {
            None
        };

        let server = Server::with_aggregator(init, aggregator_f(&ctx));

        let sync_gap = match self.sync_gaps {
            Some(gaps) => {
                super::experiment::validate_sync_gaps(&gaps, cfg.devices, cfg.h_max)
                    .map_err(|e| anyhow!(e))?;
                gaps
            }
            None => vec![1; cfg.devices],
        };

        // The per-device decode buffers back the legacy engine paths only;
        // the cohort engines keep their own O(cohort) slot buffers.
        let m = devices.len();
        Ok(Experiment {
            server,
            devices,
            population,
            sampler: client_sampler,
            agents,
            policy,
            sync_gap,
            sync_mode,
            downlink,
            scenario,
            edge,
            sim_stats: SimStats::default(),
            recorder: crate::obs::Recorder::from_cfg(&cfg),
            rng,
            total_time_s: 0.0,
            d_total,
            d_min,
            recv_bufs: (0..m).map(|_| LgcUpdate { dim: 0, layers: Vec::new() }).collect(),
            received: vec![false; m],
            cfg,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::DenseNoop;
    use crate::config::{Mechanism, Workload};
    use crate::coordinator::aggregator::WeightedBySamples;
    use crate::coordinator::trainer::NativeLrTrainer;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig {
            mechanism: Mechanism::LgcStatic,
            workload: Workload::LrMnist,
            rounds: 4,
            devices: 2,
            samples_per_device: 128,
            eval_samples: 128,
            eval_every: 2,
            h_fixed: 2,
            h_max: 4,
            use_runtime: false,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn builds_from_registry_preset() {
        let c = cfg();
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert_eq!(exp.devices.len(), 2);
        assert_eq!(exp.server.aggregator_name(), "mean");
        assert!(exp.agents.iter().all(|a| a.is_none()));
    }

    #[test]
    fn ddpg_preset_creates_agents() {
        let mut c = cfg();
        c.mechanism = Mechanism::LgcDrl;
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert!(exp.agents.iter().all(|a| a.is_some()));
    }

    #[test]
    fn unknown_mechanism_lists_registered() {
        let mut c = cfg();
        c.mechanism = Mechanism::custom("nope");
        let trainer = NativeLrTrainer::new(&c);
        let err = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("nope") && msg.contains("lgc-static"), "{msg}");
    }

    #[test]
    fn explicit_seams_override_preset() {
        let c = cfg();
        let trainer = NativeLrTrainer::new(&c);
        let mut exp = ExperimentBuilder::new(c)
            .trainer(&trainer)
            .compressor(|_ctx, _id| Box::new(DenseNoop))
            .aggregator(|_ctx| Box::new(WeightedBySamples::new()))
            .build()
            .unwrap();
        assert_eq!(exp.server.aggregator_name(), "weighted-by-samples");
        assert_eq!(exp.devices[0].compressor_name(), "dense");
        // and it still trains
        let mut trainer2 = NativeLrTrainer::new(&exp.cfg);
        let log = exp.run(&mut trainer2).unwrap();
        assert_eq!(log.records.len(), 4);
    }

    #[test]
    fn sync_mode_resolution_config_over_preset_over_barrier() {
        // Preset default: the lgc-semi-async preset carries SemiAsync.
        let mut c = cfg();
        c.mechanism = Mechanism::parse("lgc-semi-async").unwrap();
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert_eq!(exp.sync_mode, SyncMode::SemiAsync { buffer_k: 2 });
        // Explicit builder/config choice wins over the preset default.
        let mut c2 = cfg();
        c2.mechanism = Mechanism::parse("lgc-semi-async").unwrap();
        let trainer2 = NativeLrTrainer::new(&c2);
        let exp2 = ExperimentBuilder::new(c2)
            .trainer(&trainer2)
            .sync_mode(SyncMode::Barrier)
            .build()
            .unwrap();
        assert_eq!(exp2.sync_mode, SyncMode::Barrier);
        // No preset default, no config: barrier.
        let c3 = cfg();
        let trainer3 = NativeLrTrainer::new(&c3);
        let exp3 = ExperimentBuilder::new(c3).trainer(&trainer3).build().unwrap();
        assert_eq!(exp3.sync_mode, SyncMode::Barrier);
        // A standalone buffer_k override reparameterizes the preset's mode
        // without restating sync_mode.
        let mut c4 = cfg();
        c4.mechanism = Mechanism::parse("lgc-semi-async").unwrap();
        c4.buffer_k = Some(4);
        let trainer4 = NativeLrTrainer::new(&c4);
        let exp4 = ExperimentBuilder::new(c4).trainer(&trainer4).build().unwrap();
        assert_eq!(exp4.sync_mode, SyncMode::SemiAsync { buffer_k: 4 });
    }

    #[test]
    fn downlink_resolution_config_over_preset_over_disabled() {
        use crate::downlink::DownlinkCompression;
        // Default: disabled — the frozen free-broadcast semantics.
        let c = cfg();
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert!(exp.downlink.is_none());
        // The lgc-downlink preset enables the layered downlink by default.
        let mut c2 = cfg();
        c2.mechanism = Mechanism::parse("lgc-downlink").unwrap();
        let trainer2 = NativeLrTrainer::new(&c2);
        let exp2 = ExperimentBuilder::new(c2).trainer(&trainer2).build().unwrap();
        let dl = exp2.downlink.as_ref().expect("preset enables downlink");
        assert_eq!(dl.compression(), DownlinkCompression::Layered);
        assert!(!dl.accounting_only());
        // Explicit config wins over the preset default.
        let mut c3 = cfg();
        c3.mechanism = Mechanism::parse("lgc-downlink").unwrap();
        c3.downlink = Some(false);
        let trainer3 = NativeLrTrainer::new(&c3);
        let exp3 = ExperimentBuilder::new(c3).trainer(&trainer3).build().unwrap();
        assert!(exp3.downlink.is_none());
        // Standalone enable on a preset without a default: dense fallback.
        let mut c4 = cfg();
        c4.downlink = Some(true);
        let trainer4 = NativeLrTrainer::new(&c4);
        let exp4 = ExperimentBuilder::new(c4).trainer(&trainer4).build().unwrap();
        assert_eq!(
            exp4.downlink.as_ref().unwrap().compression(),
            DownlinkCompression::Dense
        );
        // Population mode gets the accounting-only downlink.
        let mut c5 = cfg();
        c5.downlink = Some(true);
        c5.population = Some(6);
        c5.cohort = Some(2);
        let trainer5 = NativeLrTrainer::new(&c5);
        let exp5 = ExperimentBuilder::new(c5).trainer(&trainer5).build().unwrap();
        assert!(exp5.downlink.as_ref().unwrap().accounting_only());
        // A bare compression key enables the downlink (population-keys
        // convention) instead of being silently ignored...
        let mut c6 = cfg();
        c6.downlink_compression = Some(DownlinkCompression::Layered);
        let trainer6 = NativeLrTrainer::new(&c6);
        let exp6 = ExperimentBuilder::new(c6).trainer(&trainer6).build().unwrap();
        assert_eq!(
            exp6.downlink.as_ref().unwrap().compression(),
            DownlinkCompression::Layered
        );
        // ...unless downlink = false says otherwise.
        let mut c7 = cfg();
        c7.downlink = Some(false);
        c7.downlink_compression = Some(DownlinkCompression::Layered);
        let trainer7 = NativeLrTrainer::new(&c7);
        let exp7 = ExperimentBuilder::new(c7).trainer(&trainer7).build().unwrap();
        assert!(exp7.downlink.is_none());
    }

    #[test]
    fn edge_resolution_config_over_preset_over_disabled() {
        use crate::edge::EdgeSettings;
        // Default: disabled — the frozen flat-topology semantics.
        let c = cfg();
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert!(exp.edge.is_none());
        // The lgc-edge preset enables the tier (and semi-async) by default.
        let mut c2 = cfg();
        c2.mechanism = Mechanism::parse("lgc-edge").unwrap();
        let trainer2 = NativeLrTrainer::new(&c2);
        let exp2 = ExperimentBuilder::new(c2).trainer(&trainer2).build().unwrap();
        let edge = exp2.edge.as_ref().expect("preset enables the edge tier");
        assert_eq!(edge.n_zones(), 1, "scenario-less world is one zone");
        assert_eq!(exp2.sync_mode, SyncMode::SemiAsync { buffer_k: 2 });
        // Explicit config wins over the preset default.
        let mut c3 = cfg();
        c3.mechanism = Mechanism::parse("lgc-edge").unwrap();
        c3.edge = Some(false);
        let trainer3 = NativeLrTrainer::new(&c3);
        let exp3 = ExperimentBuilder::new(c3).trainer(&trainer3).build().unwrap();
        assert!(exp3.edge.is_none());
        // A bare [edge] parameter enables the tier on any preset.
        let mut c4 = cfg();
        c4.edge_settings = Some(EdgeSettings { flush_k: 3, ..EdgeSettings::default() });
        let trainer4 = NativeLrTrainer::new(&c4);
        let exp4 = ExperimentBuilder::new(c4).trainer(&trainer4).build().unwrap();
        assert_eq!(exp4.edge.as_ref().unwrap().settings().flush_k, 3);
        // With a scenario, the tier gets one node per zone.
        let mut c5 = cfg();
        c5.edge = Some(true);
        c5.scenario = Some(crate::scenario::ScenarioRegistry::resolve("commute").unwrap());
        let trainer5 = NativeLrTrainer::new(&c5);
        let exp5 = ExperimentBuilder::new(c5).trainer(&trainer5).build().unwrap();
        assert_eq!(exp5.edge.as_ref().unwrap().n_zones(), 3);
    }

    #[test]
    fn noma_resolution_config_over_preset_over_scenario_over_off() {
        // Default: off — no scenario, no NOMA, the frozen oracle world.
        let c = cfg();
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert!(exp.scenario.is_none());
        // The lgc-noma preset synthesizes the single shared-cell world.
        let mut c2 = cfg();
        c2.mechanism = Mechanism::parse("lgc-noma").unwrap();
        let trainer2 = NativeLrTrainer::new(&c2);
        let exp2 = ExperimentBuilder::new(c2).trainer(&trainer2).build().unwrap();
        let sc = exp2.scenario.as_ref().expect("preset synthesizes a world");
        assert!(sc.noma());
        assert_eq!(sc.name(), "shared-cell");
        assert_eq!(sc.n_zones(), 1);
        // Explicit config wins over the preset default.
        let mut c3 = cfg();
        c3.mechanism = Mechanism::parse("lgc-noma").unwrap();
        c3.noma = Some(false);
        let trainer3 = NativeLrTrainer::new(&c3);
        let exp3 = ExperimentBuilder::new(c3).trainer(&trainer3).build().unwrap();
        assert!(exp3.scenario.is_none(), "noma = false suppresses the synthesized world");
        // `noma = true` rides an existing scenario instead of synthesizing.
        let mut c4 = cfg();
        c4.noma = Some(true);
        c4.scenario = Some(crate::scenario::ScenarioRegistry::resolve("commute").unwrap());
        let trainer4 = NativeLrTrainer::new(&c4);
        let exp4 = ExperimentBuilder::new(c4).trainer(&trainer4).build().unwrap();
        let sc4 = exp4.scenario.as_ref().unwrap();
        assert!(sc4.noma());
        assert_eq!(sc4.name(), "commute");
        // And an explicit scenario stays independent-links without the key.
        let mut c5 = cfg();
        c5.scenario = Some(crate::scenario::ScenarioRegistry::resolve("commute").unwrap());
        let trainer5 = NativeLrTrainer::new(&c5);
        let exp5 = ExperimentBuilder::new(c5).trainer(&trainer5).build().unwrap();
        assert!(!exp5.scenario.as_ref().unwrap().noma());
    }

    #[test]
    fn run_label_composes_active_seams() {
        let mut c = cfg();
        c.mechanism = Mechanism::parse("lgc-edge").unwrap();
        c.downlink = Some(true);
        c.scenario = Some(crate::scenario::ScenarioRegistry::resolve("commute").unwrap());
        let trainer = NativeLrTrainer::new(&c);
        let exp = ExperimentBuilder::new(c).trainer(&trainer).build().unwrap();
        assert_eq!(exp.run_label(), "lgc-edge-lr+downlink+edge+commute");
        let c2 = cfg();
        let trainer2 = NativeLrTrainer::new(&c2);
        let exp2 = ExperimentBuilder::new(c2).trainer(&trainer2).build().unwrap();
        assert_eq!(exp2.run_label(), "lgc-static-lr");
    }

    #[test]
    fn missing_trainer_is_an_error() {
        let err = ExperimentBuilder::new(cfg()).build().unwrap_err();
        assert!(format!("{err}").contains("trainer"));
    }

    #[test]
    fn custom_compressor_override_runs() {
        // The DESIGN.md worked example: a dense reference run on the
        // lgc-static policy, via one builder call.
        let c = cfg();
        let trainer = NativeLrTrainer::new(&c);
        let mut exp = ExperimentBuilder::new(c)
            .trainer(&trainer)
            .compressor(|_ctx, _id| Box::new(DenseNoop))
            .build()
            .unwrap();
        let mut trainer2 = NativeLrTrainer::new(&exp.cfg);
        let log = exp.run(&mut trainer2).unwrap();
        assert_eq!(log.records.len(), 4);
    }
}
