//! The experiment orchestrator: wires server, devices, channels, budgets,
//! and the per-round control policy into the full training loop of
//! Algorithm 1.
//!
//! The round loop is **mechanism-free**: everything mechanism-specific is
//! carried by the three seams assembled by
//! [`super::builder::ExperimentBuilder`] —
//!
//! - each device's [`crate::compression::Compressor`] (what is uploaded and
//!   how bytes are accounted),
//! - the server's [`super::aggregator::Aggregator`] (how uploads combine),
//! - the experiment's [`super::policy::RoundPolicy`] (per-round `H` and
//!   layer-to-channel plan, learning from outcomes).
//!
//! Execution itself runs on the discrete-event engine in [`crate::sim`]
//! under the experiment's [`SyncMode`] (barrier / semi-async / fully-async).
//! [`Experiment::step_round`] is the original synchronous loop, kept as the
//! bit-for-bit reference that the engine's barrier mode is proven against
//! (`tests/sim_engine.rs`) and as the stepping API for callers that
//! interleave rounds with their own logic (DRL episode benches).

use anyhow::Result;

use super::device::Device;
use super::policy::RoundPolicy;
use super::server::Server;
use super::trainer::LocalTrainer;
use crate::compression::LgcUpdate;
use crate::config::ExperimentConfig;
use crate::downlink::Downlink;
use crate::drl::DeviceAgent;
use crate::edge::Edge;
use crate::metrics::{percentile, RoundRecord, RunLog};
use crate::population::{ClientSampler, Population};
use crate::resources::ResourceMeter;
use crate::scenario::Scenario;
use crate::sim::{SimStats, SyncMode};
use crate::util::Rng;

/// A full FL experiment (one mechanism preset, one workload).
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub server: Server,
    /// The permanently-materialized device fleet (legacy path). Empty in
    /// population mode, where devices live transiently inside
    /// [`Experiment::population`] and only the sampled cohort is
    /// materialized each round.
    pub devices: Vec<Device>,
    /// One [`Population`] of cheap per-client specs when the config enables
    /// population mode (`population` / `cohort` / `sampler` keys);
    /// `None` on the legacy path.
    pub population: Option<Population>,
    /// The cohort-selection seam (population mode only).
    pub sampler: Option<Box<dyn ClientSampler>>,
    /// Per-client DRL agents — indexed by client id (population mode) or
    /// device id (legacy), `None` entries for non-DRL policies.
    pub agents: Vec<Option<DeviceAgent>>,
    /// The per-round control policy (decides H and the allocation plan).
    pub policy: Box<dyn RoundPolicy>,
    /// Device m synchronizes when `round % sync_gap[m] == 0` (gap(I_m) ≤ H).
    /// Barrier-mode concept; the async modes pace devices by arrival instead.
    pub sync_gap: Vec<usize>,
    /// Server synchronization discipline (resolved by the builder:
    /// `cfg.sync_mode` > mechanism-preset default > `Barrier`).
    pub sync_mode: SyncMode,
    /// The simulated downlink (resolved by the builder: `cfg.downlink` >
    /// mechanism-preset default > disabled). `None` keeps the legacy
    /// free-instant-broadcast semantics, bit-for-bit.
    pub downlink: Option<Downlink>,
    /// The live network scenario (trace-driven dynamics, mobility &
    /// handoff), built from `cfg.scenario`. `None` keeps the static
    /// single-world oracle semantics, bit-for-bit.
    pub scenario: Option<Scenario>,
    /// The hierarchical edge tier (per-zone aggregation nodes with their
    /// own backhaul links), resolved by the builder: `cfg.edge` >
    /// mechanism-preset default > disabled. `None` keeps the flat
    /// device-to-cloud topology, bit-for-bit.
    pub edge: Option<Edge>,
    /// Event-engine counters from the most recent [`Experiment::run`].
    pub sim_stats: SimStats,
    /// The telemetry seam: a zero-cost no-op by default (`trace` off), a
    /// buffered JSONL recorder + wall-clock phase timers when the config
    /// enables them. The engines take it out for the duration of a run
    /// (like the population store) and hand it back with the buffered
    /// trace and timers filled.
    pub recorder: crate::obs::Recorder,
    pub(super) rng: Rng,
    pub(crate) total_time_s: f64,
    pub(super) d_total: usize,
    pub(super) d_min: usize,
    /// Reusable per-device decode buffers: the server's wire round-trip
    /// lands here, so the sparse-wire hot path allocates nothing at steady
    /// state. (Dense/packed compressors hand over a freshly built update —
    /// same per-round cost as the seed's FedAvg path.)
    pub(crate) recv_bufs: Vec<LgcUpdate>,
    /// Which devices delivered an upload this round.
    pub(crate) received: Vec<bool>,
}

impl Experiment {
    /// Build with the mechanism preset named by `cfg.mechanism` — a thin
    /// wrapper over [`super::builder::ExperimentBuilder`]; panics on an
    /// invalid config or unknown mechanism (use the builder directly for
    /// recoverable errors or custom seams).
    pub fn new(cfg: ExperimentConfig, trainer: &dyn LocalTrainer) -> Self {
        super::builder::ExperimentBuilder::new(cfg)
            .trainer(trainer)
            .build()
            .expect("experiment build failed")
    }

    /// Configure asynchronous sync sets I_m: device m syncs every `gap[m]`
    /// rounds (must be in [1, h_max] to respect gap(I_m) ≤ H). Panicking
    /// convenience over the same validation the builder reports as an error.
    pub fn with_sync_gaps(mut self, gaps: Vec<usize>) -> Self {
        validate_sync_gaps(&gaps, self.devices.len(), self.cfg.h_max)
            .unwrap_or_else(|e| panic!("{e}"));
        self.sync_gap = gaps;
        self
    }

    /// Override the sync mode after building (test/bench convenience; the
    /// canonical path is `cfg.sync_mode` or a mechanism-preset default).
    pub fn with_sync_mode(mut self, mode: SyncMode) -> Self {
        mode.validate().unwrap_or_else(|e| panic!("{e}"));
        self.sync_mode = mode;
        self
    }

    /// Run the full experiment on the discrete-event engine under
    /// [`Experiment::sync_mode`]; returns the per-round log (one record per
    /// round under barrier, one per server aggregation in the async modes).
    pub fn run(&mut self, trainer: &mut dyn LocalTrainer) -> Result<RunLog> {
        let mut log = RunLog::new(&self.run_label());
        crate::sim::engine::run(self, trainer, &mut log)?;
        Ok(log)
    }

    /// The run label: `mechanism-model` plus one `+suffix` per active seam
    /// (`+downlink`, `+edge`, `+<scenario>`), in that fixed order. The
    /// single source of truth for `compare` output and CSV names — two runs
    /// that differ in any seam never collide on a label, and no other code
    /// path appends its own suffixes.
    pub fn run_label(&self) -> String {
        let mut name = format!(
            "{}-{}",
            self.cfg.mechanism.name(),
            self.cfg.workload.model_name()
        );
        if self.downlink.is_some() {
            name.push_str("+downlink");
        }
        if self.edge.is_some() {
            name.push_str("+edge");
        }
        if let Some(sc) = &self.scenario {
            name.push_str(&format!("+{}", sc.name()));
        }
        name
    }

    /// Execute one round of the **synchronous reference loop** (the
    /// pre-engine semantics, equal to the engine's barrier mode bit for
    /// bit). Returns None when every device is out of budget.
    pub fn step_round(
        &mut self,
        round: usize,
        trainer: &mut dyn LocalTrainer,
    ) -> Result<Option<RoundRecord>> {
        assert!(
            self.population.is_none(),
            "step_round drives the legacy fully-materialized loop; population-mode \
             experiments run their cohort engine via Experiment::run"
        );
        assert!(
            self.downlink.is_none(),
            "step_round is the frozen pre-downlink reference oracle; downlink-enabled \
             experiments run the event engine via Experiment::run"
        );
        assert!(
            self.scenario.is_none(),
            "step_round is the frozen static-world reference oracle; scenario-enabled \
             experiments run the event engine via Experiment::run"
        );
        assert!(
            self.edge.is_none(),
            "step_round is the frozen flat-topology reference oracle; edge-enabled \
             experiments run the event engine via Experiment::run"
        );
        let m = self.devices.len();
        // 1. Network dynamics advance.
        for dev in &mut self.devices {
            dev.channels.step_round();
        }
        // 2. Which devices participate (budget) and which sync this round.
        let active: Vec<bool> = self.devices.iter().map(|d| d.meter.within_budget()).collect();
        if active.iter().all(|&a| !a) {
            return Ok(None);
        }
        let syncs: Vec<bool> = (0..m)
            .map(|i| active[i] && (round + 1) % self.sync_gap[i] == 0)
            .collect();

        // 3. Per-device local work + upload.
        self.received.iter_mut().for_each(|r| *r = false);
        let mut round_wall = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut bytes_up = 0u64;
        let mut reward_acc = 0.0f64;
        let mut reward_n = 0usize;
        let mut finishes: Vec<f64> = Vec::with_capacity(m);

        for i in 0..m {
            if !active[i] {
                continue;
            }
            // --- decide (H, plan): the policy seam ----------------------
            let (h, plan) =
                self.policy
                    .decide(round, &self.devices[i], self.agents[i].as_mut());

            // --- local computation (lines 5-7) --------------------------
            let dev = &mut self.devices[i];
            let loss = dev.local_steps(trainer, h, self.cfg.lr)?;
            loss_sum += loss;
            loss_n += 1;
            let (comp_j, comp_s) = dev.compute_cost(h);

            // --- communication (lines 8-11): the compressor seam --------
            let (mut wall, comm_j, comm_money, bytes) = if syncs[i] {
                let (update, wall, costs) = dev.compress_and_upload(&plan);
                // An empty update (all-silent plan) means the device did
                // not upload: it must not be treated as received — and must
                // not be synced below — or its accumulated local progress
                // would be silently discarded.
                if !update.layers.is_empty() {
                    if dev.sparse_wire() {
                        // Round-trip through the wire format, as the server
                        // sees it, into this device's reusable buffer.
                        self.server
                            .decode_from_wire_into(&update, &mut self.recv_bufs[i])?;
                    } else {
                        self.recv_bufs[i] = update;
                    }
                    self.received[i] = true;
                }
                let (j, mo, by) = crate::channels::TransferCost::fold_totals(&costs);
                (wall, j, mo, by)
            } else {
                (0.0, 0.0, 0.0, 0) // no sync this round (Alg. 1 lines 14-17)
            };
            wall += comp_s;
            round_wall = round_wall.max(wall);
            finishes.push(wall);
            dev.meter.record_round(comp_j, comm_j, comm_money, wall);
            if dev.prev_loss.is_nan() {
                dev.prev_loss = loss;
            }
            bytes_up += bytes;

            // δ = loss improvement this round (Eq. 15a, sign flipped so
            // positive = better), feeding the policy's learning signal.
            let delta = dev.prev_loss - loss;
            dev.prev_loss = loss;
            dev.last_delta = delta;
            let done = round + 1 == self.cfg.rounds;
            if let Some(r) =
                self.policy
                    .observe(&self.devices[i], self.agents[i].as_mut(), delta, done)
            {
                reward_acc += r;
                reward_n += 1;
            }
        }

        // 4. Server aggregation + broadcast (lines 18-22): the aggregator
        // seam. Weights announce local sample counts for rules that use
        // them (e.g. WeightedBySamples); the default mean ignores them.
        let received_idx: Vec<usize> = (0..m).filter(|&i| self.received[i]).collect();
        if !received_idx.is_empty() {
            let weights: Vec<f64> = received_idx
                .iter()
                .map(|&i| trainer.device_samples(i) as f64)
                .collect();
            let uploads: Vec<&LgcUpdate> =
                received_idx.iter().map(|&i| &self.recv_bufs[i]).collect();
            self.server.set_round_weights(&weights);
            self.server.aggregate_and_apply(&uploads);
            for &i in &received_idx {
                self.devices[i].sync(&self.server.params);
            }
        }

        // 5. Evaluate + record.
        self.total_time_s += round_wall;
        let (eval_loss, eval_acc) = if round % self.cfg.eval_every == 0
            || round + 1 == self.cfg.rounds
        {
            trainer.eval(&self.server.params)?
        } else {
            (f64::NAN, f64::NAN)
        };
        let (tot_energy, tot_money) = self.devices.iter().fold((0.0, 0.0), |acc, d| {
            (acc.0 + d.meter.energy_used, acc.1 + d.meter.money_used)
        });
        Ok(Some(RoundRecord {
            round,
            train_loss: loss_sum / loss_n.max(1) as f64,
            eval_loss,
            eval_acc,
            energy_j: tot_energy,
            money: tot_money,
            round_time_s: round_wall,
            total_time_s: self.total_time_s,
            bytes_up,
            drl_reward: if reward_n > 0 {
                reward_acc / reward_n as f64
            } else {
                f64::NAN
            },
            finish_p50_s: percentile(&mut finishes, 50.0),
            finish_p95_s: percentile(&mut finishes, 95.0),
            stale_updates: 0,
            sampled: active.iter().filter(|&&a| a).count() as u64,
            completed: received_idx.len() as u64,
            dropped_offline: 0,
            staleness_p50: 0.0,
            staleness_p95: 0.0,
            down_bytes: 0,
            down_energy_j: 0.0,
            down_money: 0.0,
            handoffs: 0,
            dropped_handoff: 0,
            zone_p50: 0.0,
            backhaul_bytes: 0,
            backhaul_p95_s: 0.0,
            migrated_handoff: 0,
            edge_rounds_bound: 0,
            bound_by: "",
            crit_client: -1,
            crit_channel: -1,
        }))
    }

    /// Reset the FL problem for a new DRL episode (paper Fig. 5: the DRL
    /// agents persist and keep learning across episodes, while the FL model,
    /// error memories, meters and reward trackers restart).
    pub fn reset_episode(&mut self, trainer: &dyn LocalTrainer) {
        let init = trainer.init_params();
        self.server.reset_model(init.clone());
        for dev in &mut self.devices {
            dev.sync(&init);
            dev.reset_compressor();
            dev.prev_loss = f64::NAN;
            dev.last_delta = 0.0;
            dev.meter = ResourceMeter::new(self.cfg.energy_budget, self.cfg.money_budget);
        }
        for agent in self.agents.iter_mut().flatten() {
            agent.tracker = Default::default();
            agent.ddpg.reset_noise();
        }
        if let Some(pop) = &mut self.population {
            pop.reset_episode(self.cfg.energy_budget, self.cfg.money_budget);
        }
        if let Some(dl) = &mut self.downlink {
            dl.reset_episode(&init);
        }
        if let Some(edge) = &mut self.edge {
            edge.reset_episode();
        }
        if let Some(sc) = &mut self.scenario {
            sc.reset_episode();
            // Devices return to their initial zone's channel configuration
            // (the downlink bundles too); fading chains keep their streams.
            for dev in &mut self.devices {
                sc.configure(dev.id, &mut dev.channels);
            }
            if let Some(dl) = &mut self.downlink {
                for id in 0..self.agents.len() {
                    sc.configure(id, dl.links_mut(id));
                }
            }
        }
        for dev in &mut self.devices {
            dev.sync_state = Default::default();
        }
        self.total_time_s = 0.0;
    }

    /// Exploration RNG access for deterministic test setups.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn d_bounds(&self) -> (usize, usize) {
        (self.d_min, self.d_total)
    }
}

/// The single source of truth for the Alg. 1 sync-gap bounds, shared by
/// [`Experiment::with_sync_gaps`] and the builder.
pub(super) fn validate_sync_gaps(
    gaps: &[usize],
    devices: usize,
    h_max: usize,
) -> Result<(), String> {
    if gaps.len() != devices {
        return Err(format!("sync_gaps has {} entries for {devices} devices", gaps.len()));
    }
    if !gaps.iter().all(|&g| g >= 1 && g <= h_max) {
        return Err(format!("sync gaps must lie in [1, h_max={h_max}]"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, Mechanism, Workload};
    use crate::coordinator::trainer::NativeLrTrainer;

    fn cfg(mechanism: Mechanism, rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            mechanism,
            workload: Workload::LrMnist,
            rounds,
            devices: 3,
            samples_per_device: 256,
            eval_samples: 256,
            eval_every: 2,
            lr: 0.05,
            h_fixed: 2,
            h_max: 4,
            use_runtime: false,
            ..ExperimentConfig::default()
        }
    }

    fn run(mechanism: Mechanism, rounds: usize) -> crate::metrics::RunLog {
        let cfg = cfg(mechanism, rounds);
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        exp.run(&mut trainer).unwrap()
    }

    #[test]
    fn fedavg_learns() {
        let log = run(Mechanism::FedAvg, 30);
        assert_eq!(log.records.len(), 30);
        assert!(log.final_acc() > 0.5, "acc={}", log.final_acc());
        let first = log.records.first().unwrap().train_loss;
        let last = log.records.last().unwrap().train_loss;
        assert!(last < first);
    }

    #[test]
    fn lgc_static_learns_with_fewer_bytes_than_fedavg() {
        let lgc = run(Mechanism::LgcStatic, 30);
        let fed = run(Mechanism::FedAvg, 30);
        assert!(lgc.final_acc() > 0.5, "lgc acc={}", lgc.final_acc());
        let lgc_bytes: u64 = lgc.records.iter().map(|r| r.bytes_up).sum();
        let fed_bytes: u64 = fed.records.iter().map(|r| r.bytes_up).sum();
        assert!(
            (lgc_bytes as f64) < 0.5 * fed_bytes as f64,
            "lgc {lgc_bytes} vs fedavg {fed_bytes}"
        );
    }

    #[test]
    fn lgc_drl_runs_and_rewards_finite() {
        let log = run(Mechanism::LgcDrl, 16);
        assert_eq!(log.records.len(), 16);
        assert!(log.records.iter().all(|r| r.drl_reward.is_finite()));
        assert!(log.final_acc() > 0.3, "acc={}", log.final_acc());
    }

    #[test]
    fn topk_baseline_runs() {
        let log = run(Mechanism::TopK, 12);
        assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    }

    #[test]
    fn rand_k_baseline_runs() {
        let log = run(Mechanism::RandK, 20);
        assert_eq!(log.records.len(), 20);
        assert!(log.final_acc() > 0.3, "acc={}", log.final_acc());
    }

    #[test]
    fn qsgd_baseline_runs() {
        let log = run(Mechanism::Qsgd, 12);
        assert_eq!(log.records.len(), 12);
        assert!(log.final_acc() > 0.3, "acc={}", log.final_acc());
    }

    #[test]
    fn energy_and_money_monotone() {
        let log = run(Mechanism::LgcStatic, 10);
        for w in log.records.windows(2) {
            assert!(w[1].energy_j >= w[0].energy_j);
            assert!(w[1].money >= w[0].money);
            assert!(w[1].total_time_s >= w[0].total_time_s);
        }
    }

    #[test]
    fn budget_stops_training() {
        let mut c = cfg(Mechanism::LgcStatic, 50);
        c.energy_budget = 40.0; // tiny: a few rounds of compute+comm
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = Experiment::new(c, &trainer);
        let log = exp.run(&mut trainer).unwrap();
        assert!(log.records.len() < 50, "should stop early, ran {}", log.records.len());
    }

    #[test]
    fn async_gaps_respected() {
        let c = cfg(Mechanism::LgcStatic, 12);
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = Experiment::new(c, &trainer).with_sync_gaps(vec![1, 2, 3]);
        let log = exp.run(&mut trainer).unwrap();
        assert_eq!(log.records.len(), 12);
        // device 2 uploads only every 3rd round; total bytes lower than all-sync
        assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    }

    #[test]
    fn fedavg_equals_centralized_sgd_when_single_device_h1() {
        // M=1, H=1 FedAvg is plain SGD on the global model: loss must drop
        // monotonically-ish and match a hand-rolled loop on the same data.
        let mut c = cfg(Mechanism::FedAvg, 8);
        c.devices = 1;
        c.h_fixed = 1;
        c.h_max = 1;
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = Experiment::new(c, &trainer);
        let log = exp.run(&mut trainer).unwrap();
        let first = log.records.first().unwrap().train_loss;
        let last = log.records.last().unwrap().train_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Mechanism::LgcStatic, 6);
        let b = run(Mechanism::LgcStatic, 6);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.bytes_up, y.bytes_up);
        }
    }

    #[test]
    fn round_loop_has_no_mechanism_branching() {
        // Smoke-check the seam design: the same Experiment type runs a
        // custom mechanism that exists only in the registry.
        use crate::compression::{DenseNoop, ErrorCompensated, LgcTopAB};
        use crate::coordinator::builder::ExperimentBuilder;
        let mut c = cfg(Mechanism::custom("half-dense"), 6);
        c.devices = 2;
        let trainer = NativeLrTrainer::new(&c);
        let mut exp = ExperimentBuilder::new(c)
            .trainer(&trainer)
            .compressor(|_ctx, id| {
                if id % 2 == 0 {
                    Box::new(DenseNoop)
                } else {
                    Box::new(ErrorCompensated::new(LgcTopAB))
                }
            })
            .aggregator(|_ctx| Box::new(crate::coordinator::aggregator::MeanAggregator))
            .policy(|ctx| {
                Box::new(crate::coordinator::policy::StaticLayered {
                    h: ctx.cfg.h_fixed,
                    counts: vec![64; ctx.cfg.channel_types.len()],
                })
            })
            .build()
            .unwrap();
        let mut trainer2 = NativeLrTrainer::new(&exp.cfg);
        let log = exp.run(&mut trainer2).unwrap();
        assert_eq!(log.records.len(), 6);
    }
}
