//! The experiment orchestrator: wires server, devices, channels, budgets,
//! and (for LGC-DRL) the per-device DDPG controllers into the full training
//! loop of Algorithm 1, for every mechanism of Sec. 4.1.

use anyhow::Result;

use super::device::Device;
use super::server::Server;
use super::trainer::LocalTrainer;
use crate::channels::{AllocationPlan, DeviceChannels};
use crate::config::{ExperimentConfig, Mechanism};
use crate::drl::DeviceAgent;
use crate::metrics::{RoundRecord, RunLog};
use crate::resources::{ComputeCostModel, ResourceMeter};
use crate::util::Rng;

/// A full FL experiment (one mechanism, one workload).
pub struct Experiment {
    pub cfg: ExperimentConfig,
    pub server: Server,
    pub devices: Vec<Device>,
    pub agents: Vec<Option<DeviceAgent>>,
    /// Device m synchronizes when `round % sync_gap[m] == 0` (gap(I_m) ≤ H).
    pub sync_gap: Vec<usize>,
    rng: Rng,
    total_time_s: f64,
    /// Per-device static layer budgets (ks) for non-DRL mechanisms.
    static_ks: Vec<usize>,
    d_total: usize,
    d_min: usize,
}

impl Experiment {
    pub fn new(cfg: ExperimentConfig, trainer: &dyn LocalTrainer) -> Self {
        let rng = Rng::new(cfg.seed);
        let init = trainer.init_params();
        let nparams = trainer.nparams();
        let compute = ComputeCostModel::for_params(nparams);
        let devices: Vec<Device> = (0..cfg.devices)
            .map(|id| {
                Device::new(
                    id,
                    init.clone(),
                    DeviceChannels::new(&cfg.channel_types, &rng, id),
                    ResourceMeter::new(cfg.energy_budget, cfg.money_budget),
                    compute,
                )
            })
            .collect();
        let static_ks: Vec<usize> = cfg
            .layer_fracs
            .iter()
            .map(|&f| ((f * nparams as f64).round() as usize).max(1))
            .collect();
        // DRL action space: up to 2x the static total traffic, floor of 64.
        let d_total = (2 * static_ks.iter().sum::<usize>()).min(nparams);
        let d_min = 64.min(nparams);
        let agents: Vec<Option<DeviceAgent>> = (0..cfg.devices)
            .map(|id| {
                if cfg.mechanism == Mechanism::LgcDrl {
                    Some(DeviceAgent::new(
                        cfg.channel_types.len(),
                        cfg.h_max,
                        d_total,
                        d_min,
                        cfg.drl.clone(),
                        rng.fork(0xD_00 + id as u64),
                    ))
                } else {
                    None
                }
            })
            .collect();
        Experiment {
            server: Server::new(init),
            sync_gap: vec![1; cfg.devices],
            rng,
            total_time_s: 0.0,
            static_ks,
            d_total,
            d_min,
            devices,
            agents,
            cfg,
        }
    }

    /// Configure asynchronous sync sets I_m: device m syncs every `gap[m]`
    /// rounds (must be in [1, h_max] to respect gap(I_m) ≤ H).
    pub fn with_sync_gaps(mut self, gaps: Vec<usize>) -> Self {
        assert_eq!(gaps.len(), self.devices.len());
        assert!(gaps.iter().all(|&g| g >= 1 && g <= self.cfg.h_max));
        self.sync_gap = gaps;
        self
    }

    /// The fixed layer-to-channel plan for non-DRL LGC: layer c on channel c.
    fn static_plan(&self) -> AllocationPlan {
        let mut counts = vec![0usize; self.cfg.channel_types.len()];
        for (c, &k) in self.static_ks.iter().enumerate() {
            counts[c] = k;
        }
        AllocationPlan { counts }
    }

    /// Single-channel Top-k plan (ablation baseline): everything on the
    /// currently fastest channel.
    fn topk_plan(&self, device: usize) -> AllocationPlan {
        let mut counts = vec![0usize; self.cfg.channel_types.len()];
        counts[self.devices[device].channels.fastest()] = self.static_ks.iter().sum();
        AllocationPlan { counts }
    }

    /// Run the full experiment; returns the per-round log.
    pub fn run(&mut self, trainer: &mut dyn LocalTrainer) -> Result<RunLog> {
        let mut log = RunLog::new(&format!(
            "{}-{}",
            self.cfg.mechanism.name(),
            self.cfg.workload.model_name()
        ));
        for round in 0..self.cfg.rounds {
            if let Some(rec) = self.step_round(round, trainer)? {
                log.push(rec);
            } else {
                break; // all devices out of budget
            }
        }
        Ok(log)
    }

    /// Execute one round. Returns None when every device is out of budget.
    pub fn step_round(
        &mut self,
        round: usize,
        trainer: &mut dyn LocalTrainer,
    ) -> Result<Option<RoundRecord>> {
        let m = self.devices.len();
        // 1. Network dynamics advance.
        for dev in &mut self.devices {
            dev.channels.step_round();
        }
        // 2. Which devices participate (budget) and which sync this round.
        let active: Vec<bool> = self.devices.iter().map(|d| d.meter.within_budget()).collect();
        if active.iter().all(|&a| !a) {
            return Ok(None);
        }
        let syncs: Vec<bool> = (0..m)
            .map(|i| active[i] && (round + 1) % self.sync_gap[i] == 0)
            .collect();

        // 3. Per-device local work + upload.
        let mut uploads: Vec<Option<crate::compression::LgcUpdate>> = vec![None; m];
        let mut round_wall = 0.0f64;
        let mut loss_sum = 0.0f64;
        let mut loss_n = 0usize;
        let mut energy_round = 0.0f64;
        let mut money_round = 0.0f64;
        let mut bytes_up = 0u64;
        let mut drl_pre: Vec<Option<(Vec<f32>, usize)>> = vec![None; m]; // (state, H)
        let mut reward_acc = 0.0f64;
        let mut reward_n = 0usize;

        for i in 0..m {
            if !active[i] {
                continue;
            }
            // --- decide (H, plan) --------------------------------------
            let (h, plan, dense) = match self.cfg.mechanism {
                Mechanism::FedAvg => (self.cfg.h_fixed, None, true),
                Mechanism::LgcStatic => (self.cfg.h_fixed, Some(self.static_plan()), false),
                Mechanism::TopK => (self.cfg.h_fixed, Some(self.topk_plan(i)), false),
                Mechanism::LgcDrl => {
                    let agent = self.agents[i].as_mut().unwrap();
                    let dev = &self.devices[i];
                    let state = agent.observe_state(&dev.meter, &dev.channels, dev.last_delta);
                    let decision = agent.decide(&state, true);
                    drl_pre[i] = Some((state, decision.local_steps));
                    (decision.local_steps, Some(decision.plan), false)
                }
            };

            let dev = &mut self.devices[i];
            // --- local computation (lines 5-7) --------------------------
            let loss = dev.local_steps(trainer, h, self.cfg.lr)?;
            loss_sum += loss;
            loss_n += 1;
            let (comp_j, comp_s) = dev.compute_cost(h);

            // --- communication (lines 8-11) ------------------------------
            let (mut wall, comm_j, comm_money, bytes) = if syncs[i] {
                if dense {
                    // FedAvg: full dense model on the fastest channel.
                    let ch = dev.channels.fastest();
                    let (wall, costs) = dev.dense_upload(ch);
                    // The "update" is w_m − ŵ_m dense.
                    let g: Vec<f32> = dev
                        .params_sync
                        .iter()
                        .zip(&dev.params_hat)
                        .map(|(&w, &wh)| w - wh)
                        .collect();
                    let dim = g.len();
                    let layer = crate::compression::Layer {
                        indices: (0..dim as u32).collect(),
                        values: g,
                    };
                    uploads[i] = Some(crate::compression::LgcUpdate { dim, layers: vec![layer] });
                    let (j, mo, by) = costs.iter().fold((0.0, 0.0, 0u64), |acc, c| {
                        (acc.0 + c.energy_j, acc.1 + c.money, acc.2 + c.bytes)
                    });
                    (wall, j, mo, by)
                } else {
                    let plan = plan.expect("sparse mechanisms have a plan");
                    let (update, wall, costs) = dev.compress_and_upload(&plan);
                    // Round-trip through the wire format, as the server sees it.
                    uploads[i] = Some(Server::decode_from_wire(&update)?);
                    let (j, mo, by) = costs.iter().fold((0.0, 0.0, 0u64), |acc, c| {
                        (acc.0 + c.energy_j, acc.1 + c.money, acc.2 + c.bytes)
                    });
                    (wall, j, mo, by)
                }
            } else {
                (0.0, 0.0, 0.0, 0) // no sync this round (Alg. 1 lines 14-17)
            };
            wall += comp_s;
            round_wall = round_wall.max(wall);
            dev.meter.record_round(comp_j, comm_j, comm_money, wall);
            if dev.prev_loss.is_nan() {
                dev.prev_loss = loss;
            }
            energy_round += comp_j + comm_j;
            money_round += comm_money;
            bytes_up += bytes;

            // δ = loss improvement this round (Eq. 15a, sign flipped so
            // positive = better), feeding the Eq. 16 reward.
            let delta = dev.prev_loss - loss;
            dev.prev_loss = loss;
            dev.last_delta = delta;
            if let Some((_, _h)) = &drl_pre[i] {
                let agent = self.agents[i].as_mut().unwrap();
                let eps = [
                    dev.meter.last_round[0].total().max(1e-9),
                    dev.meter.last_round[1].total().max(1e-9),
                ];
                let next_state = agent.observe_state(&dev.meter, &dev.channels, delta);
                let done = round + 1 == self.cfg.rounds;
                let (r, _) = agent.feedback(delta, &eps, next_state, done);
                reward_acc += r;
                reward_n += 1;
            }
        }

        // 4. Server aggregation + broadcast (lines 18-22).
        let received: Vec<&crate::compression::LgcUpdate> =
            uploads.iter().flatten().collect();
        if !received.is_empty() {
            self.server.aggregate_and_apply(&received);
            for i in 0..m {
                if syncs[i] && uploads[i].is_some() {
                    self.devices[i].sync(&self.server.params);
                }
            }
        }

        // 5. Evaluate + record.
        self.total_time_s += round_wall;
        let (eval_loss, eval_acc) = if round % self.cfg.eval_every == 0
            || round + 1 == self.cfg.rounds
        {
            trainer.eval(&self.server.params)?
        } else {
            (f64::NAN, f64::NAN)
        };
        let (tot_energy, tot_money) = self.devices.iter().fold((0.0, 0.0), |acc, d| {
            (acc.0 + d.meter.energy_used, acc.1 + d.meter.money_used)
        });
        let _ = (energy_round, money_round);
        Ok(Some(RoundRecord {
            round,
            train_loss: loss_sum / loss_n.max(1) as f64,
            eval_loss,
            eval_acc,
            energy_j: tot_energy,
            money: tot_money,
            round_time_s: round_wall,
            total_time_s: self.total_time_s,
            bytes_up,
            drl_reward: if reward_n > 0 {
                reward_acc / reward_n as f64
            } else {
                f64::NAN
            },
        }))
    }

    /// Reset the FL problem for a new DRL episode (paper Fig. 5: the DRL
    /// agents persist and keep learning across episodes, while the FL model,
    /// error memories, meters and reward trackers restart).
    pub fn reset_episode(&mut self, trainer: &dyn LocalTrainer) {
        let init = trainer.init_params();
        self.server = Server::new(init.clone());
        for dev in &mut self.devices {
            dev.sync(&init);
            dev.error.reset();
            dev.prev_loss = f64::NAN;
            dev.last_delta = 0.0;
            dev.meter = ResourceMeter::new(self.cfg.energy_budget, self.cfg.money_budget);
        }
        for agent in self.agents.iter_mut().flatten() {
            agent.tracker = Default::default();
            agent.ddpg.reset_noise();
        }
        self.total_time_s = 0.0;
    }

    /// Exploration RNG access for deterministic test setups.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn d_bounds(&self) -> (usize, usize) {
        (self.d_min, self.d_total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, Mechanism, Workload};
    use crate::coordinator::trainer::NativeLrTrainer;

    fn cfg(mechanism: Mechanism, rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            mechanism,
            workload: Workload::LrMnist,
            rounds,
            devices: 3,
            samples_per_device: 256,
            eval_samples: 256,
            eval_every: 2,
            lr: 0.05,
            h_fixed: 2,
            h_max: 4,
            use_runtime: false,
            ..ExperimentConfig::default()
        }
    }

    fn run(mechanism: Mechanism, rounds: usize) -> crate::metrics::RunLog {
        let cfg = cfg(mechanism, rounds);
        let mut trainer = NativeLrTrainer::new(&cfg);
        let mut exp = Experiment::new(cfg, &trainer);
        exp.run(&mut trainer).unwrap()
    }

    #[test]
    fn fedavg_learns() {
        let log = run(Mechanism::FedAvg, 30);
        assert_eq!(log.records.len(), 30);
        assert!(log.final_acc() > 0.5, "acc={}", log.final_acc());
        let first = log.records.first().unwrap().train_loss;
        let last = log.records.last().unwrap().train_loss;
        assert!(last < first);
    }

    #[test]
    fn lgc_static_learns_with_fewer_bytes_than_fedavg() {
        let lgc = run(Mechanism::LgcStatic, 30);
        let fed = run(Mechanism::FedAvg, 30);
        assert!(lgc.final_acc() > 0.5, "lgc acc={}", lgc.final_acc());
        let lgc_bytes: u64 = lgc.records.iter().map(|r| r.bytes_up).sum();
        let fed_bytes: u64 = fed.records.iter().map(|r| r.bytes_up).sum();
        assert!(
            (lgc_bytes as f64) < 0.5 * fed_bytes as f64,
            "lgc {lgc_bytes} vs fedavg {fed_bytes}"
        );
    }

    #[test]
    fn lgc_drl_runs_and_rewards_finite() {
        let log = run(Mechanism::LgcDrl, 16);
        assert_eq!(log.records.len(), 16);
        assert!(log.records.iter().all(|r| r.drl_reward.is_finite()));
        assert!(log.final_acc() > 0.3, "acc={}", log.final_acc());
    }

    #[test]
    fn topk_baseline_runs() {
        let log = run(Mechanism::TopK, 12);
        assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    }

    #[test]
    fn energy_and_money_monotone() {
        let log = run(Mechanism::LgcStatic, 10);
        for w in log.records.windows(2) {
            assert!(w[1].energy_j >= w[0].energy_j);
            assert!(w[1].money >= w[0].money);
            assert!(w[1].total_time_s >= w[0].total_time_s);
        }
    }

    #[test]
    fn budget_stops_training() {
        let mut c = cfg(Mechanism::LgcStatic, 50);
        c.energy_budget = 40.0; // tiny: a few rounds of compute+comm
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = Experiment::new(c, &trainer);
        let log = exp.run(&mut trainer).unwrap();
        assert!(log.records.len() < 50, "should stop early, ran {}", log.records.len());
    }

    #[test]
    fn async_gaps_respected() {
        let c = cfg(Mechanism::LgcStatic, 12);
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = Experiment::new(c, &trainer).with_sync_gaps(vec![1, 2, 3]);
        let log = exp.run(&mut trainer).unwrap();
        assert_eq!(log.records.len(), 12);
        // device 2 uploads only every 3rd round; total bytes lower than all-sync
        assert!(log.final_acc() > 0.4, "acc={}", log.final_acc());
    }

    #[test]
    fn fedavg_equals_centralized_sgd_when_single_device_h1() {
        // M=1, H=1 FedAvg is plain SGD on the global model: loss must drop
        // monotonically-ish and match a hand-rolled loop on the same data.
        let mut c = cfg(Mechanism::FedAvg, 8);
        c.devices = 1;
        c.h_fixed = 1;
        c.h_max = 1;
        let mut trainer = NativeLrTrainer::new(&c);
        let mut exp = Experiment::new(c, &trainer);
        let log = exp.run(&mut trainer).unwrap();
        let first = log.records.first().unwrap().train_loss;
        let last = log.records.last().unwrap().train_loss;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(Mechanism::LgcStatic, 6);
        let b = run(Mechanism::LgcStatic, 6);
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.train_loss, y.train_loss);
            assert_eq!(x.bytes_up, y.bytes_up);
        }
    }
}
