//! The [`Aggregator`] trait — the pluggable server-side combination rule.
//!
//! The seed hard-coded mean aggregation inside `Server::aggregate_and_apply`;
//! this seam makes the rule swappable: [`MeanAggregator`] reproduces the old
//! numerics bit-for-bit (proven by `tests/compressor_contract.rs`), and
//! [`WeightedBySamples`] implements FedAvg-style sample-count weighting for
//! non-IID shards. New rules (trimmed mean, median, momentum servers, ...)
//! plug in via [`crate::coordinator::ExperimentBuilder::aggregator`] or a
//! registered mechanism preset — see DESIGN.md §"Extension points".

use crate::compression::LgcUpdate;

/// Server-side combination rule for one round's uploads.
///
/// `aggregate` must *fully overwrite* `out` with the descent direction; the
/// server then applies `params -= out`. Implementations may keep reusable
/// state across rounds (buffers, momentum, ...) — one instance lives for the
/// whole experiment.
pub trait Aggregator: Send {
    /// Short human-readable name for logs and registry listings.
    fn name(&self) -> String;

    /// Combine `uploads` (each with `dim == out.len()`) into `out`.
    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]);

    /// Optional per-round side channel: the experiment announces one weight
    /// per upload (same order as the `uploads` slice of the following
    /// `aggregate` call), e.g. local sample counts. Rules that don't weight
    /// ignore it.
    fn set_round_weights(&mut self, _weights: &[f64]) {}

    // --- Streaming accumulate/finalize API --------------------------------
    //
    // Rules that can fold uploads into a running aggregate implement these
    // three, and the server then never buffers decoded `LgcUpdate`s: each
    // upload is folded into `acc` (the server's O(model) aggregate buffer)
    // the moment it arrives — pairing naturally with the semi-/fully-async
    // sim modes and the population cohort engines. Streaming totals may
    // differ from the batch `aggregate` result by f32 accumulation order
    // (sum-then-scale vs scale-then-sum): the documented tolerance is
    // ~1e-6 relative (~1e-5 absolute on unit-scale updates), asserted by
    // `tests/population.rs`.

    /// Start a streaming round over a zeroed `dim`-sized accumulator.
    /// Returns `true` when this rule streams natively; `false` (the
    /// default) makes the server fall back to buffering clones and driving
    /// the batch [`Aggregator::aggregate`] at finalize time.
    fn stream_begin(&mut self, _dim: usize) -> bool {
        false
    }

    /// Fold one upload (with its announced weight) into `acc`.
    fn stream_accumulate(&mut self, _upload: &LgcUpdate, _weight: f64, _acc: &mut [f32]) {}

    /// Turn the accumulated `acc` into the final descent direction in
    /// place. `uploads` and `weight_sum` are the fold counts the server
    /// tracked (so stateless rules need no counters of their own).
    fn stream_finalize(&mut self, _acc: &mut [f32], _uploads: usize, _weight_sum: f64) {}
}

/// Uniform mean of the decoded updates:
/// `w̄^{t+1} = w̄^{t} − (1/M) Σ_m g_m` (Alg. 1 line 21) — the seed's exact
/// behavior, preserved bit-for-bit.
#[derive(Clone, Debug, Default)]
pub struct MeanAggregator;

impl Aggregator for MeanAggregator {
    fn name(&self) -> String {
        "mean".to_string()
    }

    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]) {
        crate::kernels::fill(out, 0.0);
        let scale = 1.0 / uploads.len() as f32;
        for upd in uploads {
            upd.add_into(out, scale);
        }
    }

    fn stream_begin(&mut self, _dim: usize) -> bool {
        true
    }

    /// Running unweighted sum; the 1/M scale is applied once at finalize
    /// (sum-then-scale vs the batch path's scale-then-sum — the documented
    /// streaming tolerance).
    fn stream_accumulate(&mut self, upload: &LgcUpdate, _weight: f64, acc: &mut [f32]) {
        upload.add_into(acc, 1.0);
    }

    fn stream_finalize(&mut self, acc: &mut [f32], uploads: usize, _weight_sum: f64) {
        let scale = 1.0 / uploads.max(1) as f32;
        crate::kernels::scale(scale, acc);
    }
}

/// Sample-count-weighted mean (McMahan et al. 2017): upload `m` contributes
/// with weight `n_m / Σ n`. Falls back to the uniform mean when no (or
/// mismatched/invalid) weights were announced for the round, so it degrades
/// to [`MeanAggregator`] rather than misweighting. An *announced* all-zero
/// cohort applies nothing (no samples, no descent) — the same answer the
/// streaming finalize produces from its zero accumulator.
#[derive(Clone, Debug, Default)]
pub struct WeightedBySamples {
    round_weights: Vec<f64>,
}

impl WeightedBySamples {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Aggregator for WeightedBySamples {
    fn name(&self) -> String {
        "weighted-by-samples".to_string()
    }

    fn set_round_weights(&mut self, weights: &[f64]) {
        self.round_weights.clear();
        self.round_weights.extend_from_slice(weights);
    }

    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]) {
        crate::kernels::fill(out, 0.0);
        let total: f64 = self.round_weights.iter().sum();
        let announced = self.round_weights.len() == uploads.len()
            && self.round_weights.iter().all(|&w| w >= 0.0 && w.is_finite());
        if announced && total > 0.0 {
            for (upd, &w) in uploads.iter().zip(&self.round_weights) {
                upd.add_into(out, (w / total) as f32);
            }
        } else if announced {
            // A zero-total-weight cohort contributed no samples: apply
            // nothing, exactly like the streaming path (zero accumulator
            // scaled at finalize) — so stream ≡ batch holds here too.
        } else {
            let scale = 1.0 / uploads.len() as f32;
            for upd in uploads {
                upd.add_into(out, scale);
            }
        }
        // Weights are strictly per-round: consume them so a missing
        // announce next round falls back to the mean instead of silently
        // reusing stale sample counts.
        self.round_weights.clear();
    }

    fn stream_begin(&mut self, _dim: usize) -> bool {
        self.round_weights.clear(); // per-upload weights arrive with each fold
        true
    }

    /// Fold `weight · upload`; normalization by Σw happens at finalize.
    /// Streaming requires positive finite weights (the drivers pass local
    /// sample counts); a degenerate weight sum yields the uniform-mean
    /// fallback, mirroring the batch path.
    fn stream_accumulate(&mut self, upload: &LgcUpdate, weight: f64, acc: &mut [f32]) {
        upload.add_into(acc, weight as f32);
    }

    fn stream_finalize(&mut self, acc: &mut [f32], uploads: usize, weight_sum: f64) {
        let scale = if weight_sum > 0.0 && weight_sum.is_finite() {
            (1.0 / weight_sum) as f32
        } else {
            // Degenerate weights: nothing meaningful was accumulated with
            // w ≈ 0; scale by 1/M like the batch fallback (acc is ~zero, so
            // this only matters for NaN/inf hygiene).
            1.0 / uploads.max(1) as f32
        };
        crate::kernels::scale(scale, acc);
    }
}

/// Layer-divergence-feedback aggregation (arXiv 2404.08324): the server
/// measures, per LGC layer, how *aligned* the devices' contributions are and
/// reweights layers accordingly — a layer where devices agree (low
/// inter-device divergence) is trusted more than one where they cancel.
///
/// Alignment is `rho_l = ||Σ_m g_{m,l}||² / (M · Σ_m ||g_{m,l}||²)`, which
/// Cauchy–Schwarz pins to `[0, 1]`: `1` when all devices ship the same
/// direction, `→ 1/M` when contributions are mutually orthogonal, `→ 0` when
/// they cancel. Weights are `rho` normalized to mean 1 over the non-empty
/// layers, so uniform alignment reproduces the plain mean exactly and the
/// total step magnitude stays comparable across rounds.
///
/// Batch-only on purpose: the rule needs every upload's layer norms before
/// any weight is known, so `stream_begin` keeps the default `false` and the
/// server falls back to buffering clones and driving this at finalize time —
/// the documented fallback path for non-streaming rules.
#[derive(Clone, Debug, Default)]
pub struct LayerDivergence {
    /// Reusable per-layer dense accumulators (one model-sized buffer per
    /// LGC layer, grown lazily, zeroed each round).
    acc: Vec<Vec<f32>>,
}

impl LayerDivergence {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Aggregator for LayerDivergence {
    fn name(&self) -> String {
        "layer-divergence".to_string()
    }

    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]) {
        crate::kernels::fill(out, 0.0);
        if uploads.is_empty() {
            return;
        }
        let m = uploads.len() as f64;
        let n_layers = uploads.iter().map(|u| u.layers.len()).max().unwrap_or(0);
        while self.acc.len() < n_layers {
            self.acc.push(Vec::new());
        }
        for buf in self.acc.iter_mut().take(n_layers) {
            buf.resize(out.len(), 0.0);
            crate::kernels::fill(buf, 0.0);
        }
        // acc_l = Σ_m g_{m,l} (dense) and sum_sq_l = Σ_m ||g_{m,l}||² (from
        // the sparse values directly — no dense pass per upload).
        let mut sum_sq = vec![0f64; n_layers];
        for upd in uploads {
            for (l, layer) in upd.layers.iter().enumerate() {
                crate::kernels::scatter_add(&mut self.acc[l], &layer.indices, &layer.values, 1.0);
                sum_sq[l] += layer.values.iter().map(|&v| v as f64 * v as f64).sum::<f64>();
            }
        }
        let mut rho = vec![0f64; n_layers];
        let mut rho_sum = 0f64;
        let mut active = 0usize;
        for l in 0..n_layers {
            if sum_sq[l] > 0.0 {
                let norm_sq = crate::kernels::reduce::norm2_chunked(&self.acc[l]);
                rho[l] = (norm_sq / (m * sum_sq[l])).clamp(0.0, 1.0);
                rho_sum += rho[l];
                active += 1;
            }
        }
        for l in 0..n_layers {
            if sum_sq[l] <= 0.0 {
                continue; // empty layer: nothing accumulated
            }
            // Mean-1 normalization over active layers; if every alignment
            // collapsed to ~0 (perfect cancellation) fall back to uniform
            // weights — the accumulators are ~zero anyway.
            let w = if rho_sum > 0.0 { rho[l] * active as f64 / rho_sum } else { 1.0 };
            crate::kernels::axpy((w / m) as f32, &self.acc[l], out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    fn upd(dim: usize, seed: u64, k: usize) -> LgcUpdate {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        lgc_compress(&u, &[k], &mut CompressScratch::default())
    }

    #[test]
    fn mean_matches_hand_rolled() {
        let a = upd(64, 1, 8);
        let b = upd(64, 2, 8);
        let mut out = vec![0f32; 64];
        MeanAggregator.aggregate(&[&a, &b], &mut out);
        let da = a.decode();
        let db = b.decode();
        for i in 0..64 {
            assert_eq!(out[i].to_bits(), (0.0f32 + da[i] * 0.5 + db[i] * 0.5).to_bits());
        }
    }

    #[test]
    fn weighted_without_weights_is_mean() {
        let a = upd(32, 3, 4);
        let b = upd(32, 4, 4);
        let mut w_out = vec![0f32; 32];
        let mut m_out = vec![0f32; 32];
        WeightedBySamples::new().aggregate(&[&a, &b], &mut w_out);
        MeanAggregator.aggregate(&[&a, &b], &mut m_out);
        assert_eq!(w_out, m_out);
    }

    #[test]
    fn weighted_respects_sample_counts() {
        let a = upd(32, 5, 32);
        let b = upd(32, 6, 32);
        let mut agg = WeightedBySamples::new();
        agg.set_round_weights(&[300.0, 100.0]);
        let mut out = vec![0f32; 32];
        agg.aggregate(&[&a, &b], &mut out);
        let da = a.decode();
        let db = b.decode();
        for i in 0..32 {
            let expect = da[i] * 0.75 + db[i] * 0.25;
            assert!((out[i] - expect).abs() < 1e-6, "at {i}: {} vs {expect}", out[i]);
        }
    }

    #[test]
    fn aggregate_overwrites_stale_out() {
        let a = upd(16, 7, 4);
        let mut out = vec![999.0f32; 16];
        MeanAggregator.aggregate(&[&a], &mut out);
        assert_eq!(out, a.decode());
    }

    #[test]
    fn streaming_mean_matches_batch_within_tolerance() {
        let ups: Vec<LgcUpdate> = (0..5).map(|s| upd(128, 40 + s, 32)).collect();
        let refs: Vec<&LgcUpdate> = ups.iter().collect();
        let mut batch = vec![0f32; 128];
        MeanAggregator.aggregate(&refs, &mut batch);
        let mut agg = MeanAggregator;
        assert!(agg.stream_begin(128));
        let mut acc = vec![0f32; 128];
        for u in &ups {
            agg.stream_accumulate(u, 1.0, &mut acc);
        }
        agg.stream_finalize(&mut acc, ups.len(), ups.len() as f64);
        for i in 0..128 {
            assert!(
                (acc[i] - batch[i]).abs() < 1e-5,
                "at {i}: stream {} vs batch {}",
                acc[i],
                batch[i]
            );
        }
    }

    #[test]
    fn layer_divergence_identical_uploads_is_mean() {
        // All devices ship the same update: every layer's alignment is 1,
        // mean-1 normalization makes every weight 1 — exactly the mean.
        let a = upd(64, 11, 8);
        let same = a.clone();
        let mut ld_out = vec![0f32; 64];
        let mut m_out = vec![0f32; 64];
        LayerDivergence::new().aggregate(&[&a, &same], &mut ld_out);
        MeanAggregator.aggregate(&[&a, &same], &mut m_out);
        for i in 0..64 {
            assert!(
                (ld_out[i] - m_out[i]).abs() < 1e-6,
                "at {i}: {} vs {}",
                ld_out[i],
                m_out[i]
            );
        }
    }

    #[test]
    fn layer_divergence_single_upload_is_identity() {
        let a = upd(32, 12, 6);
        let mut out = vec![999.0f32; 32];
        LayerDivergence::new().aggregate(&[&a], &mut out);
        let da = a.decode();
        for i in 0..32 {
            assert!((out[i] - da[i]).abs() < 1e-6, "at {i}: {} vs {}", out[i], da[i]);
        }
    }

    #[test]
    fn layer_divergence_upweights_aligned_layers() {
        use crate::compression::Layer;
        // Two uploads, two layers. Layer 0 agrees across devices (alignment
        // 1); layer 1 cancels exactly (alignment 0). The aligned layer must
        // carry more than its mean share, the cancelled one contributes the
        // zero its accumulator holds.
        let mk = |v1: f32| LgcUpdate {
            dim: 4,
            layers: vec![
                Layer { indices: vec![0], values: vec![2.0] },
                Layer { indices: vec![1], values: vec![v1] },
            ],
        };
        let a = mk(1.0);
        let b = mk(-1.0);
        let mut out = vec![0f32; 4];
        LayerDivergence::new().aggregate(&[&a, &b], &mut out);
        // Layer 0: rho = 1; layer 1: rho = 0 -> weights (2, 0) after mean-1
        // normalization over the two active layers. acc_0[0] = 4, so
        // out[0] = (w0/M) * 4 = (2/2) * 4 = 4 (the plain mean would give 2).
        assert!((out[0] - 4.0).abs() < 1e-6, "aligned layer doubled: {}", out[0]);
        assert!(out[1].abs() < 1e-6, "cancelled layer silent: {}", out[1]);
        assert_eq!(out[2], 0.0);
        assert_eq!(out[3], 0.0);
    }

    #[test]
    fn layer_divergence_overwrites_stale_out_and_reuses_buffers() {
        let a = upd(16, 13, 4);
        let b = upd(16, 14, 4);
        let mut agg = LayerDivergence::new();
        let mut first = vec![999.0f32; 16];
        agg.aggregate(&[&a, &b], &mut first);
        // Second round through the same instance (dirty accumulators) must
        // produce the identical answer.
        let mut second = vec![-7.0f32; 16];
        agg.aggregate(&[&a, &b], &mut second);
        assert_eq!(first, second);
    }

    #[test]
    fn streaming_weighted_matches_batch_within_tolerance() {
        let ups: Vec<LgcUpdate> = (0..4).map(|s| upd(96, 60 + s, 24)).collect();
        let refs: Vec<&LgcUpdate> = ups.iter().collect();
        let weights = [300.0, 120.0, 700.0, 55.0];
        let mut batch_agg = WeightedBySamples::new();
        batch_agg.set_round_weights(&weights);
        let mut batch = vec![0f32; 96];
        batch_agg.aggregate(&refs, &mut batch);
        let mut agg = WeightedBySamples::new();
        assert!(agg.stream_begin(96));
        let mut acc = vec![0f32; 96];
        for (u, &w) in ups.iter().zip(&weights) {
            agg.stream_accumulate(u, w, &mut acc);
        }
        agg.stream_finalize(&mut acc, ups.len(), weights.iter().sum());
        for i in 0..96 {
            assert!(
                (acc[i] - batch[i]).abs() < 1e-5,
                "at {i}: stream {} vs batch {}",
                acc[i],
                batch[i]
            );
        }
    }
}
