//! The [`Aggregator`] trait — the pluggable server-side combination rule.
//!
//! The seed hard-coded mean aggregation inside `Server::aggregate_and_apply`;
//! this seam makes the rule swappable: [`MeanAggregator`] reproduces the old
//! numerics bit-for-bit (proven by `tests/compressor_contract.rs`), and
//! [`WeightedBySamples`] implements FedAvg-style sample-count weighting for
//! non-IID shards. New rules (trimmed mean, median, momentum servers, ...)
//! plug in via [`crate::coordinator::ExperimentBuilder::aggregator`] or a
//! registered mechanism preset — see DESIGN.md §"Extension points".

use crate::compression::LgcUpdate;

/// Server-side combination rule for one round's uploads.
///
/// `aggregate` must *fully overwrite* `out` with the descent direction; the
/// server then applies `params -= out`. Implementations may keep reusable
/// state across rounds (buffers, momentum, ...) — one instance lives for the
/// whole experiment.
pub trait Aggregator: Send {
    /// Short human-readable name for logs and registry listings.
    fn name(&self) -> String;

    /// Combine `uploads` (each with `dim == out.len()`) into `out`.
    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]);

    /// Optional per-round side channel: the experiment announces one weight
    /// per upload (same order as the `uploads` slice of the following
    /// `aggregate` call), e.g. local sample counts. Rules that don't weight
    /// ignore it.
    fn set_round_weights(&mut self, _weights: &[f64]) {}
}

/// Uniform mean of the decoded updates:
/// `w̄^{t+1} = w̄^{t} − (1/M) Σ_m g_m` (Alg. 1 line 21) — the seed's exact
/// behavior, preserved bit-for-bit.
#[derive(Clone, Debug, Default)]
pub struct MeanAggregator;

impl Aggregator for MeanAggregator {
    fn name(&self) -> String {
        "mean".to_string()
    }

    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]) {
        out.iter_mut().for_each(|x| *x = 0.0);
        let scale = 1.0 / uploads.len() as f32;
        for upd in uploads {
            upd.add_into(out, scale);
        }
    }
}

/// Sample-count-weighted mean (McMahan et al. 2017): upload `m` contributes
/// with weight `n_m / Σ n`. Falls back to the uniform mean when no (or
/// mismatched) weights were announced for the round, so it degrades to
/// [`MeanAggregator`] rather than misweighting.
#[derive(Clone, Debug, Default)]
pub struct WeightedBySamples {
    round_weights: Vec<f64>,
}

impl WeightedBySamples {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Aggregator for WeightedBySamples {
    fn name(&self) -> String {
        "weighted-by-samples".to_string()
    }

    fn set_round_weights(&mut self, weights: &[f64]) {
        self.round_weights.clear();
        self.round_weights.extend_from_slice(weights);
    }

    fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]) {
        out.iter_mut().for_each(|x| *x = 0.0);
        let total: f64 = self.round_weights.iter().sum();
        let usable = self.round_weights.len() == uploads.len()
            && total > 0.0
            && self.round_weights.iter().all(|&w| w >= 0.0 && w.is_finite());
        if usable {
            for (upd, &w) in uploads.iter().zip(&self.round_weights) {
                upd.add_into(out, (w / total) as f32);
            }
        } else {
            let scale = 1.0 / uploads.len() as f32;
            for upd in uploads {
                upd.add_into(out, scale);
            }
        }
        // Weights are strictly per-round: consume them so a missing
        // announce next round falls back to the mean instead of silently
        // reusing stale sample counts.
        self.round_weights.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    fn upd(dim: usize, seed: u64, k: usize) -> LgcUpdate {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        lgc_compress(&u, &[k], &mut CompressScratch::default())
    }

    #[test]
    fn mean_matches_hand_rolled() {
        let a = upd(64, 1, 8);
        let b = upd(64, 2, 8);
        let mut out = vec![0f32; 64];
        MeanAggregator.aggregate(&[&a, &b], &mut out);
        let da = a.decode();
        let db = b.decode();
        for i in 0..64 {
            assert_eq!(out[i].to_bits(), (0.0f32 + da[i] * 0.5 + db[i] * 0.5).to_bits());
        }
    }

    #[test]
    fn weighted_without_weights_is_mean() {
        let a = upd(32, 3, 4);
        let b = upd(32, 4, 4);
        let mut w_out = vec![0f32; 32];
        let mut m_out = vec![0f32; 32];
        WeightedBySamples::new().aggregate(&[&a, &b], &mut w_out);
        MeanAggregator.aggregate(&[&a, &b], &mut m_out);
        assert_eq!(w_out, m_out);
    }

    #[test]
    fn weighted_respects_sample_counts() {
        let a = upd(32, 5, 32);
        let b = upd(32, 6, 32);
        let mut agg = WeightedBySamples::new();
        agg.set_round_weights(&[300.0, 100.0]);
        let mut out = vec![0f32; 32];
        agg.aggregate(&[&a, &b], &mut out);
        let da = a.decode();
        let db = b.decode();
        for i in 0..32 {
            let expect = da[i] * 0.75 + db[i] * 0.25;
            assert!((out[i] - expect).abs() < 1e-6, "at {i}: {} vs {expect}", out[i]);
        }
    }

    #[test]
    fn aggregate_overwrites_stale_out() {
        let a = upd(16, 7, 4);
        let mut out = vec![999.0f32; 16];
        MeanAggregator.aggregate(&[&a], &mut out);
        assert_eq!(out, a.decode());
    }
}
