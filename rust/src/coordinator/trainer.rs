//! Local-training abstraction: the device round loop calls a [`LocalTrainer`]
//! for gradient compute, which is either the PJRT runtime executing the AOT
//! artifacts (production path) or the pure-Rust LR reference (test path —
//! no artifacts needed, exact same interface).
//!
//! A trainer serves `cfg.devices` data shards. The legacy path maps device
//! `i` to shard `i`; population mode maps many clients onto the same shards
//! (`client_id % cfg.devices`, see
//! [`crate::population::SpecSeed::shard`]), so the dataset does not grow
//! with the client population — `local_step(shard, ...)` is indexed by
//! shard, whichever client is training on it.
//!
//! For parallel device compute, a backend can *split* its per-device shards
//! into independently-owned [`DeviceTrainer`] handles
//! ([`LocalTrainer::split_device_trainers`]): each handle carries its own
//! sampler RNG, batch buffers and model instance, so `std::thread::scope`
//! workers can train disjoint devices concurrently with results bit-identical
//! to the sequential path (per-device forked RNG streams — nothing shared).

use std::sync::Arc;

use anyhow::Result;

use crate::config::{ExperimentConfig, Workload};
use crate::data::{partition_dirichlet, BatchSampler, CharCorpus, Dataset, MnistGen};
use crate::models::NativeLr;
use crate::runtime::{BatchX, ModelExecutable, Runtime};
use crate::util::Rng;

/// Per-device mini-batch + held-out evaluation over one workload.
pub trait LocalTrainer {
    /// Flat parameter count P.
    fn nparams(&self) -> usize;
    /// Initial global parameters.
    fn init_params(&self) -> Vec<f32>;
    /// Run ONE local SGD step for `device` on a fresh mini-batch, updating
    /// `params` in place. Returns the step's training loss.
    fn local_step(&mut self, device: usize, params: &mut Vec<f32>, lr: f32) -> Result<f64>;
    /// Evaluate on the held-out set: (mean loss, accuracy in [0,1]).
    fn eval(&mut self, params: &[f32]) -> Result<(f64, f64)>;
    /// Local sample count of `device` (n_m) — feeds sample-weighted
    /// aggregation rules. Defaults to 1 (uniform) for backends that don't
    /// track shard sizes.
    fn device_samples(&self, _device: usize) -> usize {
        1
    }
    /// Move the per-device training shards out into independently-owned
    /// handles (one per device, device order) for parallel local compute
    /// (`DeviceTrainer` is `Send` by supertrait). Returns `None` when the
    /// backend cannot split (e.g. a single shared executable) — callers then
    /// fall back to sequential [`LocalTrainer::local_step`]. While split,
    /// the parent keeps evaluation and shard-size queries but cannot serve
    /// `local_step`; hand the handles back via
    /// [`LocalTrainer::restore_device_trainers`] (the engine does this at
    /// the end of every run, so a trainer stays reusable across runs).
    fn split_device_trainers(&mut self) -> Option<Vec<Box<dyn DeviceTrainer>>> {
        None
    }

    /// Reabsorb handles produced by
    /// [`LocalTrainer::split_device_trainers`], restoring sequential
    /// `local_step` service with the handles' advanced sampler state (same
    /// device order). Default: drop them.
    fn restore_device_trainers(&mut self, _handles: Vec<Box<dyn DeviceTrainer>>) {}
}

/// An independently-owned single-device training handle (see
/// [`LocalTrainer::split_device_trainers`]). Implementations must be
/// deterministic given their construction state: the engine relies on
/// thread-count-independent results.
pub trait DeviceTrainer: Send {
    /// One local SGD step on this device's shard, updating `params` in
    /// place. Must compute exactly what the parent trainer's
    /// `local_step(device, ...)` would have.
    fn local_step(&mut self, params: &mut Vec<f32>, lr: f32) -> Result<f64>;
    /// Local sample count n_m of this device's shard.
    fn samples(&self) -> usize;
    /// Type-erased self-return so the parent trainer can downcast and
    /// reabsorb the handle (`restore_device_trainers`).
    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any>;
}

// ---------------------------------------------------------------------------
// Workload data (shared by both trainer impls)
// ---------------------------------------------------------------------------

/// Materialized per-device training data + held-out eval batches.
pub enum WorkloadData {
    Mnist {
        /// Shared read-only training pool (`Arc` so split-off
        /// [`DeviceTrainer`] handles can gather from it concurrently).
        train: Arc<Dataset>,
        shards: Vec<BatchSampler>,
        /// Shard sizes, recorded at build time so `device_samples` keeps
        /// answering after the shards were split off.
        shard_sizes: Vec<usize>,
        eval_x: Vec<f32>,
        eval_y: Vec<i32>,
        batch: usize,
        idx_buf: Vec<usize>,
        xb: Vec<f32>,
        yb: Vec<i32>,
    },
    Shakespeare {
        corpus: CharCorpus,
        spans: Vec<(usize, usize)>,
        rngs: Vec<Rng>,
        eval_batches: Vec<Vec<i32>>,
        batch: usize,
        seq: usize,
        buf: Vec<i32>,
    },
}

impl WorkloadData {
    pub fn build(cfg: &ExperimentConfig, batch: usize, seq: usize) -> Self {
        let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
        match cfg.workload {
            Workload::LrMnist | Workload::CnnMnist => {
                let gen = MnistGen::new(cfg.seed);
                let total = cfg.samples_per_device * cfg.devices;
                let train = gen.dataset(0, total);
                let parts = partition_dirichlet(
                    &train,
                    cfg.devices,
                    cfg.dirichlet_alpha,
                    crate::data::mnist::CLASSES,
                    &mut rng,
                );
                let shards: Vec<BatchSampler> = parts
                    .into_iter()
                    .enumerate()
                    .map(|(i, idxs)| BatchSampler::new(idxs, rng.fork(i as u64)))
                    .collect();
                let shard_sizes = shards.iter().map(BatchSampler::len).collect();
                let eval = gen.dataset(total as u64 + 10_000, cfg.eval_samples);
                WorkloadData::Mnist {
                    eval_x: eval.x,
                    eval_y: eval.y,
                    train: Arc::new(train),
                    shards,
                    shard_sizes,
                    batch,
                    idx_buf: Vec::new(),
                    xb: Vec::new(),
                    yb: Vec::new(),
                }
            }
            Workload::RnnShakespeare => {
                let corpus = CharCorpus::embedded(seq);
                let spans = corpus.device_spans(cfg.devices);
                let rngs = (0..cfg.devices).map(|i| rng.fork(100 + i as u64)).collect();
                // Fixed eval batches drawn across the whole corpus.
                let mut eval_rng = rng.fork(999);
                let n_eval = (cfg.eval_samples / batch).max(1);
                let mut eval_batches = Vec::with_capacity(n_eval);
                let full = (0, corpus.num_positions());
                for _ in 0..n_eval {
                    let mut b = Vec::new();
                    corpus.fill_batch(&mut eval_rng, full, batch, &mut b);
                    eval_batches.push(b);
                }
                WorkloadData::Shakespeare {
                    corpus,
                    spans,
                    rngs,
                    eval_batches,
                    batch,
                    seq,
                    buf: Vec::new(),
                }
            }
        }
    }

    /// Fill the next training batch for `device`. Returns (x, y).
    pub fn next_batch(&mut self, device: usize) -> (BatchX, Vec<i32>) {
        match self {
            WorkloadData::Mnist { train, shards, batch, idx_buf, xb, yb, .. } => {
                assert!(
                    !shards.is_empty(),
                    "training shards were moved out by split_device_trainers(); \
                     use the DeviceTrainer handles for local steps (a split \
                     trainer only serves eval and shard sizes)"
                );
                shards[device].next_batch(*batch, idx_buf);
                train.gather(idx_buf, xb, yb);
                (BatchX::F32(xb.clone()), yb.clone())
            }
            WorkloadData::Shakespeare { corpus, spans, rngs, batch, buf, .. } => {
                corpus.fill_batch(&mut rngs[device], spans[device], *batch, buf);
                // y unused by the rnn graphs; keep the ABI's int32[batch].
                (BatchX::I32(buf.clone()), vec![0i32; *batch])
            }
        }
    }

    /// Local sample count of `device` (shard size / corpus span positions).
    /// Keeps answering after [`WorkloadData::split_mnist_shards`].
    pub fn device_samples(&self, device: usize) -> usize {
        match self {
            WorkloadData::Mnist { shard_sizes, .. } => shard_sizes[device],
            WorkloadData::Shakespeare { spans, .. } => {
                let (lo, hi) = spans[device];
                hi.saturating_sub(lo)
            }
        }
    }

    /// Move the MNIST shard samplers out (device order) together with the
    /// shared training pool; `None` for non-MNIST workloads or if already
    /// split. The parent keeps eval batches and `device_samples`.
    pub fn split_mnist_shards(&mut self) -> Option<(Arc<Dataset>, Vec<BatchSampler>, usize)> {
        match self {
            WorkloadData::Mnist { train, shards, batch, .. } if !shards.is_empty() => {
                Some((Arc::clone(train), std::mem::take(shards), *batch))
            }
            _ => None,
        }
    }

    /// Iterate eval batches.
    pub fn eval_batches(&self) -> Vec<(BatchX, Vec<i32>, usize)> {
        match self {
            WorkloadData::Mnist { eval_x, eval_y, batch, train, .. } => {
                let feat = train.features;
                let n = eval_y.len() / batch;
                (0..n)
                    .map(|i| {
                        let x = eval_x[i * batch * feat..(i + 1) * batch * feat].to_vec();
                        let y = eval_y[i * batch..(i + 1) * batch].to_vec();
                        (BatchX::F32(x), y, *batch)
                    })
                    .collect()
            }
            WorkloadData::Shakespeare { eval_batches, batch, seq, .. } => eval_batches
                .iter()
                .map(|b| (BatchX::I32(b.clone()), vec![0i32; *batch], *batch * *seq))
                .collect(),
        }
    }
}

/// The single LR SGD-step implementation behind both the sequential trainer
/// and the split-off per-device handles: sample a batch, gather it, take
/// one gradient step. One body means the "parallel is bit-identical to
/// sequential" contract cannot drift between copies.
#[allow(clippy::too_many_arguments)]
fn lr_local_step(
    model: &NativeLr,
    train: &Dataset,
    sampler: &mut BatchSampler,
    batch: usize,
    idx_buf: &mut Vec<usize>,
    xb: &mut Vec<f32>,
    yb: &mut Vec<i32>,
    grad_buf: &mut [f32],
    params: &mut [f32],
    lr: f32,
) -> f64 {
    sampler.next_batch(batch, idx_buf);
    train.gather(idx_buf, xb, yb);
    let loss = model.loss_grad(params, xb, yb, grad_buf);
    // p += (-lr)·g via the blocked axpy — bitwise-identical to the old
    // `p -= lr * g` loop ((-lr)·g == -(lr·g) and a + (-b) == a - b exactly).
    crate::kernels::axpy(-lr, grad_buf, params);
    loss
}

/// Split-off single-device LR trainer: own sampler (its forked RNG stream
/// moved with it), own batch buffers, own [`NativeLr`] instance — nothing
/// shared but the read-only dataset, so devices train concurrently with
/// *exactly* the sequential path's numerics.
pub struct MnistDeviceTrainer {
    model: NativeLr,
    train: Arc<Dataset>,
    sampler: BatchSampler,
    batch: usize,
    idx_buf: Vec<usize>,
    xb: Vec<f32>,
    yb: Vec<i32>,
    grad_buf: Vec<f32>,
}

impl DeviceTrainer for MnistDeviceTrainer {
    fn local_step(&mut self, params: &mut Vec<f32>, lr: f32) -> Result<f64> {
        Ok(lr_local_step(
            &self.model,
            &self.train,
            &mut self.sampler,
            self.batch,
            &mut self.idx_buf,
            &mut self.xb,
            &mut self.yb,
            &mut self.grad_buf,
            params,
            lr,
        ))
    }

    fn samples(&self) -> usize {
        self.sampler.len()
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed trainer (production path)
// ---------------------------------------------------------------------------

pub struct PjrtTrainer {
    exe: ModelExecutable,
    data: WorkloadData,
    init: Vec<f32>,
}

impl PjrtTrainer {
    pub fn new(rt: &Runtime, cfg: &ExperimentConfig) -> Result<Self> {
        let model = cfg.workload.model_name();
        let exe = rt.load_model(model)?;
        let init = rt.load_init_params(model)?;
        let data = WorkloadData::build(cfg, rt.manifest.batch, rt.manifest.seq);
        Ok(PjrtTrainer { exe, data, init })
    }
}

impl LocalTrainer for PjrtTrainer {
    fn nparams(&self) -> usize {
        self.exe.meta.params
    }

    fn init_params(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn local_step(&mut self, device: usize, params: &mut Vec<f32>, lr: f32) -> Result<f64> {
        let (x, y) = self.data.next_batch(device);
        self.exe.local_step(params, &x, &y, lr)
    }

    fn device_samples(&self, device: usize) -> usize {
        self.data.device_samples(device)
    }

    fn eval(&mut self, params: &[f32]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut positions = 0usize;
        for (x, y, npos) in self.data.eval_batches() {
            let (ls, c) = self.exe.eval_batch(params, &x, &y)?;
            loss_sum += ls;
            correct += c;
            positions += npos;
        }
        anyhow::ensure!(positions > 0, "empty eval set");
        Ok((loss_sum / positions as f64, correct / positions as f64))
    }
}

// ---------------------------------------------------------------------------
// Native LR trainer (test path — no artifacts required)
// ---------------------------------------------------------------------------

pub struct NativeLrTrainer {
    model: NativeLr,
    data: WorkloadData,
    grad_buf: Vec<f32>,
}

impl NativeLrTrainer {
    pub fn new(cfg: &ExperimentConfig) -> Self {
        assert!(
            matches!(cfg.workload, Workload::LrMnist),
            "NativeLrTrainer only supports the LR workload"
        );
        let data = WorkloadData::build(cfg, cfg.batch, 0);
        NativeLrTrainer {
            model: NativeLr::new(),
            data,
            grad_buf: vec![0f32; crate::models::LR_PARAMS],
        }
    }
}

impl LocalTrainer for NativeLrTrainer {
    fn nparams(&self) -> usize {
        crate::models::LR_PARAMS
    }

    fn init_params(&self) -> Vec<f32> {
        vec![0f32; crate::models::LR_PARAMS]
    }

    fn local_step(&mut self, device: usize, params: &mut Vec<f32>, lr: f32) -> Result<f64> {
        let WorkloadData::Mnist { train, shards, batch, idx_buf, xb, yb, .. } = &mut self.data
        else {
            unreachable!("NativeLrTrainer only supports the LR workload")
        };
        assert!(
            !shards.is_empty(),
            "training shards were moved out by split_device_trainers(); \
             use the DeviceTrainer handles for local steps"
        );
        Ok(lr_local_step(
            &self.model,
            train,
            &mut shards[device],
            *batch,
            idx_buf,
            xb,
            yb,
            &mut self.grad_buf,
            params,
            lr,
        ))
    }

    fn device_samples(&self, device: usize) -> usize {
        self.data.device_samples(device)
    }

    /// Allocation-free eval: walks the held-out set as borrowed slices
    /// straight into the shared forward kernel — no per-batch `Vec` clones
    /// like the generic [`WorkloadData::eval_batches`] path (which the
    /// PJRT trainer keeps for its buffer-upload ABI). Batch boundaries and
    /// accumulation order are identical, so results are bitwise-unchanged;
    /// `tests/alloc_steady.rs` pins the zero-allocation claim.
    fn eval(&mut self, params: &[f32]) -> Result<(f64, f64)> {
        let WorkloadData::Mnist { eval_x, eval_y, batch, train, .. } = &self.data else {
            unreachable!("NativeLrTrainer only supports the LR workload")
        };
        let batch = *batch;
        let feat = train.features;
        let nb = eval_y.len() / batch;
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut n = 0usize;
        for i in 0..nb {
            let x = &eval_x[i * batch * feat..(i + 1) * batch * feat];
            let y = &eval_y[i * batch..(i + 1) * batch];
            let (ls, c) = self.model.eval(params, x, y);
            loss_sum += ls;
            correct += c;
            n += batch;
        }
        anyhow::ensure!(n > 0, "empty eval set");
        Ok((loss_sum / n as f64, correct / n as f64))
    }

    fn split_device_trainers(&mut self) -> Option<Vec<Box<dyn DeviceTrainer>>> {
        let (train, shards, batch) = self.data.split_mnist_shards()?;
        Some(
            shards
                .into_iter()
                .map(|sampler| {
                    Box::new(MnistDeviceTrainer {
                        model: NativeLr::new(),
                        train: Arc::clone(&train),
                        sampler,
                        batch,
                        idx_buf: Vec::new(),
                        xb: Vec::new(),
                        yb: Vec::new(),
                        grad_buf: vec![0f32; crate::models::LR_PARAMS],
                    }) as Box<dyn DeviceTrainer>
                })
                .collect(),
        )
    }

    /// Reabsorbs the handles' advanced samplers (device order is trusted —
    /// hand back exactly what `split_device_trainers` produced). Panics on
    /// a foreign or miscounted handle set: silently dropping it would leave
    /// the trainer permanently unable to serve `local_step`.
    fn restore_device_trainers(&mut self, handles: Vec<Box<dyn DeviceTrainer>>) {
        let WorkloadData::Mnist { shards, shard_sizes, .. } = &mut self.data else {
            unreachable!("NativeLrTrainer only supports the LR workload")
        };
        assert!(
            shards.is_empty(),
            "restore_device_trainers called on a trainer that was never split"
        );
        assert_eq!(
            handles.len(),
            shard_sizes.len(),
            "restore_device_trainers: handle count does not match device count"
        );
        for (i, handle) in handles.into_iter().enumerate() {
            let h = handle
                .into_any()
                .downcast::<MnistDeviceTrainer>()
                .unwrap_or_else(|_| {
                    panic!("restore_device_trainers: handle {i} is not a MnistDeviceTrainer")
                });
            shards.push(h.sampler);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            samples_per_device: 128,
            eval_samples: 128,
            devices: 3,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn native_lr_trainer_descends() {
        let cfg = small_cfg();
        let mut tr = NativeLrTrainer::new(&cfg);
        let mut params = tr.init_params();
        let mut first = 0.0;
        let mut last = 0.0;
        for i in 0..30 {
            let loss = tr.local_step(0, &mut params, 0.1).unwrap();
            if i == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn eval_improves_with_training() {
        let cfg = small_cfg();
        let mut tr = NativeLrTrainer::new(&cfg);
        let mut params = tr.init_params();
        let (_, acc0) = tr.eval(&params).unwrap();
        for _ in 0..150 {
            for dev in 0..3 {
                tr.local_step(dev, &mut params, 0.1).unwrap();
            }
        }
        let (_, acc1) = tr.eval(&params).unwrap();
        assert!(acc1 > acc0 + 0.2, "acc {acc0} -> {acc1}");
    }

    #[test]
    fn devices_get_different_batches() {
        let cfg = small_cfg();
        let mut data = WorkloadData::build(&cfg, 8, 0);
        let (x0, _) = data.next_batch(0);
        let (x1, _) = data.next_batch(1);
        match (x0, x1) {
            (BatchX::F32(a), BatchX::F32(b)) => assert_ne!(a, b),
            _ => panic!(),
        }
    }

    #[test]
    fn split_handles_match_sequential_steps_bitwise() {
        let cfg = small_cfg();
        let mut seq = NativeLrTrainer::new(&cfg);
        let mut par = NativeLrTrainer::new(&cfg);
        let mut handles = par.split_device_trainers().expect("LR workload splits");
        assert_eq!(handles.len(), 3);
        let mut p_seq = seq.init_params();
        let mut p_par = p_seq.clone();
        for step in 0..5 {
            for dev in 0..3 {
                let a = seq.local_step(dev, &mut p_seq, 0.05).unwrap();
                let b = handles[dev].local_step(&mut p_par, 0.05).unwrap();
                assert_eq!(a.to_bits(), b.to_bits(), "step {step} dev {dev}");
            }
        }
        assert_eq!(p_seq, p_par);
        // The parent still evaluates and reports shard sizes, but a second
        // split yields nothing while the handles are out.
        assert!(par.split_device_trainers().is_none());
        assert_eq!(par.device_samples(1), handles[1].samples());
        par.eval(&p_par).unwrap();
        // Restoring the handles reabsorbs the advanced samplers: the parent
        // continues exactly where the handles left off.
        par.restore_device_trainers(handles);
        for dev in 0..3 {
            let a = seq.local_step(dev, &mut p_seq, 0.05).unwrap();
            let b = par.local_step(dev, &mut p_par, 0.05).unwrap();
            assert_eq!(a.to_bits(), b.to_bits(), "post-restore dev {dev}");
        }
        assert!(par.split_device_trainers().is_some(), "splittable again");
    }

    #[test]
    fn shakespeare_data_shapes() {
        let cfg = ExperimentConfig {
            workload: Workload::RnnShakespeare,
            eval_samples: 128,
            ..ExperimentConfig::default()
        };
        let mut data = WorkloadData::build(&cfg, 64, 24);
        let (x, y) = data.next_batch(2);
        assert_eq!(x.len(), 64 * 25);
        assert_eq!(y.len(), 64);
        let evals = data.eval_batches();
        assert!(!evals.is_empty());
        assert_eq!(evals[0].2, 64 * 24);
    }
}
