//! Local-training abstraction: the device round loop calls a [`LocalTrainer`]
//! for gradient compute, which is either the PJRT runtime executing the AOT
//! artifacts (production path) or the pure-Rust LR reference (test path —
//! no artifacts needed, exact same interface).

use anyhow::Result;

use crate::config::{ExperimentConfig, Workload};
use crate::data::{partition_dirichlet, BatchSampler, CharCorpus, Dataset, MnistGen};
use crate::models::NativeLr;
use crate::runtime::{BatchX, ModelExecutable, Runtime};
use crate::util::Rng;

/// Per-device mini-batch + held-out evaluation over one workload.
pub trait LocalTrainer {
    /// Flat parameter count P.
    fn nparams(&self) -> usize;
    /// Initial global parameters.
    fn init_params(&self) -> Vec<f32>;
    /// Run ONE local SGD step for `device` on a fresh mini-batch, updating
    /// `params` in place. Returns the step's training loss.
    fn local_step(&mut self, device: usize, params: &mut Vec<f32>, lr: f32) -> Result<f64>;
    /// Evaluate on the held-out set: (mean loss, accuracy in [0,1]).
    fn eval(&mut self, params: &[f32]) -> Result<(f64, f64)>;
    /// Local sample count of `device` (n_m) — feeds sample-weighted
    /// aggregation rules. Defaults to 1 (uniform) for backends that don't
    /// track shard sizes.
    fn device_samples(&self, _device: usize) -> usize {
        1
    }
}

// ---------------------------------------------------------------------------
// Workload data (shared by both trainer impls)
// ---------------------------------------------------------------------------

/// Materialized per-device training data + held-out eval batches.
pub enum WorkloadData {
    Mnist {
        train: Dataset,
        shards: Vec<BatchSampler>,
        eval_x: Vec<f32>,
        eval_y: Vec<i32>,
        batch: usize,
        idx_buf: Vec<usize>,
        xb: Vec<f32>,
        yb: Vec<i32>,
    },
    Shakespeare {
        corpus: CharCorpus,
        spans: Vec<(usize, usize)>,
        rngs: Vec<Rng>,
        eval_batches: Vec<Vec<i32>>,
        batch: usize,
        seq: usize,
        buf: Vec<i32>,
    },
}

impl WorkloadData {
    pub fn build(cfg: &ExperimentConfig, batch: usize, seq: usize) -> Self {
        let mut rng = Rng::new(cfg.seed ^ 0xDA7A);
        match cfg.workload {
            Workload::LrMnist | Workload::CnnMnist => {
                let gen = MnistGen::new(cfg.seed);
                let total = cfg.samples_per_device * cfg.devices;
                let train = gen.dataset(0, total);
                let parts = partition_dirichlet(
                    &train,
                    cfg.devices,
                    cfg.dirichlet_alpha,
                    crate::data::mnist::CLASSES,
                    &mut rng,
                );
                let shards = parts
                    .into_iter()
                    .enumerate()
                    .map(|(i, idxs)| BatchSampler::new(idxs, rng.fork(i as u64)))
                    .collect();
                let eval = gen.dataset(total as u64 + 10_000, cfg.eval_samples);
                WorkloadData::Mnist {
                    eval_x: eval.x,
                    eval_y: eval.y,
                    train,
                    shards,
                    batch,
                    idx_buf: Vec::new(),
                    xb: Vec::new(),
                    yb: Vec::new(),
                }
            }
            Workload::RnnShakespeare => {
                let corpus = CharCorpus::embedded(seq);
                let spans = corpus.device_spans(cfg.devices);
                let rngs = (0..cfg.devices).map(|i| rng.fork(100 + i as u64)).collect();
                // Fixed eval batches drawn across the whole corpus.
                let mut eval_rng = rng.fork(999);
                let n_eval = (cfg.eval_samples / batch).max(1);
                let mut eval_batches = Vec::with_capacity(n_eval);
                let full = (0, corpus.num_positions());
                for _ in 0..n_eval {
                    let mut b = Vec::new();
                    corpus.fill_batch(&mut eval_rng, full, batch, &mut b);
                    eval_batches.push(b);
                }
                WorkloadData::Shakespeare {
                    corpus,
                    spans,
                    rngs,
                    eval_batches,
                    batch,
                    seq,
                    buf: Vec::new(),
                }
            }
        }
    }

    /// Fill the next training batch for `device`. Returns (x, y).
    pub fn next_batch(&mut self, device: usize) -> (BatchX, Vec<i32>) {
        match self {
            WorkloadData::Mnist { train, shards, batch, idx_buf, xb, yb, .. } => {
                shards[device].next_batch(*batch, idx_buf);
                train.gather(idx_buf, xb, yb);
                (BatchX::F32(xb.clone()), yb.clone())
            }
            WorkloadData::Shakespeare { corpus, spans, rngs, batch, buf, .. } => {
                corpus.fill_batch(&mut rngs[device], spans[device], *batch, buf);
                // y unused by the rnn graphs; keep the ABI's int32[batch].
                (BatchX::I32(buf.clone()), vec![0i32; *batch])
            }
        }
    }

    /// Local sample count of `device` (shard size / corpus span positions).
    pub fn device_samples(&self, device: usize) -> usize {
        match self {
            WorkloadData::Mnist { shards, .. } => shards[device].len(),
            WorkloadData::Shakespeare { spans, .. } => {
                let (lo, hi) = spans[device];
                hi.saturating_sub(lo)
            }
        }
    }

    /// Iterate eval batches.
    pub fn eval_batches(&self) -> Vec<(BatchX, Vec<i32>, usize)> {
        match self {
            WorkloadData::Mnist { eval_x, eval_y, batch, train, .. } => {
                let feat = train.features;
                let n = eval_y.len() / batch;
                (0..n)
                    .map(|i| {
                        let x = eval_x[i * batch * feat..(i + 1) * batch * feat].to_vec();
                        let y = eval_y[i * batch..(i + 1) * batch].to_vec();
                        (BatchX::F32(x), y, *batch)
                    })
                    .collect()
            }
            WorkloadData::Shakespeare { eval_batches, batch, seq, .. } => eval_batches
                .iter()
                .map(|b| (BatchX::I32(b.clone()), vec![0i32; *batch], *batch * *seq))
                .collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT-backed trainer (production path)
// ---------------------------------------------------------------------------

pub struct PjrtTrainer {
    exe: ModelExecutable,
    data: WorkloadData,
    init: Vec<f32>,
}

impl PjrtTrainer {
    pub fn new(rt: &Runtime, cfg: &ExperimentConfig) -> Result<Self> {
        let model = cfg.workload.model_name();
        let exe = rt.load_model(model)?;
        let init = rt.load_init_params(model)?;
        let data = WorkloadData::build(cfg, rt.manifest.batch, rt.manifest.seq);
        Ok(PjrtTrainer { exe, data, init })
    }
}

impl LocalTrainer for PjrtTrainer {
    fn nparams(&self) -> usize {
        self.exe.meta.params
    }

    fn init_params(&self) -> Vec<f32> {
        self.init.clone()
    }

    fn local_step(&mut self, device: usize, params: &mut Vec<f32>, lr: f32) -> Result<f64> {
        let (x, y) = self.data.next_batch(device);
        self.exe.local_step(params, &x, &y, lr)
    }

    fn device_samples(&self, device: usize) -> usize {
        self.data.device_samples(device)
    }

    fn eval(&mut self, params: &[f32]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut positions = 0usize;
        for (x, y, npos) in self.data.eval_batches() {
            let (ls, c) = self.exe.eval_batch(params, &x, &y)?;
            loss_sum += ls;
            correct += c;
            positions += npos;
        }
        anyhow::ensure!(positions > 0, "empty eval set");
        Ok((loss_sum / positions as f64, correct / positions as f64))
    }
}

// ---------------------------------------------------------------------------
// Native LR trainer (test path — no artifacts required)
// ---------------------------------------------------------------------------

pub struct NativeLrTrainer {
    model: NativeLr,
    data: WorkloadData,
    grad_buf: Vec<f32>,
}

impl NativeLrTrainer {
    pub fn new(cfg: &ExperimentConfig) -> Self {
        assert!(
            matches!(cfg.workload, Workload::LrMnist),
            "NativeLrTrainer only supports the LR workload"
        );
        let data = WorkloadData::build(cfg, cfg.batch, 0);
        NativeLrTrainer {
            model: NativeLr::new(),
            data,
            grad_buf: vec![0f32; crate::models::LR_PARAMS],
        }
    }
}

impl LocalTrainer for NativeLrTrainer {
    fn nparams(&self) -> usize {
        crate::models::LR_PARAMS
    }

    fn init_params(&self) -> Vec<f32> {
        vec![0f32; crate::models::LR_PARAMS]
    }

    fn local_step(&mut self, device: usize, params: &mut Vec<f32>, lr: f32) -> Result<f64> {
        let (x, y) = self.data.next_batch(device);
        let x = match x {
            BatchX::F32(v) => v,
            _ => unreachable!(),
        };
        let loss = self.model.loss_grad(params, &x, &y, &mut self.grad_buf);
        for (p, &g) in params.iter_mut().zip(&self.grad_buf) {
            *p -= lr * g;
        }
        Ok(loss)
    }

    fn device_samples(&self, device: usize) -> usize {
        self.data.device_samples(device)
    }

    fn eval(&mut self, params: &[f32]) -> Result<(f64, f64)> {
        let mut loss_sum = 0.0;
        let mut correct = 0.0;
        let mut n = 0usize;
        for (x, y, npos) in self.data.eval_batches() {
            let x = match x {
                BatchX::F32(v) => v,
                _ => unreachable!(),
            };
            let (ls, c) = self.model.eval(params, &x, &y);
            loss_sum += ls;
            correct += c;
            n += npos;
        }
        anyhow::ensure!(n > 0, "empty eval set");
        Ok((loss_sum / n as f64, correct / n as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    fn small_cfg() -> ExperimentConfig {
        ExperimentConfig {
            samples_per_device: 128,
            eval_samples: 128,
            devices: 3,
            ..ExperimentConfig::default()
        }
    }

    #[test]
    fn native_lr_trainer_descends() {
        let cfg = small_cfg();
        let mut tr = NativeLrTrainer::new(&cfg);
        let mut params = tr.init_params();
        let mut first = 0.0;
        let mut last = 0.0;
        for i in 0..30 {
            let loss = tr.local_step(0, &mut params, 0.1).unwrap();
            if i == 0 {
                first = loss;
            }
            last = loss;
        }
        assert!(last < first, "loss {first} -> {last}");
    }

    #[test]
    fn eval_improves_with_training() {
        let cfg = small_cfg();
        let mut tr = NativeLrTrainer::new(&cfg);
        let mut params = tr.init_params();
        let (_, acc0) = tr.eval(&params).unwrap();
        for _ in 0..150 {
            for dev in 0..3 {
                tr.local_step(dev, &mut params, 0.1).unwrap();
            }
        }
        let (_, acc1) = tr.eval(&params).unwrap();
        assert!(acc1 > acc0 + 0.2, "acc {acc0} -> {acc1}");
    }

    #[test]
    fn devices_get_different_batches() {
        let cfg = small_cfg();
        let mut data = WorkloadData::build(&cfg, 8, 0);
        let (x0, _) = data.next_batch(0);
        let (x1, _) = data.next_batch(1);
        match (x0, x1) {
            (BatchX::F32(a), BatchX::F32(b)) => assert_ne!(a, b),
            _ => panic!(),
        }
    }

    #[test]
    fn shakespeare_data_shapes() {
        let cfg = ExperimentConfig {
            workload: Workload::RnnShakespeare,
            eval_samples: 128,
            ..ExperimentConfig::default()
        };
        let mut data = WorkloadData::build(&cfg, 64, 24);
        let (x, y) = data.next_batch(2);
        assert_eq!(x.len(), 64 * 25);
        assert_eq!(y.len(), 64);
        let evals = data.eval_batches();
        assert!(!evals.is_empty());
        assert_eq!(evals[0].2, 64 * 24);
    }
}
