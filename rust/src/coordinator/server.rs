//! FL edge server (Alg. 1 lines 18–22): collect the layered updates from
//! every device (decoding from the wire format, as the real server would),
//! run the pluggable [`Aggregator`], update the global model, and broadcast.

use super::aggregator::{Aggregator, MeanAggregator};
use crate::compression::{wire, Layer, LgcUpdate};

/// The central server's state.
pub struct Server {
    /// w̄ — the global model.
    pub params: Vec<f32>,
    agg_buf: Vec<f32>,
    aggregator: Box<dyn Aggregator>,
    /// Reusable wire buffer for the per-layer encode/decode round-trip (the
    /// hot loop never allocates for it at steady state).
    wire_buf: Vec<u8>,
}

impl Server {
    /// Server with the default mean aggregation (the seed's behavior).
    pub fn new(init: Vec<f32>) -> Self {
        Self::with_aggregator(init, Box::new(MeanAggregator))
    }

    /// Server with an explicit aggregation rule.
    pub fn with_aggregator(init: Vec<f32>, aggregator: Box<dyn Aggregator>) -> Self {
        let dim = init.len();
        Server { params: init, agg_buf: vec![0f32; dim], aggregator, wire_buf: Vec::new() }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Restart the global model (new episode) while keeping the configured
    /// aggregation rule.
    pub fn reset_model(&mut self, init: Vec<f32>) {
        self.agg_buf.clear();
        self.agg_buf.resize(init.len(), 0.0);
        self.params = init;
    }

    pub fn aggregator_name(&self) -> String {
        self.aggregator.name()
    }

    /// Announce per-upload weights for the next [`Server::aggregate_and_apply`]
    /// call (same order as its `uploads` slice).
    pub fn set_round_weights(&mut self, weights: &[f64]) {
        self.aggregator.set_round_weights(weights);
    }

    /// Aggregate updates through the configured rule and apply:
    /// `w̄^{t+1} = w̄^{t} − aggregate(g_1..g_M)` (line 21).
    pub fn aggregate_and_apply(&mut self, uploads: &[&LgcUpdate]) {
        assert!(!uploads.is_empty());
        for upd in uploads {
            assert_eq!(upd.dim, self.params.len(), "dim mismatch");
        }
        self.aggregator.aggregate(uploads, &mut self.agg_buf);
        for (p, &g) in self.params.iter_mut().zip(&self.agg_buf) {
            *p -= g;
        }
    }

    /// Round-trip an update through the wire format (what the channel
    /// actually carried) into a reusable output buffer — `out`'s layer
    /// vectors are recycled, so the round loop performs no steady-state
    /// allocation here. Byte-accounting consistency between what the
    /// channel simulator charges and what the wire carries is enforced by
    /// `tests/compressor_contract.rs` for every registered sparse-wire
    /// compressor.
    pub fn decode_from_wire_into(
        &mut self,
        update: &LgcUpdate,
        out: &mut LgcUpdate,
    ) -> anyhow::Result<()> {
        out.dim = update.dim;
        out.layers.truncate(update.layers.len());
        while out.layers.len() < update.layers.len() {
            out.layers.push(Layer { indices: Vec::new(), values: Vec::new() });
        }
        for (layer, dst) in update.layers.iter().zip(out.layers.iter_mut()) {
            let written = wire::encode_into(update.dim, layer, &mut self.wire_buf);
            debug_assert_eq!(written as u64, layer.wire_bytes());
            let dim = wire::decode_into(&self.wire_buf, dst)?;
            anyhow::ensure!(dim == update.dim, "wire dim mismatch");
        }
        Ok(())
    }

    /// Allocating convenience wrapper over the same per-layer wire
    /// round-trip as [`Server::decode_from_wire_into`], for tests and
    /// one-off callers (no server state involved).
    pub fn decode_from_wire(update: &LgcUpdate) -> anyhow::Result<LgcUpdate> {
        let mut buf = Vec::new();
        let mut layers = Vec::with_capacity(update.layers.len());
        for layer in &update.layers {
            wire::encode_into(update.dim, layer, &mut buf);
            let mut dst = Layer { indices: Vec::new(), values: Vec::new() };
            let dim = wire::decode_into(&buf, &mut dst)?;
            anyhow::ensure!(dim == update.dim, "wire dim mismatch");
            layers.push(dst);
        }
        Ok(LgcUpdate { dim: update.dim, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    fn upd(dim: usize, seed: u64, ks: &[usize]) -> LgcUpdate {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        lgc_compress(&u, ks, &mut CompressScratch::default())
    }

    #[test]
    fn aggregation_is_mean_of_decodes() {
        let a = upd(64, 1, &[8]);
        let b = upd(64, 2, &[8]);
        let mut server = Server::new(vec![0f32; 64]);
        server.aggregate_and_apply(&[&a, &b]);
        let da = a.decode();
        let db = b.decode();
        for i in 0..64 {
            let expect = -(da[i] + db[i]) / 2.0;
            assert!((server.params[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn wire_roundtrip_identity() {
        let u = upd(256, 3, &[8, 16, 32]);
        let d = Server::decode_from_wire(&u).unwrap();
        assert_eq!(u, d);
    }

    #[test]
    fn decode_into_reuses_buffers_and_checks_accounting() {
        let mut server = Server::new(vec![0f32; 512]);
        let mut out = LgcUpdate { dim: 0, layers: Vec::new() };
        for seed in 0..8 {
            let u = upd(512, 100 + seed, &[16, 64]);
            server.decode_from_wire_into(&u, &mut out).unwrap();
            assert_eq!(u, out, "seed {seed}");
            // byte accounting: what the channels charge == what went over
            // the wire
            for layer in &u.layers {
                assert_eq!(
                    layer.wire_bytes(),
                    wire::encoded_len(layer.len()) as u64
                );
            }
        }
        // shrinking layer counts must truncate the reusable output
        let small = upd(512, 999, &[4]);
        server.decode_from_wire_into(&small, &mut out).unwrap();
        assert_eq!(out.layers.len(), 1);
        assert_eq!(small, out);
    }

    #[test]
    fn repeated_aggregation_accumulates() {
        let mut server = Server::new(vec![0f32; 32]);
        let a = upd(32, 4, &[4]);
        server.aggregate_and_apply(&[&a]);
        let p1 = server.params.clone();
        server.aggregate_and_apply(&[&a]);
        for i in 0..32 {
            assert!((server.params[i] - 2.0 * p1[i]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_detected() {
        let mut server = Server::new(vec![0f32; 16]);
        let a = upd(32, 5, &[4]);
        server.aggregate_and_apply(&[&a]);
    }
}
