//! FL edge server (Alg. 1 lines 18–22): collect the layered updates from
//! every device (decoding from the wire format, as the real server would),
//! run the pluggable [`Aggregator`], update the global model, and broadcast.

use super::aggregator::{Aggregator, MeanAggregator};
use crate::compression::{wire, Layer, LgcUpdate};

/// The central server's state.
pub struct Server {
    /// w̄ — the global model.
    pub params: Vec<f32>,
    agg_buf: Vec<f32>,
    aggregator: Box<dyn Aggregator>,
    /// Reusable wire buffer for the per-layer encode/decode round-trip (the
    /// hot loop never allocates for it at steady state).
    wire_buf: Vec<u8>,
    /// Streaming-round state (see [`Server::stream_begin`]): whether the
    /// aggregator streams natively, fold counters, and the clone buffer of
    /// the batch fallback.
    stream_native: bool,
    stream_n: usize,
    stream_wsum: f64,
    stream_fallback: Vec<(LgcUpdate, f64)>,
}

impl Server {
    /// Server with the default mean aggregation (the seed's behavior).
    pub fn new(init: Vec<f32>) -> Self {
        Self::with_aggregator(init, Box::new(MeanAggregator))
    }

    /// Server with an explicit aggregation rule.
    pub fn with_aggregator(init: Vec<f32>, aggregator: Box<dyn Aggregator>) -> Self {
        let dim = init.len();
        Server {
            params: init,
            agg_buf: vec![0f32; dim],
            aggregator,
            wire_buf: Vec::new(),
            stream_native: false,
            stream_n: 0,
            stream_wsum: 0.0,
            stream_fallback: Vec::new(),
        }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Restart the global model (new episode) while keeping the configured
    /// aggregation rule.
    pub fn reset_model(&mut self, init: Vec<f32>) {
        self.agg_buf.clear();
        self.agg_buf.resize(init.len(), 0.0);
        self.params = init;
        self.stream_n = 0;
        self.stream_wsum = 0.0;
        self.stream_fallback.clear();
    }

    pub fn aggregator_name(&self) -> String {
        self.aggregator.name()
    }

    /// Announce per-upload weights for the next [`Server::aggregate_and_apply`]
    /// call (same order as its `uploads` slice).
    pub fn set_round_weights(&mut self, weights: &[f64]) {
        self.aggregator.set_round_weights(weights);
    }

    /// Aggregate updates through the configured rule and apply:
    /// `w̄^{t+1} = w̄^{t} − aggregate(g_1..g_M)` (line 21).
    pub fn aggregate_and_apply(&mut self, uploads: &[&LgcUpdate]) {
        assert!(!uploads.is_empty());
        for upd in uploads {
            assert_eq!(upd.dim, self.params.len(), "dim mismatch");
        }
        self.aggregator.aggregate(uploads, &mut self.agg_buf);
        for (p, &g) in self.params.iter_mut().zip(&self.agg_buf) {
            *p -= g;
        }
    }

    /// Open a streaming aggregation round: uploads folded via
    /// [`Server::stream_accumulate`] land in the server's O(model) aggregate
    /// buffer the moment they arrive, instead of every decoded `LgcUpdate`
    /// being buffered until aggregation time. When the configured rule does
    /// not stream natively (`Aggregator::stream_begin` returns false), the
    /// server transparently falls back to buffering clones and driving the
    /// batch `aggregate` at [`Server::stream_apply`] — callers never branch.
    /// Streaming vs batch results agree to the documented float tolerance
    /// (~1e-6 relative; see `coordinator::aggregator`).
    pub fn stream_begin(&mut self) {
        self.agg_buf.iter_mut().for_each(|x| *x = 0.0);
        self.stream_native = self.aggregator.stream_begin(self.params.len());
        self.stream_n = 0;
        self.stream_wsum = 0.0;
        self.stream_fallback.clear();
    }

    /// Fold one upload (with its announced weight, e.g. the client's local
    /// sample count) into the running aggregate.
    pub fn stream_accumulate(&mut self, upload: &LgcUpdate, weight: f64) {
        assert_eq!(upload.dim, self.params.len(), "dim mismatch");
        if self.stream_native {
            self.aggregator.stream_accumulate(upload, weight, &mut self.agg_buf);
        } else {
            self.stream_fallback.push((upload.clone(), weight));
        }
        self.stream_n += 1;
        self.stream_wsum += weight;
    }

    /// Finalize the streaming round and apply the descent direction:
    /// `w̄ ← w̄ − finalize(acc)`. Returns false (and applies nothing) when no
    /// upload was folded since [`Server::stream_begin`].
    pub fn stream_apply(&mut self) -> bool {
        if self.stream_n == 0 {
            return false;
        }
        if self.stream_native {
            self.aggregator
                .stream_finalize(&mut self.agg_buf, self.stream_n, self.stream_wsum);
        } else {
            let buffered = std::mem::take(&mut self.stream_fallback);
            let weights: Vec<f64> = buffered.iter().map(|(_, w)| *w).collect();
            let uploads: Vec<&LgcUpdate> = buffered.iter().map(|(u, _)| u).collect();
            self.aggregator.set_round_weights(&weights);
            self.aggregator.aggregate(&uploads, &mut self.agg_buf);
        }
        for (p, &g) in self.params.iter_mut().zip(&self.agg_buf) {
            *p -= g;
        }
        self.stream_n = 0;
        self.stream_wsum = 0.0;
        true
    }

    /// Round-trip an update through the wire format (what the channel
    /// actually carried) into a reusable output buffer — `out`'s layer
    /// vectors are recycled, so the round loop performs no steady-state
    /// allocation here. Byte-accounting consistency between what the
    /// channel simulator charges and what the wire carries is enforced by
    /// `tests/compressor_contract.rs` for every registered sparse-wire
    /// compressor.
    pub fn decode_from_wire_into(
        &mut self,
        update: &LgcUpdate,
        out: &mut LgcUpdate,
    ) -> anyhow::Result<()> {
        out.dim = update.dim;
        out.layers.truncate(update.layers.len());
        while out.layers.len() < update.layers.len() {
            out.layers.push(Layer { indices: Vec::new(), values: Vec::new() });
        }
        for (layer, dst) in update.layers.iter().zip(out.layers.iter_mut()) {
            let written = wire::encode_into(update.dim, layer, &mut self.wire_buf);
            debug_assert_eq!(written as u64, layer.wire_bytes());
            let dim = wire::decode_into(&self.wire_buf, dst)?;
            anyhow::ensure!(dim == update.dim, "wire dim mismatch");
        }
        Ok(())
    }

    /// Allocating convenience wrapper over the same per-layer wire
    /// round-trip as [`Server::decode_from_wire_into`], for tests and
    /// one-off callers (no server state involved).
    pub fn decode_from_wire(update: &LgcUpdate) -> anyhow::Result<LgcUpdate> {
        let mut buf = Vec::new();
        let mut layers = Vec::with_capacity(update.layers.len());
        for layer in &update.layers {
            wire::encode_into(update.dim, layer, &mut buf);
            let mut dst = Layer { indices: Vec::new(), values: Vec::new() };
            let dim = wire::decode_into(&buf, &mut dst)?;
            anyhow::ensure!(dim == update.dim, "wire dim mismatch");
            layers.push(dst);
        }
        Ok(LgcUpdate { dim: update.dim, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    fn upd(dim: usize, seed: u64, ks: &[usize]) -> LgcUpdate {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        lgc_compress(&u, ks, &mut CompressScratch::default())
    }

    #[test]
    fn aggregation_is_mean_of_decodes() {
        let a = upd(64, 1, &[8]);
        let b = upd(64, 2, &[8]);
        let mut server = Server::new(vec![0f32; 64]);
        server.aggregate_and_apply(&[&a, &b]);
        let da = a.decode();
        let db = b.decode();
        for i in 0..64 {
            let expect = -(da[i] + db[i]) / 2.0;
            assert!((server.params[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn wire_roundtrip_identity() {
        let u = upd(256, 3, &[8, 16, 32]);
        let d = Server::decode_from_wire(&u).unwrap();
        assert_eq!(u, d);
    }

    #[test]
    fn decode_into_reuses_buffers_and_checks_accounting() {
        let mut server = Server::new(vec![0f32; 512]);
        let mut out = LgcUpdate { dim: 0, layers: Vec::new() };
        for seed in 0..8 {
            let u = upd(512, 100 + seed, &[16, 64]);
            server.decode_from_wire_into(&u, &mut out).unwrap();
            assert_eq!(u, out, "seed {seed}");
            // byte accounting: what the channels charge == what went over
            // the wire
            for layer in &u.layers {
                assert_eq!(
                    layer.wire_bytes(),
                    wire::encoded_len(layer.len()) as u64
                );
            }
        }
        // shrinking layer counts must truncate the reusable output
        let small = upd(512, 999, &[4]);
        server.decode_from_wire_into(&small, &mut out).unwrap();
        assert_eq!(out.layers.len(), 1);
        assert_eq!(small, out);
    }

    #[test]
    fn repeated_aggregation_accumulates() {
        let mut server = Server::new(vec![0f32; 32]);
        let a = upd(32, 4, &[4]);
        server.aggregate_and_apply(&[&a]);
        let p1 = server.params.clone();
        server.aggregate_and_apply(&[&a]);
        for i in 0..32 {
            assert!((server.params[i] - 2.0 * p1[i]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_detected() {
        let mut server = Server::new(vec![0f32; 16]);
        let a = upd(32, 5, &[4]);
        server.aggregate_and_apply(&[&a]);
    }

    #[test]
    fn streaming_apply_matches_batch_within_tolerance() {
        let ups: Vec<LgcUpdate> = (0..6).map(|s| upd(64, 200 + s, &[8, 16])).collect();
        let mut batch = Server::new(vec![0f32; 64]);
        let refs: Vec<&LgcUpdate> = ups.iter().collect();
        batch.aggregate_and_apply(&refs);
        let mut stream = Server::new(vec![0f32; 64]);
        stream.stream_begin();
        for u in &ups {
            stream.stream_accumulate(u, 1.0);
        }
        assert!(stream.stream_apply());
        for i in 0..64 {
            assert!(
                (batch.params[i] - stream.params[i]).abs() < 1e-5,
                "at {i}: batch {} vs stream {}",
                batch.params[i],
                stream.params[i]
            );
        }
    }

    #[test]
    fn streaming_fallback_buffers_for_non_streaming_rules() {
        // A rule that never streams: the server must buffer and reproduce
        // the batch path exactly (bitwise — same calls, same order).
        struct BatchOnly;
        impl crate::coordinator::aggregator::Aggregator for BatchOnly {
            fn name(&self) -> String {
                "batch-only".into()
            }
            fn aggregate(&mut self, uploads: &[&LgcUpdate], out: &mut [f32]) {
                out.iter_mut().for_each(|x| *x = 0.0);
                let scale = 1.0 / uploads.len() as f32;
                for upd in uploads {
                    upd.add_into(out, scale);
                }
            }
        }
        let ups: Vec<LgcUpdate> = (0..3).map(|s| upd(32, 300 + s, &[8])).collect();
        let refs: Vec<&LgcUpdate> = ups.iter().collect();
        let mut batch = Server::with_aggregator(vec![0f32; 32], Box::new(BatchOnly));
        batch.aggregate_and_apply(&refs);
        let mut stream = Server::with_aggregator(vec![0f32; 32], Box::new(BatchOnly));
        stream.stream_begin();
        for u in &ups {
            stream.stream_accumulate(u, 1.0);
        }
        assert!(stream.stream_apply());
        for i in 0..32 {
            assert_eq!(batch.params[i].to_bits(), stream.params[i].to_bits(), "at {i}");
        }
    }

    #[test]
    fn streaming_apply_without_uploads_is_noop() {
        let mut server = Server::new(vec![0.5f32; 8]);
        server.stream_begin();
        assert!(!server.stream_apply());
        assert!(server.params.iter().all(|&p| p == 0.5));
    }
}
