//! FL edge server (Alg. 1 lines 18–22): collect the layered updates from
//! every device (decoding from the wire format, as the real server would),
//! aggregate, update the global model, and broadcast.

use crate::compression::{wire, LgcUpdate};

/// The central server's state.
pub struct Server {
    /// w̄ — the global model.
    pub params: Vec<f32>,
    agg_buf: Vec<f32>,
}

impl Server {
    pub fn new(init: Vec<f32>) -> Self {
        let dim = init.len();
        Server { params: init, agg_buf: vec![0f32; dim] }
    }

    pub fn dim(&self) -> usize {
        self.params.len()
    }

    /// Aggregate updates (mean of decoded g_m) and apply:
    /// `w̄^{t+1} = w̄^{t} − (1/M) Σ_m g_m` (line 21, mean aggregation).
    /// Updates arrive as wire chunks per layer — the server decodes them
    /// exactly as it would off the sockets.
    pub fn aggregate_and_apply(&mut self, uploads: &[&LgcUpdate]) {
        assert!(!uploads.is_empty());
        self.agg_buf.iter_mut().for_each(|x| *x = 0.0);
        let scale = 1.0 / uploads.len() as f32;
        for upd in uploads {
            assert_eq!(upd.dim, self.params.len(), "dim mismatch");
            upd.add_into(&mut self.agg_buf, scale);
        }
        for (p, &g) in self.params.iter_mut().zip(&self.agg_buf) {
            *p -= g;
        }
    }

    /// Round-trip an update through the wire format (what the channel
    /// actually carried) and return the decoded update. Detects protocol
    /// bugs in tests and charges byte-exact costs in the simulator.
    pub fn decode_from_wire(update: &LgcUpdate) -> anyhow::Result<LgcUpdate> {
        let mut layers = Vec::with_capacity(update.layers.len());
        for layer in &update.layers {
            let chunk = wire::encode(update.dim, layer);
            let (dim, decoded) = wire::decode(&chunk)?;
            anyhow::ensure!(dim == update.dim, "wire dim mismatch");
            layers.push(decoded);
        }
        Ok(LgcUpdate { dim: update.dim, layers })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::{lgc_compress, CompressScratch};
    use crate::util::Rng;

    fn upd(dim: usize, seed: u64, ks: &[usize]) -> LgcUpdate {
        let mut rng = Rng::new(seed);
        let u: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        lgc_compress(&u, ks, &mut CompressScratch::default())
    }

    #[test]
    fn aggregation_is_mean_of_decodes() {
        let a = upd(64, 1, &[8]);
        let b = upd(64, 2, &[8]);
        let mut server = Server::new(vec![0f32; 64]);
        server.aggregate_and_apply(&[&a, &b]);
        let da = a.decode();
        let db = b.decode();
        for i in 0..64 {
            let expect = -(da[i] + db[i]) / 2.0;
            assert!((server.params[i] - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn wire_roundtrip_identity() {
        let u = upd(256, 3, &[8, 16, 32]);
        let d = Server::decode_from_wire(&u).unwrap();
        assert_eq!(u, d);
    }

    #[test]
    fn repeated_aggregation_accumulates() {
        let mut server = Server::new(vec![0f32; 32]);
        let a = upd(32, 4, &[4]);
        server.aggregate_and_apply(&[&a]);
        let p1 = server.params.clone();
        server.aggregate_and_apply(&[&a]);
        for i in 0..32 {
            assert!((server.params[i] - 2.0 * p1[i]).abs() < 1e-6);
        }
    }

    #[test]
    #[should_panic(expected = "dim mismatch")]
    fn dim_mismatch_detected() {
        let mut server = Server::new(vec![0f32; 16]);
        let a = upd(32, 5, &[4]);
        server.aggregate_and_apply(&[&a]);
    }
}
