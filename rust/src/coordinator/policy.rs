//! The [`RoundPolicy`] trait — the per-round control seam.
//!
//! A policy decides, for each active device, the local step count `H_m` and
//! the layer-to-channel [`AllocationPlan`] `D_{m,n}` (the Eq. 13 action),
//! and optionally learns from the round outcome. This replaces the
//! per-mechanism `match` that used to live inside the round loop: mechanism
//! behavior is now fully determined by the registered
//! compressor/aggregator/policy triple (see [`super::registry`]).

use super::device::Device;
use crate::channels::AllocationPlan;
use crate::drl::DeviceAgent;
use crate::resources::Resource;

/// Per-round control decisions for one experiment.
///
/// `decide` runs *before* a device's local computation; `observe` runs after
/// the round's costs are recorded (so `dev.meter.last_round` is fresh) and
/// returns a reward when the policy learns online.
pub trait RoundPolicy: Send {
    /// Short human-readable name for logs and registry listings.
    fn name(&self) -> String;

    /// Whether the builder should create one DDPG [`DeviceAgent`] per
    /// device for this policy.
    fn needs_agents(&self) -> bool {
        false
    }

    /// Decide `(H, plan)` for `dev` this round.
    fn decide(
        &mut self,
        round: usize,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan);

    /// Observe the round outcome for `dev` (`delta` = loss improvement);
    /// returns the learning reward, if any.
    fn observe(
        &mut self,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
        delta: f64,
        done: bool,
    ) -> Option<f64> {
        let _ = (dev, agent, delta, done);
        None
    }
}

/// Fixed `H` and a fixed layer-to-channel mapping: layer `c` rides channel
/// `c` (channel list is fastest-first, so the base layer takes the most
/// reliable link — the layered-coding mapping of the paper).
#[derive(Clone, Debug)]
pub struct StaticLayered {
    pub h: usize,
    /// Per-channel coordinate counts (zero = silent channel).
    pub counts: Vec<usize>,
}

impl RoundPolicy for StaticLayered {
    fn name(&self) -> String {
        format!("static-layered(h={})", self.h)
    }

    fn decide(
        &mut self,
        _round: usize,
        _dev: &Device,
        _agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        (self.h, AllocationPlan { counts: self.counts.clone() })
    }
}

/// Fixed `H`, everything on the *currently fastest* channel — the
/// single-channel baselines (Top-k ablation A1; FedAvg's dense upload).
/// The plan width follows the device's actual channel count.
#[derive(Clone, Debug)]
pub struct FastestSingle {
    pub h: usize,
    /// Total coordinate budget to place on the fastest channel.
    pub total: usize,
}

impl RoundPolicy for FastestSingle {
    fn name(&self) -> String {
        format!("fastest-single(h={})", self.h)
    }

    fn decide(
        &mut self,
        _round: usize,
        dev: &Device,
        _agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        let mut counts = vec![0usize; dev.channels.len()];
        counts[dev.channels.fastest()] = self.total;
        (self.h, AllocationPlan { counts })
    }
}

/// Energy-adaptive compression-ratio control ("To Talk or to Work", arXiv
/// 2012.11804): the per-round upload budget scales with the device's
/// remaining energy fraction, so a device near exhaustion talks less and
/// spends its remaining joules on computation. Deterministic — reads only
/// the device's [`crate::resources::ResourceMeter`], no RNG.
#[derive(Clone, Debug)]
pub struct EnergyAdaptive {
    pub h: usize,
    /// Full-budget per-channel coordinate counts (zero = silent channel).
    pub counts: Vec<usize>,
    /// Lower bound on the scaling fraction, so a drained device still ships
    /// a sliver of every active layer instead of going silent.
    pub floor: f64,
}

impl RoundPolicy for EnergyAdaptive {
    fn name(&self) -> String {
        format!("energy-adaptive(h={})", self.h)
    }

    fn decide(
        &mut self,
        _round: usize,
        dev: &Device,
        _agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        let frac = dev.meter.remaining_frac(Resource::Energy).max(self.floor);
        let counts = self
            .counts
            .iter()
            .map(|&k| if k == 0 { 0 } else { ((k as f64 * frac).round() as usize).max(1) })
            .collect();
        (self.h, AllocationPlan { counts })
    }
}

/// FedGreen-style fine-grained device-side compression selection (arXiv
/// 2111.06146): each device quantizes its current per-channel quality
/// (effective bandwidth relative to the technology's nominal rate) into one
/// of `levels` compression levels and sizes that channel's layer
/// accordingly — a weak link carries a heavily-compressed layer, a clean
/// link the full budget. Reads link state only (no RNG consumption), so it
/// never perturbs any existing stream.
#[derive(Clone, Debug)]
pub struct FedGreen {
    pub h: usize,
    /// Full-budget per-channel coordinate counts (zero = silent channel).
    pub counts: Vec<usize>,
    /// Number of discrete compression levels per channel (>= 1).
    pub levels: usize,
}

impl RoundPolicy for FedGreen {
    fn name(&self) -> String {
        format!("fedgreen(h={},levels={})", self.h, self.levels)
    }

    fn decide(
        &mut self,
        _round: usize,
        dev: &Device,
        _agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        let levels = self.levels.max(1) as f64;
        let mut counts = vec![0usize; dev.channels.len()];
        for (c, slot) in counts.iter_mut().enumerate() {
            let k = self.counts.get(c).copied().unwrap_or(0);
            if k == 0 {
                continue;
            }
            let link = &dev.channels.links[c];
            if !link.is_up() {
                continue;
            }
            let q = (link.effective_bandwidth() / link.ty.bandwidth_mb_s()).clamp(0.0, 1.0);
            // Quantize up: quality in ((l-1)/levels, l/levels] selects level
            // l, so even a barely-alive link keeps its smallest layer.
            let lvl = ((q * levels).ceil()).max(1.0) / levels;
            *slot = ((k as f64 * lvl).round() as usize).max(1);
        }
        (self.h, AllocationPlan { counts })
    }
}

/// The paper's DDPG controller (Sec. 3.2–3.3): each device's agent observes
/// the Eq. 11 state, emits the `(H_m, D_{m,n})` action, and learns from the
/// Eq. 16 reward after the round.
#[derive(Clone, Debug, Default)]
pub struct DdpgPolicy;

impl RoundPolicy for DdpgPolicy {
    fn name(&self) -> String {
        "ddpg".to_string()
    }

    fn needs_agents(&self) -> bool {
        true
    }

    fn decide(
        &mut self,
        _round: usize,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        let agent = agent.expect("DdpgPolicy requires per-device agents");
        // Staleness-aware agents (downlink enabled) see the device's model
        // age as an extra state feature; legacy agents ignore the argument.
        let state = agent.observe_state(
            &dev.meter,
            &dev.channels,
            dev.last_delta,
            dev.sync_state.staleness,
        );
        let decision = agent.decide(&state, true);
        (decision.local_steps, decision.plan)
    }

    fn observe(
        &mut self,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
        delta: f64,
        done: bool,
    ) -> Option<f64> {
        let agent = agent?;
        let eps = [
            dev.meter.last_round[0].total().max(1e-9),
            dev.meter.last_round[1].total().max(1e-9),
        ];
        let next_state = agent.observe_state(
            &dev.meter,
            &dev.channels,
            delta,
            dev.sync_state.staleness,
        );
        let (r, _) = agent.feedback(delta, &eps, next_state, done);
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channels::{ChannelType, DeviceChannels};
    use crate::compression::DenseNoop;
    use crate::resources::{ComputeCostModel, ResourceMeter};
    use crate::util::Rng;

    fn device(energy_budget: f64) -> Device {
        let types = vec![ChannelType::G5, ChannelType::G4, ChannelType::G3];
        Device::new(
            0,
            vec![0.0; 16],
            Box::new(DenseNoop),
            DeviceChannels::new(&types, &Rng::new(7), 0),
            ResourceMeter::new(energy_budget, f64::INFINITY),
            ComputeCostModel::for_params(16),
        )
    }

    #[test]
    fn energy_adaptive_scales_with_remaining_budget() {
        let mut pol = EnergyAdaptive { h: 2, counts: vec![100, 40, 0], floor: 0.1 };
        let mut dev = device(100.0);
        // Full budget: the full counts, zeros staying silent.
        let (h, plan) = pol.decide(0, &dev, None);
        assert_eq!(h, 2);
        assert_eq!(plan.counts, vec![100, 40, 0]);
        // Half the budget burned: counts halve.
        dev.meter.record_round(30.0, 20.0, 0.0, 1.0);
        let (_, plan) = pol.decide(1, &dev, None);
        assert_eq!(plan.counts, vec![50, 20, 0]);
        // Exhausted: the floor keeps a sliver of every active layer.
        dev.meter.record_round(100.0, 0.0, 0.0, 1.0);
        let (_, plan) = pol.decide(2, &dev, None);
        assert_eq!(plan.counts, vec![10, 4, 0]);
    }

    #[test]
    fn energy_adaptive_unbudgeted_is_static() {
        let mut pol = EnergyAdaptive { h: 3, counts: vec![64, 32, 16], floor: 0.1 };
        let mut dev = device(f64::INFINITY);
        dev.meter.record_round(1e9, 1e9, 0.0, 1.0);
        let (_, plan) = pol.decide(0, &dev, None);
        assert_eq!(plan.counts, vec![64, 32, 16], "infinite budget never throttles");
    }

    #[test]
    fn fedgreen_full_quality_keeps_full_counts_and_down_links_go_silent() {
        let mut pol = FedGreen { h: 2, counts: vec![100, 40, 20], levels: 4 };
        let mut dev = device(f64::INFINITY);
        // Fresh links start in the Good fading state (gain 1): level 4/4.
        let (h, plan) = pol.decide(0, &dev, None);
        assert_eq!(h, 2);
        assert_eq!(plan.counts, vec![100, 40, 20]);
        // A masked link carries nothing; the rest are untouched.
        dev.channels.links[1].set_up(false);
        let (_, plan) = pol.decide(1, &dev, None);
        assert_eq!(plan.counts, vec![100, 0, 20]);
    }

    #[test]
    fn fedgreen_quantizes_degraded_links_down() {
        let mut pol = FedGreen { h: 2, counts: vec![100, 40, 20], levels: 4 };
        let mut dev = device(f64::INFINITY);
        // Throttle the 5G link to 30% of nominal: ceil(0.3 * 4)/4 = 1/2.
        let params = dev.channels.links[0].params;
        dev.channels.links[0].apply_profile(
            true,
            params,
            crate::scenario::ChannelDynamics::Markov,
            0.3,
            1.0,
        );
        let (_, plan) = pol.decide(0, &dev, None);
        assert_eq!(plan.counts, vec![50, 40, 20]);
        // Decisions consume no RNG: twin devices decide identically twice.
        let (_, again) = pol.decide(1, &dev, None);
        assert_eq!(again.counts, plan.counts);
    }
}
