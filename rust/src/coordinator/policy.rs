//! The [`RoundPolicy`] trait — the per-round control seam.
//!
//! A policy decides, for each active device, the local step count `H_m` and
//! the layer-to-channel [`AllocationPlan`] `D_{m,n}` (the Eq. 13 action),
//! and optionally learns from the round outcome. This replaces the
//! per-mechanism `match` that used to live inside the round loop: mechanism
//! behavior is now fully determined by the registered
//! compressor/aggregator/policy triple (see [`super::registry`]).

use super::device::Device;
use crate::channels::AllocationPlan;
use crate::drl::DeviceAgent;

/// Per-round control decisions for one experiment.
///
/// `decide` runs *before* a device's local computation; `observe` runs after
/// the round's costs are recorded (so `dev.meter.last_round` is fresh) and
/// returns a reward when the policy learns online.
pub trait RoundPolicy: Send {
    /// Short human-readable name for logs and registry listings.
    fn name(&self) -> String;

    /// Whether the builder should create one DDPG [`DeviceAgent`] per
    /// device for this policy.
    fn needs_agents(&self) -> bool {
        false
    }

    /// Decide `(H, plan)` for `dev` this round.
    fn decide(
        &mut self,
        round: usize,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan);

    /// Observe the round outcome for `dev` (`delta` = loss improvement);
    /// returns the learning reward, if any.
    fn observe(
        &mut self,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
        delta: f64,
        done: bool,
    ) -> Option<f64> {
        let _ = (dev, agent, delta, done);
        None
    }
}

/// Fixed `H` and a fixed layer-to-channel mapping: layer `c` rides channel
/// `c` (channel list is fastest-first, so the base layer takes the most
/// reliable link — the layered-coding mapping of the paper).
#[derive(Clone, Debug)]
pub struct StaticLayered {
    pub h: usize,
    /// Per-channel coordinate counts (zero = silent channel).
    pub counts: Vec<usize>,
}

impl RoundPolicy for StaticLayered {
    fn name(&self) -> String {
        format!("static-layered(h={})", self.h)
    }

    fn decide(
        &mut self,
        _round: usize,
        _dev: &Device,
        _agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        (self.h, AllocationPlan { counts: self.counts.clone() })
    }
}

/// Fixed `H`, everything on the *currently fastest* channel — the
/// single-channel baselines (Top-k ablation A1; FedAvg's dense upload).
/// The plan width follows the device's actual channel count.
#[derive(Clone, Debug)]
pub struct FastestSingle {
    pub h: usize,
    /// Total coordinate budget to place on the fastest channel.
    pub total: usize,
}

impl RoundPolicy for FastestSingle {
    fn name(&self) -> String {
        format!("fastest-single(h={})", self.h)
    }

    fn decide(
        &mut self,
        _round: usize,
        dev: &Device,
        _agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        let mut counts = vec![0usize; dev.channels.len()];
        counts[dev.channels.fastest()] = self.total;
        (self.h, AllocationPlan { counts })
    }
}

/// The paper's DDPG controller (Sec. 3.2–3.3): each device's agent observes
/// the Eq. 11 state, emits the `(H_m, D_{m,n})` action, and learns from the
/// Eq. 16 reward after the round.
#[derive(Clone, Debug, Default)]
pub struct DdpgPolicy;

impl RoundPolicy for DdpgPolicy {
    fn name(&self) -> String {
        "ddpg".to_string()
    }

    fn needs_agents(&self) -> bool {
        true
    }

    fn decide(
        &mut self,
        _round: usize,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
    ) -> (usize, AllocationPlan) {
        let agent = agent.expect("DdpgPolicy requires per-device agents");
        // Staleness-aware agents (downlink enabled) see the device's model
        // age as an extra state feature; legacy agents ignore the argument.
        let state = agent.observe_state(
            &dev.meter,
            &dev.channels,
            dev.last_delta,
            dev.sync_state.staleness,
        );
        let decision = agent.decide(&state, true);
        (decision.local_steps, decision.plan)
    }

    fn observe(
        &mut self,
        dev: &Device,
        agent: Option<&mut DeviceAgent>,
        delta: f64,
        done: bool,
    ) -> Option<f64> {
        let agent = agent?;
        let eps = [
            dev.meter.last_round[0].total().max(1e-9),
            dev.meter.last_round[1].total().max(1e-9),
        ];
        let next_state = agent.observe_state(
            &dev.meter,
            &dev.channels,
            delta,
            dev.sync_state.staleness,
        );
        let (r, _) = agent.feedback(delta, &eps, next_state, done);
        Some(r)
    }
}
