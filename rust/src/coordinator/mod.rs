//! L3 coordinator — the paper's system contribution, organized around three
//! pluggable seams (see DESIGN.md §"Extension points"):
//!
//! - [`server`]: the FL edge server; aggregation runs through the
//!   [`aggregator::Aggregator`] trait (Alg. 1 lines 18–22)
//! - [`device`]: the edge device round procedure; compression runs through
//!   the [`crate::compression::Compressor`] trait (Alg. 1 lines 4–17)
//! - [`policy`]: per-round control — `H` and the layer-to-channel plan
//! - [`registry`]: string-keyed mechanism presets
//!   (compressor × aggregator × policy)
//! - [`builder`]: [`builder::ExperimentBuilder`], the assembly point
//! - [`trainer`]: local-training backends (PJRT artifacts / native LR),
//!   splittable into per-device [`trainer::DeviceTrainer`] handles for
//!   parallel compute
//! - [`experiment`]: the mechanism-free orchestration state; execution runs
//!   on the [`crate::sim`] event engine under a
//!   [`crate::sim::SyncMode`] (barrier / semi-async / fully-async)

pub mod aggregator;
pub mod builder;
pub mod device;
pub mod experiment;
pub mod policy;
pub mod registry;
pub mod server;
pub mod trainer;

pub use aggregator::{Aggregator, MeanAggregator, WeightedBySamples};
pub use builder::ExperimentBuilder;
pub use device::{Device, DeviceParts, DeviceUpload, LayerTransfer, UploadOutcome};
pub use experiment::Experiment;
pub use policy::{DdpgPolicy, FastestSingle, RoundPolicy, StaticLayered};
pub use registry::{BuildCtx, MechanismPreset, MechanismRegistry, SamplerFactory};
pub use server::Server;
pub use trainer::{
    DeviceTrainer, LocalTrainer, MnistDeviceTrainer, NativeLrTrainer, PjrtTrainer, WorkloadData,
};
