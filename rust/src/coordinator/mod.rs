//! L3 coordinator — the paper's system contribution.
//!
//! - [`server`]: the FL edge server (aggregate + broadcast, Alg. 1 18–22)
//! - [`device`]: the edge device round procedure (Alg. 1 4–17)
//! - [`trainer`]: local-training backends (PJRT artifacts / native LR)
//! - [`experiment`]: the full orchestrated loop for every mechanism
//!   (FedAvg, LGC-static, LGC-DRL, single-channel Top-k)

pub mod device;
pub mod experiment;
pub mod server;
pub mod trainer;

pub use device::{Device, DeviceUpload};
pub use experiment::Experiment;
pub use server::Server;
pub use trainer::{LocalTrainer, NativeLrTrainer, PjrtTrainer, WorkloadData};
