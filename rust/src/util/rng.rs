//! Deterministic PRNG substrate (no `rand` crate available offline).
//!
//! `Rng` is xoshiro256++ (Blackman & Vigna) seeded via splitmix64 — the same
//! construction `rand_xoshiro` uses.  On top of the raw stream we provide the
//! distributions the simulator needs: uniform, normal (Ziggurat-free
//! Box-Muller with caching), exponential, Dirichlet, permutations and
//! weighted choice.  Everything is reproducible from a single `u64` seed, and
//! `fork(tag)` derives independent streams for devices/channels/agents.

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the last Box-Muller draw.
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is fine: state is expanded
    /// through splitmix64 per the xoshiro authors' recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for a sub-component. Streams forked with
    /// different tags from the same parent are statistically independent.
    pub fn fork(&self, tag: u64) -> Rng {
        // Mix the parent state with the tag through splitmix64.
        let mut sm = self
            .s
            .iter()
            .fold(tag ^ 0xA076_1D64_78BD_642F, |a, &b| a.rotate_left(17) ^ b);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize index in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Rejection-free polar-less form; u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn gaussian(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Gamma(shape, 1) via Marsaglia-Tsang (shape >= 0.01).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u = 1.0 - self.uniform();
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over `n` categories.
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = v.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut v {
            *x /= s;
        }
        v
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Weighted index choice proportional to non-negative `weights`.
    pub fn choice_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Vector of standard normals (f32).
    pub fn normal_vec_f32(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn fork_streams_differ() {
        let root = Rng::new(7);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_smoke() {
        let mut r = Rng::new(4);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gaussian_shift_scale() {
        let mut r = Rng::new(6);
        let n = 30_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian(1296.0, 0.00033)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - 1296.0).abs() < 0.01);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(8);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 10);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(10);
        let w = [0.0, 3.0, 1.0];
        let mut counts = [0u32; 3];
        for _ in 0..40_000 {
            counts[r.choice_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let ratio = counts[1] as f64 / counts[2] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(12);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 30_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.1 * shape.max(0.5), "shape={shape} mean={mean}");
        }
    }
}
