//! Small shared substrates: PRNG, vector math helpers, timing.

pub mod rng;

pub use rng::Rng;

/// Euclidean norm squared of an f32 slice — the chunked 8-lane kernel
/// (reassociated vs. a sequential sum, deterministic for a given input;
/// see [`crate::kernels::reduce`]).
#[inline]
pub fn norm2(xs: &[f32]) -> f64 {
    crate::kernels::reduce::norm2_chunked(xs)
}

/// In-place axpy: y += a * x (delegates to the blocked kernel — bitwise
/// identical to the plain loop).
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    crate::kernels::axpy(a, x, y);
}

/// In-place scale: x *= a (delegates to the blocked kernel — bitwise
/// identical to the plain loop).
#[inline]
pub fn scale(a: f32, x: &mut [f32]) {
    crate::kernels::scale(a, x);
}

/// Mean of an f64 slice (0 for empty).
#[inline]
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for < 2 samples).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

// NOTE: percentile lives in `crate::metrics::percentile` (nearest-rank,
// NaN on empty) — the single implementation behind the straggler stats.

/// Clamp helper for f64.
#[inline]
pub fn clamp(x: f64, lo: f64, hi: f64) -> f64 {
    x.max(lo).min(hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn norm2_basic() {
        assert_eq!(norm2(&[3.0, 4.0]), 25.0);
        assert_eq!(norm2(&[]), 0.0);
    }

    #[test]
    fn axpy_scale() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
        scale(0.5, &mut y);
        assert_eq!(y, [6.0, 12.0]);
    }

    #[test]
    fn stats_helpers() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((stddev(&xs) - 1.2909944).abs() < 1e-6);
    }
}
