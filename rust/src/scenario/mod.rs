//! Network scenarios: trace-driven channel dynamics, mobility & handoff,
//! and a scripted timeline DSL — the ROADMAP's scenario-diversity axis.
//!
//! Until this module every run drove one hard-coded 3-state Markov chain
//! with fixed constants, so the DRL controller and all four engines were
//! only ever evaluated against a single network world. A scenario describes
//! a *world*:
//!
//! - **Zones** ([`ZoneSpec`]): regions that define which [`ChannelType`]s
//!   exist there (a mask over the experiment's channel list), the zone's
//!   [`FadingParams`], a bandwidth scale, and the zone's
//!   [`dynamics::ChannelDynamics`] source — the classic Markov chain or a
//!   replayed trace (diurnal sinusoid, congestion bursts, Gilbert–Elliott
//!   drive-test, or a CSV drive log).
//! - **Mobility**: every client carries a zone id and moves on a seeded
//!   per-client chain (`move_prob` per tick, uniform over the other
//!   zones). A move is a **handoff**: the device's channel set changes
//!   mid-run. Plans are projected off vanished channels
//!   ([`crate::channels::AllocationPlan::project_onto`]) and an uplink
//!   layer caught mid-flight on a vanished channel is dropped into the
//!   existing error-feedback restitution path (counted as
//!   `dropped_handoff`).
//! - **Phases** ([`PhaseSpec`]): a scripted timeline,
//!   `[[scenario.phase]] at_s = 300.0, zone = 2, bw_scale_4g = 0.5, …` in
//!   TOML — at virtual time `at_s` the phase can force everyone into a
//!   zone, change the mobility rate, scale a technology's bandwidth
//!   globally, or scale loss probabilities (flash crowds, outages, rush
//!   hours).
//!
//! [`ScenarioRegistry`] ships named presets (`commute`,
//! `stadium-flash-crowd`, `rural-3g`, `diurnal`); `scenario = "name"`,
//! `scenario_file = "world.toml"`, or an inline `[scenario]` tree in the
//! config selects one. With no scenario configured, nothing here runs and
//! every engine stays **bit-for-bit** on the frozen `step_round` oracle
//! (asserted in `tests/sim_engine.rs` — a trivial single-zone scenario
//! with default parameters is *also* bitwise on the oracle, which pins the
//! seam's zero-cost claim). See DESIGN.md §"Scenarios, mobility &
//! handoff".

pub mod dynamics;

use std::collections::BTreeMap;
use std::sync::Arc;

pub use dynamics::{
    congestion_burst_trace, diurnal_trace, gilbert_elliott_trace, trace_from_csv,
    ChannelDynamics, TracePoint, TraceReplay,
};

use crate::channels::{ChannelType, DeviceChannels, FadingParams};
use crate::config::toml::{Document, Value};
use crate::util::Rng;

/// Stable slot per channel technology for the per-type phase scales
/// (3G = 0, 4G = 1, 5G = 2 — independent of the experiment's channel
/// ordering).
pub(crate) fn type_slot(ty: ChannelType) -> usize {
    match ty {
        ChannelType::G3 => 0,
        ChannelType::G4 => 1,
        ChannelType::G5 => 2,
    }
}

/// Which [`ChannelDynamics`] source a zone installs on its links.
#[derive(Clone, Debug, PartialEq)]
pub enum DynamicsKind {
    /// The parameterized Markov fading chain (the oracle's default).
    Markov,
    /// Deterministic day/night sinusoid between `floor` and 1.0.
    Diurnal { period_ticks: usize, floor: f64 },
    /// Two-state congestion bursts (cell overload).
    Bursts { enter: f64, exit: f64, depth: f64, loss: f64 },
    /// Gilbert–Elliott two-state burst-loss channel (drive-test shape).
    GilbertElliott { p_gb: f64, p_bg: f64, bad_bw: f64, bad_loss: f64 },
    /// Replay a CSV trace file (`bw` or `bw,loss` per line).
    CsvTrace { path: String },
}

/// One zone of the scenario world.
#[derive(Clone, Debug, PartialEq)]
pub struct ZoneSpec {
    pub name: String,
    /// Channel technologies that exist in this zone — must be a non-empty
    /// subset of the experiment's `channel_types`.
    pub channels: Vec<ChannelType>,
    /// Zone-wide bandwidth multiplier in `(0, 1]`.
    pub bw_scale: f64,
    /// Fading-chain constants for this zone's links.
    pub fading: FadingParams,
    pub dynamics: DynamicsKind,
}

/// One scripted timeline event, applied when virtual time reaches `at_s`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseSpec {
    pub at_s: f64,
    /// Force every client into this zone (each actual change is a handoff).
    pub zone: Option<usize>,
    /// New per-tick mobility rate from this point on.
    pub move_prob: Option<f64>,
    /// Global per-technology bandwidth scales (slots via [`type_slot`]:
    /// 3G, 4G, 5G), each in `(0, 1]`.
    pub bw_scale: [Option<f64>; 3],
    /// Multiplier on every zone's loss probabilities (clamped to stay a
    /// probability).
    pub loss_scale: Option<f64>,
    /// Edge-tier backhaul bandwidth scale in `(0, 1]` from this point on
    /// (ignored when the edge tier is disabled).
    pub backhaul_scale: Option<f64>,
}

/// A parsed, validated-on-build scenario description (pure data — the
/// runtime state lives in [`Scenario`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    pub name: String,
    /// Per-tick probability that a client moves to a uniformly-chosen
    /// other zone.
    pub move_prob: f64,
    /// Start clients spread round-robin over the zones (else all in zone 0).
    pub start_spread: bool,
    /// Length of generated synthetic traces (samples; replay wraps).
    pub trace_len: usize,
    pub zones: Vec<ZoneSpec>,
    /// Timeline, sorted by `at_s`.
    pub phases: Vec<PhaseSpec>,
    /// NOMA shared-uplink mode (arXiv 2003.01344): co-zone devices contend
    /// for one carrier per technology, so each link's bandwidth scale is
    /// further divided by the device's current zone population. `false`
    /// (the default everywhere) keeps the independent-links model
    /// bit-for-bit.
    pub noma: bool,
}

fn get_f64(kvs: &BTreeMap<String, Value>, key: &str) -> Option<f64> {
    kvs.get(key).and_then(Value::as_f64)
}

fn get_usize(kvs: &BTreeMap<String, Value>, key: &str) -> Result<Option<usize>, String> {
    match kvs.get(key).map(|v| v.as_i64().ok_or_else(|| format!("{key} must be an integer"))) {
        None => Ok(None),
        Some(Err(e)) => Err(e),
        Some(Ok(i)) => {
            usize::try_from(i).map(Some).map_err(|_| format!("{key} must be >= 0, got {i}"))
        }
    }
}

fn get_triple(kvs: &BTreeMap<String, Value>, key: &str) -> Result<Option<[f64; 3]>, String> {
    match kvs.get(key) {
        None => Ok(None),
        Some(v) => {
            let arr: Vec<f64> = v
                .as_array()
                .map(|a| a.iter().filter_map(Value::as_f64).collect())
                .unwrap_or_default();
            if arr.len() != 3 {
                return Err(format!("{key} must be an array of 3 numbers"));
            }
            Ok(Some([arr[0], arr[1], arr[2]]))
        }
    }
}

impl ZoneSpec {
    fn from_kvs(idx: usize, kvs: &BTreeMap<String, Value>) -> Result<ZoneSpec, String> {
        let name = kvs
            .get("name")
            .and_then(Value::as_str)
            .map(str::to_string)
            .unwrap_or_else(|| format!("zone-{idx}"));
        let channels = match kvs.get("channels") {
            Some(v) => {
                let mut out = Vec::new();
                for item in v.as_array().ok_or("channels must be an array of strings")? {
                    let s = item.as_str().ok_or("channels must be strings")?;
                    out.push(ChannelType::parse(s)?);
                }
                out
            }
            None => return Err(format!("zone {idx} needs a `channels` list")),
        };
        let mut fading = FadingParams::default();
        if let Some(g) = get_triple(kvs, "gain")? {
            fading.gain = g;
        }
        if let Some(l) = get_triple(kvs, "loss")? {
            fading.loss = l;
        }
        for (row, key) in ["t_good", "t_mid", "t_bad"].iter().enumerate() {
            if let Some(r) = get_triple(kvs, key)? {
                fading.transition[row] = r;
            }
        }
        let kind = kvs.get("dynamics").and_then(Value::as_str).unwrap_or("markov");
        let dynamics = match kind.to_ascii_lowercase().as_str() {
            "markov" => DynamicsKind::Markov,
            "diurnal" => DynamicsKind::Diurnal {
                period_ticks: get_usize(kvs, "period_ticks")?.unwrap_or(240),
                floor: get_f64(kvs, "floor").unwrap_or(0.2),
            },
            "bursts" | "congestion" => DynamicsKind::Bursts {
                enter: get_f64(kvs, "burst_enter").unwrap_or(0.08),
                exit: get_f64(kvs, "burst_exit").unwrap_or(0.30),
                depth: get_f64(kvs, "burst_depth").unwrap_or(0.15),
                loss: get_f64(kvs, "burst_loss").unwrap_or(0.25),
            },
            "gilbert-elliott" | "ge" | "drive-test" => DynamicsKind::GilbertElliott {
                p_gb: get_f64(kvs, "p_gb").unwrap_or(0.06),
                p_bg: get_f64(kvs, "p_bg").unwrap_or(0.35),
                bad_bw: get_f64(kvs, "bad_bw").unwrap_or(0.10),
                bad_loss: get_f64(kvs, "bad_loss").unwrap_or(0.30),
            },
            "csv" | "trace" => DynamicsKind::CsvTrace {
                path: kvs
                    .get("trace_file")
                    .and_then(Value::as_str)
                    .ok_or("dynamics = \"csv\" needs trace_file")?
                    .to_string(),
            },
            other => return Err(format!("unknown zone dynamics `{other}`")),
        };
        Ok(ZoneSpec {
            name,
            channels,
            bw_scale: get_f64(kvs, "bw_scale").unwrap_or(1.0),
            fading,
            dynamics,
        })
    }
}

impl PhaseSpec {
    fn from_kvs(idx: usize, kvs: &BTreeMap<String, Value>) -> Result<PhaseSpec, String> {
        let at_s = get_f64(kvs, "at_s").ok_or_else(|| format!("phase {idx} needs at_s"))?;
        Ok(PhaseSpec {
            at_s,
            zone: get_usize(kvs, "zone")?,
            move_prob: get_f64(kvs, "move_prob"),
            bw_scale: [
                get_f64(kvs, "bw_scale_3g"),
                get_f64(kvs, "bw_scale_4g"),
                get_f64(kvs, "bw_scale_5g"),
            ],
            loss_scale: get_f64(kvs, "loss_scale"),
            backhaul_scale: get_f64(kvs, "backhaul_scale"),
        })
    }
}

impl ScenarioSpec {
    /// Parse the scenario tree of a config document: the `[scenario]`
    /// section, `[scenario.zone.N]` / `[[scenario.zone]]` zones and
    /// `[[scenario.phase]]` timeline entries. Returns `Ok(None)` when the
    /// document carries no scenario at all.
    pub fn from_document(doc: &Document) -> Result<Option<ScenarioSpec>, String> {
        let top = doc.sections.get("scenario");
        let zone_sections = doc.array_sections("scenario.zone");
        let phase_sections = doc.array_sections("scenario.phase");
        let has_top = top.map(|s| !s.is_empty()).unwrap_or(false);
        if !has_top && zone_sections.is_empty() && phase_sections.is_empty() {
            return Ok(None);
        }
        let empty = BTreeMap::new();
        let top = top.unwrap_or(&empty);
        let mut zones = Vec::new();
        for (pos, (n, kvs)) in zone_sections.iter().enumerate() {
            // Zone ids are positional (phases reference them by index), so
            // the written numbering must be contiguous from 0 — otherwise
            // a gap would silently renumber the zones a phase points at.
            if *n != pos {
                return Err(format!(
                    "zone sections must be numbered contiguously from 0: found \
                     scenario.zone.{n} where scenario.zone.{pos} was expected"
                ));
            }
            zones.push(ZoneSpec::from_kvs(*n, kvs)?);
        }
        let mut phases = Vec::new();
        for (n, kvs) in &phase_sections {
            phases.push(PhaseSpec::from_kvs(*n, kvs)?);
        }
        phases.sort_by(|a, b| a.at_s.total_cmp(&b.at_s));
        Ok(Some(ScenarioSpec {
            name: top
                .get("name")
                .and_then(Value::as_str)
                .unwrap_or("custom")
                .to_string(),
            move_prob: get_f64(top, "move_prob").unwrap_or(0.0),
            start_spread: top.get("start_spread").and_then(Value::as_bool).unwrap_or(false),
            trace_len: get_usize(top, "trace_len")?.unwrap_or(1024),
            zones,
            phases,
            noma: top.get("noma").and_then(Value::as_bool).unwrap_or(false),
        }))
    }

    /// Validate against the experiment's channel list. Enforces the
    /// handoff invariant at the source: every zone keeps at least one
    /// channel of the experiment's set, so a device can never be left with
    /// zero channels.
    pub fn validate(&self, channel_types: &[ChannelType]) -> Result<(), String> {
        if self.zones.is_empty() {
            return Err("scenario needs at least one zone".into());
        }
        if !(0.0..=1.0).contains(&self.move_prob) {
            return Err(format!("move_prob {} not in [0, 1]", self.move_prob));
        }
        if self.trace_len < 2 {
            return Err(format!("trace_len must be >= 2, got {}", self.trace_len));
        }
        for (zi, z) in self.zones.iter().enumerate() {
            if z.channels.is_empty() {
                return Err(format!("zone {zi} ({}) has no channels", z.name));
            }
            for &ty in &z.channels {
                if !channel_types.contains(&ty) {
                    return Err(format!(
                        "zone {zi} ({}) lists {} which the experiment's channel set lacks",
                        z.name,
                        ty.name()
                    ));
                }
            }
            if !(z.bw_scale > 0.0 && z.bw_scale <= 1.0) {
                return Err(format!("zone {zi} bw_scale {} not in (0, 1]", z.bw_scale));
            }
            z.fading.validate().map_err(|e| format!("zone {zi}: {e}"))?;
            match &z.dynamics {
                DynamicsKind::Markov => {}
                DynamicsKind::Diurnal { period_ticks, floor } => {
                    if *period_ticks == 0 {
                        return Err(format!("zone {zi}: diurnal period_ticks must be > 0"));
                    }
                    if !(*floor > 0.0 && *floor <= 1.0) {
                        return Err(format!("zone {zi}: diurnal floor {floor} not in (0, 1]"));
                    }
                }
                DynamicsKind::Bursts { enter, exit, depth, loss } => {
                    if !(0.0..1.0).contains(enter) || !(0.0..=1.0).contains(exit) {
                        return Err(format!("zone {zi}: burst probabilities out of range"));
                    }
                    if !(*depth > 0.0 && *depth <= 1.0) {
                        return Err(format!("zone {zi}: burst_depth {depth} not in (0, 1]"));
                    }
                    if !(0.0..1.0).contains(loss) {
                        return Err(format!("zone {zi}: burst_loss {loss} not in [0, 1)"));
                    }
                }
                DynamicsKind::GilbertElliott { p_gb, p_bg, bad_bw, bad_loss } => {
                    if !(0.0..1.0).contains(p_gb) || !(0.0..=1.0).contains(p_bg) {
                        return Err(format!("zone {zi}: GE probabilities out of range"));
                    }
                    if !(*bad_bw > 0.0 && *bad_bw <= 1.0) {
                        return Err(format!("zone {zi}: bad_bw {bad_bw} not in (0, 1]"));
                    }
                    if !(0.0..1.0).contains(bad_loss) {
                        return Err(format!("zone {zi}: bad_loss {bad_loss} not in [0, 1)"));
                    }
                }
                DynamicsKind::CsvTrace { path } => {
                    if path.is_empty() {
                        return Err(format!("zone {zi}: empty trace_file path"));
                    }
                }
            }
        }
        for (pi, p) in self.phases.iter().enumerate() {
            if !(p.at_s.is_finite() && p.at_s >= 0.0) {
                return Err(format!("phase {pi}: at_s {} must be finite and >= 0", p.at_s));
            }
            if let Some(z) = p.zone {
                if z >= self.zones.len() {
                    return Err(format!(
                        "phase {pi}: zone {z} out of range ({} zones)",
                        self.zones.len()
                    ));
                }
            }
            if let Some(m) = p.move_prob {
                if !(0.0..=1.0).contains(&m) {
                    return Err(format!("phase {pi}: move_prob {m} not in [0, 1]"));
                }
            }
            for (slot, s) in p.bw_scale.iter().enumerate() {
                if let Some(s) = s {
                    if !(*s > 0.0 && *s <= 1.0) {
                        return Err(format!(
                            "phase {pi}: bw_scale slot {slot} value {s} not in (0, 1]"
                        ));
                    }
                }
            }
            if let Some(l) = p.loss_scale {
                if !(l > 0.0 && l.is_finite()) {
                    return Err(format!("phase {pi}: loss_scale {l} must be finite and > 0"));
                }
            }
            if let Some(b) = p.backhaul_scale {
                if !(b > 0.0 && b <= 1.0) {
                    return Err(format!("phase {pi}: backhaul_scale {b} not in (0, 1]"));
                }
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Registry of named presets
// ---------------------------------------------------------------------------

/// Named scenario presets, mirroring the mechanism registry: `scenario =
/// "stadium-flash-crowd"` in the config (or `--scenario=…` on the CLI)
/// resolves here.
pub struct ScenarioRegistry {
    presets: BTreeMap<String, ScenarioSpec>,
}

fn zone(
    name: &str,
    channels: &[ChannelType],
    bw_scale: f64,
    fading: FadingParams,
    dynamics: DynamicsKind,
) -> ZoneSpec {
    ZoneSpec {
        name: name.to_string(),
        channels: channels.to_vec(),
        bw_scale,
        fading,
        dynamics,
    }
}

impl ScenarioRegistry {
    pub fn empty() -> Self {
        ScenarioRegistry { presets: BTreeMap::new() }
    }

    /// The built-in worlds. All validate against the default channel set
    /// `[5G, 4G, 3G]` (asserted in tests).
    pub fn builtin() -> Self {
        use ChannelType::{G3, G4, G5};
        let mut reg = Self::empty();
        let d = FadingParams::default();

        // Day/night load curve on every technology; single zone, no
        // mobility — pure trace-replay dynamics.
        reg.register(ScenarioSpec {
            name: "diurnal".into(),
            move_prob: 0.0,
            start_spread: false,
            trace_len: 1024,
            zones: vec![zone(
                "metro",
                &[G5, G4, G3],
                1.0,
                d,
                DynamicsKind::Diurnal { period_ticks: 240, floor: 0.2 },
            )],
            phases: Vec::new(),
            noma: false,
        });

        // Deep-rural coverage: 3G only, long Bad-fading dwells, real
        // erasure even in Good conditions.
        let mut rural = d;
        rural.gain = [1.0, 0.35, 0.08];
        rural.loss = [0.01, 0.08, 0.35];
        rural.transition = [
            [0.70, 0.20, 0.10],
            [0.15, 0.60, 0.25],
            [0.05, 0.25, 0.70],
        ];
        reg.register(ScenarioSpec {
            name: "rural-3g".into(),
            move_prob: 0.0,
            start_spread: false,
            trace_len: 1024,
            zones: vec![zone("countryside", &[G3], 1.0, rural, DynamicsKind::Markov)],
            phases: Vec::new(),
            noma: false,
        });

        // Home / transit / office loop: diurnal home cell, Gilbert–Elliott
        // drive-test transit links, clean office smallcell (no 3G indoors);
        // rush-hour phases spike the mobility rate.
        reg.register(ScenarioSpec {
            name: "commute".into(),
            move_prob: 0.05,
            start_spread: true,
            trace_len: 1024,
            zones: vec![
                zone(
                    "home",
                    &[G4, G3],
                    1.0,
                    d,
                    DynamicsKind::Diurnal { period_ticks: 120, floor: 0.3 },
                ),
                zone(
                    "transit",
                    &[G5, G4, G3],
                    0.9,
                    d,
                    DynamicsKind::GilbertElliott {
                        p_gb: 0.08,
                        p_bg: 0.35,
                        bad_bw: 0.10,
                        bad_loss: 0.30,
                    },
                ),
                zone("office", &[G5, G4], 1.0, d, DynamicsKind::Markov),
            ],
            phases: vec![
                PhaseSpec { at_s: 60.0, move_prob: Some(0.30), ..Default::default() },
                PhaseSpec { at_s: 240.0, move_prob: Some(0.05), ..Default::default() },
                PhaseSpec { at_s: 480.0, move_prob: Some(0.30), ..Default::default() },
            ],
            noma: false,
        });

        // Flash crowd: everyone surges into the stadium smallcell zone
        // (which has no 3G — a handoff there strands slow 3G enhancement
        // layers mid-flight), the 5G macro layer is throttled, congestion
        // bursts and a loss spike follow, then the crowd disperses.
        reg.register(ScenarioSpec {
            name: "stadium-flash-crowd".into(),
            move_prob: 0.02,
            start_spread: false,
            trace_len: 1024,
            zones: vec![
                zone("city", &[G5, G4, G3], 1.0, d, DynamicsKind::Markov),
                zone(
                    "stadium",
                    &[G5, G4],
                    0.8,
                    d,
                    DynamicsKind::Bursts {
                        enter: 0.12,
                        exit: 0.25,
                        depth: 0.15,
                        loss: 0.25,
                    },
                ),
            ],
            phases: vec![
                PhaseSpec {
                    at_s: 2.0,
                    zone: Some(1),
                    move_prob: Some(0.35),
                    bw_scale: [None, None, Some(0.6)],
                    ..Default::default()
                },
                PhaseSpec { at_s: 60.0, loss_scale: Some(1.5), ..Default::default() },
                PhaseSpec {
                    at_s: 150.0,
                    zone: Some(0),
                    move_prob: Some(0.05),
                    ..Default::default()
                },
            ],
            noma: false,
        });

        reg
    }

    /// Register (or replace) a preset under its `name`.
    pub fn register(&mut self, spec: ScenarioSpec) {
        self.presets.insert(spec.name.clone(), spec);
    }

    /// Exact lookup, then case-insensitive (config-file spellings).
    pub fn get(&self, name: &str) -> Option<&ScenarioSpec> {
        if let Some(s) = self.presets.get(name) {
            return Some(s);
        }
        self.presets.values().find(|s| s.name.eq_ignore_ascii_case(name))
    }

    /// Registered preset names, sorted.
    pub fn names(&self) -> Vec<&str> {
        self.presets.keys().map(String::as_str).collect()
    }

    /// Resolve a preset name with an error that lists what exists.
    pub fn resolve(name: &str) -> Result<ScenarioSpec, String> {
        let reg = Self::builtin();
        reg.get(name).cloned().ok_or_else(|| {
            format!(
                "unknown scenario `{name}` — registered: {}",
                reg.names().join(", ")
            )
        })
    }
}

impl Default for ScenarioRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

// ---------------------------------------------------------------------------
// Runtime
// ---------------------------------------------------------------------------

/// Per-record-window scenario counters, drained into each
/// [`crate::metrics::RoundRecord`] (same pattern as the downlink window).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScenarioWindow {
    /// Zone changes (mobility moves + phase-forced relocations).
    pub handoffs: u64,
    /// In-flight uplink layers dropped because their channel vanished in a
    /// handoff (restituted into error-feedback memory).
    pub dropped_handoff: u64,
}

impl ScenarioWindow {
    pub fn take(&mut self) -> ScenarioWindow {
        std::mem::take(self)
    }
}

/// What one scenario tick asks the engine to do.
#[derive(Clone, Debug, Default)]
pub struct TickEffects {
    /// Ascending client ids whose channel bundles must be re-configured
    /// (their zone changed, or a phase changed the global scales — then
    /// every id is listed). Demobilized population clients can be skipped:
    /// they pick the current configuration up at materialization.
    pub reconfigure: Vec<usize>,
}

/// One zone's runtime form: mask aligned to the experiment's channel list
/// plus the shared generated trace (None = Markov dynamics).
struct ZoneRuntime {
    mask: Vec<bool>,
    bw_scale: f64,
    fading: FadingParams,
    trace: Option<Arc<[TracePoint]>>,
}

/// The live scenario state an [`crate::coordinator::Experiment`] carries:
/// per-client zones and mobility chains, the phase cursor, global phase
/// scales, and the metrics windows. All RNG streams are forked off the
/// experiment seed with scenario-private tags, so enabling a scenario
/// never perturbs any existing stream.
///
/// Cost model: mobility is O(population) per tick — the same population-
/// wide dynamics budget [`crate::population::Population::step_round`]
/// already spends on fading/churn chains each tick; per-record telemetry
/// (`zone_p50`) is O(zones) via an incremental histogram, and per-client
/// state is a zone id plus one small RNG (no O(model) anything).
pub struct Scenario {
    spec: ScenarioSpec,
    zones: Vec<ZoneRuntime>,
    zone_of: Vec<usize>,
    start_zone_of: Vec<usize>,
    /// Clients per zone, maintained incrementally by `relocate` — keeps
    /// `zone_p50` O(zones) per record instead of sorting O(population).
    zone_counts: Vec<u64>,
    move_rng: Vec<Rng>,
    move_prob: f64,
    /// Global per-technology bandwidth scales (slots via [`type_slot`]).
    type_scale: [f64; 3],
    loss_scale: f64,
    /// Phase-scripted edge backhaul scale (read by the engines when the
    /// edge tier is enabled; inert otherwise).
    backhaul_scale: f64,
    next_phase: usize,
    ticks: u64,
    pub window: ScenarioWindow,
    total_handoffs: u64,
    total_dropped: u64,
}

impl Scenario {
    /// Build the runtime for `n_clients` clients against the experiment's
    /// channel list. Validates the spec, generates each zone's trace from
    /// a dedicated forked stream, and seeds one mobility chain per client.
    pub fn new(
        spec: ScenarioSpec,
        n_clients: usize,
        channel_types: &[ChannelType],
        rng: &Rng,
    ) -> Result<Self, String> {
        spec.validate(channel_types)?;
        let mut zones = Vec::with_capacity(spec.zones.len());
        for (zi, z) in spec.zones.iter().enumerate() {
            let mask: Vec<bool> =
                channel_types.iter().map(|ty| z.channels.contains(ty)).collect();
            // Multiplied tag mixing (like the per-client mobility forks
            // below) so zone-trace streams can never structurally collide
            // with a client's mobility stream.
            let mut zrng =
                rng.fork(0x5CE_2000 ^ (zi as u64).wrapping_mul(0xA24B_AED4_963E_E407));
            let trace = match &z.dynamics {
                DynamicsKind::Markov => None,
                DynamicsKind::Diurnal { period_ticks, floor } => {
                    Some(diurnal_trace(spec.trace_len, *period_ticks, *floor))
                }
                DynamicsKind::Bursts { enter, exit, depth, loss } => Some(
                    congestion_burst_trace(spec.trace_len, &mut zrng, *enter, *exit, *depth, *loss),
                ),
                DynamicsKind::GilbertElliott { p_gb, p_bg, bad_bw, bad_loss } => Some(
                    gilbert_elliott_trace(spec.trace_len, &mut zrng, *p_gb, *p_bg, *bad_bw, *bad_loss),
                ),
                DynamicsKind::CsvTrace { path } => {
                    let text = std::fs::read_to_string(path)
                        .map_err(|e| format!("zone {zi}: read trace {path}: {e}"))?;
                    Some(trace_from_csv(&text).map_err(|e| format!("zone {zi}: {e}"))?)
                }
            };
            zones.push(ZoneRuntime { mask, bw_scale: z.bw_scale, fading: z.fading, trace });
        }
        let nz = zones.len();
        let zone_of: Vec<usize> = (0..n_clients)
            .map(|id| if spec.start_spread { id % nz } else { 0 })
            .collect();
        let move_rng = (0..n_clients)
            .map(|id| rng.fork(0x5CE_0000 ^ (id as u64).wrapping_mul(0x9E37_79B9)))
            .collect();
        let move_prob = spec.move_prob;
        let mut zone_counts = vec![0u64; nz];
        for &z in &zone_of {
            zone_counts[z] += 1;
        }
        Ok(Scenario {
            spec,
            zones,
            start_zone_of: zone_of.clone(),
            zone_of,
            zone_counts,
            move_rng,
            move_prob,
            type_scale: [1.0; 3],
            loss_scale: 1.0,
            backhaul_scale: 1.0,
            next_phase: 0,
            ticks: 0,
            window: ScenarioWindow::default(),
            total_handoffs: 0,
            total_dropped: 0,
        })
    }

    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn n_zones(&self) -> usize {
        self.zones.len()
    }

    pub fn n_phases(&self) -> usize {
        self.spec.phases.len()
    }

    pub fn n_clients(&self) -> usize {
        self.zone_of.len()
    }

    /// Current mobility rate (phases may have changed it).
    pub fn move_prob(&self) -> f64 {
        self.move_prob
    }

    pub fn zone_of(&self, id: usize) -> usize {
        self.zone_of[id]
    }

    /// Whether this world runs the NOMA shared-uplink model.
    pub fn noma(&self) -> bool {
        self.spec.noma
    }

    /// Current client count of zone `zi` (the NOMA contention divisor).
    pub fn zone_count(&self, zi: usize) -> u64 {
        self.zone_counts[zi]
    }

    /// Current phase-scripted edge backhaul scale (1.0 until a
    /// `backhaul_scale` phase fires).
    pub fn backhaul_scale(&self) -> f64 {
        self.backhaul_scale
    }

    /// Run-total handoffs (see also the per-window counters).
    pub fn handoffs_total(&self) -> u64 {
        self.total_handoffs
    }

    /// Run-total in-flight layers dropped to handoffs.
    pub fn dropped_total(&self) -> u64 {
        self.total_dropped
    }

    /// Record `n` in-flight layers dropped by a handoff (engine callback).
    pub fn note_dropped(&mut self, n: u64) {
        self.window.dropped_handoff += n;
        self.total_dropped += n;
    }

    fn relocate(&mut self, id: usize, z: usize) {
        let from = self.zone_of[id];
        if from != z {
            self.zone_of[id] = z;
            self.zone_counts[from] -= 1;
            self.zone_counts[z] += 1;
            self.window.handoffs += 1;
            self.total_handoffs += 1;
        }
    }

    /// One scenario tick at virtual time `t`: step each client's mobility
    /// chain, then apply every phase whose `at_s` has been reached (phases
    /// run last so a forced relocation is the tick's final word). Barrier
    /// engines call this once per round (with the cumulative round clock),
    /// async engines on every `FadingTick`.
    pub fn tick(&mut self, t: f64) -> TickEffects {
        self.ticks += 1;
        let nz = self.zones.len();
        let mut moved: Vec<usize> = Vec::new();
        if nz > 1 && self.move_prob > 0.0 {
            for id in 0..self.zone_of.len() {
                if self.move_rng[id].uniform() < self.move_prob {
                    // Uniform over the *other* zones.
                    let mut z = self.move_rng[id].index(nz - 1);
                    if z >= self.zone_of[id] {
                        z += 1;
                    }
                    self.relocate(id, z);
                    moved.push(id);
                }
            }
        }
        let mut phase_fired = false;
        while self.next_phase < self.spec.phases.len()
            && self.spec.phases[self.next_phase].at_s <= t
        {
            let ph = self.spec.phases[self.next_phase].clone();
            self.next_phase += 1;
            phase_fired = true;
            if let Some(m) = ph.move_prob {
                self.move_prob = m;
            }
            if let Some(l) = ph.loss_scale {
                self.loss_scale = l;
            }
            if let Some(b) = ph.backhaul_scale {
                self.backhaul_scale = b;
            }
            for (slot, s) in ph.bw_scale.iter().enumerate() {
                if let Some(s) = s {
                    self.type_scale[slot] = *s;
                }
            }
            if let Some(z) = ph.zone {
                for id in 0..self.zone_of.len() {
                    self.relocate(id, z);
                }
            }
        }
        let reconfigure = if phase_fired || (self.spec.noma && !moved.is_empty()) {
            // A phase changes global scales (or relocates everyone): every
            // live channel bundle must pick the new world up. Under NOMA a
            // single move changes the per-device carrier share in both the
            // source and destination zones, so everyone re-reads the world
            // there too.
            (0..self.zone_of.len()).collect()
        } else {
            moved
        };
        TickEffects { reconfigure }
    }

    /// Apply client `id`'s current zone configuration onto a channel
    /// bundle (uplink or downlink): availability mask, fading constants
    /// (with the phase loss scale), dynamics source, and bandwidth scale.
    /// Fading state and link RNG streams are preserved; trace cursors are
    /// re-phased from the scenario clock so repeated configuration stays
    /// deterministic.
    pub fn configure(&self, id: usize, ch: &mut DeviceChannels) {
        let zi = self.zone_of[id];
        let z = &self.zones[zi];
        // NOMA shared uplink: the zone's carrier is one medium per
        // technology, so each co-zone device gets an equal share of it.
        // With one device in the zone the share is 1 and this reduces to
        // the independent-links model exactly.
        let share = if self.spec.noma {
            1.0 / (self.zone_counts[zi] as f64).max(1.0)
        } else {
            1.0
        };
        for (i, link) in ch.links.iter_mut().enumerate() {
            let up = z.mask.get(i).copied().unwrap_or(true);
            let scale = (z.bw_scale * self.type_scale[type_slot(link.ty)] * share).min(1.0);
            let dynamics = match &z.trace {
                None => ChannelDynamics::Markov,
                Some(pts) => ChannelDynamics::Trace(TraceReplay::new(
                    pts.clone(),
                    id.wrapping_mul(131)
                        .wrapping_add(i.wrapping_mul(17))
                        .wrapping_add(self.ticks as usize),
                )),
            };
            // The phase loss scale rides on the link itself so it reaches
            // Markov *and* trace dynamics uniformly.
            link.apply_profile(up, z.fading, dynamics, scale, self.loss_scale);
        }
        debug_assert!(
            ch.links.iter().any(crate::channels::Link::is_up),
            "zone validation guarantees at least one live channel"
        );
    }

    /// Median zone id over all clients — the `zone_p50` CSV column.
    /// Nearest-rank over the incremental per-zone histogram (the same
    /// convention as [`crate::metrics::percentile`] at p = 50), so the
    /// per-record cost is O(zones) regardless of population size.
    pub fn zone_p50(&self) -> f64 {
        let total: u64 = self.zone_counts.iter().sum();
        if total == 0 {
            return f64::NAN;
        }
        let rank = total.div_ceil(2).max(1);
        let mut cum = 0u64;
        for (z, &c) in self.zone_counts.iter().enumerate() {
            cum += c;
            if cum >= rank {
                return z as f64;
            }
        }
        (self.zone_counts.len() - 1) as f64
    }

    /// Fresh FL episode: zones, phase cursor, scales and counters restart;
    /// mobility chains keep their streams (like the fading chains).
    pub fn reset_episode(&mut self) {
        self.zone_of.copy_from_slice(&self.start_zone_of);
        self.zone_counts.iter_mut().for_each(|c| *c = 0);
        for &z in &self.zone_of {
            self.zone_counts[z] += 1;
        }
        self.move_prob = self.spec.move_prob;
        self.type_scale = [1.0; 3];
        self.loss_scale = 1.0;
        self.backhaul_scale = 1.0;
        self.next_phase = 0;
        self.ticks = 0;
        self.window = ScenarioWindow::default();
        self.total_handoffs = 0;
        self.total_dropped = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_types() -> Vec<ChannelType> {
        vec![ChannelType::G5, ChannelType::G4, ChannelType::G3]
    }

    #[test]
    fn builtin_presets_validate_against_default_channels() {
        let reg = ScenarioRegistry::builtin();
        let types = default_types();
        assert_eq!(
            reg.names(),
            vec!["commute", "diurnal", "rural-3g", "stadium-flash-crowd"]
        );
        for name in reg.names() {
            let spec = reg.get(name).unwrap();
            spec.validate(&types).unwrap_or_else(|e| panic!("{name}: {e}"));
            // And the runtime builds.
            Scenario::new(spec.clone(), 5, &types, &Rng::new(1)).unwrap();
        }
        assert!(ScenarioRegistry::resolve("Stadium-Flash-Crowd").is_ok());
        let err = ScenarioRegistry::resolve("warp").unwrap_err();
        assert!(err.contains("rural-3g"), "{err}");
    }

    #[test]
    fn spec_parses_from_toml_dsl() {
        let text = r#"
[scenario]
name = "two-world"
move_prob = 0.1
start_spread = true

[scenario.zone.0]
name = "city"
channels = ["5g", "4g", "3g"]

[[scenario.zone]]
name = "tunnel"
channels = ["3g"]
dynamics = "gilbert-elliott"
bad_bw = 0.2

[[scenario.phase]]
at_s = 30.0
zone = 1
bw_scale_4g = 0.5
backhaul_scale = 0.3

[[scenario.phase]]
at_s = 10.0
move_prob = 0.5
"#;
        let doc = Document::parse(text).unwrap();
        let spec = ScenarioSpec::from_document(&doc).unwrap().expect("scenario present");
        assert_eq!(spec.name, "two-world");
        assert_eq!(spec.zones.len(), 2);
        assert_eq!(spec.zones[1].name, "tunnel");
        assert!(matches!(
            spec.zones[1].dynamics,
            DynamicsKind::GilbertElliott { bad_bw, .. } if (bad_bw - 0.2).abs() < 1e-12
        ));
        // Phases sorted by at_s regardless of document order.
        assert_eq!(spec.phases.len(), 2);
        assert!(spec.phases[0].at_s < spec.phases[1].at_s);
        assert_eq!(spec.phases[1].zone, Some(1));
        assert_eq!(spec.phases[1].bw_scale[1], Some(0.5));
        assert_eq!(spec.phases[1].backhaul_scale, Some(0.3));
        spec.validate(&default_types()).unwrap();
        // No scenario tree at all -> None.
        assert!(ScenarioSpec::from_document(&Document::parse("rounds = 3").unwrap())
            .unwrap()
            .is_none());
        // Zone numbering gaps are an error, not a silent renumbering
        // (phases reference zones positionally).
        let gap = Document::parse("[scenario.zone.1]\nchannels = [\"5g\"]\n").unwrap();
        let err = ScenarioSpec::from_document(&gap).unwrap_err();
        assert!(err.contains("contiguously"), "{err}");
    }

    #[test]
    fn validation_rejects_broken_worlds() {
        let types = default_types();
        let reg = ScenarioRegistry::builtin();
        let base = reg.get("diurnal").unwrap().clone();
        // Zone with a channel the experiment lacks.
        let mut bad = base.clone();
        bad.zones[0].channels = vec![ChannelType::G5];
        assert!(bad.validate(&[ChannelType::G3]).is_err());
        // Empty zone list / empty channels.
        let mut bad = base.clone();
        bad.zones.clear();
        assert!(bad.validate(&types).is_err());
        let mut bad = base.clone();
        bad.zones[0].channels.clear();
        assert!(bad.validate(&types).is_err());
        // Phase referencing a missing zone.
        let mut bad = base.clone();
        bad.phases.push(PhaseSpec { at_s: 1.0, zone: Some(7), ..Default::default() });
        assert!(bad.validate(&types).is_err());
        // Out-of-range scales.
        let mut bad = base.clone();
        bad.zones[0].bw_scale = 1.5;
        assert!(bad.validate(&types).is_err());
        let mut bad = base;
        bad.move_prob = -0.1;
        assert!(bad.validate(&types).is_err());
    }

    #[test]
    fn forced_phase_relocates_everyone_and_counts_handoffs() {
        let spec = ScenarioRegistry::resolve("stadium-flash-crowd").unwrap();
        let mut sc = Scenario::new(spec, 4, &default_types(), &Rng::new(3)).unwrap();
        assert_eq!(sc.zone_p50(), 0.0);
        // Before the phase: nothing moves at t < 2 with move_prob 0.02
        // (draws may move someone, but the forced phase is the sure thing).
        let fx = sc.tick(2.5);
        assert_eq!(fx.reconfigure.len(), 4, "phase fire reconfigures everyone");
        assert!((0..4).all(|id| sc.zone_of(id) == 1));
        assert!(sc.handoffs_total() >= 4);
        assert_eq!(sc.zone_p50(), 1.0);
        assert!((sc.move_prob() - 0.35).abs() < 1e-12);
        // The 5G throttle phase applied.
        assert!((sc.type_scale[type_slot(ChannelType::G5)] - 0.6).abs() < 1e-12);
        let w = sc.window.take();
        assert!(w.handoffs >= 4);
        // Reset restores the initial world.
        sc.reset_episode();
        assert!((0..4).all(|id| sc.zone_of(id) == 0));
        assert!((sc.move_prob() - 0.02).abs() < 1e-12);
    }

    #[test]
    fn configure_masks_channels_and_is_deterministic() {
        let spec = ScenarioRegistry::resolve("stadium-flash-crowd").unwrap();
        let types = default_types();
        let mut sc = Scenario::new(spec, 2, &types, &Rng::new(5)).unwrap();
        sc.tick(3.0); // force everyone into the stadium (no 3G)
        let rng = Rng::new(9);
        let mut ch = DeviceChannels::new(&types, &rng, 0);
        sc.configure(0, &mut ch);
        assert_eq!(ch.up_mask(), vec![true, true, false], "stadium masks 3G");
        assert!(ch.first_up().is_some());
        // Stadium runs congestion-burst traces: bandwidth comes from the
        // trace, deterministically for the same scenario seed and clock.
        let mut ch2 = DeviceChannels::new(&types, &rng, 0);
        sc.configure(0, &mut ch2);
        for (a, b) in ch.links.iter().zip(&ch2.links) {
            assert_eq!(
                a.effective_bandwidth().to_bits(),
                b.effective_bandwidth().to_bits()
            );
        }
    }

    #[test]
    fn noma_shares_the_carrier_among_co_zone_devices() {
        let types = default_types();
        let mut spec = ScenarioRegistry::resolve("diurnal").unwrap();
        spec.noma = true;
        let n = 4;
        let sc = Scenario::new(spec.clone(), n, &types, &Rng::new(21)).unwrap();
        let rng = Rng::new(33);
        // All n clients share zone 0: each link's bandwidth is 1/n of what
        // the same world hands a lone device.
        let mut shared = DeviceChannels::new(&types, &rng, 0);
        sc.configure(0, &mut shared);
        let mut alone_spec = spec.clone();
        alone_spec.noma = false;
        let alone_sc = Scenario::new(alone_spec, n, &types, &Rng::new(21)).unwrap();
        let mut alone = DeviceChannels::new(&types, &rng, 0);
        alone_sc.configure(0, &mut alone);
        for (s, a) in shared.links.iter().zip(&alone.links) {
            let want = a.effective_bandwidth() / n as f64;
            assert!(
                (s.effective_bandwidth() - want).abs() < 1e-12,
                "shared {} vs {want}",
                s.effective_bandwidth()
            );
        }
        // One device per zone: NOMA reduces to the independent-links model
        // bit-for-bit.
        let solo = Scenario::new(spec, 1, &types, &Rng::new(21)).unwrap();
        let mut noma_ch = DeviceChannels::new(&types, &rng, 0);
        solo.configure(0, &mut noma_ch);
        let mut plain_ch = DeviceChannels::new(&types, &rng, 0);
        alone_sc.configure(0, &mut plain_ch);
        for (a, b) in noma_ch.links.iter().zip(&plain_ch.links) {
            assert_eq!(a.effective_bandwidth().to_bits(), b.effective_bandwidth().to_bits());
        }
    }

    #[test]
    fn mobility_chain_moves_clients_between_zones() {
        let spec = ScenarioSpec {
            name: "pair".into(),
            move_prob: 0.5,
            start_spread: false,
            trace_len: 64,
            zones: vec![
                zone(
                    "a",
                    &[ChannelType::G5, ChannelType::G4, ChannelType::G3],
                    1.0,
                    FadingParams::default(),
                    DynamicsKind::Markov,
                ),
                zone(
                    "b",
                    &[ChannelType::G4],
                    1.0,
                    FadingParams::default(),
                    DynamicsKind::Markov,
                ),
            ],
            phases: Vec::new(),
            noma: false,
        };
        let mut sc = Scenario::new(spec, 8, &default_types(), &Rng::new(11)).unwrap();
        let mut moves = 0u64;
        for t in 0..40 {
            let fx = sc.tick(t as f64);
            moves += fx.reconfigure.len() as u64;
        }
        assert!(moves > 20, "move_prob 0.5 over 8x40 draws moved only {moves}");
        assert_eq!(sc.handoffs_total(), moves);
        // Determinism: a twin scenario replays the same move sequence.
        let spec2 = ScenarioRegistry::resolve("commute").unwrap();
        let a = Scenario::new(spec2.clone(), 6, &default_types(), &Rng::new(2));
        let b = Scenario::new(spec2, 6, &default_types(), &Rng::new(2));
        let (mut a, mut b) = (a.unwrap(), b.unwrap());
        for t in 0..30 {
            assert_eq!(a.tick(t as f64).reconfigure, b.tick(t as f64).reconfigure);
        }
    }
}
